//! Offline stand-in for the `anyhow` crate: the API subset this repository
//! uses (`Result`, `Error`, `anyhow!`, `bail!`, `Context`), implemented
//! without any external dependency so the workspace builds with no
//! network access.  Matches `anyhow`'s observable behavior where it
//! matters here:
//!
//! - `Error` converts `From` any `std::error::Error + Send + Sync`
//!   (capturing the source chain),
//! - `.context(..)` / `.with_context(..)` wrap `Result` and `Option`,
//! - `{e}` prints the outermost message, `{e:#}` the full `a: b: c`
//!   chain, `{e:?}` the message plus a `Caused by:` listing.
//!
//! Swap back to the real crate by replacing the path dependency in
//! `rust/Cargo.toml` — no source changes needed.

use std::fmt;

/// Dynamic error with a chain of context messages.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap with an outer context message (the inner error becomes the
    /// source).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        let mut first = true;
        while let Some(e) = cur {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket conversion
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs: Vec<String> = Vec::new();
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut tail: Option<Box<Error>> = None;
        for m in msgs.into_iter().rev() {
            tail = Some(Box::new(Error { msg: m, source: tail }));
        }
        Error { msg: e.to_string(), source: tail }
    }
}

/// `anyhow::Result<T>`: `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 3);
        assert_eq!(format!("{e}"), "bad value 3");
        fn f() -> Result<()> {
            bail!("nope {}", "x");
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope x");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e: Error = Error::from(io_err()).context("layer1").context("layer2");
        let d = format!("{e:?}");
        assert!(d.contains("layer2"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("gone"));
    }
}
