//! Stub of the `xla` crate (xla_extension PJRT bindings).
//!
//! The runtime layer (`gwtf::runtime`, `gwtf::trainer`) compiles against
//! this API surface on machines without the PJRT shared library; every
//! entry point that would touch PJRT returns [`Error`] at runtime, and the
//! PJRT-backed tests/benches skip when the artifact manifest is missing
//! (see `rust/tests/runtime_integration.rs`).  To run real training, swap
//! the path dependency in `rust/Cargo.toml` for the actual bindings — the
//! signatures below mirror them.
#![allow(dead_code)]

use std::fmt;
use std::path::Path;

/// PJRT-unavailable error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: built against the offline xla stub \
         (rust/vendor/xla); swap in the real bindings to execute artifacts"
            .to_string(),
    ))
}

/// Element types crossing the PJRT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host-native scalar types accepted by literals and buffers.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}
impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
}
impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
}
impl NativeType for u64 {
    const TY: ElementType = ElementType::U64;
}

/// Shape of a dense array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side literal (stub: shape-only, no payload).
#[derive(Debug, Clone)]
pub struct Literal {
    shape: ArrayShape,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { shape: ArrayShape { dims: vec![data.len() as i64], ty: T::TY } }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let _ = dims;
        unavailable()
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let _ = computation;
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let _ = (data, dims, device);
        unavailable()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn execute_b(&self, args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let _ = args;
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        unavailable()
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        let _ = proto;
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_carries_shape_type() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert_eq!(l.shape.dims, vec![2]);
        assert_eq!(l.shape.ty, ElementType::F32);
        assert!(l.to_tuple().is_err());
    }
}
