//! GWTF's decentralized flow optimization (paper §V-A, §V-C).
//!
//! Flows (abstract pipelines for one microbatch each) are built *in
//! reverse*, from the sink side: a last-stage relay first pairs with a
//! data node (Request Flow towards the sink), advertising its cost to
//! sink; earlier-stage relays then extend chains front-ward, each picking
//! the successor minimizing `d(i,j) + cost_to_sink(j)` (Eq. 1); finally the
//! data node pairs its per-iteration microbatch budget with the cheapest
//! stage-1 chain heads, completing flows.
//!
//! Two local refinement moves then reduce cost while training runs:
//!
//! - **Request Change**: two same-stage nodes with flows to the same sink
//!   swap their next-stage peers when that lowers the objective
//!   (the min-max edge cost — §V-A's local relaxation of Eq. 2).
//! - **Request Redirect**: a spare-capacity node offers to replace a more
//!   expensive peer inside an existing flow.  To escape local minima both
//!   moves use simulated-annealing acceptance (T = 1.7, α = 0.95).
//!
//! Every decision uses only knowledge a node can hold locally: its peer
//! view (adjacent stages, from the DHT), the advertised `cost_to_sink` of
//! those peers, and pairwise Eq. 1 costs to them.  The round loop is a
//! synchronous rendering of the asynchronous gossip the paper describes;
//! each round corresponds to one "iteration of the algorithm" on Fig. 7's
//! x-axis.

use std::collections::BTreeMap;

use crate::cost::NodeId;
use crate::util::Rng;

use super::annealing::Annealer;
use super::graph::{FlowPath, FlowProblem};

/// Tunables (paper §VI Setup).
#[derive(Debug, Clone)]
pub struct FlowParams {
    pub temperature: f64,
    pub alpha: f64,
    /// Enable Request Change moves.
    pub enable_change: bool,
    /// Enable Request Redirect moves.
    pub enable_redirect: bool,
    /// Objective for Change/Redirect: true = min-max edge cost (paper),
    /// false = sum of edge costs (ablation).
    pub minmax_objective: bool,
}

impl Default for FlowParams {
    fn default() -> Self {
        FlowParams {
            temperature: 1.7,
            alpha: 0.95,
            enable_change: true,
            enable_redirect: true,
            minmax_objective: true,
        }
    }
}

/// One flow under construction or established: relays from `head_stage`
/// through the last stage, plus the sink data node it returns to.
#[derive(Debug, Clone)]
pub struct Chain {
    pub sink: NodeId,
    /// Relays in stage order; `nodes[0]` is at `head_stage`.
    pub nodes: Vec<NodeId>,
    pub head_stage: usize,
    /// Head is paired with the sink data node's source budget.
    pub complete: bool,
    /// Round at which this chain last made progress (seeded/extended).
    /// Incomplete chains stalled past a timeout are torn down so their
    /// capacity can be re-offered (the §V-D "excluded until they free
    /// memory" rule applied to flow construction).
    pub last_progress: usize,
}

/// Per-round convergence statistics (Fig. 7 series + scale diagnostics).
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    pub round: usize,
    pub complete_flows: usize,
    pub avg_cost_per_microbatch: f64,
    pub max_edge_cost: f64,
    pub moves_applied: usize,
    /// Chains alive at the end of the round (complete + under
    /// construction) — the `chains` in the O(chains·k) bound.
    pub chains: usize,
    /// Peer-candidate evaluations this round outside Request Change
    /// (seed / extend / redirect visibility + cost checks).
    pub candidate_scans: usize,
    /// Request Change pair candidates examined this round; bounded by
    /// 2·chains ≤ k·chains for any overlay fanout k ≥ 2 (asserted in
    /// `rust/tests/overlay.rs`).
    pub change_scans: usize,
}

/// The decentralized optimizer state.
pub struct DecentralizedFlow<'p> {
    pub prob: &'p FlowProblem,
    pub params: FlowParams,
    pub chains: Vec<Chain>,
    /// Remaining capacity per node (node.0-indexed).
    cap_left: Vec<usize>,
    /// Remaining sink acceptances per data node.
    sink_left: BTreeMap<NodeId, usize>,
    /// Remaining source pairings per data node.
    source_left: BTreeMap<NodeId, usize>,
    annealer: Annealer,
    rng: Rng,
    round: usize,
    /// Per-node overlay neighbor lists (sorted; see
    /// [`set_neighbors`](Self::set_neighbors)).  None = legacy global
    /// adjacent-stage visibility.
    neighbors: Option<BTreeMap<NodeId, Vec<NodeId>>>,
    /// Nodes currently dead (crashed); they take part in nothing.
    dead: Vec<bool>,
    /// Candidate-scan counters for the round in flight (RoundStats).
    scans: usize,
    change_scans: usize,
}

impl<'p> DecentralizedFlow<'p> {
    pub fn new(prob: &'p FlowProblem, params: FlowParams, seed: u64) -> Self {
        let cap_left = prob.cap.clone();
        let mut sink_left = BTreeMap::new();
        let mut source_left = BTreeMap::new();
        for (di, &d) in prob.graph.data_nodes.iter().enumerate() {
            sink_left.insert(d, prob.demand[di]);
            source_left.insert(d, prob.demand[di]);
        }
        let annealer = Annealer::new(params.temperature, params.alpha);
        DecentralizedFlow {
            prob,
            params,
            chains: Vec::new(),
            cap_left,
            sink_left,
            source_left,
            annealer,
            rng: Rng::new(seed),
            round: 0,
            neighbors: None,
            dead: vec![false; prob.cap.len()],
            scans: 0,
            change_scans: 0,
        }
    }

    /// Restrict every node's candidate pool to its overlay neighbor list
    /// (`NodeId -> visible peers`, typically
    /// [`crate::net::Overlay::neighbor_map`]).  Lists are sorted and
    /// deduplicated here so [`sees`](Self::sees) can binary-search on the
    /// planner's hottest path.  A node absent from the map sees no one
    /// (data nodes never act as viewers, so they need no entry).
    ///
    /// With lists covering the full adjacent stages (overlay fanout
    /// `k >= n-1`) every decision — including RNG draws and tie-breaks —
    /// matches the global-visibility planner bit for bit; the parity
    /// test in `rust/tests/overlay.rs` holds this invariant.
    pub fn set_neighbors(&mut self, mut map: BTreeMap<NodeId, Vec<NodeId>>) {
        for peers in map.values_mut() {
            peers.sort_unstable();
            peers.dedup();
        }
        self.neighbors = Some(map);
    }

    /// Warm-start construction (§V-A/§V-D): adopt the surviving chains of
    /// a previous plan instead of rebuilding every flow from scratch.
    /// Capacity, sink and source bookkeeping is recomputed from the
    /// adopted chains; `temperature` continues the annealing schedule
    /// where the previous plan left it (a converged plan re-heated to the
    /// initial temperature would undo its own chains).
    ///
    /// Chains through *crashed* nodes are adopted as-is — the caller must
    /// follow up with [`remove_node`](Self::remove_node) for every dead
    /// node, which tears down or locally repairs exactly the affected
    /// flows, then [`run`](Self::run) a few rounds to re-complete and
    /// refine.  Chains that no longer fit the problem (stage shape
    /// changed, budget exceeded) are dropped here, freeing their budget
    /// for reconstruction.
    pub fn warm_start(
        prob: &'p FlowProblem,
        params: FlowParams,
        seed: u64,
        chains: Vec<Chain>,
        temperature: f64,
    ) -> Self {
        let mut f = DecentralizedFlow::new(prob, params, seed);
        f.annealer.temperature = temperature.max(1e-12);
        for mut ch in chains {
            let shape_ok = !ch.nodes.is_empty()
                && ch.head_stage + ch.nodes.len() == prob.graph.n_stages()
                && prob.graph.data_nodes.contains(&ch.sink)
                && ch
                    .nodes
                    .iter()
                    .enumerate()
                    .all(|(i, n)| prob.graph.stages[ch.head_stage + i].contains(n));
            // Dead nodes carry cap 0 in the liveness-masked problem; they
            // are adoptable (pending remove_node repair).  Alive nodes
            // must still have budget left.
            let budget_ok = shape_ok
                && ch
                    .nodes
                    .iter()
                    .all(|&n| prob.cap[n.0] == 0 || f.cap_left[n.0] > 0)
                && f.sink_left[&ch.sink] > 0
                && (!ch.complete || f.source_left[&ch.sink] > 0);
            if !budget_ok {
                continue;
            }
            for &n in &ch.nodes {
                f.cap_left[n.0] = f.cap_left[n.0].saturating_sub(1);
            }
            *f.sink_left.get_mut(&ch.sink).unwrap() -= 1;
            if ch.complete {
                *f.source_left.get_mut(&ch.sink).unwrap() -= 1;
            }
            ch.last_progress = 0;
            f.chains.push(ch);
        }
        f
    }

    /// Current annealer temperature (carried into warm restarts).
    pub fn temperature(&self) -> f64 {
        self.annealer.temperature
    }

    fn n_stages(&self) -> usize {
        self.prob.graph.n_stages()
    }

    fn alive(&self, n: NodeId) -> bool {
        !self.dead[n.0]
    }

    /// Can `viewer` see `peer`? (partial-membership restriction; lists
    /// are sorted by [`set_neighbors`](Self::set_neighbors))
    fn sees(&self, viewer: NodeId, peer: NodeId) -> bool {
        match &self.neighbors {
            None => true,
            Some(v) => {
                v.get(&viewer).map(|ps| ps.binary_search(&peer).is_ok()).unwrap_or(false)
            }
        }
    }

    /// Cost from a chain's head back to its sink (local info: each node
    /// advertises this after a successful Request Flow).
    pub fn cost_to_sink(&self, chain: &Chain) -> f64 {
        let mut c = 0.0;
        for w in chain.nodes.windows(2) {
            c += self.prob.cost(w[0], w[1]);
        }
        c + self.prob.cost(*chain.nodes.last().unwrap(), chain.sink)
    }

    /// Full path cost including the data-node -> head hop.
    fn full_cost(&self, chain: &Chain) -> f64 {
        self.prob.cost(chain.sink, chain.nodes[0]) + self.cost_to_sink(chain)
    }

    /// One synchronous round of the protocol.  Returns stats.
    pub fn step(&mut self) -> RoundStats {
        self.round += 1;
        self.scans = 0;
        self.change_scans = 0;
        let mut moves = 0;
        moves += self.seed_chains();
        moves += self.extend_chains();
        moves += self.pair_sources();
        moves += self.reclaim_stalled();
        if self.params.enable_change {
            moves += self.request_change();
        }
        if self.params.enable_redirect {
            moves += self.request_redirect();
        }
        self.stats(moves)
    }

    /// Run until steady state (no moves for `patience` rounds) or `max_rounds`.
    pub fn run(&mut self, max_rounds: usize, patience: usize) -> Vec<RoundStats> {
        let mut out = Vec::new();
        let mut idle = 0;
        for _ in 0..max_rounds {
            let s = self.step();
            idle = if s.moves_applied == 0 { idle + 1 } else { 0 };
            out.push(s);
            if idle >= patience {
                break;
            }
        }
        out
    }

    fn stats(&self, moves: usize) -> RoundStats {
        let complete: Vec<&Chain> = self.chains.iter().filter(|c| c.complete).collect();
        let avg = if complete.is_empty() {
            f64::INFINITY
        } else {
            complete.iter().map(|c| self.full_cost(c)).sum::<f64>() / complete.len() as f64
        };
        let max_edge = complete
            .iter()
            .map(|c| self.path_of(c).max_edge_cost(self.prob))
            .fold(0.0f64, f64::max);
        RoundStats {
            round: self.round,
            complete_flows: complete.len(),
            avg_cost_per_microbatch: avg,
            max_edge_cost: max_edge,
            moves_applied: moves,
            chains: self.chains.len(),
            candidate_scans: self.scans,
            change_scans: self.change_scans,
        }
    }

    /// Stage-(S-1) relays with spare capacity request flow to a data node
    /// (seeding a new chain at the sink side).
    fn seed_chains(&mut self) -> usize {
        let last = self.n_stages() - 1;
        let mut members = self.prob.graph.stages[last].clone();
        self.rng.shuffle(&mut members);
        let mut moves = 0;
        for r in members {
            if !self.alive(r) || self.cap_left[r.0] == 0 {
                continue;
            }
            // Cheapest data node with remaining sink budget this relay can
            // see (first minimal wins, as `Iterator::min_by` would pick).
            let mut best: Option<(NodeId, f64)> = None;
            for &d in &self.prob.graph.data_nodes {
                if self.sink_left[&d] == 0 || !self.sees(r, d) {
                    continue;
                }
                self.scans += 1;
                let c = self.prob.cost(r, d);
                if best.map(|(_, bc)| c < bc).unwrap_or(true) {
                    best = Some((d, c));
                }
            }
            if let Some((d, _)) = best {
                *self.sink_left.get_mut(&d).unwrap() -= 1;
                self.cap_left[r.0] -= 1;
                let round = self.round;
                self.chains.push(Chain {
                    sink: d,
                    nodes: vec![r],
                    head_stage: last,
                    complete: false,
                    last_progress: round,
                });
                moves += 1;
            }
        }
        moves
    }

    /// Relays with spare capacity extend chains whose head sits one stage
    /// after them (Request Flow towards the head).
    ///
    /// Chains open at each stage boundary are indexed by their head node,
    /// so a relay only evaluates the chains headed by its overlay
    /// neighbors — O(k·chains) per round instead of every relay scanning
    /// every chain.  Candidates are always visited in ascending chain
    /// order (first minimal wins), which keeps partial and global views
    /// on identical tie-breaks: full neighbor lists reproduce the legacy
    /// global scan bit for bit.
    fn extend_chains(&mut self) -> usize {
        let mut moves = 0;
        for s in (0..self.n_stages() - 1).rev() {
            // Index the chains open for extension at this boundary:
            // incomplete, head at stage s+1.  `open` is ascending by
            // construction; `by_head` serves the neighbor-scoped lookups.
            let mut open: Vec<usize> = Vec::new();
            let mut by_head: BTreeMap<NodeId, Vec<usize>> = BTreeMap::new();
            for (ci, ch) in self.chains.iter().enumerate() {
                if !ch.complete && ch.head_stage == s + 1 {
                    open.push(ci);
                    by_head.entry(ch.nodes[0]).or_default().push(ci);
                }
            }
            let mut members = self.prob.graph.stages[s].clone();
            self.rng.shuffle(&mut members);
            for i in members {
                if !self.alive(i) || self.cap_left[i.0] == 0 {
                    continue;
                }
                // Global mode iterates the shared `open` index in place;
                // neighbor mode materializes the (small) per-relay set.
                let scoped: Option<Vec<usize>> = match &self.neighbors {
                    None => None,
                    Some(map) => {
                        let Some(peers) = map.get(&i) else { continue };
                        let mut v: Vec<usize> = peers
                            .iter()
                            .filter_map(|p| by_head.get(p))
                            .flatten()
                            .copied()
                            .collect();
                        v.sort_unstable();
                        Some(v)
                    }
                };
                let cand: &[usize] = scoped.as_deref().unwrap_or(&open);
                let mut best: Option<(usize, f64)> = None;
                for &ci in cand {
                    self.scans += 1;
                    let ch = &self.chains[ci];
                    let c = self.prob.cost(i, ch.nodes[0]) + self.cost_to_sink(ch);
                    if best.map(|(_, bc)| c < bc).unwrap_or(true) {
                        best = Some((ci, c));
                    }
                }
                if let Some((ci, _)) = best {
                    // The chain's head moves to stage s: drop it from this
                    // boundary's index so later relays skip it.
                    let head = self.chains[ci].nodes[0];
                    open.retain(|&x| x != ci);
                    if let Some(v) = by_head.get_mut(&head) {
                        v.retain(|&x| x != ci);
                    }
                    self.chains[ci].nodes.insert(0, i);
                    self.chains[ci].head_stage = s;
                    self.chains[ci].last_progress = self.round;
                    self.cap_left[i.0] -= 1;
                    moves += 1;
                }
            }
        }
        moves
    }

    /// Data nodes pair their microbatch budget with stage-0 chain heads.
    fn pair_sources(&mut self) -> usize {
        let mut moves = 0;
        let data_nodes = self.prob.graph.data_nodes.clone();
        for d in data_nodes {
            while self.source_left[&d] > 0 {
                let mut best: Option<(usize, f64)> = None;
                for (ci, ch) in self.chains.iter().enumerate() {
                    if ch.complete || ch.head_stage != 0 || ch.sink != d {
                        continue;
                    }
                    let c = self.prob.cost(d, ch.nodes[0]) + self.cost_to_sink(ch);
                    if best.map(|(_, bc)| c < bc).unwrap_or(true) {
                        best = Some((ci, c));
                    }
                }
                match best {
                    Some((ci, _)) => {
                        self.chains[ci].complete = true;
                        *self.source_left.get_mut(&d).unwrap() -= 1;
                        moves += 1;
                    }
                    None => break,
                }
            }
        }
        moves
    }

    /// Tear down incomplete chains that made no progress for a few rounds,
    /// refunding their relays' capacity and the sink slot so a different
    /// subset of relays can retry.  Without this, a chain stranded behind
    /// an exhausted stage holds budget forever and the system under-routes
    /// (the paper's objective is to *maximize* routed microbatches).
    fn reclaim_stalled(&mut self) -> usize {
        const STALL_ROUNDS: usize = 3;
        let round = self.round;
        let mut moves = 0;
        let mut ci = 0;
        while ci < self.chains.len() {
            let ch = &self.chains[ci];
            if !ch.complete && round.saturating_sub(ch.last_progress) >= STALL_ROUNDS {
                for &n in &ch.nodes {
                    self.cap_left[n.0] += 1;
                }
                *self.sink_left.get_mut(&ch.sink).unwrap() += 1;
                self.chains.remove(ci);
                moves += 1;
            } else {
                ci += 1;
            }
        }
        moves
    }

    /// Objective used by Change/Redirect when comparing two local options.
    fn pair_objective(&self, a: f64, b: f64) -> f64 {
        if self.params.minmax_objective {
            a.max(b)
        } else {
            a + b
        }
    }

    /// Request Change: same-stage pairs swap successors for the same sink.
    fn request_change(&mut self) -> usize {
        let mut moves = 0;
        // Consider every stage boundary: edge from position p to p+1 within
        // chains (position 0 edge is data->head, handled by Redirect).
        let n_chains = self.chains.len();
        if n_chains < 2 {
            return 0;
        }
        let attempts = n_chains * 2;
        for _ in 0..attempts {
            let a = self.rng.index(n_chains);
            let b = self.rng.index(n_chains);
            if a == b {
                continue;
            }
            let (ca, cb) = (self.chains[a].clone(), self.chains[b].clone());
            if ca.sink != cb.sink || !ca.complete || !cb.complete {
                continue;
            }
            // pick a random boundary: edge leaving stage s
            if ca.nodes.len() < 2 {
                continue;
            }
            let pos = self.rng.index(ca.nodes.len() - 1);
            if cb.nodes.len() != ca.nodes.len() {
                continue;
            }
            let (i1, j1) = (ca.nodes[pos], ca.nodes[pos + 1]);
            let (i2, j2) = (cb.nodes[pos], cb.nodes[pos + 1]);
            if i1 == i2 || j1 == j2 {
                continue;
            }
            self.change_scans += 1;
            // Nodes must see each other's peers to negotiate the swap —
            // with an overlay attached, swap partners come only from the
            // nodes' bounded neighbor views.
            if !self.sees(i1, j2) || !self.sees(i2, j1) {
                continue;
            }
            let cur = self.pair_objective(self.prob.cost(i1, j1), self.prob.cost(i2, j2));
            let new = self.pair_objective(self.prob.cost(i1, j2), self.prob.cost(i2, j1));
            if self.annealer.accept(cur, new, &mut self.rng) && new != cur {
                // Swap suffixes after `pos`.
                let tail_a: Vec<NodeId> = self.chains[a].nodes.split_off(pos + 1);
                let tail_b: Vec<NodeId> = self.chains[b].nodes.split_off(pos + 1);
                self.chains[a].nodes.extend(tail_b);
                self.chains[b].nodes.extend(tail_a);
                moves += 1;
            }
        }
        moves
    }

    /// Request Redirect: spare node m replaces node x inside a chain.
    fn request_redirect(&mut self) -> usize {
        let mut moves = 0;
        let n_chains = self.chains.len();
        for ci in 0..n_chains {
            let ch = self.chains[ci].clone();
            if !ch.complete {
                continue;
            }
            for (pi, &x) in ch.nodes.iter().enumerate() {
                let stage = ch.head_stage + pi;
                let prev = if pi == 0 { ch.sink } else { ch.nodes[pi - 1] };
                let next = if pi + 1 < ch.nodes.len() { ch.nodes[pi + 1] } else { ch.sink };
                // Candidate replacements with spare capacity in the same stage.
                let mut scans = 0usize;
                let cand: Vec<NodeId> = self.prob.graph.stages[stage]
                    .iter()
                    .filter(|&&m| {
                        if m == x || !self.alive(m) || self.cap_left[m.0] == 0 {
                            return false;
                        }
                        scans += 1;
                        self.sees(m, prev) && self.sees(m, next)
                    })
                    .copied()
                    .collect();
                self.scans += scans;
                let Some(&m) = cand.iter().min_by(|&&p, &&q| {
                    let cp = self.prob.cost(prev, p) + self.prob.cost(p, next);
                    let cq = self.prob.cost(prev, q) + self.prob.cost(q, next);
                    cp.partial_cmp(&cq).unwrap()
                }) else {
                    continue;
                };
                let cur = self.pair_objective(self.prob.cost(prev, x), self.prob.cost(x, next));
                let new = self.pair_objective(self.prob.cost(prev, m), self.prob.cost(m, next));
                if new != cur && self.annealer.accept(cur, new, &mut self.rng) {
                    self.cap_left[m.0] -= 1;
                    self.cap_left[x.0] += 1;
                    self.chains[ci].nodes[pi] = m;
                    moves += 1;
                    break; // one redirect per chain per round
                }
            }
        }
        moves
    }

    /// Record a node as dead without repairing yet.  Callers tearing down
    /// several nodes should mark them all first, then
    /// [`remove_node`](Self::remove_node) each: repair then knows every
    /// dead flow neighbour regardless of removal order (the dead-endpoint
    /// exemption in the candidate filter depends on it).
    pub fn mark_dead(&mut self, x: NodeId) {
        self.dead[x.0] = true;
        self.cap_left[x.0] = 0;
    }

    /// A node crashed: repair flows through it (§IV "amend a broken flow").
    /// Repair finds the last alive node before the crash and reconnects to
    /// the first alive node after it through a spare-capacity peer; if no
    /// peer exists, the whole chain is torn down (capacity refunded).
    pub fn remove_node(&mut self, x: NodeId) -> (usize, usize) {
        self.mark_dead(x);
        let mut repaired = 0;
        let mut destroyed = 0;
        let mut ci = 0;
        while ci < self.chains.len() {
            let Some(pi) = self.chains[ci].nodes.iter().position(|&n| n == x) else {
                ci += 1;
                continue;
            };
            let ch = self.chains[ci].clone();
            let stage = ch.head_stage + pi;
            let prev = if pi == 0 { ch.sink } else { ch.nodes[pi - 1] };
            let next = if pi + 1 < ch.nodes.len() { ch.nodes[pi + 1] } else { ch.sink };
            // §V-D repair is a local negotiation too: the stand-in must be
            // able to see its *living* flow neighbours (a dead endpoint is
            // itself pending removal — its own repair re-links that side,
            // so requiring visibility towards it would veto repairs the
            // global planner performs and break k = n-1 parity).
            let cand: Vec<NodeId> = self.prob.graph.stages[stage]
                .iter()
                .filter(|&&m| {
                    m != x
                        && self.alive(m)
                        && self.cap_left[m.0] > 0
                        && (!self.alive(prev) || self.sees(m, prev))
                        && (!self.alive(next) || self.sees(m, next))
                })
                .copied()
                .collect();
            let best = cand.iter().min_by(|&&p, &&q| {
                let cp = self.prob.cost(prev, p) + self.prob.cost(p, next);
                let cq = self.prob.cost(prev, q) + self.prob.cost(q, next);
                cp.partial_cmp(&cq).unwrap()
            });
            match best {
                Some(&m) => {
                    self.cap_left[m.0] -= 1;
                    self.chains[ci].nodes[pi] = m;
                    repaired += 1;
                    ci += 1;
                }
                None => {
                    // refund all other relays and the budgets
                    for (qi, &n) in ch.nodes.iter().enumerate() {
                        if qi != pi {
                            self.cap_left[n.0] += 1;
                        }
                    }
                    *self.sink_left.get_mut(&ch.sink).unwrap() += 1;
                    if ch.complete {
                        *self.source_left.get_mut(&ch.sink).unwrap() += 1;
                    }
                    self.chains.remove(ci);
                    destroyed += 1;
                }
            }
        }
        (repaired, destroyed)
    }

    /// A node (re)joins with capacity `cap` at stage `stage` (assumes the
    /// graph already lists it there).
    pub fn revive_node(&mut self, n: NodeId, cap: usize) {
        self.dead[n.0] = false;
        self.cap_left[n.0] = cap;
    }

    fn path_of(&self, c: &Chain) -> FlowPath {
        FlowPath { source: c.sink, relays: c.nodes.clone() }
    }

    /// Established complete flows as routing paths.
    pub fn established_paths(&self) -> Vec<FlowPath> {
        self.chains
            .iter()
            .filter(|c| c.complete && c.head_stage == 0)
            .map(|c| self.path_of(c))
            .collect()
    }

    /// Sum of Eq. 1 costs over complete flows (the Eq. 2 objective).
    pub fn total_cost(&self) -> f64 {
        self.chains.iter().filter(|c| c.complete).map(|c| self.full_cost(c)).sum()
    }

    pub fn complete_flows(&self) -> usize {
        self.chains.iter().filter(|c| c.complete).count()
    }

    pub fn cap_left(&self, n: NodeId) -> usize {
        self.cap_left[n.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::graph::{random_problem, validate_paths};
    use crate::flow::mcmf::mcmf_min_cost;

    fn run_default(seed: u64, sources: usize, relays: usize, stages: usize) -> (FlowProblem, Vec<RoundStats>, Vec<FlowPath>) {
        let mut rng = Rng::new(seed);
        let prob = random_problem(sources, relays, stages, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), seed ^ 0xF10);
        let stats = f.run(120, 10);
        let paths = f.established_paths();
        (prob, stats, paths)
    }

    #[test]
    fn builds_complete_flows() {
        let (prob, stats, paths) = run_default(1, 1, 24, 4);
        assert!(!paths.is_empty());
        assert_eq!(paths.len(), prob.max_throughput().min(prob.demand[0]));
        assert!(stats.last().unwrap().complete_flows == paths.len());
    }

    #[test]
    fn paths_validate() {
        for seed in 0..8 {
            let (prob, _stats, paths) = run_default(seed, 1, 24, 4);
            validate_paths(&paths, &prob).unwrap();
        }
    }

    #[test]
    fn multi_source_routes_each_commodity_home() {
        let (prob, _stats, paths) = run_default(3, 2, 40, 8);
        assert!(!paths.is_empty());
        validate_paths(&paths, &prob).unwrap();
        // every source present
        for &d in &prob.graph.data_nodes {
            assert!(paths.iter().any(|p| p.source == d), "no flow for {d}");
        }
    }

    #[test]
    fn cost_decreases_over_rounds() {
        let (_prob, stats, _paths) = run_default(5, 1, 40, 8);
        let first_complete = stats.iter().find(|s| s.complete_flows > 0).unwrap();
        let last = stats.last().unwrap();
        assert!(
            last.avg_cost_per_microbatch <= first_complete.avg_cost_per_microbatch + 1e-9,
            "{} -> {}",
            first_complete.avg_cost_per_microbatch,
            last.avg_cost_per_microbatch
        );
    }

    #[test]
    fn within_factor_of_optimal_single_source() {
        // Paper Fig. 7: GWTF approaches the optimal baseline on tests 1-4.
        let mut worse = 0;
        for seed in 0..6 {
            let mut rng = Rng::new(seed);
            let prob = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
            let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), seed);
            f.run(120, 10);
            let opt = mcmf_min_cost(&prob);
            if opt.flow == f.complete_flows() && opt.flow > 0 {
                let ratio = f.total_cost() / opt.total_cost;
                assert!(ratio >= 1.0 - 1e-9, "decentralized beat the optimum?! {ratio}");
                if ratio > 2.0 {
                    worse += 1;
                }
            }
        }
        assert!(worse <= 1, "too many instances far from optimal");
    }

    #[test]
    fn crash_repair_keeps_paths_valid() {
        let mut rng = Rng::new(9);
        let prob = random_problem(1, 24, 4, (2.0, 4.0), (1.0, 20.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 9);
        f.run(120, 10);
        let before = f.complete_flows();
        assert!(before > 0);
        // crash one node that is actually used
        let victim = f.established_paths()[0].relays[1];
        let (rep, des) = f.remove_node(victim);
        assert!(rep + des > 0);
        let paths = f.established_paths();
        for p in &paths {
            assert!(!p.relays.contains(&victim));
        }
        validate_paths(&paths, &prob).unwrap();
    }

    #[test]
    fn destroyed_chains_refund_capacity() {
        // one relay per stage: crashing it destroys the chain entirely
        let mut rng = Rng::new(11);
        let prob = random_problem(1, 4, 4, (1.0, 2.0), (1.0, 5.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 11);
        f.run(60, 8);
        let victim = prob.graph.stages[1][0];
        let used_before: usize = prob.graph.stages[2].iter().map(|&n| prob.cap[n.0] - f.cap_left(n)).sum();
        assert!(used_before > 0);
        let (_rep, des) = f.remove_node(victim);
        assert!(des > 0, "single-relay stage must destroy");
        let used_after: usize = prob.graph.stages[2].iter().map(|&n| prob.cap[n.0] - f.cap_left(n)).sum();
        assert!(used_after < used_before);
    }

    #[test]
    fn greedy_vs_annealing_ablation() {
        // Annealing should on average match or beat pure greedy refinement.
        let mut anneal_total = 0.0;
        let mut greedy_total = 0.0;
        for seed in 0..10 {
            let mut rng = Rng::new(seed + 100);
            let prob = random_problem(1, 32, 8, (1.0, 3.0), (5.0, 100.0), &mut rng);
            let mut fa = DecentralizedFlow::new(&prob, FlowParams::default(), seed);
            fa.run(120, 10);
            let mut pg = FlowParams::default();
            pg.temperature = 1e-12;
            let mut fg = DecentralizedFlow::new(&prob, pg, seed);
            fg.run(120, 10);
            if fa.complete_flows() == fg.complete_flows() && fa.complete_flows() > 0 {
                anneal_total += fa.total_cost();
                greedy_total += fg.total_cost();
            }
        }
        assert!(anneal_total <= greedy_total * 1.15, "annealing {anneal_total} vs greedy {greedy_total}");
    }

    #[test]
    fn warm_start_adopts_chains_and_bookkeeping() {
        let mut rng = Rng::new(31);
        let prob = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut cold = DecentralizedFlow::new(&prob, FlowParams::default(), 31);
        cold.run(120, 10);
        let flows_before = cold.complete_flows();
        assert!(flows_before > 0);
        let chains = cold.chains.clone();
        let temp = cold.temperature();

        let warm =
            DecentralizedFlow::warm_start(&prob, FlowParams::default(), 32, chains, temp);
        assert_eq!(warm.complete_flows(), flows_before, "all chains adopted");
        // bookkeeping matches the cold optimizer's
        for s in &prob.graph.stages {
            for &n in s {
                assert_eq!(warm.cap_left(n), cold.cap_left(n), "cap mismatch at {n}");
            }
        }
        validate_paths(&warm.established_paths(), &prob).unwrap();
        assert!(warm.temperature() <= FlowParams::default().temperature);
    }

    #[test]
    fn warm_start_converges_in_fewer_rounds_after_crash() {
        let mut rng = Rng::new(33);
        let prob = random_problem(1, 24, 4, (2.0, 4.0), (1.0, 20.0), &mut rng);
        let mut cold = DecentralizedFlow::new(&prob, FlowParams::default(), 33);
        let cold_rounds = cold.run(120, 8).len();
        let flows = cold.complete_flows();
        assert!(flows > 0);
        let victim = cold.established_paths()[0].relays[1];

        let mut warm = DecentralizedFlow::warm_start(
            &prob,
            FlowParams::default(),
            34,
            cold.chains.clone(),
            cold.temperature(),
        );
        warm.remove_node(victim);
        let warm_rounds = warm.run(120, 4).len();
        assert_eq!(warm.complete_flows(), flows, "repair keeps the flow count");
        validate_paths(&warm.established_paths(), &prob).unwrap();
        for p in warm.established_paths() {
            assert!(!p.relays.contains(&victim));
        }
        assert!(
            warm_rounds < cold_rounds,
            "warm {warm_rounds} rounds vs cold {cold_rounds}"
        );
    }

    #[test]
    fn warm_start_drops_misshapen_chains() {
        let mut rng = Rng::new(35);
        let prob = random_problem(1, 16, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut cold = DecentralizedFlow::new(&prob, FlowParams::default(), 35);
        cold.run(120, 10);
        assert!(cold.complete_flows() > 0);
        let mut chains = cold.chains.clone();
        // corrupt one chain: truncate its relay list (stage shape mismatch)
        if let Some(c) = chains.iter_mut().find(|c| c.complete) {
            c.nodes.pop();
        }
        let warm = DecentralizedFlow::warm_start(
            &prob,
            FlowParams::default(),
            36,
            chains,
            cold.temperature(),
        );
        validate_paths(&warm.established_paths(), &prob).unwrap();
        assert_eq!(warm.complete_flows(), cold.complete_flows() - 1);
    }

    #[test]
    fn restricted_visibility_still_builds_flows() {
        let mut rng = Rng::new(21);
        let prob = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        // Each node sees only half of each adjacent stage (plus data nodes).
        let mut vis: BTreeMap<NodeId, Vec<NodeId>> = BTreeMap::new();
        let all = prob.graph.all_nodes();
        for &n in &all {
            let mut seen: Vec<NodeId> = prob.graph.data_nodes.clone();
            for s in &prob.graph.stages {
                for (i, &m) in s.iter().enumerate() {
                    if i % 2 == (n.0 % 2) {
                        seen.push(m);
                    }
                }
            }
            vis.insert(n, seen);
        }
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 21);
        f.set_neighbors(vis);
        f.run(120, 10);
        assert!(f.complete_flows() > 0);
        validate_paths(&f.established_paths(), &prob).unwrap();
    }

    /// Full neighbor lists must reproduce the global-visibility planner
    /// bit for bit — same RNG draws, same tie-breaks, same chains.
    #[test]
    fn full_neighbor_lists_match_global_scan_bitwise() {
        let mut rng = Rng::new(55);
        let prob = random_problem(2, 32, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let all = prob.graph.all_nodes();
        let mut full: BTreeMap<NodeId, Vec<NodeId>> = BTreeMap::new();
        for &n in &all {
            full.insert(n, all.iter().copied().filter(|&m| m != n).collect());
        }
        let mut a = DecentralizedFlow::new(&prob, FlowParams::default(), 55);
        let mut b = DecentralizedFlow::new(&prob, FlowParams::default(), 55);
        b.set_neighbors(full);
        let (sa, sb) = (a.run(120, 10), b.run(120, 10));
        assert_eq!(sa.len(), sb.len(), "same convergence trajectory");
        for (x, y) in sa.iter().zip(&sb) {
            assert_eq!(x.moves_applied, y.moves_applied, "round {}", x.round);
            assert_eq!(
                x.avg_cost_per_microbatch.to_bits(),
                y.avg_cost_per_microbatch.to_bits(),
                "round {}",
                x.round
            );
        }
        assert_eq!(a.established_paths(), b.established_paths());
        assert_eq!(a.total_cost().to_bits(), b.total_cost().to_bits());
    }

    #[test]
    fn round_stats_report_bounded_change_scans() {
        let mut rng = Rng::new(61);
        let prob = random_problem(1, 40, 8, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 61);
        let stats = f.run(60, 8);
        assert!(stats.iter().any(|s| s.candidate_scans > 0), "scans must be counted");
        for s in &stats {
            assert!(
                s.change_scans <= 2 * s.chains.max(1),
                "round {}: {} change scans for {} chains",
                s.round,
                s.change_scans,
                s.chains
            );
        }
    }
}
