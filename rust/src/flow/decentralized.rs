//! GWTF's decentralized flow optimization (paper §V-A, §V-C).
//!
//! Flows (abstract pipelines for one microbatch each) are built *in
//! reverse*, from the sink side: a last-stage relay first pairs with a
//! data node (Request Flow towards the sink), advertising its cost to
//! sink; earlier-stage relays then extend chains front-ward, each picking
//! the successor minimizing `d(i,j) + cost_to_sink(j)` (Eq. 1); finally the
//! data node pairs its per-iteration microbatch budget with the cheapest
//! stage-1 chain heads, completing flows.
//!
//! Two local refinement moves then reduce cost while training runs:
//!
//! - **Request Change**: two same-stage nodes with flows to the same sink
//!   swap their next-stage peers when that lowers the objective
//!   (the min-max edge cost — §V-A's local relaxation of Eq. 2).
//! - **Request Redirect**: a spare-capacity node offers to replace a more
//!   expensive peer inside an existing flow.  To escape local minima both
//!   moves use simulated-annealing acceptance (T = 1.7, α = 0.95).
//!
//! Every decision uses only knowledge a node can hold locally: its peer
//! view (adjacent stages, from the DHT), the advertised `cost_to_sink` of
//! those peers, and pairwise Eq. 1 costs to them.  The round loop is a
//! synchronous rendering of the asynchronous gossip the paper describes;
//! each round corresponds to one "iteration of the algorithm" on Fig. 7's
//! x-axis.
//!
//! # Hot paths
//!
//! `NodeId(pub usize)` is a dense index, so the per-round state is laid
//! out as flat arenas instead of ordered maps: sink/source budgets are
//! `Vec<usize>`, liveness is a [`BitSet`] and overlay visibility a
//! [`BitMatrix`] (one shift+mask per `sees`).  Chains open for extension
//! are indexed per head stage in round-persistent sorted lists
//! (`open_at`), updated on seed/extend/complete and rebuilt on chain
//! removal.  Refinement moves borrow chains in place and mutate only on
//! acceptance, so a rejected candidate allocates nothing.  Candidate
//! *costs* — pure functions of the problem — are precomputed into flat
//! matrices, optionally across scoped worker threads
//! ([`FlowParams::threads`]); every *decision* that consumes them (RNG
//! draws, tie-breaks, capacity checks) replays sequentially on the
//! caller's thread, which is why results are bit-for-bit identical at any
//! thread count.

use std::collections::BTreeMap;

use crate::cost::NodeId;
use crate::util::{BitMatrix, BitSet, Rng};

use super::annealing::Annealer;
use super::graph::{max_edge_cost_over, FlowPath, FlowProblem};

/// Tunables (paper §VI Setup).
#[derive(Debug, Clone)]
pub struct FlowParams {
    pub temperature: f64,
    pub alpha: f64,
    /// Enable Request Change moves.
    pub enable_change: bool,
    /// Enable Request Redirect moves.
    pub enable_redirect: bool,
    /// Objective for Change/Redirect: true = min-max edge cost (paper),
    /// false = sum of edge costs (ablation).
    pub minmax_objective: bool,
    /// Worker threads for the pure candidate-cost precompute (0 and 1
    /// both mean sequential).  Never changes results: workers only fill
    /// f64 matrices, all decisions replay on the calling thread.
    pub threads: usize,
}

impl Default for FlowParams {
    fn default() -> Self {
        FlowParams {
            temperature: 1.7,
            alpha: 0.95,
            enable_change: true,
            enable_redirect: true,
            minmax_objective: true,
            threads: 1,
        }
    }
}

/// One flow under construction or established: relays from `head_stage`
/// through the last stage, plus the sink data node it returns to.
#[derive(Debug, Clone)]
pub struct Chain {
    pub sink: NodeId,
    /// Relays in stage order; `nodes[0]` is at `head_stage`.
    pub nodes: Vec<NodeId>,
    pub head_stage: usize,
    /// Head is paired with the sink data node's source budget.
    pub complete: bool,
    /// Round at which this chain last made progress (seeded/extended).
    /// Incomplete chains stalled past a timeout are torn down so their
    /// capacity can be re-offered (the §V-D "excluded until they free
    /// memory" rule applied to flow construction).
    pub last_progress: usize,
}

/// Per-round convergence statistics (Fig. 7 series + scale diagnostics).
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    pub round: usize,
    pub complete_flows: usize,
    pub avg_cost_per_microbatch: f64,
    pub max_edge_cost: f64,
    pub moves_applied: usize,
    /// Chains alive at the end of the round (complete + under
    /// construction) — the `chains` in the O(chains·k) bound.
    pub chains: usize,
    /// Peer-candidate evaluations this round outside Request Change
    /// (seed / extend / redirect visibility + cost checks).
    pub candidate_scans: usize,
    /// Request Change pair candidates examined this round; bounded by
    /// 2·chains ≤ k·chains for any overlay fanout k ≥ 2 (asserted in
    /// `rust/tests/overlay.rs`).
    pub change_scans: usize,
}

/// A snapshotted Request Redirect site: position `pi` of chain `ci`,
/// currently held by `x`, between `prev` and `next` at `stage`.
#[derive(Debug, Clone, Copy)]
struct RedirPos {
    ci: usize,
    pi: usize,
    x: NodeId,
    prev: NodeId,
    next: NodeId,
    stage: usize,
}

/// Cell count below which a threaded matrix fill is pure spawn overhead.
const PAR_MIN_CELLS: usize = 2048;

/// Fill `out` (a rows x cols row-major matrix, `out.len() == rows*cols`)
/// with `f(row, col)`, fanning contiguous row bands across scoped worker
/// threads.  `f` must be pure: workers only precompute f64s, and every
/// decision that consumes them stays on the caller's thread — the
/// planner's results cannot depend on `threads`.
fn par_fill(out: &mut [f64], cols: usize, threads: usize, f: impl Fn(usize, usize) -> f64 + Sync) {
    if cols == 0 || out.is_empty() {
        return;
    }
    if threads <= 1 || out.len() < PAR_MIN_CELLS {
        for (i, v) in out.iter_mut().enumerate() {
            *v = f(i / cols, i % cols);
        }
        return;
    }
    let rows = out.len() / cols;
    let band = rows.div_ceil(threads).max(1);
    std::thread::scope(|scope| {
        for (t, chunk) in out.chunks_mut(band * cols).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = t * band;
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = f(base + i / cols, i % cols);
                }
            });
        }
    });
}

/// Ragged variant of [`par_fill`]: row `r` occupies
/// `offsets[r]..offsets[r+1]` of `out` and is filled with
/// `f(r, col_in_row)`.  Same purity/determinism contract.
fn par_fill_ragged(
    out: &mut [f64],
    offsets: &[usize],
    threads: usize,
    f: impl Fn(usize, usize) -> f64 + Sync,
) {
    let rows = offsets.len().saturating_sub(1);
    // Fills rows r0..r1 into a slice whose first cell is flat `base`.
    let fill = |slice: &mut [f64], r0: usize, r1: usize, base: usize| {
        for r in r0..r1 {
            let (lo, hi) = (offsets[r] - base, offsets[r + 1] - base);
            for (c, v) in slice[lo..hi].iter_mut().enumerate() {
                *v = f(r, c);
            }
        }
    };
    if threads <= 1 || out.len() < PAR_MIN_CELLS || rows == 0 {
        fill(out, 0, rows, 0);
        return;
    }
    let band = rows.div_ceil(threads).max(1);
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut r0 = 0usize;
        while r0 < rows {
            let r1 = (r0 + band).min(rows);
            let (chunk, tail) = rest.split_at_mut(offsets[r1] - offsets[r0]);
            rest = tail;
            let fill = &fill;
            scope.spawn(move || fill(chunk, r0, r1, offsets[r0]));
            r0 = r1;
        }
    });
}

fn sorted_insert(v: &mut Vec<usize>, x: usize) {
    if let Err(i) = v.binary_search(&x) {
        v.insert(i, x);
    }
}

fn sorted_remove(v: &mut Vec<usize>, x: usize) {
    if let Ok(i) = v.binary_search(&x) {
        v.remove(i);
    }
}

/// The decentralized optimizer state.
pub struct DecentralizedFlow<'p> {
    pub prob: &'p FlowProblem,
    pub params: FlowParams,
    pub chains: Vec<Chain>,
    /// Remaining capacity per node (node.0-indexed).
    cap_left: Vec<usize>,
    /// Remaining sink acceptances per data node (node.0-indexed arena;
    /// only data-node slots are ever touched).
    sink_left: Vec<usize>,
    /// Remaining source pairings per data node (node.0-indexed arena).
    source_left: Vec<usize>,
    annealer: Annealer,
    rng: Rng,
    round: usize,
    /// Overlay visibility as a dense bit matrix (`viewer.0, peer.0`).
    /// None = legacy global adjacent-stage visibility.
    neighbors: Option<BitMatrix>,
    /// Nodes currently dead (crashed); they take part in nothing.
    dead: BitSet,
    /// Candidate-scan counters for the round in flight (RoundStats).
    scans: usize,
    change_scans: usize,
    /// Round-persistent extension index: `open_at[s]` = indices of
    /// incomplete chains whose head sits at stage `s`, ascending.
    /// Maintained on seed/extend/complete; rebuilt when `chains` indices
    /// shift (stall reclaim, crash teardown).
    open_at: Vec<Vec<usize>>,
    /// Scratch buffers reused across rounds (no per-round allocation).
    shuffle_buf: Vec<NodeId>,
    cand_buf: Vec<(usize, NodeId, f64)>,
    cost_buf: Vec<f64>,
    redir_buf: Vec<RedirPos>,
    redir_off: Vec<usize>,
}

impl<'p> DecentralizedFlow<'p> {
    pub fn new(prob: &'p FlowProblem, params: FlowParams, seed: u64) -> Self {
        let n = prob.cap.len();
        let cap_left = prob.cap.clone();
        let mut sink_left = vec![0usize; n];
        let mut source_left = vec![0usize; n];
        for (di, &d) in prob.graph.data_nodes.iter().enumerate() {
            sink_left[d.0] = prob.demand[di];
            source_left[d.0] = prob.demand[di];
        }
        let annealer = Annealer::new(params.temperature, params.alpha);
        let n_stages = prob.graph.n_stages();
        DecentralizedFlow {
            prob,
            params,
            chains: Vec::new(),
            cap_left,
            sink_left,
            source_left,
            annealer,
            rng: Rng::new(seed),
            round: 0,
            neighbors: None,
            dead: BitSet::new(n),
            scans: 0,
            change_scans: 0,
            open_at: vec![Vec::new(); n_stages],
            shuffle_buf: Vec::new(),
            cand_buf: Vec::new(),
            cost_buf: Vec::new(),
            redir_buf: Vec::new(),
            redir_off: Vec::new(),
        }
    }

    /// Restrict every node's candidate pool to its overlay neighbor list
    /// (`NodeId -> visible peers`, typically
    /// [`crate::net::Overlay::neighbor_map`]).  Lists are flattened into
    /// a dense [`BitMatrix`] so [`sees`](Self::sees) is one shift+mask on
    /// the planner's hottest path.  A node absent from the map sees no
    /// one (data nodes never act as viewers, so they need no entry).
    ///
    /// With lists covering the full adjacent stages (overlay fanout
    /// `k >= n-1`) every decision — including RNG draws and tie-breaks —
    /// matches the global-visibility planner bit for bit; the parity
    /// test in `rust/tests/overlay.rs` holds this invariant.
    pub fn set_neighbors(&mut self, map: BTreeMap<NodeId, Vec<NodeId>>) {
        self.set_neighbor_edges(
            map.iter().flat_map(|(&v, ps)| ps.iter().map(move |&p| (v, p))),
        );
    }

    /// [`set_neighbors`](Self::set_neighbors) without the intermediate
    /// map: stream `(viewer, peer)` edges straight into the visibility
    /// bits (e.g. from
    /// [`crate::net::Overlay::for_each_planning_edge`]).  Order and
    /// duplicates are irrelevant — a bit is a bit.
    pub fn set_neighbor_edges(&mut self, edges: impl IntoIterator<Item = (NodeId, NodeId)>) {
        let mut m = BitMatrix::new(self.prob.cap.len());
        for (v, p) in edges {
            m.set(v.0, p.0);
        }
        self.neighbors = Some(m);
    }

    /// Warm-start construction (§V-A/§V-D): adopt the surviving chains of
    /// a previous plan instead of rebuilding every flow from scratch.
    /// Capacity, sink and source bookkeeping is recomputed from the
    /// adopted chains; `temperature` continues the annealing schedule
    /// where the previous plan left it (a converged plan re-heated to the
    /// initial temperature would undo its own chains).
    ///
    /// Chains through *crashed* nodes are adopted as-is — the caller must
    /// follow up with [`remove_node`](Self::remove_node) for every dead
    /// node, which tears down or locally repairs exactly the affected
    /// flows, then [`run`](Self::run) a few rounds to re-complete and
    /// refine.  Chains that no longer fit the problem (stage shape
    /// changed, budget exceeded) are dropped here, freeing their budget
    /// for reconstruction.
    pub fn warm_start(
        prob: &'p FlowProblem,
        params: FlowParams,
        seed: u64,
        chains: Vec<Chain>,
        temperature: f64,
    ) -> Self {
        let mut f = DecentralizedFlow::new(prob, params, seed);
        f.annealer.temperature = temperature.max(1e-12);
        for mut ch in chains {
            let shape_ok = !ch.nodes.is_empty()
                && ch.head_stage + ch.nodes.len() == prob.graph.n_stages()
                && prob.graph.data_nodes.contains(&ch.sink)
                && ch
                    .nodes
                    .iter()
                    .enumerate()
                    .all(|(i, n)| prob.graph.stages[ch.head_stage + i].contains(n));
            // Dead nodes carry cap 0 in the liveness-masked problem; they
            // are adoptable (pending remove_node repair).  Alive nodes
            // must still have budget left.
            let budget_ok = shape_ok
                && ch
                    .nodes
                    .iter()
                    .all(|&n| prob.cap[n.0] == 0 || f.cap_left[n.0] > 0)
                && f.sink_left[ch.sink.0] > 0
                && (!ch.complete || f.source_left[ch.sink.0] > 0);
            if !budget_ok {
                continue;
            }
            for &n in &ch.nodes {
                f.cap_left[n.0] = f.cap_left[n.0].saturating_sub(1);
            }
            f.sink_left[ch.sink.0] -= 1;
            if ch.complete {
                f.source_left[ch.sink.0] -= 1;
            }
            ch.last_progress = 0;
            f.chains.push(ch);
        }
        f.rebuild_open_index();
        f
    }

    /// Current annealer temperature (carried into warm restarts).
    pub fn temperature(&self) -> f64 {
        self.annealer.temperature
    }

    fn n_stages(&self) -> usize {
        self.prob.graph.n_stages()
    }

    fn alive(&self, n: NodeId) -> bool {
        !self.dead.contains(n.0)
    }

    /// Can `viewer` see `peer`? (partial-membership restriction; one bit
    /// test on the dense matrix built by
    /// [`set_neighbors`](Self::set_neighbors))
    fn sees(&self, viewer: NodeId, peer: NodeId) -> bool {
        match &self.neighbors {
            None => true,
            Some(m) => m.get(viewer.0, peer.0),
        }
    }

    /// Rebuild `open_at` from scratch — required whenever a
    /// `chains.remove` shifts the indices the sorted lists point at.
    /// Cheap: the chain count is bounded by total demand, not fleet size.
    fn rebuild_open_index(&mut self) {
        for v in &mut self.open_at {
            v.clear();
        }
        for (ci, ch) in self.chains.iter().enumerate() {
            if !ch.complete {
                self.open_at[ch.head_stage].push(ci);
            }
        }
    }

    /// Cost from a chain's head back to its sink (local info: each node
    /// advertises this after a successful Request Flow).
    pub fn cost_to_sink(&self, chain: &Chain) -> f64 {
        let mut c = 0.0;
        for w in chain.nodes.windows(2) {
            c += self.prob.cost(w[0], w[1]);
        }
        c + self.prob.cost(*chain.nodes.last().unwrap(), chain.sink)
    }

    /// Full path cost including the data-node -> head hop.
    fn full_cost(&self, chain: &Chain) -> f64 {
        self.prob.cost(chain.sink, chain.nodes[0]) + self.cost_to_sink(chain)
    }

    /// One synchronous round of the protocol.  Returns stats.
    pub fn step(&mut self) -> RoundStats {
        self.round += 1;
        self.scans = 0;
        self.change_scans = 0;
        let mut moves = 0;
        moves += self.seed_chains();
        moves += self.extend_chains();
        moves += self.pair_sources();
        moves += self.reclaim_stalled();
        if self.params.enable_change {
            moves += self.request_change();
        }
        if self.params.enable_redirect {
            moves += self.request_redirect();
        }
        self.stats(moves)
    }

    /// Run until steady state (no moves for `patience` rounds) or `max_rounds`.
    pub fn run(&mut self, max_rounds: usize, patience: usize) -> Vec<RoundStats> {
        let mut out = Vec::new();
        let mut idle = 0;
        for _ in 0..max_rounds {
            let s = self.step();
            idle = if s.moves_applied == 0 { idle + 1 } else { 0 };
            out.push(s);
            if idle >= patience {
                break;
            }
        }
        out
    }

    fn stats(&self, moves: usize) -> RoundStats {
        let mut complete = 0usize;
        let mut cost_sum = 0.0f64;
        let mut max_edge = 0.0f64;
        for c in self.chains.iter().filter(|c| c.complete) {
            complete += 1;
            cost_sum += self.full_cost(c);
            max_edge = max_edge.max(max_edge_cost_over(self.prob, c.sink, &c.nodes));
        }
        let avg = if complete == 0 { f64::INFINITY } else { cost_sum / complete as f64 };
        RoundStats {
            round: self.round,
            complete_flows: complete,
            avg_cost_per_microbatch: avg,
            max_edge_cost: max_edge,
            moves_applied: moves,
            chains: self.chains.len(),
            candidate_scans: self.scans,
            change_scans: self.change_scans,
        }
    }

    /// Stage-(S-1) relays with spare capacity request flow to a data node
    /// (seeding a new chain at the sink side).
    fn seed_chains(&mut self) -> usize {
        let prob = self.prob;
        let last = self.n_stages() - 1;
        // Shuffle a scratch copy of the *pristine* stage order — reusing
        // a previously shuffled buffer would compose permutations and
        // change every RNG-dependent decision downstream.
        let mut members = std::mem::take(&mut self.shuffle_buf);
        members.clear();
        members.extend_from_slice(&prob.graph.stages[last]);
        self.rng.shuffle(&mut members);
        let mut moves = 0;
        for &r in &members {
            if !self.alive(r) || self.cap_left[r.0] == 0 {
                continue;
            }
            // Cheapest data node with remaining sink budget this relay can
            // see (first minimal wins, as `Iterator::min_by` would pick).
            let mut best: Option<(NodeId, f64)> = None;
            for &d in &prob.graph.data_nodes {
                if self.sink_left[d.0] == 0 || !self.sees(r, d) {
                    continue;
                }
                self.scans += 1;
                let c = prob.cost(r, d);
                if best.map(|(_, bc)| c < bc).unwrap_or(true) {
                    best = Some((d, c));
                }
            }
            if let Some((d, _)) = best {
                self.sink_left[d.0] -= 1;
                self.cap_left[r.0] -= 1;
                let round = self.round;
                self.chains.push(Chain {
                    sink: d,
                    nodes: vec![r],
                    head_stage: last,
                    complete: false,
                    last_progress: round,
                });
                // chains.len()-1 exceeds every index already listed, so a
                // plain push keeps open_at[last] ascending.
                self.open_at[last].push(self.chains.len() - 1);
                moves += 1;
            }
        }
        self.shuffle_buf = members;
        moves
    }

    /// Relays with spare capacity extend chains whose head sits one stage
    /// after them (Request Flow towards the head).
    ///
    /// Chains open at each boundary come from the round-persistent
    /// `open_at` index (ascending chain order — first minimal wins, which
    /// keeps partial and global views on identical tie-breaks).  Each
    /// candidate's `cost_to_sink` is relay-independent and advertised by
    /// the head, so it is hoisted and computed once per chain per
    /// boundary; the member x candidate cost matrix is precomputed (and
    /// optionally threaded) before the sequential claim loop.
    fn extend_chains(&mut self) -> usize {
        let prob = self.prob;
        let threads = self.params.threads;
        let mut moves = 0;
        for s in (0..self.n_stages() - 1).rev() {
            // Snapshot this boundary's open chains: (index, head,
            // advertised cost-to-sink).
            let mut cand = std::mem::take(&mut self.cand_buf);
            cand.clear();
            for &ci in &self.open_at[s + 1] {
                let ch = &self.chains[ci];
                cand.push((ci, ch.nodes[0], self.cost_to_sink(ch)));
            }
            let mut members = std::mem::take(&mut self.shuffle_buf);
            members.clear();
            members.extend_from_slice(&prob.graph.stages[s]);
            self.rng.shuffle(&mut members);
            // Pure cost rows: cost(member, head) + cost_to_sink(head).
            let cols = cand.len();
            let mut costs = std::mem::take(&mut self.cost_buf);
            costs.clear();
            costs.resize(members.len() * cols, 0.0);
            {
                let (cand, members) = (&cand[..], &members[..]);
                par_fill(&mut costs, cols, threads, move |r, c| {
                    prob.cost(members[r], cand[c].1) + cand[c].2
                });
            }
            for (mi, &i) in members.iter().enumerate() {
                if !self.alive(i) || self.cap_left[i.0] == 0 {
                    continue;
                }
                let row = &costs[mi * cols..(mi + 1) * cols];
                let mut best: Option<(usize, usize, f64)> = None;
                for (slot, &(ci, head, _)) in cand.iter().enumerate() {
                    if ci == usize::MAX {
                        continue; // claimed earlier in this boundary pass
                    }
                    if !self.sees(i, head) {
                        continue; // outside the relay's view: no candidate
                    }
                    self.scans += 1;
                    let c = row[slot];
                    if best.map(|(_, _, bc)| c < bc).unwrap_or(true) {
                        best = Some((slot, ci, c));
                    }
                }
                if let Some((slot, ci, _)) = best {
                    // The chain's head moves to stage s: claim its slot so
                    // later relays skip it, and migrate the open index.
                    cand[slot].0 = usize::MAX;
                    sorted_remove(&mut self.open_at[s + 1], ci);
                    sorted_insert(&mut self.open_at[s], ci);
                    self.chains[ci].nodes.insert(0, i);
                    self.chains[ci].head_stage = s;
                    self.chains[ci].last_progress = self.round;
                    self.cap_left[i.0] -= 1;
                    moves += 1;
                }
            }
            self.cand_buf = cand;
            self.shuffle_buf = members;
            self.cost_buf = costs;
        }
        moves
    }

    /// Data nodes pair their microbatch budget with stage-0 chain heads.
    fn pair_sources(&mut self) -> usize {
        let prob = self.prob;
        let mut moves = 0;
        for &d in &prob.graph.data_nodes {
            while self.source_left[d.0] > 0 {
                // Only stage-0 incomplete chains qualify — exactly what
                // `open_at[0]` lists, in ascending chain order.
                let mut best: Option<(usize, f64)> = None;
                for &ci in &self.open_at[0] {
                    let ch = &self.chains[ci];
                    if ch.sink != d {
                        continue;
                    }
                    let c = prob.cost(d, ch.nodes[0]) + self.cost_to_sink(ch);
                    if best.map(|(_, bc)| c < bc).unwrap_or(true) {
                        best = Some((ci, c));
                    }
                }
                match best {
                    Some((ci, _)) => {
                        self.chains[ci].complete = true;
                        sorted_remove(&mut self.open_at[0], ci);
                        self.source_left[d.0] -= 1;
                        moves += 1;
                    }
                    None => break,
                }
            }
        }
        moves
    }

    /// Tear down incomplete chains that made no progress for a few rounds,
    /// refunding their relays' capacity and the sink slot so a different
    /// subset of relays can retry.  Without this, a chain stranded behind
    /// an exhausted stage holds budget forever and the system under-routes
    /// (the paper's objective is to *maximize* routed microbatches).
    fn reclaim_stalled(&mut self) -> usize {
        const STALL_ROUNDS: usize = 3;
        let round = self.round;
        let mut moves = 0;
        let mut ci = 0;
        while ci < self.chains.len() {
            let ch = &self.chains[ci];
            if !ch.complete && round.saturating_sub(ch.last_progress) >= STALL_ROUNDS {
                for &n in &ch.nodes {
                    self.cap_left[n.0] += 1;
                }
                self.sink_left[ch.sink.0] += 1;
                self.chains.remove(ci);
                moves += 1;
            } else {
                ci += 1;
            }
        }
        if moves > 0 {
            self.rebuild_open_index();
        }
        moves
    }

    /// Objective used by Change/Redirect when comparing two local options.
    fn pair_objective(&self, a: f64, b: f64) -> f64 {
        if self.params.minmax_objective {
            a.max(b)
        } else {
            a + b
        }
    }

    /// Request Change: same-stage pairs swap successors for the same sink.
    fn request_change(&mut self) -> usize {
        let prob = self.prob;
        let mut moves = 0;
        // Consider every stage boundary: edge from position p to p+1 within
        // chains (position 0 edge is data->head, handled by Redirect).
        let n_chains = self.chains.len();
        if n_chains < 2 {
            return 0;
        }
        let attempts = n_chains * 2;
        for _ in 0..attempts {
            let a = self.rng.index(n_chains);
            let b = self.rng.index(n_chains);
            if a == b {
                continue;
            }
            let (sink_a, complete_a, len_a) = {
                let ca = &self.chains[a];
                (ca.sink, ca.complete, ca.nodes.len())
            };
            let (sink_b, complete_b, len_b) = {
                let cb = &self.chains[b];
                (cb.sink, cb.complete, cb.nodes.len())
            };
            if sink_a != sink_b || !complete_a || !complete_b {
                continue;
            }
            // pick a random boundary: edge leaving stage s
            if len_a < 2 {
                continue;
            }
            let pos = self.rng.index(len_a - 1);
            if len_b != len_a {
                continue;
            }
            let (i1, j1) = (self.chains[a].nodes[pos], self.chains[a].nodes[pos + 1]);
            let (i2, j2) = (self.chains[b].nodes[pos], self.chains[b].nodes[pos + 1]);
            if i1 == i2 || j1 == j2 {
                continue;
            }
            self.change_scans += 1;
            // Nodes must see each other's peers to negotiate the swap —
            // with an overlay attached, swap partners come only from the
            // nodes' bounded neighbor views.
            if !self.sees(i1, j2) || !self.sees(i2, j1) {
                continue;
            }
            let cur = self.pair_objective(prob.cost(i1, j1), prob.cost(i2, j2));
            let new = self.pair_objective(prob.cost(i1, j2), prob.cost(i2, j1));
            if self.annealer.accept(cur, new, &mut self.rng) && new != cur {
                // Swap the suffixes after `pos` element-wise: the chains
                // have equal length, so this is the old split_off/extend
                // swap without its two Vec allocations.
                let (lo, hi) = if a < b { (a, b) } else { (b, a) };
                let (left, right) = self.chains.split_at_mut(hi);
                let (ta, tb) = (&mut left[lo].nodes, &mut right[0].nodes);
                for k in pos + 1..len_a {
                    std::mem::swap(&mut ta[k], &mut tb[k]);
                }
                moves += 1;
            }
        }
        moves
    }

    /// Request Redirect: spare node m replaces node x inside a chain.
    ///
    /// Runs in two phases.  Phase 1 snapshots every (chain, position)
    /// site of the complete chains and precomputes the pure candidate
    /// costs `d(prev,m) + d(m,next)` into flat rows (optionally across
    /// scoped threads) — chains only self-modify during redirect and then
    /// break out of their own position loop, so prev/x/next per site are
    /// fixed here.  Phase 2 replays the decisions sequentially in the
    /// original order with the live capacity state and the main RNG.
    fn request_redirect(&mut self) -> usize {
        let prob = self.prob;
        let threads = self.params.threads;
        let mut moves = 0;
        let mut sites = std::mem::take(&mut self.redir_buf);
        let mut off = std::mem::take(&mut self.redir_off);
        sites.clear();
        off.clear();
        off.push(0);
        for (ci, ch) in self.chains.iter().enumerate() {
            if !ch.complete {
                continue;
            }
            for (pi, &x) in ch.nodes.iter().enumerate() {
                let stage = ch.head_stage + pi;
                let prev = if pi == 0 { ch.sink } else { ch.nodes[pi - 1] };
                let next = if pi + 1 < ch.nodes.len() { ch.nodes[pi + 1] } else { ch.sink };
                sites.push(RedirPos { ci, pi, x, prev, next, stage });
                off.push(off.last().unwrap() + prob.graph.stages[stage].len());
            }
        }
        let mut costs = std::mem::take(&mut self.cost_buf);
        costs.clear();
        costs.resize(*off.last().unwrap(), 0.0);
        {
            let sites = &sites[..];
            par_fill_ragged(&mut costs, &off, threads, move |r, c| {
                let p = &sites[r];
                let m = prob.graph.stages[p.stage][c];
                prob.cost(p.prev, m) + prob.cost(m, p.next)
            });
        }
        let mut r = 0;
        while r < sites.len() {
            let p = sites[r];
            let row = &costs[off[r]..off[r + 1]];
            r += 1;
            // Candidate replacements with spare capacity in the same
            // stage; first minimal wins (what `min_by` returned).
            let mut scans = 0usize;
            let mut best: Option<(NodeId, f64)> = None;
            for (c, &m) in prob.graph.stages[p.stage].iter().enumerate() {
                if m == p.x || !self.alive(m) || self.cap_left[m.0] == 0 {
                    continue;
                }
                scans += 1;
                if !self.sees(m, p.prev) || !self.sees(m, p.next) {
                    continue;
                }
                let cm = row[c];
                if best.map(|(_, bc)| cm < bc).unwrap_or(true) {
                    best = Some((m, cm));
                }
            }
            self.scans += scans;
            let Some((m, _)) = best else {
                continue;
            };
            let cur = self.pair_objective(prob.cost(p.prev, p.x), prob.cost(p.x, p.next));
            let new = self.pair_objective(prob.cost(p.prev, m), prob.cost(m, p.next));
            if new != cur && self.annealer.accept(cur, new, &mut self.rng) {
                self.cap_left[m.0] -= 1;
                self.cap_left[p.x.0] += 1;
                self.chains[p.ci].nodes[p.pi] = m;
                moves += 1;
                // one redirect per chain per round
                while r < sites.len() && sites[r].ci == p.ci {
                    r += 1;
                }
            }
        }
        self.redir_buf = sites;
        self.redir_off = off;
        self.cost_buf = costs;
        moves
    }

    /// Record a node as dead without repairing yet.  Callers tearing down
    /// several nodes should mark them all first, then
    /// [`remove_node`](Self::remove_node) each: repair then knows every
    /// dead flow neighbour regardless of removal order (the dead-endpoint
    /// exemption in the candidate filter depends on it).
    pub fn mark_dead(&mut self, x: NodeId) {
        self.dead.insert(x.0);
        self.cap_left[x.0] = 0;
    }

    /// A node crashed: repair flows through it (§IV "amend a broken flow").
    /// Repair finds the last alive node before the crash and reconnects to
    /// the first alive node after it through a spare-capacity peer; if no
    /// peer exists, the whole chain is torn down (capacity refunded).
    pub fn remove_node(&mut self, x: NodeId) -> (usize, usize) {
        self.mark_dead(x);
        let prob = self.prob;
        let mut repaired = 0;
        let mut destroyed = 0;
        let mut ci = 0;
        while ci < self.chains.len() {
            let Some(pi) = self.chains[ci].nodes.iter().position(|&n| n == x) else {
                ci += 1;
                continue;
            };
            let (stage, prev, next) = {
                let ch = &self.chains[ci];
                let stage = ch.head_stage + pi;
                let prev = if pi == 0 { ch.sink } else { ch.nodes[pi - 1] };
                let next = if pi + 1 < ch.nodes.len() { ch.nodes[pi + 1] } else { ch.sink };
                (stage, prev, next)
            };
            // §V-D repair is a local negotiation too: the stand-in must be
            // able to see its *living* flow neighbours (a dead endpoint is
            // itself pending removal — its own repair re-links that side,
            // so requiring visibility towards it would veto repairs the
            // global planner performs and break k = n-1 parity).
            let mut best: Option<(NodeId, f64)> = None;
            for &m in &prob.graph.stages[stage] {
                if m == x || !self.alive(m) || self.cap_left[m.0] == 0 {
                    continue;
                }
                if self.alive(prev) && !self.sees(m, prev) {
                    continue;
                }
                if self.alive(next) && !self.sees(m, next) {
                    continue;
                }
                let c = prob.cost(prev, m) + prob.cost(m, next);
                if best.map(|(_, bc)| c < bc).unwrap_or(true) {
                    best = Some((m, c));
                }
            }
            match best {
                Some((m, _)) => {
                    self.cap_left[m.0] -= 1;
                    self.chains[ci].nodes[pi] = m;
                    repaired += 1;
                    ci += 1;
                }
                None => {
                    // refund all other relays and the budgets
                    let (sink, complete) = {
                        let ch = &self.chains[ci];
                        for (qi, &n) in ch.nodes.iter().enumerate() {
                            if qi != pi {
                                self.cap_left[n.0] += 1;
                            }
                        }
                        (ch.sink, ch.complete)
                    };
                    self.sink_left[sink.0] += 1;
                    if complete {
                        self.source_left[sink.0] += 1;
                    }
                    self.chains.remove(ci);
                    destroyed += 1;
                }
            }
        }
        if destroyed > 0 {
            self.rebuild_open_index();
        }
        (repaired, destroyed)
    }

    /// A node (re)joins with capacity `cap` at stage `stage` (assumes the
    /// graph already lists it there).
    pub fn revive_node(&mut self, n: NodeId, cap: usize) {
        self.dead.remove(n.0);
        self.cap_left[n.0] = cap;
    }

    fn path_of(&self, c: &Chain) -> FlowPath {
        FlowPath { source: c.sink, relays: c.nodes.clone() }
    }

    /// Established complete flows as routing paths.
    pub fn established_paths(&self) -> Vec<FlowPath> {
        self.chains
            .iter()
            .filter(|c| c.complete && c.head_stage == 0)
            .map(|c| self.path_of(c))
            .collect()
    }

    /// Sum of Eq. 1 costs over complete flows (the Eq. 2 objective).
    pub fn total_cost(&self) -> f64 {
        self.chains.iter().filter(|c| c.complete).map(|c| self.full_cost(c)).sum()
    }

    pub fn complete_flows(&self) -> usize {
        self.chains.iter().filter(|c| c.complete).count()
    }

    pub fn cap_left(&self, n: NodeId) -> usize {
        self.cap_left[n.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::graph::{random_problem, validate_paths};
    use crate::flow::mcmf::mcmf_min_cost;

    fn run_default(seed: u64, sources: usize, relays: usize, stages: usize) -> (FlowProblem, Vec<RoundStats>, Vec<FlowPath>) {
        let mut rng = Rng::new(seed);
        let prob = random_problem(sources, relays, stages, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), seed ^ 0xF10);
        let stats = f.run(120, 10);
        let paths = f.established_paths();
        (prob, stats, paths)
    }

    #[test]
    fn builds_complete_flows() {
        let (prob, stats, paths) = run_default(1, 1, 24, 4);
        assert!(!paths.is_empty());
        assert_eq!(paths.len(), prob.max_throughput().min(prob.demand[0]));
        assert!(stats.last().unwrap().complete_flows == paths.len());
    }

    #[test]
    fn paths_validate() {
        for seed in 0..8 {
            let (prob, _stats, paths) = run_default(seed, 1, 24, 4);
            validate_paths(&paths, &prob).unwrap();
        }
    }

    #[test]
    fn multi_source_routes_each_commodity_home() {
        let (prob, _stats, paths) = run_default(3, 2, 40, 8);
        assert!(!paths.is_empty());
        validate_paths(&paths, &prob).unwrap();
        // every source present
        for &d in &prob.graph.data_nodes {
            assert!(paths.iter().any(|p| p.source == d), "no flow for {d}");
        }
    }

    #[test]
    fn cost_decreases_over_rounds() {
        let (_prob, stats, _paths) = run_default(5, 1, 40, 8);
        let first_complete = stats.iter().find(|s| s.complete_flows > 0).unwrap();
        let last = stats.last().unwrap();
        assert!(
            last.avg_cost_per_microbatch <= first_complete.avg_cost_per_microbatch + 1e-9,
            "{} -> {}",
            first_complete.avg_cost_per_microbatch,
            last.avg_cost_per_microbatch
        );
    }

    #[test]
    fn within_factor_of_optimal_single_source() {
        // Paper Fig. 7: GWTF approaches the optimal baseline on tests 1-4.
        let mut worse = 0;
        for seed in 0..6 {
            let mut rng = Rng::new(seed);
            let prob = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
            let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), seed);
            f.run(120, 10);
            let opt = mcmf_min_cost(&prob);
            if opt.flow == f.complete_flows() && opt.flow > 0 {
                let ratio = f.total_cost() / opt.total_cost;
                assert!(ratio >= 1.0 - 1e-9, "decentralized beat the optimum?! {ratio}");
                if ratio > 2.0 {
                    worse += 1;
                }
            }
        }
        assert!(worse <= 1, "too many instances far from optimal");
    }

    #[test]
    fn crash_repair_keeps_paths_valid() {
        let mut rng = Rng::new(9);
        let prob = random_problem(1, 24, 4, (2.0, 4.0), (1.0, 20.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 9);
        f.run(120, 10);
        let before = f.complete_flows();
        assert!(before > 0);
        // crash one node that is actually used
        let victim = f.established_paths()[0].relays[1];
        let (rep, des) = f.remove_node(victim);
        assert!(rep + des > 0);
        let paths = f.established_paths();
        for p in &paths {
            assert!(!p.relays.contains(&victim));
        }
        validate_paths(&paths, &prob).unwrap();
    }

    #[test]
    fn destroyed_chains_refund_capacity() {
        // one relay per stage: crashing it destroys the chain entirely
        let mut rng = Rng::new(11);
        let prob = random_problem(1, 4, 4, (1.0, 2.0), (1.0, 5.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 11);
        f.run(60, 8);
        let victim = prob.graph.stages[1][0];
        let used_before: usize = prob.graph.stages[2].iter().map(|&n| prob.cap[n.0] - f.cap_left(n)).sum();
        assert!(used_before > 0);
        let (_rep, des) = f.remove_node(victim);
        assert!(des > 0, "single-relay stage must destroy");
        let used_after: usize = prob.graph.stages[2].iter().map(|&n| prob.cap[n.0] - f.cap_left(n)).sum();
        assert!(used_after < used_before);
    }

    #[test]
    fn greedy_vs_annealing_ablation() {
        // Annealing should on average match or beat pure greedy refinement.
        let mut anneal_total = 0.0;
        let mut greedy_total = 0.0;
        for seed in 0..10 {
            let mut rng = Rng::new(seed + 100);
            let prob = random_problem(1, 32, 8, (1.0, 3.0), (5.0, 100.0), &mut rng);
            let mut fa = DecentralizedFlow::new(&prob, FlowParams::default(), seed);
            fa.run(120, 10);
            let mut pg = FlowParams::default();
            pg.temperature = 1e-12;
            let mut fg = DecentralizedFlow::new(&prob, pg, seed);
            fg.run(120, 10);
            if fa.complete_flows() == fg.complete_flows() && fa.complete_flows() > 0 {
                anneal_total += fa.total_cost();
                greedy_total += fg.total_cost();
            }
        }
        assert!(anneal_total <= greedy_total * 1.15, "annealing {anneal_total} vs greedy {greedy_total}");
    }

    #[test]
    fn warm_start_adopts_chains_and_bookkeeping() {
        let mut rng = Rng::new(31);
        let prob = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut cold = DecentralizedFlow::new(&prob, FlowParams::default(), 31);
        cold.run(120, 10);
        let flows_before = cold.complete_flows();
        assert!(flows_before > 0);
        let chains = cold.chains.clone();
        let temp = cold.temperature();

        let warm =
            DecentralizedFlow::warm_start(&prob, FlowParams::default(), 32, chains, temp);
        assert_eq!(warm.complete_flows(), flows_before, "all chains adopted");
        // bookkeeping matches the cold optimizer's
        for s in &prob.graph.stages {
            for &n in s {
                assert_eq!(warm.cap_left(n), cold.cap_left(n), "cap mismatch at {n}");
            }
        }
        validate_paths(&warm.established_paths(), &prob).unwrap();
        assert!(warm.temperature() <= FlowParams::default().temperature);
    }

    #[test]
    fn warm_start_converges_in_fewer_rounds_after_crash() {
        let mut rng = Rng::new(33);
        let prob = random_problem(1, 24, 4, (2.0, 4.0), (1.0, 20.0), &mut rng);
        let mut cold = DecentralizedFlow::new(&prob, FlowParams::default(), 33);
        let cold_rounds = cold.run(120, 8).len();
        let flows = cold.complete_flows();
        assert!(flows > 0);
        let victim = cold.established_paths()[0].relays[1];

        let mut warm = DecentralizedFlow::warm_start(
            &prob,
            FlowParams::default(),
            34,
            cold.chains.clone(),
            cold.temperature(),
        );
        warm.remove_node(victim);
        let warm_rounds = warm.run(120, 4).len();
        assert_eq!(warm.complete_flows(), flows, "repair keeps the flow count");
        validate_paths(&warm.established_paths(), &prob).unwrap();
        for p in warm.established_paths() {
            assert!(!p.relays.contains(&victim));
        }
        assert!(
            warm_rounds < cold_rounds,
            "warm {warm_rounds} rounds vs cold {cold_rounds}"
        );
    }

    #[test]
    fn warm_start_drops_misshapen_chains() {
        let mut rng = Rng::new(35);
        let prob = random_problem(1, 16, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut cold = DecentralizedFlow::new(&prob, FlowParams::default(), 35);
        cold.run(120, 10);
        assert!(cold.complete_flows() > 0);
        let mut chains = cold.chains.clone();
        // corrupt one chain: truncate its relay list (stage shape mismatch)
        if let Some(c) = chains.iter_mut().find(|c| c.complete) {
            c.nodes.pop();
        }
        let warm = DecentralizedFlow::warm_start(
            &prob,
            FlowParams::default(),
            36,
            chains,
            cold.temperature(),
        );
        validate_paths(&warm.established_paths(), &prob).unwrap();
        assert_eq!(warm.complete_flows(), cold.complete_flows() - 1);
    }

    #[test]
    fn restricted_visibility_still_builds_flows() {
        let mut rng = Rng::new(21);
        let prob = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        // Each node sees only half of each adjacent stage (plus data nodes).
        let mut vis: BTreeMap<NodeId, Vec<NodeId>> = BTreeMap::new();
        let all = prob.graph.all_nodes();
        for &n in &all {
            let mut seen: Vec<NodeId> = prob.graph.data_nodes.clone();
            for s in &prob.graph.stages {
                for (i, &m) in s.iter().enumerate() {
                    if i % 2 == (n.0 % 2) {
                        seen.push(m);
                    }
                }
            }
            vis.insert(n, seen);
        }
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 21);
        f.set_neighbors(vis);
        f.run(120, 10);
        assert!(f.complete_flows() > 0);
        validate_paths(&f.established_paths(), &prob).unwrap();
    }

    /// Full neighbor lists must reproduce the global-visibility planner
    /// bit for bit — same RNG draws, same tie-breaks, same chains, and
    /// (the dense-state refactor's guard) the same per-round candidate
    /// and Request Change scan counts.
    #[test]
    fn full_neighbor_lists_match_global_scan_bitwise() {
        let mut rng = Rng::new(55);
        let prob = random_problem(2, 32, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let all = prob.graph.all_nodes();
        let mut full: BTreeMap<NodeId, Vec<NodeId>> = BTreeMap::new();
        for &n in &all {
            full.insert(n, all.iter().copied().filter(|&m| m != n).collect());
        }
        let mut a = DecentralizedFlow::new(&prob, FlowParams::default(), 55);
        let mut b = DecentralizedFlow::new(&prob, FlowParams::default(), 55);
        b.set_neighbors(full);
        let (sa, sb) = (a.run(120, 10), b.run(120, 10));
        assert_eq!(sa.len(), sb.len(), "same convergence trajectory");
        for (x, y) in sa.iter().zip(&sb) {
            assert_eq!(x.moves_applied, y.moves_applied, "round {}", x.round);
            assert_eq!(x.candidate_scans, y.candidate_scans, "round {}", x.round);
            assert_eq!(x.change_scans, y.change_scans, "round {}", x.round);
            assert_eq!(
                x.avg_cost_per_microbatch.to_bits(),
                y.avg_cost_per_microbatch.to_bits(),
                "round {}",
                x.round
            );
        }
        assert_eq!(a.established_paths(), b.established_paths());
        assert_eq!(a.total_cost().to_bits(), b.total_cost().to_bits());
    }

    /// Worker threads only precompute pure cost matrices; every decision
    /// replays sequentially, so any thread count must produce the same
    /// bits.  400 relays x 4 stages pushes the Redirect rows past the
    /// `PAR_MIN_CELLS` threshold, so threads > 1 genuinely exercises the
    /// scoped-thread fill.
    #[test]
    fn threaded_candidate_evaluation_matches_sequential_bitwise() {
        let mut rng = Rng::new(71);
        let prob = random_problem(2, 400, 4, (2.0, 4.0), (1.0, 20.0), &mut rng);
        let run = |threads: usize| {
            let params = FlowParams { threads, ..FlowParams::default() };
            let mut f = DecentralizedFlow::new(&prob, params, 71);
            let stats = f.run(60, 8);
            let trace: Vec<(usize, usize, usize, u64)> = stats
                .iter()
                .map(|s| {
                    (
                        s.moves_applied,
                        s.candidate_scans,
                        s.change_scans,
                        s.avg_cost_per_microbatch.to_bits(),
                    )
                })
                .collect();
            (trace, f.established_paths(), f.total_cost().to_bits())
        };
        let base = run(1);
        for threads in [2, 4, 8] {
            assert_eq!(run(threads), base, "threads={threads} diverged from sequential");
        }
    }

    #[test]
    fn round_stats_report_bounded_change_scans() {
        let mut rng = Rng::new(61);
        let prob = random_problem(1, 40, 8, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 61);
        let stats = f.run(60, 8);
        assert!(stats.iter().any(|s| s.candidate_scans > 0), "scans must be counted");
        for s in &stats {
            assert!(
                s.change_scans <= 2 * s.chains.max(1),
                "round {}: {} change scans for {} chains",
                s.round,
                s.change_scans,
                s.chains
            );
        }
    }
}
