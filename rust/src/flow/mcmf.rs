//! Exact minimum-cost maximum-flow — the paper's *optimal* baseline.
//!
//! The paper compares GWTF against the out-of-kilter algorithm
//! [Fulkerson 1961] on single-source instances (Tables IV/V, Fig. 5 and
//! Fig. 7 tests 1–4).  We implement the equivalent successive-shortest-
//! paths algorithm with Johnson potentials, which computes the same
//! optimum (min-cost max-flow is unique in value) with better constants.
//!
//! Node capacities (`cap_i`) are handled by the standard node-splitting
//! transformation: every relay becomes `in -> out` with an internal edge
//! of capacity `cap_i`.  Because a microbatch must return to its origin
//! data node, the sink is a *virtual* terminal fed only by the
//! last-stage -> data-node return edges of that origin (single-commodity
//! case; multi-source instances are routed per-commodity, matching the
//! paper's note that its formulation differs there).

use crate::cost::NodeId;

use super::graph::{FlowPath, FlowProblem};

/// Internal edge for the residual graph.
#[derive(Debug, Clone)]
struct Edge {
    to: usize,
    cap: i64,
    cost: f64,
    /// Index of the reverse edge in `graph[to]`.
    rev: usize,
    /// True for original edges, false for residual reverse edges.
    forward: bool,
}

/// Residual-graph MCMF solver.
struct Solver {
    graph: Vec<Vec<Edge>>,
}

impl Solver {
    fn new(n: usize) -> Self {
        Solver { graph: vec![Vec::new(); n] }
    }

    fn add_edge(&mut self, from: usize, to: usize, cap: i64, cost: f64) {
        let rev_from = self.graph[to].len();
        let rev_to = self.graph[from].len();
        self.graph[from].push(Edge { to, cap, cost, rev: rev_from, forward: true });
        self.graph[to].push(Edge { to: from, cap: 0, cost: -cost, rev: rev_to, forward: false });
    }

    /// Min-cost flow of up to `max_flow` units from `s` to `t`.
    /// Returns (flow_sent, total_cost).
    fn run(&mut self, s: usize, t: usize, max_flow: i64) -> (i64, f64) {
        let n = self.graph.len();
        let mut flow = 0i64;
        let mut cost = 0.0f64;
        let mut potential = vec![0.0f64; n];

        // All our costs are non-negative, so potentials start at zero and
        // plain Dijkstra is sound from the first augmentation.
        while flow < max_flow {
            // Dijkstra over reduced costs.
            let mut dist = vec![f64::INFINITY; n];
            let mut prev: Vec<Option<(usize, usize)>> = vec![None; n];
            dist[s] = 0.0;
            let mut heap = std::collections::BinaryHeap::new();
            heap.push(std::cmp::Reverse((OrdF64(0.0), s)));
            while let Some(std::cmp::Reverse((OrdF64(d), u))) = heap.pop() {
                if d > dist[u] + 1e-12 {
                    continue;
                }
                for (ei, e) in self.graph[u].iter().enumerate() {
                    if e.cap <= 0 {
                        continue;
                    }
                    let nd = d + e.cost + potential[u] - potential[e.to];
                    if nd + 1e-12 < dist[e.to] {
                        dist[e.to] = nd;
                        prev[e.to] = Some((u, ei));
                        heap.push(std::cmp::Reverse((OrdF64(nd), e.to)));
                    }
                }
            }
            if dist[t].is_infinite() {
                break; // no augmenting path remains
            }
            for v in 0..n {
                if dist[v].is_finite() {
                    potential[v] += dist[v];
                }
            }
            // Find bottleneck along the path.
            let mut push = max_flow - flow;
            let mut v = t;
            while let Some((u, ei)) = prev[v] {
                push = push.min(self.graph[u][ei].cap);
                v = u;
            }
            // Apply.
            let mut v = t;
            while let Some((u, ei)) = prev[v] {
                let rev = self.graph[u][ei].rev;
                self.graph[u][ei].cap -= push;
                self.graph[v][rev].cap += push;
                cost += self.graph[u][ei].cost * push as f64;
                v = u;
            }
            flow += push;
        }
        (flow, cost)
    }
}

/// f64 ordered wrapper for the Dijkstra heap.
#[derive(PartialEq, PartialOrd)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Result of the optimal solver.
#[derive(Debug, Clone)]
pub struct McmfResult {
    /// Number of microbatch units routed.
    pub flow: usize,
    /// Sum of Eq. 1 costs over all routed units (the paper's Eq. 2 objective).
    pub total_cost: f64,
    /// Decomposed unit paths (one per microbatch).
    pub paths: Vec<FlowPath>,
}

impl McmfResult {
    pub fn avg_cost_per_microbatch(&self) -> f64 {
        if self.flow == 0 {
            0.0
        } else {
            self.total_cost / self.flow as f64
        }
    }
}

/// Node-index layout for the expanded graph of one commodity.
struct Layout {
    n_relays_offset: usize,
    n: usize,
}

impl Layout {
    /// relay r -> (in, out) vertex ids; data node/source/sink are fixed.
    fn relay_in(&self, idx: usize) -> usize {
        self.n_relays_offset + 2 * idx
    }
    fn relay_out(&self, idx: usize) -> usize {
        self.n_relays_offset + 2 * idx + 1
    }
    fn source(&self) -> usize {
        0
    }
    fn sink(&self) -> usize {
        1
    }
    fn len(&self) -> usize {
        self.n
    }
}

/// Solve one commodity (one data node's microbatches) optimally.
///
/// `blocked` nodes (crashed) are excluded.  Residual node capacities are
/// passed in `cap_left` so multi-source instances can be solved
/// sequentially per commodity.
fn solve_commodity(
    prob: &FlowProblem,
    data: NodeId,
    demand: usize,
    cap_left: &mut [usize],
) -> McmfResult {
    // Collect relays and index them.
    let relays: Vec<NodeId> = prob.graph.stages.iter().flatten().copied().collect();
    let relay_idx: std::collections::HashMap<NodeId, usize> =
        relays.iter().enumerate().map(|(i, &n)| (n, i)).collect();
    let layout = Layout { n_relays_offset: 2, n: 2 + 2 * relays.len() };
    let mut solver = Solver::new(layout.len());

    // source -> stage-0 relays
    for &r in &prob.graph.stages[0] {
        solver.add_edge(layout.source(), layout.relay_in(relay_idx[&r]), i64::MAX / 4, prob.cost(data, r));
    }
    // relay internal capacity edges
    for (i, &r) in relays.iter().enumerate() {
        solver.add_edge(layout.relay_in(i), layout.relay_out(i), cap_left[r.0] as i64, 0.0);
    }
    // stage s -> stage s+1
    for s in 0..prob.graph.n_stages() - 1 {
        for &a in &prob.graph.stages[s] {
            for &b in &prob.graph.stages[s + 1] {
                solver.add_edge(
                    layout.relay_out(relay_idx[&a]),
                    layout.relay_in(relay_idx[&b]),
                    i64::MAX / 4,
                    prob.cost(a, b),
                );
            }
        }
    }
    // last stage -> sink (cost of the return hop to the origin data node)
    let last = prob.graph.n_stages() - 1;
    for &r in &prob.graph.stages[last] {
        solver.add_edge(layout.relay_out(relay_idx[&r]), layout.sink(), i64::MAX / 4, prob.cost(r, data));
    }

    let (flow, total_cost) = solver.run(layout.source(), layout.sink(), demand as i64);

    // Decompose into unit paths by walking used edges (flow = cap of the
    // reverse edge).
    let mut used: Vec<Vec<(usize, i64)>> = vec![Vec::new(); layout.len()];
    for (u, edges) in solver.graph.iter().enumerate() {
        for e in edges {
            if e.forward || e.cap <= 0 {
                // Residual reverse edges carry cap = flow used on the
                // corresponding forward edge (e.to -> u).
                continue;
            }
            used[e.to].push((u, e.cap));
        }
    }
    let mut paths = Vec::new();
    'outer: for _ in 0..flow {
        // trace one unit from source to sink
        let mut path_nodes = Vec::new();
        let mut cur = layout.source();
        while cur != layout.sink() {
            let Some(slot) = used[cur].iter_mut().find(|(_, f)| *f > 0) else {
                break 'outer;
            };
            slot.1 -= 1;
            cur = slot.0;
            path_nodes.push(cur);
        }
        // Map in/out vertex pairs back to relays (every relay contributes
        // its in and out vertex consecutively).
        let mut relays_on_path = Vec::new();
        for v in path_nodes {
            if v >= layout.n_relays_offset && (v - layout.n_relays_offset) % 2 == 0 {
                relays_on_path.push(relays[(v - layout.n_relays_offset) / 2]);
            }
        }
        for &r in &relays_on_path {
            cap_left[r.0] -= 1;
        }
        paths.push(FlowPath { source: data, relays: relays_on_path });
    }

    McmfResult { flow: flow as usize, total_cost, paths }
}

/// Optimal (global-knowledge) min-cost flow for the whole problem.
///
/// Single data node: exact optimum.  Multiple data nodes: commodities are
/// routed sequentially in data-node order (the paper does not compare the
/// optimal baseline on multi-source tests; this is used for reporting only).
pub fn mcmf_min_cost(prob: &FlowProblem) -> McmfResult {
    let mut cap_left = prob.cap.clone();
    let mut flow = 0;
    let mut total_cost = 0.0;
    let mut paths = Vec::new();
    for (di, &d) in prob.graph.data_nodes.iter().enumerate() {
        let r = solve_commodity(prob, d, prob.demand[di], &mut cap_left);
        flow += r.flow;
        total_cost += r.total_cost;
        paths.extend(r.paths);
    }
    McmfResult { flow, total_cost, paths }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::graph::{random_problem, validate_paths, StageGraph};
    use crate::util::Rng;

    fn diamond() -> FlowProblem {
        // data 0; stage0 = {1 (cheap), 2 (pricey)}; stage1 = {3}.
        // cap: n1=1, n2=1, n3=2; demand 2 => one unit must take the pricey relay.
        let graph = std::sync::Arc::new(StageGraph {
            stages: vec![vec![NodeId(1), NodeId(2)], vec![NodeId(3)]],
            data_nodes: vec![NodeId(0)],
        });
        FlowProblem {
            graph,
            cap: vec![8, 1, 1, 2],
            demand: vec![2],
            cost: Box::new(|i, j| match (i.0, j.0) {
                (0, 1) | (1, 0) => 1.0,
                (0, 2) | (2, 0) => 5.0,
                (1, 3) | (3, 1) => 1.0,
                (2, 3) | (3, 2) => 5.0,
                (3, 0) | (0, 3) => 1.0,
                _ => 100.0,
            }),
        }
    }

    #[test]
    fn finds_exact_optimum_on_diamond() {
        let p = diamond();
        let r = mcmf_min_cost(&p);
        assert_eq!(r.flow, 2);
        // best: 0-1-3-0 = 1+1+1 = 3; second: 0-2-3-0 = 5+5+1 = 11; total 14.
        assert!((r.total_cost - 14.0).abs() < 1e-9, "{}", r.total_cost);
    }

    #[test]
    fn decomposed_paths_match_cost_and_validate() {
        let p = diamond();
        let r = mcmf_min_cost(&p);
        assert_eq!(r.paths.len(), 2);
        validate_paths(&r.paths, &p).unwrap();
        let sum: f64 = r.paths.iter().map(|pa| pa.cost(&p)).sum();
        assert!((sum - r.total_cost).abs() < 1e-9);
    }

    #[test]
    fn respects_capacity_limit() {
        let mut p = diamond();
        p.cap[3] = 1; // stage-1 bottleneck of 1
        let r = mcmf_min_cost(&p);
        assert_eq!(r.flow, 1);
        assert!((r.total_cost - 3.0).abs() < 1e-9);
    }

    #[test]
    fn zero_demand_zero_flow() {
        let mut p = diamond();
        p.demand = vec![0];
        let r = mcmf_min_cost(&p);
        assert_eq!(r.flow, 0);
        assert_eq!(r.avg_cost_per_microbatch(), 0.0);
    }

    #[test]
    fn random_instances_validate() {
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let p = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
            let r = mcmf_min_cost(&p);
            assert!(r.flow > 0);
            validate_paths(&r.paths, &p).unwrap();
            let sum: f64 = r.paths.iter().map(|pa| pa.cost(&p)).sum();
            assert!((sum - r.total_cost).abs() < 1e-6, "{} vs {}", sum, r.total_cost);
        }
    }

    #[test]
    fn optimum_beats_greedy_on_random() {
        // sanity: optimal total cost <= a naive greedy routing's cost
        let mut rng = Rng::new(123);
        let p = random_problem(1, 16, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let opt = mcmf_min_cost(&p);
        // greedy: route each unit through the cheapest next hop with capacity
        let mut cap = p.cap.clone();
        let mut greedy_cost = 0.0;
        let mut routed = 0;
        'unit: for _ in 0..p.demand[0] {
            let mut prev = p.graph.data_nodes[0];
            let mut relays = Vec::new();
            for s in 0..p.graph.n_stages() {
                let Some(&best) = p.graph.stages[s]
                    .iter()
                    .filter(|&&n| cap[n.0] > 0)
                    .min_by(|&&a, &&b| p.cost(prev, a).partial_cmp(&p.cost(prev, b)).unwrap())
                else {
                    break 'unit;
                };
                relays.push(best);
                cap[best.0] -= 1;
                prev = best;
            }
            routed += 1;
            let path = FlowPath { source: p.graph.data_nodes[0], relays };
            greedy_cost += path.cost(&p);
        }
        if routed == opt.flow {
            assert!(opt.total_cost <= greedy_cost + 1e-9);
        }
    }
}
