//! Simulated annealing acceptance rule (paper §V-C Request Redirect).
//!
//! "changes that increase cost may still be accepted with probability
//! e^{(cost_current - cost_new)/T} > U(0,1), where T is temperature,
//! reduced after each accepted change by a factor α."  The paper's
//! evaluation uses T = 1.7 and α = 0.95 (§VI Setup).

use crate::util::Rng;

/// Annealing schedule state.
#[derive(Debug, Clone)]
pub struct Annealer {
    pub temperature: f64,
    pub alpha: f64,
    /// Count of accepted uphill (cost-increasing) moves — for diagnostics.
    pub uphill_accepted: usize,
}

impl Annealer {
    /// Paper defaults: T = 1.7, α = 0.95.
    pub fn paper_default() -> Self {
        Annealer::new(1.7, 0.95)
    }

    pub fn new(temperature: f64, alpha: f64) -> Self {
        assert!(temperature > 0.0 && (0.0..=1.0).contains(&alpha));
        Annealer { temperature, alpha, uphill_accepted: 0 }
    }

    /// Disabled annealing (greedy; ablation baseline).
    pub fn greedy() -> Self {
        Annealer { temperature: 1e-12, alpha: 1.0, uphill_accepted: 0 }
    }

    /// Decide whether to accept a move from `cost_current` to `cost_new`.
    /// Improving moves are always accepted; worsening moves follow the
    /// Metropolis rule.  Cools on every accepted change (as in the paper).
    pub fn accept(&mut self, cost_current: f64, cost_new: f64, rng: &mut Rng) -> bool {
        let accepted = if cost_new <= cost_current {
            true
        } else {
            let p = ((cost_current - cost_new) / self.temperature).exp();
            let took = p > rng.f64();
            if took {
                self.uphill_accepted += 1;
            }
            took
        };
        if accepted {
            self.temperature = (self.temperature * self.alpha).max(1e-12);
        }
        accepted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_accepts_improvement() {
        let mut a = Annealer::paper_default();
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            assert!(a.accept(10.0, 5.0, &mut rng));
        }
    }

    #[test]
    fn uphill_probability_shrinks_with_gap() {
        let mut rng = Rng::new(1);
        let trials = 20_000;
        let mut acc_small = 0;
        let mut acc_big = 0;
        for _ in 0..trials {
            let mut a = Annealer::new(1.7, 1.0); // no cooling for a clean estimate
            if a.accept(1.0, 1.5, &mut rng) {
                acc_small += 1;
            }
            let mut a = Annealer::new(1.7, 1.0);
            if a.accept(1.0, 6.0, &mut rng) {
                acc_big += 1;
            }
        }
        let p_small = acc_small as f64 / trials as f64;
        let p_big = acc_big as f64 / trials as f64;
        // theory: e^{-0.5/1.7} ≈ 0.745, e^{-5/1.7} ≈ 0.053
        assert!((p_small - 0.745).abs() < 0.02, "{p_small}");
        assert!((p_big - 0.053).abs() < 0.02, "{p_big}");
        assert!(p_small > p_big);
    }

    #[test]
    fn cools_on_accept() {
        let mut a = Annealer::new(2.0, 0.5);
        let mut rng = Rng::new(2);
        a.accept(10.0, 1.0, &mut rng);
        assert!((a.temperature - 1.0).abs() < 1e-12);
        a.accept(10.0, 1.0, &mut rng);
        assert!((a.temperature - 0.5).abs() < 1e-12);
    }

    #[test]
    fn greedy_never_takes_uphill() {
        let mut a = Annealer::greedy();
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert!(!a.accept(1.0, 1.0001, &mut rng));
        }
        assert_eq!(a.uphill_accepted, 0);
    }
}
