//! Flow optimization — the paper's core contribution.
//!
//! GWTF models the routing of microbatches through pipeline stages as a
//! minimum-cost flow problem over a staged graph whose sources and sinks
//! are both the data nodes (a microbatch travels from its data node through
//! every stage and back for loss computation, §V-A).
//!
//! - [`graph`] — the staged flow network shared by all algorithms.
//! - [`mcmf`]  — exact minimum-cost maximum-flow (successive shortest
//!   paths with potentials; optimal, requires global knowledge — the
//!   paper's out-of-kilter baseline [Fulkerson 1961]).
//! - [`decentralized`] — GWTF's novel local-knowledge algorithm built on
//!   Request Flow / Request Change / Request Redirect with simulated
//!   annealing (§V-C).
//! - [`annealing`] — the temperature schedule (T, α from §VI Setup).

pub mod annealing;
pub mod decentralized;
pub mod graph;
pub mod mcmf;

pub use annealing::Annealer;
pub use decentralized::{DecentralizedFlow, FlowParams, RoundStats};
pub use graph::{FlowProblem, StageGraph};
pub use mcmf::{mcmf_min_cost, McmfResult};
