//! The staged flow network shared by GWTF, SWARM and the optimal baseline.
//!
//! A `FlowProblem` is: data nodes (each a source *and* the sink of its own
//! microbatches), `n_stages` relay stages, per-node capacities (`cap_i`,
//! max concurrent microbatches) and a pairwise cost function following
//! Eq. 1.  Costs may come from a simulated [`crate::net::Topology`] or
//! from the abstract `U(..)`-sampled settings of Tables IV/V.

use std::sync::Arc;

use crate::cost::NodeId;
use crate::util::Rng;

/// Staged graph: which node sits in which stage.
#[derive(Debug, Clone)]
pub struct StageGraph {
    /// Relay stages in pipeline order; `stages[s]` lists the member nodes.
    pub stages: Vec<Vec<NodeId>>,
    /// Data nodes (sources + sinks).
    pub data_nodes: Vec<NodeId>,
}

impl StageGraph {
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Stage index of a relay node (None for data nodes / unknown).
    pub fn stage_of(&self, n: NodeId) -> Option<usize> {
        self.stages.iter().position(|s| s.contains(&n))
    }

    pub fn is_data_node(&self, n: NodeId) -> bool {
        self.data_nodes.contains(&n)
    }

    /// All nodes (data + relay).
    pub fn all_nodes(&self) -> Vec<NodeId> {
        let mut v = self.data_nodes.clone();
        for s in &self.stages {
            v.extend_from_slice(s);
        }
        v
    }
}

/// A complete flow-routing problem instance.
///
/// The stage graph is shared behind an [`Arc`]: routers rebuild a
/// `FlowProblem` with fresh capacities on every (re)plan, and the graph —
/// the one immutable, potentially large piece — must not be deep-cloned
/// on that hot path (the scale bench plans over 200 relays every
/// iteration).
pub struct FlowProblem {
    pub graph: Arc<StageGraph>,
    /// `cap[node.0]` = node capacity in concurrent microbatches.
    pub cap: Vec<usize>,
    /// Microbatches each data node pushes per iteration.
    pub demand: Vec<usize>,
    /// Eq. 1 edge cost between two adjacent-stage nodes.  Congestion-aware
    /// scenarios route this through
    /// [`crate::net::Topology::congestion_cost`], which adds the expected
    /// NIC-queueing term derived from the same shared-capacity substrate
    /// parameters ([`crate::cost::NicConfig`]) the simulator executes;
    /// under unlimited NICs that variant is plain Eq. 1 bit for bit.
    pub cost: Box<dyn Fn(NodeId, NodeId) -> f64 + Send + Sync>,
}

impl std::fmt::Debug for FlowProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlowProblem")
            .field("graph", &self.graph)
            .field("cap", &self.cap)
            .field("demand", &self.demand)
            .finish()
    }
}

impl FlowProblem {
    pub fn cost(&self, i: NodeId, j: NodeId) -> f64 {
        (self.cost)(i, j)
    }

    pub fn capacity(&self, n: NodeId) -> usize {
        self.cap[n.0]
    }

    /// Total capacity of a stage (the paper's stage-throughput bound).
    pub fn stage_capacity(&self, s: usize) -> usize {
        self.graph.stages[s].iter().map(|n| self.cap[n.0]).sum()
    }

    /// Index of the bottleneck stage (minimum total capacity).
    pub fn bottleneck_stage(&self) -> usize {
        (0..self.graph.n_stages())
            .min_by_key(|&s| self.stage_capacity(s))
            .expect("no stages")
    }

    /// Max microbatches an iteration can theoretically route.
    pub fn max_throughput(&self) -> usize {
        let stage_min = (0..self.graph.n_stages())
            .map(|s| self.stage_capacity(s))
            .min()
            .unwrap_or(0);
        let demand: usize = self.demand.iter().sum();
        stage_min.min(demand)
    }
}

/// A routed flow: one microbatch path `data -> stage0 -> .. -> stageS-1 -> data`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlowPath {
    /// Originating (and terminating) data node.
    pub source: NodeId,
    /// One relay per stage, in order.
    pub relays: Vec<NodeId>,
}

impl FlowPath {
    /// Total Eq. 1 cost of this path in `prob` (including the return hop).
    pub fn cost(&self, prob: &FlowProblem) -> f64 {
        let mut c = 0.0;
        let mut prev = self.source;
        for &r in &self.relays {
            c += prob.cost(prev, r);
            prev = r;
        }
        c + prob.cost(prev, self.source)
    }

    /// Maximum single-edge cost along the path (the min-max objective).
    pub fn max_edge_cost(&self, prob: &FlowProblem) -> f64 {
        max_edge_cost_over(prob, self.source, &self.relays)
    }
}

/// [`FlowPath::max_edge_cost`] over a borrowed relay slice — lets the
/// planner score an established chain without materializing a `FlowPath`.
pub fn max_edge_cost_over(prob: &FlowProblem, source: NodeId, relays: &[NodeId]) -> f64 {
    let mut m: f64 = 0.0;
    let mut prev = source;
    for &r in relays {
        m = m.max(prob.cost(prev, r));
        prev = r;
    }
    m.max(prob.cost(prev, source))
}

/// Check a set of paths respects stage structure and node capacities.
pub fn validate_paths(paths: &[FlowPath], prob: &FlowProblem) -> Result<(), String> {
    let n_stages = prob.graph.n_stages();
    let mut usage = vec![0usize; prob.cap.len()];
    let mut per_source = std::collections::BTreeMap::new();
    for p in paths {
        if p.relays.len() != n_stages {
            return Err(format!("path has {} relays, expected {n_stages}", p.relays.len()));
        }
        if !prob.graph.is_data_node(p.source) {
            return Err(format!("source {} is not a data node", p.source));
        }
        for (s, &r) in p.relays.iter().enumerate() {
            if !prob.graph.stages[s].contains(&r) {
                return Err(format!("relay {} not in stage {s}", r));
            }
            usage[r.0] += 1;
        }
        *per_source.entry(p.source).or_insert(0usize) += 1;
    }
    for (i, &u) in usage.iter().enumerate() {
        if u > prob.cap[i] {
            return Err(format!("node n{i} over capacity: {u} > {}", prob.cap[i]));
        }
    }
    for (&src, &cnt) in &per_source {
        let di = prob.graph.data_nodes.iter().position(|&d| d == src).unwrap();
        if cnt > prob.demand[di] {
            return Err(format!("data node {src} routed {cnt} > demand {}", prob.demand[di]));
        }
    }
    Ok(())
}

/// Build an abstract problem from the Table IV/V experiment settings:
/// random capacities and link costs, `sources` data nodes, `relays` relay
/// nodes split evenly over `stages` stages.
pub fn random_problem(
    sources: usize,
    relays: usize,
    stages: usize,
    cap_range: (f64, f64),
    cost_range: (f64, f64),
    rng: &mut Rng,
) -> FlowProblem {
    let n = sources + relays;
    let data_nodes: Vec<NodeId> = (0..sources).map(NodeId).collect();
    let per_stage = relays / stages;
    assert!(per_stage > 0, "need at least one relay per stage");
    let mut stage_vec = Vec::with_capacity(stages);
    let mut next = sources;
    for s in 0..stages {
        let extra = if s < relays % stages { 1 } else { 0 };
        let members: Vec<NodeId> = (0..per_stage + extra).map(|_| {
            let id = NodeId(next);
            next += 1;
            id
        }).collect();
        stage_vec.push(members);
    }
    let mut cap = vec![0usize; n];
    for c in cap.iter_mut().take(n) {
        *c = rng.uniform(cap_range.0, cap_range.1).floor().max(1.0) as usize;
    }
    // Data nodes get ample capacity ("source-sinks were given sufficient
    // capacity to prevent bottlenecks", §VI Ablation).
    let mut demand = vec![0usize; sources];
    for d in 0..sources {
        cap[d] = relays; // effectively unbounded
        demand[d] = 4;
    }
    // Dense random cost matrix (floor(U(lo,hi)) as in Table V).
    let mut costs = vec![vec![0.0f64; n]; n];
    for (i, row) in costs.iter_mut().enumerate() {
        for (j, c) in row.iter_mut().enumerate() {
            if i != j {
                *c = rng.uniform(cost_range.0, cost_range.1).floor().max(1.0);
            }
        }
    }
    FlowProblem {
        graph: Arc::new(StageGraph { stages: stage_vec, data_nodes }),
        cap,
        demand,
        cost: Box::new(move |i, j| costs[i.0][j.0]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> FlowProblem {
        // 1 data node, 2 stages x 2 relays, unit demand 2.
        let graph = Arc::new(StageGraph {
            stages: vec![vec![NodeId(1), NodeId(2)], vec![NodeId(3), NodeId(4)]],
            data_nodes: vec![NodeId(0)],
        });
        FlowProblem {
            graph,
            cap: vec![4, 1, 1, 1, 1],
            demand: vec![2],
            cost: Box::new(|i, j| (1 + (i.0 * 7 + j.0 * 13) % 5) as f64),
        }
    }

    #[test]
    fn stage_lookup() {
        let p = tiny();
        assert_eq!(p.graph.stage_of(NodeId(3)), Some(1));
        assert_eq!(p.graph.stage_of(NodeId(0)), None);
        assert!(p.graph.is_data_node(NodeId(0)));
    }

    #[test]
    fn stage_capacity_and_bottleneck() {
        let p = tiny();
        assert_eq!(p.stage_capacity(0), 2);
        assert_eq!(p.max_throughput(), 2);
    }

    #[test]
    fn path_cost_includes_return() {
        let p = tiny();
        let path = FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3)] };
        let expect = p.cost(NodeId(0), NodeId(1)) + p.cost(NodeId(1), NodeId(3)) + p.cost(NodeId(3), NodeId(0));
        assert!((path.cost(&p) - expect).abs() < 1e-12);
        assert!(path.max_edge_cost(&p) <= path.cost(&p));
    }

    #[test]
    fn validate_catches_capacity_violation() {
        let p = tiny();
        let path = FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3)] };
        assert!(validate_paths(&[path.clone()], &p).is_ok());
        assert!(validate_paths(&[path.clone(), path.clone()], &p).is_err());
    }

    #[test]
    fn validate_catches_wrong_stage() {
        let p = tiny();
        let bad = FlowPath { source: NodeId(0), relays: vec![NodeId(3), NodeId(1)] };
        assert!(validate_paths(&[bad], &p).is_err());
    }

    #[test]
    fn random_problem_shape() {
        let mut rng = Rng::new(0);
        let p = random_problem(2, 40, 8, (1.0, 3.0), (1.0, 20.0), &mut rng);
        assert_eq!(p.graph.data_nodes.len(), 2);
        assert_eq!(p.graph.n_stages(), 8);
        let total: usize = p.graph.stages.iter().map(|s| s.len()).sum();
        assert_eq!(total, 40);
        for s in &p.graph.stages {
            for &n in s {
                assert!((1..=3).contains(&p.cap[n.0]));
            }
        }
    }
}
