//! Continuous-time scenarios beyond the paper's tables.
//!
//! Both experiments exercise event kinds the old iteration-synchronous
//! simulator could not express (see `sim::engine`):
//!
//! - [`run_mid_agg_crash`] — a relay dies *inside* the §V-E aggregation
//!   barrier; its stage re-runs the invalidated fraction of the weight
//!   exchange among the survivors.  Columns compare a crash-free run,
//!   a mid-aggregation crash, and the same crash under warm re-planning.
//! - [`run_link_jitter`] — piecewise-constant link-latency jitter windows
//!   layered over the Table II topology; columns sweep the jitter
//!   amplitude.

use anyhow::Result;

use crate::coordinator::GwtfRouter;
use crate::flow::FlowParams;
use crate::metrics::MetricsTable;
use crate::sim::scenario::{build, ScenarioConfig};
use crate::sim::sources::{LinkJitterSource, MidAggCrashSource};

/// Options shared by the continuous-time scenario experiments.
#[derive(Debug, Clone)]
pub struct ScenarioOpts {
    pub reps: usize,
    pub iters_per_rep: usize,
    pub seed: u64,
}

impl Default for ScenarioOpts {
    fn default() -> Self {
        ScenarioOpts { reps: 10, iters_per_rep: 4, seed: 1 }
    }
}

/// Mid-aggregation crash: at iteration 1 a last-stage relay dies halfway
/// through the aggregation barrier.
pub fn run_mid_agg_crash(opts: &ScenarioOpts) -> Result<MetricsTable> {
    let mut table = MetricsTable::new(
        "Mid-aggregation crash — §V-E barrier recovery (continuous-time only)",
    );
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 7919;
        let cfg = ScenarioConfig::table2(true, 0.0, seed);
        let sc = build(&cfg);
        let last_stage = sc.prob.graph.n_stages() - 1;
        let victim = sc.prob.graph.stages[last_stage][0];

        // baseline: same scenario, no crash
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            let cell = table.cell("table2 homogeneous", "no-crash");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
        // the crash, cold re-planning every iteration
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            engine.add_source(Box::new(MidAggCrashSource::new(1, victim, 0.5)));
            let cell = table.cell("table2 homogeneous", "midagg-crash");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
        // the crash, warm-start re-planning (GWTF keeps surviving chains)
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            engine.warm_replan = true;
            engine.add_source(Box::new(MidAggCrashSource::new(1, victim, 0.5)));
            let cell = table.cell("table2 homogeneous", "midagg-crash-warm");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
    }
    Ok(table)
}

/// Link-latency jitter sweep: 0% / 25% / 50% amplitude, fresh multiplier
/// every 30 virtual seconds.
pub fn run_link_jitter(opts: &ScenarioOpts) -> Result<MetricsTable> {
    let mut table =
        MetricsTable::new("Link-latency jitter — time-varying links (continuous-time only)");
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 6007;
        let cfg = ScenarioConfig::table2(true, 0.0, seed);
        let sc = build(&cfg);
        for &(label, amp) in
            &[("jitter 0%", 0.0), ("jitter 25%", 0.25), ("jitter 50%", 0.5)]
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            if amp > 0.0 {
                engine.add_source(Box::new(LinkJitterSource::new(amp, 30.0, seed ^ 0x11)));
            }
            let cell = table.cell(label, "gwtf");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> ScenarioOpts {
        ScenarioOpts { reps: 2, iters_per_rep: 3, seed: 7 }
    }

    #[test]
    fn mid_agg_crash_produces_all_columns() {
        let t = run_mid_agg_crash(&fast()).unwrap();
        let row = "table2 homogeneous".to_string();
        for col in ["no-crash", "midagg-crash", "midagg-crash-warm"] {
            let acc = &t.cells[&(row.clone(), col.to_string())];
            assert_eq!(acc.throughput.len(), 2 * 3, "{col}");
        }
        // the crash columns must record exactly one barrier recovery per rep
        let crash = &t.cells[&(row.clone(), "midagg-crash".to_string())];
        assert_eq!(crash.agg_recoveries.iter().sum::<f64>(), 2.0);
        let clean = &t.cells[&(row, "no-crash".to_string())];
        assert_eq!(clean.agg_recoveries.iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn jitter_sweep_produces_all_amplitudes() {
        let t = run_link_jitter(&fast()).unwrap();
        for row in ["jitter 0%", "jitter 25%", "jitter 50%"] {
            let acc = &t.cells[&(row.to_string(), "gwtf".to_string())];
            assert_eq!(acc.throughput.len(), 2 * 3, "{row}");
            assert!(acc.makespan_min.iter().all(|m| m.is_finite()));
        }
    }
}
