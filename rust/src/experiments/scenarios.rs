//! Continuous-time scenarios beyond the paper's tables.
//!
//! These experiments exercise event kinds the old iteration-synchronous
//! simulator could not express (see `sim::engine`):
//!
//! - [`run_mid_agg_crash`] — a relay dies *inside* the §V-E aggregation
//!   barrier; its stage re-runs the invalidated fraction of the weight
//!   exchange among the survivors.  Columns compare a crash-free run,
//!   a mid-aggregation crash, and the same crash under warm re-planning.
//! - [`run_link_jitter`] — piecewise-constant link-latency jitter windows
//!   layered over the Table II topology; columns sweep the jitter
//!   amplitude.
//! - [`run_poisson_churn`] — the §VI churn grid re-run under the
//!   continuous-clock Poisson churn model (`sim::churn`): crash/rejoin
//!   arrivals land mid-iteration from exponential clocks instead of
//!   synchronized Bernoulli flips.  GWTF runs with warm re-planning, so
//!   every arbitrary-timestamp crash exercises `RoutingPolicy::on_crash`
//!   mid-pipeline and the next iteration's warm re-plan repair; SWARM
//!   and DT-FM are the baselines.
//! - [`run_scale`] — Table II's shape at 100/200 relays under 20%
//!   Poisson churn with the gossip overlay attached (GWTF plans over
//!   bounded neighbor views, O(chains·k) per round) vs SWARM and DT-FM.
//!   Besides the usual metrics table it measures planner wall time and
//!   protocol rounds per (re)plan; `gwtf bench scale` and the
//!   `rust/tests/scale_guard.rs` regression gate write those numbers to
//!   `BENCH_scale.json` at the repo root.
//! - [`run_plan_lag`] — the plan lifecycle on the clock
//!   (`gwtf bench planlag`): sweep the flow protocol's per-round RTT
//!   against the iteration length with GWTF warm re-plans under the
//!   [`crate::sim::engine::PlanLifecycle::RoundLatency`] lifecycle.
//!   While `rounds x RTT` fits inside an iteration the overlap hides
//!   planning entirely (the §V-C claim); past that point every iteration
//!   pays a growing stall — makespan grows monotonically with the RTT.
//!   Results land in `BENCH_planlag.json` (`test_sized` profile via
//!   `rust/tests/plan_lag.rs`, `full` via the CLI bench).
//! - [`run_congestion`] — the shared-capacity network substrate
//!   (`gwtf bench congestion`): a bandwidth-starved WAN with a fan-in
//!   hub per stage (`ScenarioConfig::congestion`), swept over the NIC
//!   transmission-concurrency cap.  Columns compare capacity-oblivious
//!   GWTF, congestion-aware GWTF (Eq. 1 + expected NIC queueing), SWARM
//!   and DT-FM.  Makespan must grow monotonically as the NIC cap
//!   shrinks, and at tight caps congestion-aware routing must beat
//!   SWARM's nearest-peer funnel — both gated by
//!   `rust/tests/congestion_guard.rs` over the `test_sized` profile of
//!   `BENCH_congestion.json` (`full` via the CLI bench).
//! - [`run_adversary`] — adversarial relays (`gwtf bench adversary`):
//!   the Table II shape with a fraction f of relays running Byzantine
//!   service policies ([`crate::sim::adversary`]: free-riders, DENY
//!   storms, deliberate stragglers, eclipse liars), swept over
//!   f ∈ {0, 10%, 25%}.  Columns compare reputation-oblivious GWTF,
//!   reputation-aware GWTF ([`crate::net::reputation`] feeding the Eq. 1
//!   penalty) and SWARM.  The reputation-aware arm must retain goodput
//!   under attack where the oblivious arm bleeds it — gated by
//!   `rust/tests/adversary_guard.rs` over the `test_sized` profile of
//!   `BENCH_adversary.json` (`full` via the CLI bench).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::baselines::GaParams;
use crate::coordinator::GwtfRouter;
use crate::cost::NodeId;
use crate::flow::FlowParams;
use crate::metrics::MetricsTable;
use crate::sim::scenario::{build, ScenarioConfig, DEFAULT_OVERLAY_FANOUT};
use crate::sim::sources::{LinkJitterSource, MidAggCrashSource};
use crate::sim::training::{
    IterationMetrics, PlanOutcome, PlanRequest, PlanTicket, RecoveryPolicy, RoutingPolicy,
};
use crate::sim::ChurnModel;
use crate::util::json::Json;

use super::tables::{dtfm_router, swarm_router};

/// Options shared by the continuous-time scenario experiments.
#[derive(Debug, Clone)]
pub struct ScenarioOpts {
    pub reps: usize,
    pub iters_per_rep: usize,
    pub seed: u64,
}

impl Default for ScenarioOpts {
    fn default() -> Self {
        ScenarioOpts { reps: 10, iters_per_rep: 4, seed: 1 }
    }
}

/// Mid-aggregation crash: at iteration 1 a last-stage relay dies halfway
/// through the aggregation barrier.
pub fn run_mid_agg_crash(opts: &ScenarioOpts) -> Result<MetricsTable> {
    let mut table = MetricsTable::new(
        "Mid-aggregation crash — §V-E barrier recovery (continuous-time only)",
    );
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 7919;
        let cfg = ScenarioConfig::table2(true, 0.0, seed);
        let sc = build(&cfg);
        let last_stage = sc.prob.graph.n_stages() - 1;
        let victim = sc.prob.graph.stages[last_stage][0];

        // baseline: same scenario, no crash
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            let cell = table.cell("table2 homogeneous", "no-crash");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
        // the crash, cold re-planning every iteration
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            engine.add_source(Box::new(MidAggCrashSource::new(1, victim, 0.5)));
            let cell = table.cell("table2 homogeneous", "midagg-crash");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
        // the crash, warm-start re-planning (GWTF keeps surviving chains)
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            engine.warm_replan = true;
            engine.add_source(Box::new(MidAggCrashSource::new(1, victim, 0.5)));
            let cell = table.cell("table2 homogeneous", "midagg-crash-warm");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
    }
    Ok(table)
}

/// Link-latency jitter sweep: 0% / 25% / 50% amplitude, fresh multiplier
/// every 30 virtual seconds.
pub fn run_link_jitter(opts: &ScenarioOpts) -> Result<MetricsTable> {
    let mut table =
        MetricsTable::new("Link-latency jitter — time-varying links (continuous-time only)");
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 6007;
        let cfg = ScenarioConfig::table2(true, 0.0, seed);
        let sc = build(&cfg);
        for &(label, amp) in
            &[("jitter 0%", 0.0), ("jitter 25%", 0.25), ("jitter 50%", 0.5)]
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            if amp > 0.0 {
                engine.add_source(Box::new(LinkJitterSource::new(amp, 30.0, seed ^ 0x11)));
            }
            let cell = table.cell(label, "gwtf");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
    }
    Ok(table)
}

/// Continuous-clock Poisson churn: the paper's 10%/20% join-leave grid
/// with crash/rejoin arrivals sampled from rate-equivalent exponential
/// clocks, GWTF (warm re-planning) vs SWARM vs DT-FM.
pub fn run_poisson_churn(opts: &ScenarioOpts) -> Result<MetricsTable> {
    let mut table = MetricsTable::new(
        "Poisson churn — continuous-clock crash/rejoin arrivals (rate-equivalent to §VI churn)",
    );
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 104651;
        for &(row, p) in &[("poisson 10%", 0.1), ("poisson 20%", 0.2)] {
            let mut cfg = ScenarioConfig::table2(true, p, seed);
            cfg.churn_model = ChurnModel::Poisson;
            let sc = build(&cfg);
            // GWTF with warm re-plans: crashes at arbitrary timestamps hit
            // RoutingPolicy::on_crash mid-pipeline; the next iteration's
            // warm replan resumes the surviving chains around them.
            {
                let mut router =
                    GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
                let mut engine = sc.engine(seed ^ 0x1);
                engine.warm_replan = true;
                let cell = table.cell(row, "gwtf");
                for _ in 0..opts.iters_per_rep {
                    cell.push(&engine.step(&sc.prob, &mut router));
                }
            }
            // SWARM: comm-only greedy wiring, full-pipeline restarts.
            {
                let mut router = swarm_router(&sc, seed ^ 0xB);
                let mut engine = sc.engine(seed ^ 0x1);
                let cell = table.cell(row, "swarm");
                for _ in 0..opts.iters_per_rep {
                    cell.push(&engine.step(&sc.prob, &mut router));
                }
            }
            // DT-FM: static GA arrangement, recomputed from scratch when a
            // pipeline node dies (its plan cache sees the churned
            // membership each iteration).
            {
                let mut router = dtfm_router(
                    &sc,
                    GaParams { generations: 60, ..Default::default() },
                    seed ^ 0xC,
                );
                let mut engine = sc.engine(seed ^ 0x1);
                let cell = table.cell(row, "dtfm");
                for _ in 0..opts.iters_per_rep {
                    cell.push(&engine.step(&sc.prob, &mut router));
                }
            }
        }
    }
    Ok(table)
}

/// Options for the 100+ relay scale scenario.
#[derive(Debug, Clone)]
pub struct ScaleOpts {
    /// Relay counts to sweep (the paper's Table II stops at 16).
    pub sizes: Vec<usize>,
    /// Relay counts measured with GWTF only — the 1000-relay raw-speed
    /// gate.  The baselines' global O(n²) scans would dominate the sweep's
    /// wall time there without informing the gate (which compares GWTF
    /// against its own committed baseline, not against SWARM/DT-FM).
    pub gwtf_only_sizes: Vec<usize>,
    pub reps: usize,
    pub iters_per_rep: usize,
    pub seed: u64,
    /// Poisson join-leave hazard per relay-iteration.
    pub churn_p: f64,
    /// GA budget for the DT-FM baseline (its cost is what the paper
    /// criticizes; keep it affordable at 200 relays).
    pub dtfm_generations: usize,
    /// Worker threads for GWTF's candidate evaluation
    /// ([`FlowParams::threads`]); plans are bit-identical at any value.
    pub planner_threads: usize,
}

impl Default for ScaleOpts {
    fn default() -> Self {
        ScaleOpts {
            sizes: vec![100, 200],
            gwtf_only_sizes: vec![1000],
            reps: 3,
            iters_per_rep: 4,
            seed: 1,
            churn_p: 0.2,
            dtfm_generations: 30,
            planner_threads: 1,
        }
    }
}

/// Aggregate critical-path attribution for one sweep profile: every
/// measured iteration's [`crate::sim::CritPath`] buckets summed, plus
/// the summed makespans they attribute.  Serialized as the `crit_path`
/// block of every `BENCH_*.json` profile, so each committed baseline
/// records not just *how fast* the sweep ran but *where its virtual
/// time went*.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CritProfile {
    pub compute_s: f64,
    pub tx_s: f64,
    pub prop_s: f64,
    pub queue_s: f64,
    pub plan_s: f64,
    pub agg_s: f64,
    pub stale_s: f64,
    /// Sum of the attributed makespans (the buckets above sum to this
    /// within float rounding).
    pub makespan_s: f64,
}

impl CritProfile {
    /// Fold one measured iteration into the profile.
    pub fn add(&mut self, m: &IterationMetrics) {
        self.compute_s += m.crit_path.compute_s;
        self.tx_s += m.crit_path.tx_s;
        self.prop_s += m.crit_path.prop_s;
        self.queue_s += m.crit_path.queue_s;
        self.plan_s += m.crit_path.plan_s;
        self.agg_s += m.crit_path.agg_s;
        self.stale_s += m.crit_path.stale_s;
        self.makespan_s += m.makespan_s;
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("compute_s".into(), Json::Num(self.compute_s));
        o.insert("tx_s".into(), Json::Num(self.tx_s));
        o.insert("prop_s".into(), Json::Num(self.prop_s));
        o.insert("queue_s".into(), Json::Num(self.queue_s));
        o.insert("plan_s".into(), Json::Num(self.plan_s));
        o.insert("agg_s".into(), Json::Num(self.agg_s));
        o.insert("stale_s".into(), Json::Num(self.stale_s));
        o.insert("makespan_s".into(), Json::Num(self.makespan_s));
        Json::Obj(o)
    }

    /// Lenient: a report without a `crit_path` block (pre-attribution
    /// committed baselines) parses as all-zero rather than failing.
    pub fn from_json(j: Option<&Json>) -> CritProfile {
        let Some(j) = j else { return CritProfile::default() };
        let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        CritProfile {
            compute_s: num("compute_s"),
            tx_s: num("tx_s"),
            prop_s: num("prop_s"),
            queue_s: num("queue_s"),
            plan_s: num("plan_s"),
            agg_s: num("agg_s"),
            stale_s: num("stale_s"),
            makespan_s: num("makespan_s"),
        }
    }
}

/// Planner-cost instrumentation for one (relay count, system) cell of the
/// scale sweep, summed over reps and iterations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScaleCase {
    pub relays: usize,
    pub system: String,
    /// Planning sessions measured (`RoutingPolicy::request_plan` calls).
    pub plan_calls: usize,
    /// Protocol rounds across all (re)plans (deterministic per seed —
    /// the quantity the CI regression gate compares).
    pub plan_rounds_total: usize,
    /// Rounds of each rep's first, cold plan (convergence rounds),
    /// summed over reps.
    pub cold_rounds: usize,
    /// Wall-clock spent inside (re)plans, milliseconds (machine-
    /// dependent; informational).
    pub plan_wall_ms: f64,
    /// Microbatches completed across all measured iterations.
    pub throughput_total: f64,
    /// Kernel events dispatched across all measured iterations
    /// (deterministic per seed — a second quantity the gate can compare).
    pub events_total: usize,
    /// Wall-clock spent inside `Engine::step` across all measured
    /// iterations, milliseconds, planning included (machine-dependent;
    /// informational — the events/sec numerator's denominator).
    pub engine_wall_ms: f64,
    /// Peak resident set of the whole bench process after this case's
    /// iterations, MiB ([`crate::util::mem::peak_rss_mib`]; machine-
    /// dependent and monotone across the sweep — informational, never
    /// gated; 0 where the platform hides `/proc`).
    pub peak_rss_mib: f64,
    /// Resident `LinkParams` entries in the case's topology: n² on the
    /// Dense arm, regions² on the Procedural arm — the O(regions²)
    /// acceptance telemetry for the sparse substrate.
    pub resident_link_entries: usize,
    /// Resident congestion-cache entries at the end of the case's
    /// iterations: whole-matrix (2·n²) on the dense arm, touched edges
    /// only on the sparse arm; 0 when the scenario plans without the
    /// cache.
    pub resident_cache_entries: usize,
}

impl ScaleCase {
    /// Engine event throughput over the measured iterations
    /// (machine-dependent; informational).
    pub fn events_per_sec(&self) -> f64 {
        if self.engine_wall_ms > 0.0 {
            self.events_total as f64 / (self.engine_wall_ms / 1e3)
        } else {
            0.0
        }
    }
}

/// The `BENCH_scale.json` payload for one profile (test-sized or full).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScaleReport {
    pub fanout: usize,
    pub churn_p: f64,
    pub reps: usize,
    pub iters_per_rep: usize,
    /// Planner candidate-evaluation threads the sweep ran with
    /// (informational — plans are thread-count invariant).
    pub planner_threads: usize,
    pub cases: Vec<ScaleCase>,
    /// Where the sweep's virtual time went ([`CritProfile`]).
    pub crit_path: CritProfile,
    /// Peak resident set of the bench process when the sweep finished,
    /// MiB (machine-dependent; informational, never gated; 0 where the
    /// platform hides `/proc` — see [`crate::util::mem::peak_rss_mib`]).
    pub peak_rss_mib: f64,
}

impl ScaleReport {
    pub fn case(&self, relays: usize, system: &str) -> Option<&ScaleCase> {
        self.cases.iter().find(|c| c.relays == relays && c.system == system)
    }

    pub fn to_json(&self) -> Json {
        let case_json = |c: &ScaleCase| {
            let mut o = BTreeMap::new();
            o.insert("relays".into(), Json::Num(c.relays as f64));
            o.insert("system".into(), Json::Str(c.system.clone()));
            o.insert("plan_calls".into(), Json::Num(c.plan_calls as f64));
            o.insert("plan_rounds_total".into(), Json::Num(c.plan_rounds_total as f64));
            o.insert("cold_rounds".into(), Json::Num(c.cold_rounds as f64));
            o.insert("plan_wall_ms".into(), Json::Num((c.plan_wall_ms * 1e3).round() / 1e3));
            o.insert("throughput_total".into(), Json::Num(c.throughput_total));
            o.insert("events_total".into(), Json::Num(c.events_total as f64));
            o.insert("engine_wall_ms".into(), Json::Num((c.engine_wall_ms * 1e3).round() / 1e3));
            o.insert(
                "events_per_sec".into(),
                Json::Num(c.events_per_sec().round()), // derived; not parsed back
            );
            o.insert("peak_rss_mib".into(), Json::Num((c.peak_rss_mib * 1e3).round() / 1e3));
            o.insert(
                "resident_link_entries".into(),
                Json::Num(c.resident_link_entries as f64),
            );
            o.insert(
                "resident_cache_entries".into(),
                Json::Num(c.resident_cache_entries as f64),
            );
            Json::Obj(o)
        };
        let mut root = BTreeMap::new();
        root.insert("fanout".into(), Json::Num(self.fanout as f64));
        root.insert("churn_p".into(), Json::Num(self.churn_p));
        root.insert("reps".into(), Json::Num(self.reps as f64));
        root.insert("iters_per_rep".into(), Json::Num(self.iters_per_rep as f64));
        root.insert("planner_threads".into(), Json::Num(self.planner_threads as f64));
        root.insert("cases".into(), Json::Arr(self.cases.iter().map(case_json).collect()));
        root.insert("crit_path".into(), self.crit_path.to_json());
        root.insert("peak_rss_mib".into(), Json::Num((self.peak_rss_mib * 1e3).round() / 1e3));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Option<ScaleReport> {
        let num = |o: &Json, k: &str| o.get(k).and_then(Json::as_f64);
        let cases = match j.get("cases")? {
            Json::Arr(v) => v
                .iter()
                .map(|c| {
                    Some(ScaleCase {
                        relays: num(c, "relays")? as usize,
                        system: c.get("system")?.as_str()?.to_string(),
                        plan_calls: num(c, "plan_calls")? as usize,
                        plan_rounds_total: num(c, "plan_rounds_total")? as usize,
                        cold_rounds: num(c, "cold_rounds")? as usize,
                        plan_wall_ms: num(c, "plan_wall_ms")?,
                        throughput_total: num(c, "throughput_total")?,
                        // Leniently absent in pre-raw-speed baselines: a
                        // committed report without engine columns still
                        // parses (the gate treats 0 as "no baseline").
                        events_total: num(c, "events_total").unwrap_or(0.0) as usize,
                        engine_wall_ms: num(c, "engine_wall_ms").unwrap_or(0.0),
                        // Leniently absent in pre-sparse-substrate
                        // baselines (same rationale).
                        peak_rss_mib: num(c, "peak_rss_mib").unwrap_or(0.0),
                        resident_link_entries: num(c, "resident_link_entries")
                            .unwrap_or(0.0) as usize,
                        resident_cache_entries: num(c, "resident_cache_entries")
                            .unwrap_or(0.0) as usize,
                    })
                })
                .collect::<Option<Vec<_>>>()?,
            _ => return None,
        };
        Some(ScaleReport {
            fanout: num(j, "fanout")? as usize,
            churn_p: num(j, "churn_p")?,
            reps: num(j, "reps")? as usize,
            iters_per_rep: num(j, "iters_per_rep")? as usize,
            planner_threads: num(j, "planner_threads").map_or(1, |t| t as usize),
            cases,
            crit_path: CritProfile::from_json(j.get("crit_path")),
            // Leniently absent in pre-sparse-substrate baselines.
            peak_rss_mib: num(j, "peak_rss_mib").unwrap_or(0.0),
        })
    }
}

/// Canonical location of `BENCH_scale.json`, shared by the CLI bench,
/// `cargo bench --bench scale_bench` and the regression gate so they can
/// never write one file and read another.  Defaults to the repo root of
/// the build tree (right for every in-tree cargo invocation — the gate
/// compares against the *committed* file there); a relocated/installed
/// `gwtf` binary should set `GWTF_SCALE_JSON` to a writable path.
pub fn scale_json_path() -> std::path::PathBuf {
    std::env::var("GWTF_SCALE_JSON").map(std::path::PathBuf::from).unwrap_or_else(|_| {
        std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_scale.json"))
    })
}

/// Read one profile (`"test_sized"` / `"full"`) from `BENCH_scale.json`.
/// `None` when the file, the profile, or a parseable report is absent —
/// the guard's capture mode.
pub fn read_scale_profile(path: &Path, profile: &str) -> Option<ScaleReport> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(text.trim()).ok()?;
    ScaleReport::from_json(j.get(profile)?)
}

/// Write one profile into `BENCH_scale.json`, preserving the other
/// profile.  A present-but-corrupt file is an error, not a reset — a
/// silent rewrite would null the committed baseline and disarm the CI
/// regression gate without anyone noticing.
pub fn update_scale_json(path: &Path, profile: &str, report: &ScaleReport) -> Result<()> {
    crate::util::bench::update_profile_json(
        path,
        "scale",
        "rust/src/experiments/scenarios.rs::run_scale",
        profile,
        report.to_json(),
    )
}

/// Wall-time + protocol-round instrumentation around any
/// [`RoutingPolicy`]: the planning CPU work happens at `request_plan`
/// (and any §V-D repair at `commit_plan`), so both ends of the lifecycle
/// are timed.
struct TimedRouter<R: RoutingPolicy> {
    inner: R,
    wall_ms: f64,
    calls: usize,
    rounds_total: usize,
    cold_rounds: usize,
}

impl<R: RoutingPolicy> TimedRouter<R> {
    fn new(inner: R) -> Self {
        TimedRouter { inner, wall_ms: 0.0, calls: 0, rounds_total: 0, cold_rounds: 0 }
    }
}

impl<R: RoutingPolicy> RoutingPolicy for TimedRouter<R> {
    fn name(&self) -> String {
        self.inner.name()
    }
    fn request_plan(&mut self, req: &PlanRequest) -> PlanTicket {
        let t0 = Instant::now();
        let ticket = self.inner.request_plan(req);
        self.wall_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.calls += 1;
        let rounds = self.inner.last_plan_rounds();
        self.rounds_total += rounds;
        if self.calls == 1 {
            self.cold_rounds = rounds;
        }
        ticket
    }
    fn commit_plan(&mut self, ticket: &PlanTicket, invalidated: &[NodeId]) -> PlanOutcome {
        let t0 = Instant::now();
        let out = self.inner.commit_plan(ticket, invalidated);
        self.wall_ms += t0.elapsed().as_secs_f64() * 1e3;
        out
    }
    fn last_plan_rounds(&self) -> usize {
        self.inner.last_plan_rounds()
    }
    fn on_crash(&mut self, node: NodeId) {
        self.inner.on_crash(node)
    }
    fn on_gossip(&mut self, t: crate::sim::events::Time) {
        self.inner.on_gossip(t)
    }
    fn choose_replacement(
        &mut self,
        prev: NodeId,
        next: NodeId,
        candidates: &[NodeId],
    ) -> Option<NodeId> {
        self.inner.choose_replacement(prev, next, candidates)
    }
    fn recovery(&self) -> RecoveryPolicy {
        self.inner.recovery()
    }
}

/// The 100+ relay scale sweep: GWTF plans over the gossip overlay's
/// bounded neighbor views (warm re-plans, O(chains·k) rounds) against
/// SWARM and DT-FM, all under continuous-clock Poisson churn.  Returns
/// the usual metrics table plus the planner-cost report that lands in
/// `BENCH_scale.json`.
pub fn run_scale(opts: &ScaleOpts) -> Result<(MetricsTable, ScaleReport)> {
    let mut table = MetricsTable::new(
        "Scale — 100+ relays, gossip-overlay GWTF vs SWARM vs DT-FM under Poisson churn",
    );
    let mut cases: BTreeMap<(usize, String), ScaleCase> = BTreeMap::new();
    let mut crit = CritProfile::default();

    /// One (scenario, system) measurement: drive the engine, accumulate
    /// the metrics cell and fold the planner instrumentation into the
    /// per-(relays, system) case.
    struct ScaleRun<'a> {
        table: &'a mut MetricsTable,
        cases: &'a mut BTreeMap<(usize, String), ScaleCase>,
        crit: &'a mut CritProfile,
        sc: &'a crate::sim::scenario::Scenario,
        relays: usize,
        engine_seed: u64,
        iters: usize,
    }

    impl ScaleRun<'_> {
        fn measure<R: RoutingPolicy>(&mut self, system: &str, warm_replan: bool, inner: R) {
            let mut router = TimedRouter::new(inner);
            let mut engine = self.sc.engine(self.engine_seed);
            engine.warm_replan = warm_replan;
            let mut throughput = 0.0;
            let mut events = 0usize;
            let cell = self.table.cell(&format!("scale {}", self.relays), system);
            let t0 = Instant::now();
            for _ in 0..self.iters {
                let mut m = engine.step(&self.sc.prob, &mut router);
                // After the step, never inside the engine (see
                // `IterationMetrics::peak_rss_mib`).
                m.peak_rss_mib = crate::util::mem::peak_rss_mib();
                throughput += m.completed as f64;
                events += m.events;
                self.crit.add(&m);
                cell.push(&m);
            }
            let engine_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            let c = self
                .cases
                .entry((self.relays, system.to_string()))
                .or_insert_with(|| ScaleCase {
                    relays: self.relays,
                    system: system.to_string(),
                    ..Default::default()
                });
            c.plan_wall_ms += router.wall_ms;
            c.plan_calls += router.calls;
            c.plan_rounds_total += router.rounds_total;
            c.cold_rounds += router.cold_rounds;
            c.throughput_total += throughput;
            c.events_total += events;
            c.engine_wall_ms += engine_wall_ms;
            c.peak_rss_mib = c.peak_rss_mib.max(crate::util::mem::peak_rss_mib());
            c.resident_link_entries =
                c.resident_link_entries.max(self.sc.topo.resident_link_entries());
            c.resident_cache_entries = c
                .resident_cache_entries
                .max(self.sc.cost_cache.as_ref().map_or(0, |cache| cache.resident_entries()));
        }
    }

    let gwtf_params =
        || FlowParams { threads: opts.planner_threads.max(1), ..FlowParams::default() };
    let all_sizes = opts
        .sizes
        .iter()
        .map(|&r| (r, false))
        .chain(opts.gwtf_only_sizes.iter().map(|&r| (r, true)));
    for (relays, gwtf_only) in all_sizes {
        for rep in 0..opts.reps {
            let seed = opts.seed + rep as u64 * 8369;
            let cfg = ScenarioConfig::scale(relays, opts.churn_p, seed);
            let sc = build(&cfg);
            let mut run = ScaleRun {
                table: &mut table,
                cases: &mut cases,
                crit: &mut crit,
                sc: &sc,
                relays,
                engine_seed: seed ^ 0x1,
                iters: opts.iters_per_rep,
            };
            // GWTF: overlay-scoped planning + warm re-plans; engine gossip
            // ticks drive the failure detector between plans.
            run.measure(
                "gwtf",
                true,
                GwtfRouter::from_scenario(&sc, gwtf_params(), seed ^ 0xA),
            );
            if gwtf_only {
                continue;
            }
            // SWARM: greedy comm-only wiring, global view.
            run.measure("swarm", false, swarm_router(&sc, seed ^ 0xB));
            // DT-FM: centralized GA, recomputed whenever churn breaks a
            // pipeline — the cost the overlay exists to avoid.
            run.measure(
                "dtfm",
                false,
                dtfm_router(
                    &sc,
                    GaParams { generations: opts.dtfm_generations, ..Default::default() },
                    seed ^ 0xC,
                ),
            );
        }
    }

    let report = ScaleReport {
        fanout: DEFAULT_OVERLAY_FANOUT,
        churn_p: opts.churn_p,
        reps: opts.reps,
        iters_per_rep: opts.iters_per_rep,
        planner_threads: opts.planner_threads.max(1),
        cases: cases.into_values().collect(),
        crit_path: crit,
        peak_rss_mib: crate::util::mem::peak_rss_mib(),
    };
    Ok((table, report))
}

/// Options for the plan-lifecycle round-RTT sweep (`gwtf bench planlag`).
#[derive(Debug, Clone)]
pub struct PlanLagOpts {
    /// Per-round RTTs to sweep, seconds.  `0.0` means the degenerate
    /// commit-at-request lifecycle (the blocking reference point).
    pub rtts_s: Vec<f64>,
    pub reps: usize,
    pub iters_per_rep: usize,
    pub seed: u64,
    /// Bernoulli join-leave chance for the churn rows (the 0%-churn rows
    /// are the monotonicity gate; churn adds staleness on top).
    pub churn_p: f64,
}

impl Default for PlanLagOpts {
    fn default() -> Self {
        PlanLagOpts {
            rtts_s: vec![0.0, 0.5, 2.0, 8.0, 30.0, 120.0],
            reps: 3,
            iters_per_rep: 6,
            seed: 1,
            churn_p: 0.1,
        }
    }
}

/// One (churn, RTT) cell of the plan-lag sweep, summed/averaged over
/// reps and iterations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanLagCase {
    pub churn_p: f64,
    pub rtt_s: f64,
    /// Mean iteration makespan, seconds (the monotonicity gate at 0%
    /// churn: grows once `rounds x RTT` stops fitting the iteration).
    pub makespan_mean_s: f64,
    /// Mean planning charge per iteration (cold-start + stalls).
    pub stall_mean_s: f64,
    /// Mean planning seconds hidden behind training per iteration.
    pub overlap_mean_s: f64,
    /// Tickets invalidated by mid-planning churn, total.
    pub stale_total: usize,
    /// Microbatches completed, total.
    pub throughput_total: f64,
}

/// The `BENCH_planlag.json` payload for one profile.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanLagReport {
    pub reps: usize,
    pub iters_per_rep: usize,
    pub cases: Vec<PlanLagCase>,
    /// Where the sweep's virtual time went ([`CritProfile`]).
    pub crit_path: CritProfile,
    /// Peak resident set when the sweep finished, MiB (informational,
    /// never gated; 0 where `/proc` is hidden).
    pub peak_rss_mib: f64,
}

impl PlanLagReport {
    pub fn case(&self, churn_p: f64, rtt_s: f64) -> Option<&PlanLagCase> {
        self.cases.iter().find(|c| c.churn_p == churn_p && c.rtt_s == rtt_s)
    }

    pub fn to_json(&self) -> Json {
        let case_json = |c: &PlanLagCase| {
            let mut o = BTreeMap::new();
            o.insert("churn_p".into(), Json::Num(c.churn_p));
            o.insert("rtt_s".into(), Json::Num(c.rtt_s));
            o.insert("makespan_mean_s".into(), Json::Num(c.makespan_mean_s));
            o.insert("stall_mean_s".into(), Json::Num(c.stall_mean_s));
            o.insert("overlap_mean_s".into(), Json::Num(c.overlap_mean_s));
            o.insert("stale_total".into(), Json::Num(c.stale_total as f64));
            o.insert("throughput_total".into(), Json::Num(c.throughput_total));
            Json::Obj(o)
        };
        let mut root = BTreeMap::new();
        root.insert("reps".into(), Json::Num(self.reps as f64));
        root.insert("iters_per_rep".into(), Json::Num(self.iters_per_rep as f64));
        root.insert("cases".into(), Json::Arr(self.cases.iter().map(case_json).collect()));
        root.insert("crit_path".into(), self.crit_path.to_json());
        root.insert("peak_rss_mib".into(), Json::Num((self.peak_rss_mib * 1e3).round() / 1e3));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Option<PlanLagReport> {
        let num = |o: &Json, k: &str| o.get(k).and_then(Json::as_f64);
        let cases = match j.get("cases")? {
            Json::Arr(v) => v
                .iter()
                .map(|c| {
                    Some(PlanLagCase {
                        churn_p: num(c, "churn_p")?,
                        rtt_s: num(c, "rtt_s")?,
                        makespan_mean_s: num(c, "makespan_mean_s")?,
                        stall_mean_s: num(c, "stall_mean_s")?,
                        overlap_mean_s: num(c, "overlap_mean_s")?,
                        stale_total: num(c, "stale_total")? as usize,
                        throughput_total: num(c, "throughput_total")?,
                    })
                })
                .collect::<Option<Vec<_>>>()?,
            _ => return None,
        };
        Some(PlanLagReport {
            reps: num(j, "reps")? as usize,
            iters_per_rep: num(j, "iters_per_rep")? as usize,
            cases,
            crit_path: CritProfile::from_json(j.get("crit_path")),
            peak_rss_mib: num(j, "peak_rss_mib").unwrap_or(0.0),
        })
    }
}

/// Canonical location of `BENCH_planlag.json` (same convention as
/// [`scale_json_path`]): the repo root of the build tree, overridable via
/// `GWTF_PLANLAG_JSON` for relocated binaries.
pub fn plan_lag_json_path() -> std::path::PathBuf {
    std::env::var("GWTF_PLANLAG_JSON").map(std::path::PathBuf::from).unwrap_or_else(|_| {
        std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_planlag.json"))
    })
}

/// Read one profile (`"test_sized"` / `"full"`) from `BENCH_planlag.json`.
pub fn read_plan_lag_profile(path: &Path, profile: &str) -> Option<PlanLagReport> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(text.trim()).ok()?;
    PlanLagReport::from_json(j.get(profile)?)
}

/// Write one profile into `BENCH_planlag.json`, preserving the other
/// profile; a present-but-corrupt file is an error, not a reset (same
/// rationale as [`update_scale_json`]).
pub fn update_plan_lag_json(path: &Path, profile: &str, report: &PlanLagReport) -> Result<()> {
    crate::util::bench::update_profile_json(
        path,
        "planlag",
        "rust/src/experiments/scenarios.rs::run_plan_lag",
        profile,
        report.to_json(),
    )
}

/// Drive one measured arm of a sweep: build the scenario's engine from
/// `engine_seed`, run `iters` iterations against `router`, and fold
/// every measured iteration into the `(row, system)` metrics cell and
/// the sweep-wide critical-path profile.  Each iteration is also handed
/// to `on_iter` so the caller can accumulate its own per-case totals.
/// This is the arm-iteration shape the congestion, async and adversary
/// sweeps all share; keeping it here means a new sweep adds only its
/// scenario wiring and case bookkeeping.
#[allow(clippy::too_many_arguments)]
fn measure_arm(
    table: &mut MetricsTable,
    crit: &mut CritProfile,
    row: &str,
    system: &str,
    sc: &crate::sim::scenario::Scenario,
    router: &mut dyn RoutingPolicy,
    engine_seed: u64,
    iters: usize,
    warm_replan: bool,
    mut on_iter: impl FnMut(&IterationMetrics),
) {
    let mut engine = sc.engine(engine_seed);
    engine.warm_replan = warm_replan;
    let cell = table.cell(row, system);
    for _ in 0..iters {
        let mut m = engine.step(&sc.prob, router);
        // Stamped here, after the step returns — never inside the engine,
        // where the monotone probe would differ between otherwise
        // bit-identical runs (see `IterationMetrics::peak_rss_mib`).
        m.peak_rss_mib = crate::util::mem::peak_rss_mib();
        crit.add(&m);
        cell.push(&m);
        on_iter(&m);
    }
}

/// Options for the shared-capacity congestion sweep
/// (`gwtf bench congestion`).
#[derive(Debug, Clone)]
pub struct CongestionOpts {
    /// WAN NIC concurrency caps to sweep; `0` means unlimited — the
    /// contention-free reference every other column must dominate.
    pub nic_caps: Vec<usize>,
    pub reps: usize,
    pub iters_per_rep: usize,
    pub seed: u64,
}

impl Default for CongestionOpts {
    fn default() -> Self {
        CongestionOpts { nic_caps: vec![0, 8, 4, 2, 1], reps: 3, iters_per_rep: 3, seed: 1 }
    }
}

/// One (NIC cap, system) cell of the congestion sweep, averaged over
/// reps and iterations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CongestionCase {
    /// WAN NIC concurrency; 0 = unlimited (legacy contention-free).
    pub nic: usize,
    pub system: String,
    /// Mean iteration makespan, seconds (the monotonicity gate for
    /// capacity-oblivious GWTF: queueing only ever delays fixed paths).
    pub makespan_mean_s: f64,
    /// Mean NIC-queueing seconds per iteration (0 at `nic = 0`).
    pub queue_mean_s: f64,
    /// Mean transfer seconds per iteration (transmission + propagation).
    pub comm_mean_s: f64,
    /// Mean peak per-node NIC load (busiest node's demanded tx seconds
    /// over the makespan; >1 = oversubscribed under unlimited
    /// concurrency — not a wall-clock busy fraction).
    pub nic_util_max_mean: f64,
    /// Microbatches completed, total.
    pub throughput_total: f64,
}

/// The `BENCH_congestion.json` payload for one profile.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CongestionReport {
    pub reps: usize,
    pub iters_per_rep: usize,
    pub cases: Vec<CongestionCase>,
    /// Where the sweep's virtual time went ([`CritProfile`]).
    pub crit_path: CritProfile,
    /// Peak resident set when the sweep finished, MiB (informational,
    /// never gated; 0 where `/proc` is hidden).
    pub peak_rss_mib: f64,
}

impl CongestionReport {
    pub fn case(&self, nic: usize, system: &str) -> Option<&CongestionCase> {
        self.cases.iter().find(|c| c.nic == nic && c.system == system)
    }

    pub fn to_json(&self) -> Json {
        let case_json = |c: &CongestionCase| {
            let mut o = BTreeMap::new();
            o.insert("nic".into(), Json::Num(c.nic as f64));
            o.insert("system".into(), Json::Str(c.system.clone()));
            o.insert("makespan_mean_s".into(), Json::Num(c.makespan_mean_s));
            o.insert("queue_mean_s".into(), Json::Num(c.queue_mean_s));
            o.insert("comm_mean_s".into(), Json::Num(c.comm_mean_s));
            o.insert("nic_util_max_mean".into(), Json::Num(c.nic_util_max_mean));
            o.insert("throughput_total".into(), Json::Num(c.throughput_total));
            Json::Obj(o)
        };
        let mut root = BTreeMap::new();
        root.insert("reps".into(), Json::Num(self.reps as f64));
        root.insert("iters_per_rep".into(), Json::Num(self.iters_per_rep as f64));
        root.insert("cases".into(), Json::Arr(self.cases.iter().map(case_json).collect()));
        root.insert("crit_path".into(), self.crit_path.to_json());
        root.insert("peak_rss_mib".into(), Json::Num((self.peak_rss_mib * 1e3).round() / 1e3));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Option<CongestionReport> {
        let num = |o: &Json, k: &str| o.get(k).and_then(Json::as_f64);
        let cases = match j.get("cases")? {
            Json::Arr(v) => v
                .iter()
                .map(|c| {
                    Some(CongestionCase {
                        nic: num(c, "nic")? as usize,
                        system: c.get("system")?.as_str()?.to_string(),
                        makespan_mean_s: num(c, "makespan_mean_s")?,
                        queue_mean_s: num(c, "queue_mean_s")?,
                        comm_mean_s: num(c, "comm_mean_s")?,
                        nic_util_max_mean: num(c, "nic_util_max_mean")?,
                        throughput_total: num(c, "throughput_total")?,
                    })
                })
                .collect::<Option<Vec<_>>>()?,
            _ => return None,
        };
        Some(CongestionReport {
            reps: num(j, "reps")? as usize,
            iters_per_rep: num(j, "iters_per_rep")? as usize,
            cases,
            crit_path: CritProfile::from_json(j.get("crit_path")),
            peak_rss_mib: num(j, "peak_rss_mib").unwrap_or(0.0),
        })
    }
}

/// Canonical location of `BENCH_congestion.json` (same convention as
/// [`scale_json_path`]): the repo root of the build tree, overridable via
/// `GWTF_CONGESTION_JSON` for relocated binaries.
pub fn congestion_json_path() -> std::path::PathBuf {
    std::env::var("GWTF_CONGESTION_JSON").map(std::path::PathBuf::from).unwrap_or_else(|_| {
        std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_congestion.json"))
    })
}

/// Read one profile (`"test_sized"` / `"full"`) from
/// `BENCH_congestion.json`.
pub fn read_congestion_profile(path: &Path, profile: &str) -> Option<CongestionReport> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(text.trim()).ok()?;
    CongestionReport::from_json(j.get(profile)?)
}

/// Write one profile into `BENCH_congestion.json`, preserving the other
/// profile; a present-but-corrupt file is an error, not a reset (same
/// rationale as [`update_scale_json`]).
pub fn update_congestion_json(
    path: &Path,
    profile: &str,
    report: &CongestionReport,
) -> Result<()> {
    crate::util::bench::update_profile_json(
        path,
        "congestion",
        "rust/src/experiments/scenarios.rs::run_congestion",
        profile,
        report.to_json(),
    )
}

/// Row label for one NIC cap of the congestion sweep.
fn nic_row(cap: usize) -> String {
    if cap == 0 {
        "nic unlimited".into()
    } else {
        format!("nic {cap:>2}")
    }
}

/// The shared-capacity congestion sweep: the fan-in-hub scenario
/// ([`crate::sim::scenario::ScenarioConfig::congestion`]) swept over the
/// WAN NIC concurrency cap.  Four systems per cap: capacity-oblivious
/// GWTF (fixed paths — the pure-queueing monotonicity column),
/// congestion-aware GWTF (Eq. 1 + expected NIC queueing, same substrate
/// parameters the simulator executes), SWARM (nearest-peer funnel,
/// capacity-oblivious by design) and DT-FM.  Returns the metrics table
/// plus the report that lands in `BENCH_congestion.json`.
pub fn run_congestion(opts: &CongestionOpts) -> Result<(MetricsTable, CongestionReport)> {
    let mut table = MetricsTable::new(
        "Congestion — shared-capacity NICs over a bandwidth-starved WAN with fan-in hubs",
    );
    /// Raw per-iteration samples for one (cap, system) cell.
    #[derive(Default)]
    struct CaseAcc {
        makespan: Vec<f64>,
        queue: Vec<f64>,
        comm: Vec<f64>,
        util: Vec<f64>,
        throughput: f64,
    }
    let mut cases: BTreeMap<(usize, String), CaseAcc> = BTreeMap::new();
    let mut crit = CritProfile::default();
    for &cap in &opts.nic_caps {
        let nic_wan = if cap == 0 { None } else { Some(cap) };
        let row = nic_row(cap);
        for rep in 0..opts.reps {
            let seed = opts.seed + rep as u64 * 6113;
            let sc = build(&ScenarioConfig::congestion(nic_wan, false, seed));
            let sc_aware = build(&ScenarioConfig::congestion(nic_wan, true, seed));
            let mut measure = |system: &str,
                               sc: &crate::sim::scenario::Scenario,
                               router: &mut dyn RoutingPolicy| {
                let acc = cases.entry((cap, system.to_string())).or_default();
                measure_arm(
                    &mut table,
                    &mut crit,
                    &row,
                    system,
                    sc,
                    router,
                    seed ^ 0x1,
                    opts.iters_per_rep,
                    false,
                    |m| {
                        acc.makespan.push(m.makespan_s);
                        acc.queue.push(m.queue_s);
                        acc.comm.push(m.comm_s);
                        acc.util.push(m.nic_util_max);
                        acc.throughput += m.completed as f64;
                    },
                );
            };
            measure(
                "gwtf",
                &sc,
                &mut GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA),
            );
            measure(
                "gwtf-aware",
                &sc_aware,
                &mut GwtfRouter::from_scenario(&sc_aware, FlowParams::default(), seed ^ 0xA),
            );
            measure("swarm", &sc, &mut swarm_router(&sc, seed ^ 0xB));
            measure(
                "dtfm",
                &sc,
                &mut dtfm_router(
                    &sc,
                    GaParams { generations: 20, ..Default::default() },
                    seed ^ 0xC,
                ),
            );
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let report = CongestionReport {
        reps: opts.reps,
        iters_per_rep: opts.iters_per_rep,
        cases: cases
            .into_iter()
            .map(|((nic, system), acc)| CongestionCase {
                nic,
                system,
                makespan_mean_s: mean(&acc.makespan),
                queue_mean_s: mean(&acc.queue),
                comm_mean_s: mean(&acc.comm),
                nic_util_max_mean: mean(&acc.util),
                throughput_total: acc.throughput,
            })
            .collect(),
        crit_path: crit,
        peak_rss_mib: crate::util::mem::peak_rss_mib(),
    };
    Ok((table, report))
}

/// Options for the bounded-staleness asynchronous-training sweep
/// (`gwtf bench async`).
#[derive(Debug, Clone)]
pub struct AsyncOpts {
    /// Staleness bounds to sweep (each `>= 1`); the synchronous-barrier
    /// reference arm is always measured alongside.
    pub bounds: Vec<usize>,
    /// Continuous-clock Poisson churn rate for every arm.
    pub churn_p: f64,
    pub reps: usize,
    pub iters_per_rep: usize,
    pub seed: u64,
}

impl Default for AsyncOpts {
    fn default() -> Self {
        AsyncOpts { bounds: vec![1, 2, 4], churn_p: 0.2, reps: 3, iters_per_rep: 4, seed: 1 }
    }
}

/// One arm of the async sweep, totalled over reps and iterations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AsyncCase {
    /// Staleness bound; 0 = the synchronous §V-E barrier reference.
    pub staleness: usize,
    /// Summed iteration makespans, seconds (goodput denominator).
    pub makespan_total_s: f64,
    /// Mean aggregation seconds charged per iteration (barrier or
    /// rolling exchanges + catch-up).
    pub agg_mean_s: f64,
    /// Mean weight staleness trained against (generations behind).
    pub staleness_mean: f64,
    /// Microbatches deferred by the admission rule, total.
    pub deferred_total: f64,
    /// Microbatches completed, total.
    pub throughput_total: f64,
}

impl AsyncCase {
    /// Completed microbatches per makespan second — the async guard's
    /// monotone gate: removing the barrier must buy goodput.
    pub fn goodput(&self) -> f64 {
        if self.makespan_total_s > 0.0 {
            self.throughput_total / self.makespan_total_s
        } else {
            0.0
        }
    }
}

/// The `BENCH_async.json` payload for one profile.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AsyncReport {
    pub reps: usize,
    pub iters_per_rep: usize,
    pub churn_p: f64,
    pub cases: Vec<AsyncCase>,
    /// Where the sweep's virtual time went ([`CritProfile`]).
    pub crit_path: CritProfile,
    /// Peak resident set when the sweep finished, MiB (informational,
    /// never gated; 0 where `/proc` is hidden).
    pub peak_rss_mib: f64,
}

impl AsyncReport {
    pub fn case(&self, staleness: usize) -> Option<&AsyncCase> {
        self.cases.iter().find(|c| c.staleness == staleness)
    }

    pub fn to_json(&self) -> Json {
        let case_json = |c: &AsyncCase| {
            let mut o = BTreeMap::new();
            o.insert("staleness".into(), Json::Num(c.staleness as f64));
            o.insert("makespan_total_s".into(), Json::Num(c.makespan_total_s));
            o.insert("agg_mean_s".into(), Json::Num(c.agg_mean_s));
            o.insert("staleness_mean".into(), Json::Num(c.staleness_mean));
            o.insert("deferred_total".into(), Json::Num(c.deferred_total));
            o.insert("throughput_total".into(), Json::Num(c.throughput_total));
            // Derived, for human readers of the JSON; not parsed back.
            o.insert("goodput_mb_per_s".into(), Json::Num(c.goodput()));
            Json::Obj(o)
        };
        let mut root = BTreeMap::new();
        root.insert("reps".into(), Json::Num(self.reps as f64));
        root.insert("iters_per_rep".into(), Json::Num(self.iters_per_rep as f64));
        root.insert("churn_p".into(), Json::Num(self.churn_p));
        root.insert("cases".into(), Json::Arr(self.cases.iter().map(case_json).collect()));
        root.insert("crit_path".into(), self.crit_path.to_json());
        root.insert("peak_rss_mib".into(), Json::Num((self.peak_rss_mib * 1e3).round() / 1e3));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Option<AsyncReport> {
        let num = |o: &Json, k: &str| o.get(k).and_then(Json::as_f64);
        let cases = match j.get("cases")? {
            Json::Arr(v) => v
                .iter()
                .map(|c| {
                    Some(AsyncCase {
                        staleness: num(c, "staleness")? as usize,
                        makespan_total_s: num(c, "makespan_total_s")?,
                        agg_mean_s: num(c, "agg_mean_s")?,
                        staleness_mean: num(c, "staleness_mean")?,
                        deferred_total: num(c, "deferred_total")?,
                        throughput_total: num(c, "throughput_total")?,
                    })
                })
                .collect::<Option<Vec<_>>>()?,
            _ => return None,
        };
        Some(AsyncReport {
            reps: num(j, "reps")? as usize,
            iters_per_rep: num(j, "iters_per_rep")? as usize,
            churn_p: num(j, "churn_p")?,
            cases,
            crit_path: CritProfile::from_json(j.get("crit_path")),
            peak_rss_mib: num(j, "peak_rss_mib").unwrap_or(0.0),
        })
    }
}

/// Canonical location of `BENCH_async.json` (same convention as
/// [`congestion_json_path`]), overridable via `GWTF_ASYNC_JSON`.
pub fn async_json_path() -> std::path::PathBuf {
    std::env::var("GWTF_ASYNC_JSON").map(std::path::PathBuf::from).unwrap_or_else(|_| {
        std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_async.json"))
    })
}

/// Read one profile (`"test_sized"` / `"full"`) from `BENCH_async.json`.
pub fn read_async_profile(path: &Path, profile: &str) -> Option<AsyncReport> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(text.trim()).ok()?;
    AsyncReport::from_json(j.get(profile)?)
}

/// Write one profile into `BENCH_async.json`, preserving the other
/// profile; a present-but-corrupt file is an error, not a reset (same
/// rationale as [`update_congestion_json`]).
pub fn update_async_json(path: &Path, profile: &str, report: &AsyncReport) -> Result<()> {
    crate::util::bench::update_profile_json(
        path,
        "async",
        "rust/src/experiments/scenarios.rs::run_async",
        profile,
        report.to_json(),
    )
}

/// Row label for one arm of the async sweep.
fn staleness_row(s: usize) -> String {
    if s == 0 {
        "sync".into()
    } else {
        format!("async s={s}")
    }
}

/// The bounded-staleness sweep: GWTF with warm re-plans on the
/// heterogeneous Table II shape under continuous-clock Poisson churn
/// ([`ScenarioConfig::bounded_staleness`]), swept over the staleness
/// bound with the synchronous barrier as the reference arm.  Every arm
/// sees the same topologies and churn processes (same seeds; the bound
/// does not consume randomness), so the sweep isolates the barrier-vs-
/// rolling-aggregation difference.  Returns the metrics table plus the
/// report that lands in `BENCH_async.json`.
pub fn run_async(opts: &AsyncOpts) -> Result<(MetricsTable, AsyncReport)> {
    let mut table = MetricsTable::new(
        "Bounded staleness — rolling per-stage aggregation vs the synchronous §V-E barrier",
    );
    let mut arms: Vec<usize> = vec![0];
    arms.extend(opts.bounds.iter().copied().filter(|&s| s >= 1));
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let mut cases = Vec::new();
    let mut crit = CritProfile::default();
    for &s in &arms {
        let row = staleness_row(s);
        let bound = if s == 0 { None } else { Some(s) };
        let mut makespan_total = 0.0;
        let mut agg = Vec::new();
        let mut stale = Vec::new();
        let mut deferred_total = 0.0;
        let mut throughput_total = 0.0;
        for rep in 0..opts.reps {
            let seed = opts.seed + rep as u64 * 104729;
            let sc = build(&ScenarioConfig::bounded_staleness(bound, opts.churn_p, seed));
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            measure_arm(
                &mut table,
                &mut crit,
                &row,
                "gwtf",
                &sc,
                &mut router,
                seed ^ 0x1,
                opts.iters_per_rep,
                true,
                |m| {
                    makespan_total += m.makespan_s;
                    agg.push(m.agg_s);
                    stale.push(m.staleness_mean);
                    deferred_total += m.deferred as f64;
                    throughput_total += m.completed as f64;
                },
            );
        }
        cases.push(AsyncCase {
            staleness: s,
            makespan_total_s: makespan_total,
            agg_mean_s: mean(&agg),
            staleness_mean: mean(&stale),
            deferred_total,
            throughput_total,
        });
    }
    let report = AsyncReport {
        reps: opts.reps,
        iters_per_rep: opts.iters_per_rep,
        churn_p: opts.churn_p,
        cases,
        crit_path: crit,
        peak_rss_mib: crate::util::mem::peak_rss_mib(),
    };
    Ok((table, report))
}

/// Options for the adversarial-relay sweep (`gwtf bench adversary`).
#[derive(Debug, Clone)]
pub struct AdversaryOpts {
    /// Adversarial fractions to sweep; `0.0` is the clean-fleet
    /// reference every retention gate divides by.
    pub fractions: Vec<f64>,
    pub reps: usize,
    pub iters_per_rep: usize,
    pub seed: u64,
}

impl Default for AdversaryOpts {
    fn default() -> Self {
        AdversaryOpts { fractions: vec![0.0, 0.10, 0.25], reps: 3, iters_per_rep: 4, seed: 1 }
    }
}

/// One (adversarial fraction, system) cell of the adversary sweep,
/// totalled over reps and iterations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdversaryCase {
    /// Adversarial fraction as a percentage (0, 10, 25); stored as an
    /// integer so JSON roundtrips and case lookups stay exact.
    pub fraction_pct: usize,
    pub system: String,
    /// Summed iteration makespans, seconds (goodput denominator).
    pub makespan_total_s: f64,
    /// Microbatches completed, total (goodput numerator).
    pub throughput_total: f64,
    /// Memory-overload DENYs, total — DENY storms and phantom-capacity
    /// bounces both land here.
    pub denies_total: f64,
}

impl AdversaryCase {
    /// Completed microbatches per makespan second — the retention
    /// gate's unit: reputation-aware GWTF at f = 25% must keep >= 70% of
    /// its clean-fleet goodput, and the oblivious arm must retain
    /// strictly less.
    pub fn goodput(&self) -> f64 {
        if self.makespan_total_s > 0.0 {
            self.throughput_total / self.makespan_total_s
        } else {
            0.0
        }
    }
}

/// The `BENCH_adversary.json` payload for one profile.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdversaryReport {
    pub reps: usize,
    pub iters_per_rep: usize,
    pub cases: Vec<AdversaryCase>,
    /// Where the sweep's virtual time went ([`CritProfile`]).
    pub crit_path: CritProfile,
    /// Peak resident set when the sweep finished, MiB (informational,
    /// never gated; 0 where `/proc` is hidden).
    pub peak_rss_mib: f64,
}

impl AdversaryReport {
    pub fn case(&self, fraction_pct: usize, system: &str) -> Option<&AdversaryCase> {
        self.cases.iter().find(|c| c.fraction_pct == fraction_pct && c.system == system)
    }

    pub fn to_json(&self) -> Json {
        let case_json = |c: &AdversaryCase| {
            let mut o = BTreeMap::new();
            o.insert("fraction_pct".into(), Json::Num(c.fraction_pct as f64));
            o.insert("system".into(), Json::Str(c.system.clone()));
            o.insert("makespan_total_s".into(), Json::Num(c.makespan_total_s));
            o.insert("throughput_total".into(), Json::Num(c.throughput_total));
            o.insert("denies_total".into(), Json::Num(c.denies_total));
            // Derived, for human readers of the JSON; not parsed back.
            o.insert("goodput_mb_per_s".into(), Json::Num(c.goodput()));
            Json::Obj(o)
        };
        let mut root = BTreeMap::new();
        root.insert("reps".into(), Json::Num(self.reps as f64));
        root.insert("iters_per_rep".into(), Json::Num(self.iters_per_rep as f64));
        root.insert("cases".into(), Json::Arr(self.cases.iter().map(case_json).collect()));
        root.insert("crit_path".into(), self.crit_path.to_json());
        root.insert("peak_rss_mib".into(), Json::Num((self.peak_rss_mib * 1e3).round() / 1e3));
        Json::Obj(root)
    }

    pub fn from_json(j: &Json) -> Option<AdversaryReport> {
        let num = |o: &Json, k: &str| o.get(k).and_then(Json::as_f64);
        let cases = match j.get("cases")? {
            Json::Arr(v) => v
                .iter()
                .map(|c| {
                    Some(AdversaryCase {
                        fraction_pct: num(c, "fraction_pct")? as usize,
                        system: c.get("system")?.as_str()?.to_string(),
                        makespan_total_s: num(c, "makespan_total_s")?,
                        throughput_total: num(c, "throughput_total")?,
                        denies_total: num(c, "denies_total")?,
                    })
                })
                .collect::<Option<Vec<_>>>()?,
            _ => return None,
        };
        Some(AdversaryReport {
            reps: num(j, "reps")? as usize,
            iters_per_rep: num(j, "iters_per_rep")? as usize,
            cases,
            crit_path: CritProfile::from_json(j.get("crit_path")),
            peak_rss_mib: num(j, "peak_rss_mib").unwrap_or(0.0),
        })
    }
}

/// Canonical location of `BENCH_adversary.json` (same convention as
/// [`congestion_json_path`]), overridable via `GWTF_ADVERSARY_JSON`.
pub fn adversary_json_path() -> std::path::PathBuf {
    std::env::var("GWTF_ADVERSARY_JSON").map(std::path::PathBuf::from).unwrap_or_else(|_| {
        std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_adversary.json"))
    })
}

/// Read one profile (`"test_sized"` / `"full"`) from
/// `BENCH_adversary.json`.
pub fn read_adversary_profile(path: &Path, profile: &str) -> Option<AdversaryReport> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(text.trim()).ok()?;
    AdversaryReport::from_json(j.get(profile)?)
}

/// Write one profile into `BENCH_adversary.json`, preserving the other
/// profile; a present-but-corrupt file is an error, not a reset (same
/// rationale as [`update_congestion_json`]).
pub fn update_adversary_json(path: &Path, profile: &str, report: &AdversaryReport) -> Result<()> {
    crate::util::bench::update_profile_json(
        path,
        "adversary",
        "rust/src/experiments/scenarios.rs::run_adversary",
        profile,
        report.to_json(),
    )
}

/// Row label for one adversarial fraction of the adversary sweep.
fn adversary_row(pct: usize) -> String {
    format!("adv {pct:>2}%")
}

/// The adversarial-relay sweep: the Table II shape with a deterministic
/// roster of Byzantine relays ([`ScenarioConfig::adversary`]), swept
/// over the adversarial fraction.  Three systems per fraction:
/// reputation-oblivious GWTF (plans into phantom capacity and re-routes
/// only after DENY bounces), reputation-aware GWTF (the
/// [`crate::net::reputation`] EWMA book feeds the Eq. 1 penalty, so
/// re-plans price liars out), and SWARM (nearest-peer wiring, oblivious
/// by design).  Both GWTF arms share seeds — the scenarios differ only
/// in whether the reputation book exists, so the comparison isolates
/// the routing policy from the draw of the topology.  Returns the
/// metrics table plus the report that lands in `BENCH_adversary.json`.
pub fn run_adversary(opts: &AdversaryOpts) -> Result<(MetricsTable, AdversaryReport)> {
    let mut table = MetricsTable::new(
        "Adversarial relays — oblivious GWTF vs reputation-aware GWTF vs SWARM",
    );
    /// Running totals for one (fraction, system) cell.
    #[derive(Default)]
    struct CaseAcc {
        makespan: f64,
        throughput: f64,
        denies: f64,
    }
    let mut cases: BTreeMap<(usize, String), CaseAcc> = BTreeMap::new();
    let mut crit = CritProfile::default();
    for &f in &opts.fractions {
        let pct = (f * 100.0).round() as usize;
        let row = adversary_row(pct);
        for rep in 0..opts.reps {
            let seed = opts.seed + rep as u64 * 7457;
            let sc_obl = build(&ScenarioConfig::adversary(f, false, seed));
            let sc_rep = build(&ScenarioConfig::adversary(f, true, seed));
            let mut run = |system: &str,
                           sc: &crate::sim::scenario::Scenario,
                           router: &mut dyn RoutingPolicy| {
                let acc = cases.entry((pct, system.to_string())).or_default();
                measure_arm(
                    &mut table,
                    &mut crit,
                    &row,
                    system,
                    sc,
                    router,
                    seed ^ 0x1,
                    opts.iters_per_rep,
                    false,
                    |m| {
                        acc.makespan += m.makespan_s;
                        acc.throughput += m.completed as f64;
                        acc.denies += m.denies as f64;
                    },
                );
            };
            run(
                "gwtf",
                &sc_obl,
                &mut GwtfRouter::from_scenario(&sc_obl, FlowParams::default(), seed ^ 0xA),
            );
            run(
                "gwtf-rep",
                &sc_rep,
                &mut GwtfRouter::from_scenario(&sc_rep, FlowParams::default(), seed ^ 0xA),
            );
            run("swarm", &sc_obl, &mut swarm_router(&sc_obl, seed ^ 0xB));
        }
    }
    let report = AdversaryReport {
        reps: opts.reps,
        iters_per_rep: opts.iters_per_rep,
        cases: cases
            .into_iter()
            .map(|((fraction_pct, system), acc)| AdversaryCase {
                fraction_pct,
                system,
                makespan_total_s: acc.makespan,
                throughput_total: acc.throughput,
                denies_total: acc.denies,
            })
            .collect(),
        crit_path: crit,
        peak_rss_mib: crate::util::mem::peak_rss_mib(),
    };
    Ok((table, report))
}

/// The plan-lifecycle round-RTT sweep: GWTF with warm re-plans on the
/// Table II scenario, planning rounds riding the engine clock
/// ([`crate::sim::engine::PlanLifecycle::RoundLatency`]).  Rows sweep
/// the per-round RTT at 0% churn (pure overlap-vs-stall: makespan must
/// grow monotonically with the RTT once `rounds x RTT` stops fitting
/// inside an iteration) and at `churn_p` (staleness on top: mid-planning
/// crashes invalidate in-flight tickets, visible in the `stale_replans`
/// column).  `rtt = 0` is the degenerate blocking lifecycle for
/// reference.
pub fn run_plan_lag(opts: &PlanLagOpts) -> Result<(MetricsTable, PlanLagReport)> {
    let mut table = MetricsTable::new(
        "Plan lag — flow-protocol round-RTT vs iteration length (plan lifecycle on the clock)",
    );
    let mut cases = Vec::new();
    let mut crit = CritProfile::default();
    // 0% churn is always measured (the monotonicity gate); the churn row
    // is added on top unless it would duplicate it (`--churn 0`).
    let mut churn_rows = vec![0.0];
    if opts.churn_p > 0.0 {
        churn_rows.push(opts.churn_p);
    }
    for &churn_p in &churn_rows {
        for &rtt in &opts.rtts_s {
            let row = format!("churn {:>2.0}% rtt {:>5.1}s", churn_p * 100.0, rtt);
            let mut makespans = Vec::new();
            let mut stalls = Vec::new();
            let mut overlaps = Vec::new();
            let mut stale_total = 0usize;
            let mut throughput_total = 0.0;
            for rep in 0..opts.reps {
                let seed = opts.seed + rep as u64 * 9001;
                let mut cfg = ScenarioConfig::table2(true, churn_p, seed);
                // rtt > 0 opts into the round-latency lifecycle through
                // the scenario knob (the same path `Engine::from_scenario`
                // wires for any plan_round_rtt_s scenario); rtt = 0 keeps
                // the degenerate blocking reference.
                if rtt > 0.0 {
                    cfg.plan_round_rtt_s = Some(rtt);
                }
                let sc = build(&cfg);
                let mut router =
                    GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
                let mut engine = sc.engine(seed ^ 0x1);
                engine.warm_replan = true;
                let cell = table.cell(&row, "gwtf");
                for _ in 0..opts.iters_per_rep {
                    let m = engine.step(&sc.prob, &mut router);
                    makespans.push(m.makespan_s);
                    stalls.push(m.planning_s);
                    overlaps.push(m.plan_overlap_s);
                    stale_total += m.stale_replans;
                    throughput_total += m.completed as f64;
                    crit.add(&m);
                    cell.push(&m);
                }
            }
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
            cases.push(PlanLagCase {
                churn_p,
                rtt_s: rtt,
                makespan_mean_s: mean(&makespans),
                stall_mean_s: mean(&stalls),
                overlap_mean_s: mean(&overlaps),
                stale_total,
                throughput_total,
            });
        }
    }
    let report = PlanLagReport {
        reps: opts.reps,
        iters_per_rep: opts.iters_per_rep,
        cases,
        crit_path: crit,
        peak_rss_mib: crate::util::mem::peak_rss_mib(),
    };
    Ok((table, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> ScenarioOpts {
        ScenarioOpts { reps: 2, iters_per_rep: 3, seed: 7 }
    }

    #[test]
    fn mid_agg_crash_produces_all_columns() {
        let t = run_mid_agg_crash(&fast()).unwrap();
        let row = "table2 homogeneous".to_string();
        for col in ["no-crash", "midagg-crash", "midagg-crash-warm"] {
            let acc = &t.cells[&(row.clone(), col.to_string())];
            assert_eq!(acc.throughput.len(), 2 * 3, "{col}");
        }
        // the crash columns must record exactly one barrier recovery per rep
        let crash = &t.cells[&(row.clone(), "midagg-crash".to_string())];
        assert_eq!(crash.agg_recoveries.iter().sum::<f64>(), 2.0);
        let clean = &t.cells[&(row, "no-crash".to_string())];
        assert_eq!(clean.agg_recoveries.iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn poisson_churn_produces_all_cells() {
        let t = run_poisson_churn(&fast()).unwrap();
        assert_eq!(t.cells.len(), 6, "2 rates x 3 systems");
        for row in ["poisson 10%", "poisson 20%"] {
            for col in ["gwtf", "swarm", "dtfm"] {
                let acc = &t.cells[&(row.to_string(), col.to_string())];
                assert_eq!(acc.throughput.len(), 2 * 3, "{row}/{col}");
                assert!(acc.makespan_min.iter().all(|m| m.is_finite()), "{row}/{col}");
            }
        }
        // GWTF warm-replans must be recorded in the new diagnostics column.
        let gwtf = &t.cells[&("poisson 20%".to_string(), "gwtf".to_string())];
        assert!(
            gwtf.replan_rounds.iter().sum::<f64>() > 0.0,
            "gwtf plans/replans must report protocol rounds"
        );
    }

    #[test]
    fn scale_sweep_produces_cells_and_planner_report() {
        let opts = ScaleOpts {
            sizes: vec![60],
            gwtf_only_sizes: vec![72],
            reps: 1,
            iters_per_rep: 2,
            seed: 5,
            churn_p: 0.2,
            dtfm_generations: 8,
            planner_threads: 2,
        };
        let (t, report) = run_scale(&opts).unwrap();
        assert_eq!(t.cells.len(), 4, "1 size x 3 systems + 1 gwtf-only size");
        for col in ["gwtf", "swarm", "dtfm"] {
            let acc = &t.cells[&("scale 60".to_string(), col.to_string())];
            assert_eq!(acc.throughput.len(), 2, "{col}");
        }
        let gwtf = report.case(60, "gwtf").expect("gwtf case");
        assert_eq!(gwtf.plan_calls, 2, "one (re)plan per iteration");
        assert!(gwtf.plan_rounds_total > 0, "protocol rounds recorded");
        assert!(gwtf.cold_rounds > 0 && gwtf.cold_rounds <= gwtf.plan_rounds_total);
        assert!(gwtf.throughput_total > 0.0, "overlay planning must route work");
        assert!(gwtf.events_total > 0, "kernel events counted");
        assert!(gwtf.engine_wall_ms > 0.0 && gwtf.events_per_sec() > 0.0);
        // Below PROCEDURAL_MIN_NODES the scale scenario keeps the legacy
        // Dense substrate: n² resident link entries, no congestion cache.
        assert_eq!(gwtf.resident_link_entries, 60 * 60, "dense arm is n²");
        assert_eq!(gwtf.resident_cache_entries, 0, "no cache below the threshold");
        assert!(report.case(60, "swarm").is_some() && report.case(60, "dtfm").is_some());
        // The gwtf-only size runs GWTF and skips both baselines.
        assert!(report.case(72, "gwtf").is_some(), "gwtf-only size measured");
        assert!(report.case(72, "swarm").is_none() && report.case(72, "dtfm").is_none());
        assert_eq!(report.planner_threads, 2);
    }

    #[test]
    fn scale_report_json_roundtrip_and_profile_update() {
        let report = ScaleReport {
            fanout: 8,
            churn_p: 0.2,
            reps: 1,
            iters_per_rep: 2,
            planner_threads: 4,
            cases: vec![ScaleCase {
                relays: 100,
                system: "gwtf".into(),
                plan_calls: 2,
                plan_rounds_total: 57,
                cold_rounds: 41,
                plan_wall_ms: 12.5,
                throughput_total: 30.0,
                events_total: 4096,
                engine_wall_ms: 250.125,
                peak_rss_mib: 41.25,
                resident_link_entries: 100,
                resident_cache_entries: 37,
            }],
            crit_path: CritProfile {
                compute_s: 10.5,
                tx_s: 2.25,
                prop_s: 1.5,
                queue_s: 0.75,
                plan_s: 3.0,
                agg_s: 1.25,
                stale_s: 0.5,
                makespan_s: 19.75,
            },
            peak_rss_mib: 96.5,
        };
        let back = ScaleReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);
        // Pre-raw-speed baselines lack the engine columns and the thread
        // count; they must still parse (the guard's capture mode).
        let mut legacy = report.to_json();
        if let Json::Obj(root) = &mut legacy {
            root.remove("planner_threads");
            root.remove("crit_path");
            root.remove("peak_rss_mib");
            if let Some(Json::Arr(cases)) = root.get_mut("cases") {
                for c in cases {
                    if let Json::Obj(o) = c {
                        o.remove("events_total");
                        o.remove("engine_wall_ms");
                        o.remove("events_per_sec");
                        o.remove("peak_rss_mib");
                        o.remove("resident_link_entries");
                        o.remove("resident_cache_entries");
                    }
                }
            }
        }
        let old = ScaleReport::from_json(&legacy).expect("legacy report parses");
        assert_eq!(old.planner_threads, 1);
        assert_eq!(old.cases[0].events_total, 0);
        assert_eq!(old.cases[0].engine_wall_ms, 0.0);
        assert_eq!(old.cases[0].peak_rss_mib, 0.0);
        assert_eq!(old.cases[0].resident_link_entries, 0);
        assert_eq!(old.cases[0].resident_cache_entries, 0);
        assert_eq!(old.peak_rss_mib, 0.0, "pre-RSS baselines parse as unmeasured");
        assert_eq!(old.crit_path, CritProfile::default(), "missing block is all-zero");

        let dir = std::env::temp_dir().join("gwtf_scale_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_scale.json");
        let _ = std::fs::remove_file(&path);
        assert!(read_scale_profile(&path, "test_sized").is_none(), "missing file");
        update_scale_json(&path, "test_sized", &report).unwrap();
        assert_eq!(read_scale_profile(&path, "test_sized").unwrap(), report);
        assert!(read_scale_profile(&path, "full").is_none(), "other profile null");
        // updating the other profile preserves the first
        update_scale_json(&path, "full", &report).unwrap();
        assert_eq!(read_scale_profile(&path, "test_sized").unwrap(), report);
        assert_eq!(read_scale_profile(&path, "full").unwrap(), report);
    }

    #[test]
    fn plan_lag_sweep_shapes_table_and_report() {
        // Shape checks only — the acceptance property (monotone makespan
        // growth with the round-RTT) is gated by rust/tests/plan_lag.rs,
        // which CI runs in the dedicated guard step; duplicating the
        // heavy sweep here would defeat the workspace-pass --skip.
        let opts = PlanLagOpts {
            rtts_s: vec![0.0, 0.5],
            reps: 1,
            iters_per_rep: 2,
            seed: 5,
            churn_p: 0.2,
        };
        let (t, report) = run_plan_lag(&opts).unwrap();
        assert_eq!(t.cells.len(), 2 * 2, "2 churn rows x 2 RTTs");
        for acc in t.cells.values() {
            assert_eq!(acc.throughput.len(), 2, "1 rep x 2 iterations");
        }
        assert_eq!(report.cases.len(), 4);
        for &(churn, rtt) in &[(0.0, 0.0), (0.0, 0.5), (0.2, 0.0), (0.2, 0.5)] {
            let c = report.case(churn, rtt).expect("case present");
            assert!(c.makespan_mean_s > 0.0 && c.throughput_total > 0.0);
        }
        // On-the-clock sessions record their overlap window.
        assert!(report.case(0.0, 0.5).unwrap().overlap_mean_s > 0.0);
    }

    #[test]
    fn plan_lag_zero_churn_skips_duplicate_row() {
        let opts = PlanLagOpts {
            rtts_s: vec![0.0, 0.5],
            reps: 1,
            iters_per_rep: 2,
            seed: 5,
            churn_p: 0.0, // --churn 0: the churn row would duplicate 0%
        };
        let (t, report) = run_plan_lag(&opts).unwrap();
        assert_eq!(t.cells.len(), 2, "one churn row x 2 RTTs");
        assert_eq!(report.cases.len(), 2, "no duplicate (0.0, rtt) cases");
        for acc in t.cells.values() {
            assert_eq!(acc.throughput.len(), 2, "cells not double-accumulated");
        }
    }

    #[test]
    fn plan_lag_report_json_roundtrip_and_profile_update() {
        let report = PlanLagReport {
            reps: 1,
            iters_per_rep: 4,
            cases: vec![PlanLagCase {
                churn_p: 0.0,
                rtt_s: 2.0,
                makespan_mean_s: 512.25,
                stall_mean_s: 3.5,
                overlap_mean_s: 40.0,
                stale_total: 1,
                throughput_total: 32.0,
            }],
            crit_path: CritProfile { compute_s: 400.5, plan_s: 3.5, ..Default::default() },
            peak_rss_mib: 52.5,
        };
        let back = PlanLagReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);

        let dir = std::env::temp_dir().join("gwtf_planlag_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_planlag.json");
        let _ = std::fs::remove_file(&path);
        assert!(read_plan_lag_profile(&path, "test_sized").is_none(), "missing file");
        update_plan_lag_json(&path, "test_sized", &report).unwrap();
        assert_eq!(read_plan_lag_profile(&path, "test_sized").unwrap(), report);
        assert!(read_plan_lag_profile(&path, "full").is_none(), "other profile null");
        update_plan_lag_json(&path, "full", &report).unwrap();
        assert_eq!(read_plan_lag_profile(&path, "test_sized").unwrap(), report);
        assert_eq!(read_plan_lag_profile(&path, "full").unwrap(), report);
    }

    #[test]
    fn congestion_sweep_shapes_table_and_report() {
        // Shape checks only — the acceptance properties (monotone
        // makespan growth as the NIC cap shrinks, congestion-aware GWTF
        // beating SWARM at tight caps) are gated by
        // rust/tests/congestion_guard.rs, which CI runs in the dedicated
        // guard step.
        let opts = CongestionOpts { nic_caps: vec![0, 1], reps: 1, iters_per_rep: 2, seed: 5 };
        let (t, report) = run_congestion(&opts).unwrap();
        assert_eq!(t.cells.len(), 2 * 4, "2 caps x 4 systems");
        for ((row, col), acc) in &t.cells {
            assert_eq!(acc.throughput.len(), 2, "{row}/{col}: 1 rep x 2 iterations");
        }
        assert_eq!(report.cases.len(), 8);
        for sys in ["gwtf", "gwtf-aware", "swarm", "dtfm"] {
            let free = report.case(0, sys).expect("unlimited case");
            assert_eq!(free.queue_mean_s, 0.0, "{sys}: unlimited NICs never queue");
            assert!(free.throughput_total > 0.0, "{sys}");
            assert!(report.case(1, sys).is_some(), "{sys}: cap-1 case present");
        }
        // The hub-funnelling systems must queue at concurrency 1 (DT-FM's
        // GA may spread enough to dodge it in a run this small).
        for sys in ["gwtf", "gwtf-aware", "swarm"] {
            let tight = report.case(1, sys).unwrap();
            assert!(tight.queue_mean_s > 0.0, "{sys}: cap 1 must queue");
        }
    }

    #[test]
    fn congestion_report_json_roundtrip_and_profile_update() {
        let report = CongestionReport {
            reps: 2,
            iters_per_rep: 3,
            cases: vec![CongestionCase {
                nic: 2,
                system: "gwtf-aware".into(),
                makespan_mean_s: 812.5,
                queue_mean_s: 113.25,
                comm_mean_s: 640.0,
                nic_util_max_mean: 0.62,
                throughput_total: 48.0,
            }],
            crit_path: CritProfile { tx_s: 320.25, queue_s: 113.5, ..Default::default() },
            peak_rss_mib: 64.25,
        };
        let back = CongestionReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);

        let dir = std::env::temp_dir().join("gwtf_congestion_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_congestion.json");
        let _ = std::fs::remove_file(&path);
        assert!(read_congestion_profile(&path, "test_sized").is_none(), "missing file");
        update_congestion_json(&path, "test_sized", &report).unwrap();
        assert_eq!(read_congestion_profile(&path, "test_sized").unwrap(), report);
        assert!(read_congestion_profile(&path, "full").is_none(), "other profile null");
        update_congestion_json(&path, "full", &report).unwrap();
        assert_eq!(read_congestion_profile(&path, "test_sized").unwrap(), report);
        assert_eq!(read_congestion_profile(&path, "full").unwrap(), report);
    }

    #[test]
    fn async_sweep_shapes_table_and_report() {
        // Shape only; the goodput gates live in rust/tests/async_guard.rs
        // (CI's dedicated guard step).
        let opts =
            AsyncOpts { bounds: vec![1, 2], churn_p: 0.0, reps: 1, iters_per_rep: 2, seed: 5 };
        let (t, report) = run_async(&opts).unwrap();
        assert_eq!(t.cells.len(), 3, "sync + 2 bounds");
        for ((row, col), acc) in &t.cells {
            assert_eq!(acc.throughput.len(), 2, "{row}/{col}: 1 rep x 2 iterations");
        }
        assert_eq!(report.cases.len(), 3);
        let sync = report.case(0).expect("sync reference arm");
        assert!(sync.throughput_total > 0.0);
        assert!(sync.goodput() > 0.0);
        assert_eq!(sync.staleness_mean, 0.0);
        assert_eq!(sync.deferred_total, 0.0);
        for s in [1, 2] {
            let arm = report.case(s).expect("async arm");
            assert!(arm.throughput_total > 0.0, "s={s}");
            assert!(arm.agg_mean_s > 0.0, "s={s}: rolling exchanges still charged");
        }
    }

    #[test]
    fn async_report_json_roundtrip_and_profile_update() {
        let report = AsyncReport {
            reps: 2,
            iters_per_rep: 4,
            churn_p: 0.2,
            cases: vec![AsyncCase {
                staleness: 2,
                makespan_total_s: 1900.5,
                agg_mean_s: 14.25,
                staleness_mean: 0.5,
                deferred_total: 3.0,
                throughput_total: 60.0,
            }],
            crit_path: CritProfile { agg_s: 57.0, stale_s: 6.5, ..Default::default() },
            peak_rss_mib: 71.125,
        };
        let back = AsyncReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);

        let dir = std::env::temp_dir().join("gwtf_async_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_async.json");
        let _ = std::fs::remove_file(&path);
        assert!(read_async_profile(&path, "test_sized").is_none(), "missing file");
        update_async_json(&path, "test_sized", &report).unwrap();
        assert_eq!(read_async_profile(&path, "test_sized").unwrap(), report);
        assert!(read_async_profile(&path, "full").is_none(), "other profile null");
        update_async_json(&path, "full", &report).unwrap();
        assert_eq!(read_async_profile(&path, "test_sized").unwrap(), report);
        assert_eq!(read_async_profile(&path, "full").unwrap(), report);
    }

    #[test]
    fn adversary_sweep_shapes_table_and_report() {
        // Shape only; the retention gates live in
        // rust/tests/adversary_guard.rs (CI's dedicated guard step).
        let opts = AdversaryOpts {
            fractions: vec![0.0, 0.25],
            reps: 1,
            iters_per_rep: 2,
            seed: 5,
        };
        let (t, report) = run_adversary(&opts).unwrap();
        assert_eq!(t.cells.len(), 2 * 3, "2 fractions x 3 systems");
        for ((row, col), acc) in &t.cells {
            assert_eq!(acc.throughput.len(), 2, "{row}/{col}: 1 rep x 2 iterations");
        }
        assert_eq!(report.cases.len(), 6);
        for sys in ["gwtf", "gwtf-rep", "swarm"] {
            let clean = report.case(0, sys).expect("clean-fleet case");
            assert!(clean.goodput() > 0.0, "{sys}");
            assert!(report.case(25, sys).is_some(), "{sys}: f=25% case present");
        }
        // With no adversaries the reputation book never leaves its
        // all-honest prior, so both GWTF arms measure identically.
        let obl = report.case(0, "gwtf").unwrap();
        let rep = report.case(0, "gwtf-rep").unwrap();
        assert_eq!(obl.makespan_total_s.to_bits(), rep.makespan_total_s.to_bits());
        assert_eq!(obl.throughput_total, rep.throughput_total);
        // DENY storms must actually show up in the denies column.
        let attacked = report.case(25, "gwtf").unwrap();
        assert!(attacked.denies_total > 0.0, "storm relays must DENY");
    }

    #[test]
    fn adversary_report_json_roundtrip_and_profile_update() {
        let report = AdversaryReport {
            reps: 2,
            iters_per_rep: 4,
            cases: vec![AdversaryCase {
                fraction_pct: 25,
                system: "gwtf-rep".into(),
                makespan_total_s: 2100.25,
                throughput_total: 58.0,
                denies_total: 17.0,
            }],
            crit_path: CritProfile { compute_s: 1800.5, queue_s: 42.0, ..Default::default() },
            peak_rss_mib: 88.75,
        };
        let back = AdversaryReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);

        let dir = std::env::temp_dir().join("gwtf_adversary_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_adversary.json");
        let _ = std::fs::remove_file(&path);
        assert!(read_adversary_profile(&path, "test_sized").is_none(), "missing file");
        update_adversary_json(&path, "test_sized", &report).unwrap();
        assert_eq!(read_adversary_profile(&path, "test_sized").unwrap(), report);
        assert!(read_adversary_profile(&path, "full").is_none(), "other profile null");
        update_adversary_json(&path, "full", &report).unwrap();
        assert_eq!(read_adversary_profile(&path, "test_sized").unwrap(), report);
        assert_eq!(read_adversary_profile(&path, "full").unwrap(), report);
    }

    #[test]
    fn jitter_sweep_produces_all_amplitudes() {
        let t = run_link_jitter(&fast()).unwrap();
        for row in ["jitter 0%", "jitter 25%", "jitter 50%"] {
            let acc = &t.cells[&(row.to_string(), "gwtf".to_string())];
            assert_eq!(acc.throughput.len(), 2 * 3, "{row}");
            assert!(acc.makespan_min.iter().all(|m| m.is_finite()));
        }
    }
}
