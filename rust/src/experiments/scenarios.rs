//! Continuous-time scenarios beyond the paper's tables.
//!
//! These experiments exercise event kinds the old iteration-synchronous
//! simulator could not express (see `sim::engine`):
//!
//! - [`run_mid_agg_crash`] — a relay dies *inside* the §V-E aggregation
//!   barrier; its stage re-runs the invalidated fraction of the weight
//!   exchange among the survivors.  Columns compare a crash-free run,
//!   a mid-aggregation crash, and the same crash under warm re-planning.
//! - [`run_link_jitter`] — piecewise-constant link-latency jitter windows
//!   layered over the Table II topology; columns sweep the jitter
//!   amplitude.
//! - [`run_poisson_churn`] — the §VI churn grid re-run under the
//!   continuous-clock Poisson churn model (`sim::churn`): crash/rejoin
//!   arrivals land mid-iteration from exponential clocks instead of
//!   synchronized Bernoulli flips.  GWTF runs with warm re-planning, so
//!   every arbitrary-timestamp crash exercises `Router::on_crash`
//!   mid-pipeline and the next iteration's warm `Router::replan` repair;
//!   SWARM and DT-FM are the baselines.

use anyhow::Result;

use crate::baselines::GaParams;
use crate::coordinator::GwtfRouter;
use crate::flow::FlowParams;
use crate::metrics::MetricsTable;
use crate::sim::scenario::{build, ScenarioConfig};
use crate::sim::sources::{LinkJitterSource, MidAggCrashSource};
use crate::sim::ChurnModel;

use super::tables::{dtfm_router, swarm_router};

/// Options shared by the continuous-time scenario experiments.
#[derive(Debug, Clone)]
pub struct ScenarioOpts {
    pub reps: usize,
    pub iters_per_rep: usize,
    pub seed: u64,
}

impl Default for ScenarioOpts {
    fn default() -> Self {
        ScenarioOpts { reps: 10, iters_per_rep: 4, seed: 1 }
    }
}

/// Mid-aggregation crash: at iteration 1 a last-stage relay dies halfway
/// through the aggregation barrier.
pub fn run_mid_agg_crash(opts: &ScenarioOpts) -> Result<MetricsTable> {
    let mut table = MetricsTable::new(
        "Mid-aggregation crash — §V-E barrier recovery (continuous-time only)",
    );
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 7919;
        let cfg = ScenarioConfig::table2(true, 0.0, seed);
        let sc = build(&cfg);
        let last_stage = sc.prob.graph.n_stages() - 1;
        let victim = sc.prob.graph.stages[last_stage][0];

        // baseline: same scenario, no crash
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            let cell = table.cell("table2 homogeneous", "no-crash");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
        // the crash, cold re-planning every iteration
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            engine.add_source(Box::new(MidAggCrashSource::new(1, victim, 0.5)));
            let cell = table.cell("table2 homogeneous", "midagg-crash");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
        // the crash, warm-start re-planning (GWTF keeps surviving chains)
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            engine.warm_replan = true;
            engine.add_source(Box::new(MidAggCrashSource::new(1, victim, 0.5)));
            let cell = table.cell("table2 homogeneous", "midagg-crash-warm");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
    }
    Ok(table)
}

/// Link-latency jitter sweep: 0% / 25% / 50% amplitude, fresh multiplier
/// every 30 virtual seconds.
pub fn run_link_jitter(opts: &ScenarioOpts) -> Result<MetricsTable> {
    let mut table =
        MetricsTable::new("Link-latency jitter — time-varying links (continuous-time only)");
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 6007;
        let cfg = ScenarioConfig::table2(true, 0.0, seed);
        let sc = build(&cfg);
        for &(label, amp) in
            &[("jitter 0%", 0.0), ("jitter 25%", 0.25), ("jitter 50%", 0.5)]
        {
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
            let mut engine = sc.engine(seed ^ 0x1);
            if amp > 0.0 {
                engine.add_source(Box::new(LinkJitterSource::new(amp, 30.0, seed ^ 0x11)));
            }
            let cell = table.cell(label, "gwtf");
            for _ in 0..opts.iters_per_rep {
                cell.push(&engine.step(&sc.prob, &mut router));
            }
        }
    }
    Ok(table)
}

/// Continuous-clock Poisson churn: the paper's 10%/20% join-leave grid
/// with crash/rejoin arrivals sampled from rate-equivalent exponential
/// clocks, GWTF (warm re-planning) vs SWARM vs DT-FM.
pub fn run_poisson_churn(opts: &ScenarioOpts) -> Result<MetricsTable> {
    let mut table = MetricsTable::new(
        "Poisson churn — continuous-clock crash/rejoin arrivals (rate-equivalent to §VI churn)",
    );
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 104651;
        for &(row, p) in &[("poisson 10%", 0.1), ("poisson 20%", 0.2)] {
            let mut cfg = ScenarioConfig::table2(true, p, seed);
            cfg.churn_model = ChurnModel::Poisson;
            let sc = build(&cfg);
            // GWTF with warm re-plans: crashes at arbitrary timestamps hit
            // Router::on_crash mid-pipeline; the next iteration's warm
            // replan resumes the surviving chains around them.
            {
                let mut router =
                    GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
                let mut engine = sc.engine(seed ^ 0x1);
                engine.warm_replan = true;
                let cell = table.cell(row, "gwtf");
                for _ in 0..opts.iters_per_rep {
                    cell.push(&engine.step(&sc.prob, &mut router));
                }
            }
            // SWARM: comm-only greedy wiring, full-pipeline restarts.
            {
                let mut router = swarm_router(&sc, seed ^ 0xB);
                let mut engine = sc.engine(seed ^ 0x1);
                let cell = table.cell(row, "swarm");
                for _ in 0..opts.iters_per_rep {
                    cell.push(&engine.step(&sc.prob, &mut router));
                }
            }
            // DT-FM: static GA arrangement, recomputed from scratch when a
            // pipeline node dies (its plan cache sees the churned
            // membership each iteration).
            {
                let mut router = dtfm_router(
                    &sc,
                    GaParams { generations: 60, ..Default::default() },
                    seed ^ 0xC,
                );
                let mut engine = sc.engine(seed ^ 0x1);
                let cell = table.cell(row, "dtfm");
                for _ in 0..opts.iters_per_rep {
                    cell.push(&engine.step(&sc.prob, &mut router));
                }
            }
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> ScenarioOpts {
        ScenarioOpts { reps: 2, iters_per_rep: 3, seed: 7 }
    }

    #[test]
    fn mid_agg_crash_produces_all_columns() {
        let t = run_mid_agg_crash(&fast()).unwrap();
        let row = "table2 homogeneous".to_string();
        for col in ["no-crash", "midagg-crash", "midagg-crash-warm"] {
            let acc = &t.cells[&(row.clone(), col.to_string())];
            assert_eq!(acc.throughput.len(), 2 * 3, "{col}");
        }
        // the crash columns must record exactly one barrier recovery per rep
        let crash = &t.cells[&(row.clone(), "midagg-crash".to_string())];
        assert_eq!(crash.agg_recoveries.iter().sum::<f64>(), 2.0);
        let clean = &t.cells[&(row, "no-crash".to_string())];
        assert_eq!(clean.agg_recoveries.iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn poisson_churn_produces_all_cells() {
        let t = run_poisson_churn(&fast()).unwrap();
        assert_eq!(t.cells.len(), 6, "2 rates x 3 systems");
        for row in ["poisson 10%", "poisson 20%"] {
            for col in ["gwtf", "swarm", "dtfm"] {
                let acc = &t.cells[&(row.to_string(), col.to_string())];
                assert_eq!(acc.throughput.len(), 2 * 3, "{row}/{col}");
                assert!(acc.makespan_min.iter().all(|m| m.is_finite()), "{row}/{col}");
            }
        }
        // GWTF warm-replans must be recorded in the new diagnostics column.
        let gwtf = &t.cells[&("poisson 20%".to_string(), "gwtf".to_string())];
        assert!(
            gwtf.replan_rounds.iter().sum::<f64>() > 0.0,
            "gwtf plans/replans must report protocol rounds"
        );
    }

    #[test]
    fn jitter_sweep_produces_all_amplitudes() {
        let t = run_link_jitter(&fast()).unwrap();
        for row in ["jitter 0%", "jitter 25%", "jitter 50%"] {
            let acc = &t.cells[&(row.to_string(), "gwtf".to_string())];
            assert_eq!(acc.throughput.len(), 2 * 3, "{row}");
            assert!(acc.makespan_min.iter().all(|m| m.is_finite()));
        }
    }
}
