//! Experiment harness: one module per paper table/figure.
//!
//! Every table AND figure in the paper's evaluation (§VI) has a
//! regeneration entry point here, shared by the `gwtf bench` CLI and the
//! `rust/benches/*` targets.  Results are written to `bench_results/` as
//! Markdown + CSV and summarized on stdout.
//!
//! | paper | module | harness |
//! |---|---|---|
//! | Table II (LLaMA-like, crash-prone) | [`tables`] | `run_table2` |
//! | Table III (GPT-like, crash-prone) | [`tables`] | `run_table3` |
//! | Table VI (vs DT-FM optimal schedule) | [`tables`] | `run_table6` |
//! | Fig. 5 (node addition) | [`figures`] | `run_fig5` |
//! | Fig. 6 (loss convergence) | [`figures`] | `run_fig6` (needs artifacts) |
//! | Fig. 7 (flow tests 1–6) | [`figures`] | `run_fig7` |
//!
//! Beyond the paper, [`scenarios`] holds continuous-time experiments the
//! old per-iteration churn model could not express (mid-aggregation
//! crashes, link-latency jitter, continuous-clock Poisson churn, the
//! gossip-overlay scale sweep at 100+ relays, the plan-lifecycle
//! round-RTT sweep, the shared-capacity NIC congestion sweep, the
//! bounded-staleness asynchronous-training sweep, and the
//! adversarial-relay reputation sweep) —
//! `gwtf bench midagg|jitter|poissonchurn|scale|planlag|congestion|async|adversary`.

pub mod figures;
pub mod scenarios;
pub mod tables;

pub use figures::{fig5_summary, run_fig5, run_fig6, run_fig7, Fig6Opts};
pub use scenarios::{
    adversary_json_path, async_json_path, congestion_json_path, plan_lag_json_path,
    read_adversary_profile, read_async_profile, read_congestion_profile, read_plan_lag_profile,
    read_scale_profile, run_adversary, run_async, run_congestion, run_link_jitter,
    run_mid_agg_crash, run_plan_lag, run_poisson_churn, run_scale, scale_json_path,
    update_adversary_json, update_async_json, update_congestion_json, update_plan_lag_json,
    update_scale_json, AdversaryCase, AdversaryOpts, AdversaryReport, AsyncCase, AsyncOpts,
    AsyncReport, CongestionCase, CongestionOpts, CongestionReport, CritProfile, PlanLagCase,
    PlanLagOpts, PlanLagReport, ScaleOpts, ScaleReport, ScenarioOpts,
};
pub use tables::{run_table2, run_table3, run_table6, TableOpts};

/// Where reports land (`bench_results/` next to the manifest).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("GWTF_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("bench_results"))
}
