//! Figures 5, 6 and 7 (paper §VI "Handling Joining Nodes", "Training
//! Convergence" and "Ablation studies").

use std::sync::Arc;

use anyhow::Result;

use crate::baselines::join_eval::{compare_policies, JoinSetting};
use crate::baselines::SwarmRouter;
use crate::flow::decentralized::{DecentralizedFlow, FlowParams};
use crate::flow::graph::random_problem;
use crate::flow::mcmf::mcmf_min_cost;
use crate::metrics::SeriesReport;
use crate::sim::scenario::ScenarioConfig;
use crate::trainer::{ChurnTrainer, PipelineTrainer};
use crate::util::{Rng, Summary};

/// Fig. 5: average improvement of the node-insertion sequence under the
/// four placement policies, per Table IV setting, over `runs` seeds.
///
/// `full` switches between the paper-size instance (97 nodes, 20 joins —
/// slow because the optimal baseline is exhaustive) and a reduced instance
/// with the same structure.
pub fn run_fig5(runs: usize, seed: u64, full: bool) -> Result<SeriesReport> {
    let mut report = SeriesReport::new(
        "Fig. 5 — node-addition improvement (higher is better)",
        "setting",
    );
    for si in 1..=5 {
        let setting =
            if full { JoinSetting::setting(si) } else { JoinSetting::setting(si).reduced() };
        let mut per_policy: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        for run in 0..runs {
            let outcomes = compare_policies(&setting, seed + run as u64 * 31);
            for (name, o) in outcomes {
                per_policy.entry(name).or_default().push(o.improvement());
            }
        }
        for (name, xs) in per_policy {
            let s = Summary::of(&xs);
            report.push(name, si as f64, s.mean);
            report.push(&format!("{name}_std"), si as f64, s.std);
        }
    }
    Ok(report)
}

/// One Fig. 7 flow-test: run the decentralized optimizer for up to 120
/// rounds, recording avg cost per microbatch per round; plus the SWARM
/// greedy baseline and (tests 1–4, single source) the exact optimum.
pub fn run_fig7(reps: usize, seed: u64) -> Result<SeriesReport> {
    let mut report =
        SeriesReport::new("Fig. 7 — average cost per microbatch in flow tests", "round");
    // Table V settings: (sources, relays, stages, cap range, cost range)
    let settings: [(usize, usize, usize, (f64, f64), (f64, f64)); 6] = [
        (1, 40, 8, (1.0, 3.0), (1.0, 20.0)),
        (1, 40, 10, (1.0, 3.0), (1.0, 20.0)),
        (1, 40, 8, (5.0, 15.0), (1.0, 20.0)),
        (1, 40, 8, (1.0, 3.0), (5.0, 100.0)),
        (2, 40, 8, (1.0, 3.0), (1.0, 20.0)),
        (4, 80, 8, (1.0, 3.0), (1.0, 20.0)),
    ];
    for (ti, &(sources, relays, stages, caps, costs)) in settings.iter().enumerate() {
        let test = ti + 1;
        let mut gwtf_final = Vec::new();
        let mut swarm_final = Vec::new();
        let mut opt_final = Vec::new();
        for rep in 0..reps {
            let s = seed + rep as u64 * 131;
            let mut rng = Rng::new(s);
            let prob = random_problem(sources, relays, stages, caps, costs, &mut rng);

            // GWTF decentralized optimizer, per-round trace.  "In order to
            // compare to the optimal result of Fulkerson's algorithm, our
            // procedure attempts to minimize the sum of the costs of all
            // flows" (§VI Ablation) — so the sum objective is used here.
            let params = FlowParams { minmax_objective: false, ..FlowParams::default() };
            let mut f = DecentralizedFlow::new(&prob, params, s ^ 0xF);
            let stats = f.run(120, 120); // fixed 120 rounds, no early stop
            for st in &stats {
                if st.complete_flows > 0 {
                    report.push(
                        &format!("t{test}_gwtf"),
                        st.round as f64,
                        st.avg_cost_per_microbatch,
                    );
                }
            }
            if f.complete_flows() > 0 {
                gwtf_final.push(f.total_cost() / f.complete_flows() as f64);
            }

            // SWARM greedy baseline (one-shot wiring)
            let cost_fn: crate::baselines::CostFn = {
                let mut rng2 = Rng::new(s);
                let prob2 = random_problem(sources, relays, stages, caps, costs, &mut rng2);
                Arc::new(move |i, j| prob2.cost(i, j))
            };
            let mut swarm = SwarmRouter::from_problem(&prob, cost_fn, s ^ 0x5);
            // The Table V instances have binding capacities U(1,3); a
            // capacity-oblivious wiring would route flow the instance
            // forbids, so the greedy baseline honours caps here.
            swarm.ignore_capacity = false;
            let alive = vec![true; prob.cap.len()];
            let (paths, _) =
                crate::sim::training::BlockingPlanner::plan_once(&mut swarm, &alive);
            if !paths.is_empty() {
                swarm_final.push(swarm.total_cost(&paths) / paths.len() as f64);
            }

            // Exact optimum (single-commodity tests only, as in the paper)
            if sources == 1 {
                let opt = mcmf_min_cost(&prob);
                if opt.flow > 0 {
                    opt_final.push(opt.total_cost / opt.flow as f64);
                }
            }
        }
        let s1 = Summary::of(&gwtf_final);
        report.push(&format!("t{test}_gwtf_final"), 120.0, s1.mean);
        let s2 = Summary::of(&swarm_final);
        report.push(&format!("t{test}_swarm_final"), 120.0, s2.mean);
        if !opt_final.is_empty() {
            let s3 = Summary::of(&opt_final);
            report.push(&format!("t{test}_optimal_final"), 120.0, s3.mean);
        }
    }
    Ok(report)
}

/// Per-setting Fig. 5 summary table (the `to_text` view shows only the
/// final setting; this prints all five, like the paper's bar groups).
pub fn fig5_summary(report: &SeriesReport) -> String {
    let mut s = format!(
        "{:>8} {:>8} {:>10} {:>8} {:>8}\n",
        "setting", "gwtf", "cap-first", "random", "optimal"
    );
    for i in 0..5 {
        let get = |name: &str| {
            report.series.get(name).and_then(|v| v.get(i)).map(|&(_, y)| y).unwrap_or(f64::NAN)
        };
        s.push_str(&format!(
            "{:>8} {:>8.3} {:>10.3} {:>8.3} {:>8.3}\n",
            i + 1,
            get("gwtf"),
            get("capacity-first"),
            get("random"),
            get("optimal"),
        ));
    }
    s
}

/// Fig. 6 options (the only experiment that needs `make artifacts`).
#[derive(Debug, Clone)]
pub struct Fig6Opts {
    pub artifacts_dir: std::path::PathBuf,
    pub family: String,
    pub steps: usize,
    pub microbatches_per_step: usize,
    pub lr: f32,
    pub churn_p: f64,
    pub seed: u64,
}

impl Default for Fig6Opts {
    fn default() -> Self {
        Fig6Opts {
            artifacts_dir: crate::runtime::Manifest::default_dir(),
            family: "llama".into(),
            steps: 40,
            microbatches_per_step: 4,
            lr: 0.1,
            churn_p: 0.1,
            seed: 42,
        }
    }
}

/// Fig. 6: loss convergence of GWTF under churn vs the centralized
/// baseline with the same batch schedule.  Returns (report, max |Δloss|).
///
/// GWTF executes the full model per microbatch (like the centralized
/// run), so the two loss curves must be *identical* — this harness
/// verifies the paper's convergence claim in its strongest form, while
/// also recording the simulated iteration times of the churned run.
pub fn run_fig6(opts: &Fig6Opts) -> Result<(SeriesReport, f64)> {
    let mut report = SeriesReport::new("Fig. 6 — loss convergence", "step");

    // centralized baseline
    let mut central = PipelineTrainer::new(
        &opts.artifacts_dir,
        &opts.family,
        opts.seed,
        opts.lr,
        opts.microbatches_per_step,
    )?;
    let mut central_losses = Vec::with_capacity(opts.steps);
    for _ in 0..opts.steps {
        let m = central.step()?;
        central_losses.push(m.loss);
        report.push("centralized", m.step as f64, m.loss);
    }

    // GWTF under churn (same seed -> same params + same batches)
    let trainer = PipelineTrainer::new(
        &opts.artifacts_dir,
        &opts.family,
        opts.seed,
        opts.lr,
        opts.microbatches_per_step,
    )?;
    let mut cfg = ScenarioConfig::table2(false, opts.churn_p, opts.seed);
    cfg.microbatches_per_data = (opts.microbatches_per_step / 2).max(1);
    let mut gwtf = ChurnTrainer::new(trainer, &cfg);
    let mut max_delta: f64 = 0.0;
    for i in 0..opts.steps {
        let m = gwtf.step()?;
        report.push("gwtf_churn", m.step as f64, m.loss);
        report.push("gwtf_sim_makespan_s", m.step as f64, m.sim_makespan_s);
        max_delta = max_delta.max((m.loss - central_losses[i]).abs());
    }
    Ok((report, max_delta))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_small_run_has_expected_series() {
        let r = run_fig7(1, 3).unwrap();
        assert!(r.series.contains_key("t1_gwtf"));
        assert!(r.series.contains_key("t1_swarm_final"));
        assert!(r.series.contains_key("t1_optimal_final"));
        // multi-source tests have no optimal baseline
        assert!(!r.series.contains_key("t5_optimal_final"));
        assert!(!r.series.contains_key("t6_optimal_final"));
    }

    #[test]
    fn fig7_gwtf_beats_swarm_on_average() {
        // The paper's ablation: GWTF consistently outperforms the greedy
        // baseline by up to 50%.
        let r = run_fig7(3, 17).unwrap();
        let mut wins = 0;
        for t in 1..=6 {
            let g = r.series[&format!("t{t}_gwtf_final")].last().unwrap().1;
            let s = r.series[&format!("t{t}_swarm_final")].last().unwrap().1;
            if g <= s + 1e-9 {
                wins += 1;
            }
        }
        assert!(wins >= 4, "gwtf won only {wins}/6 flow tests");
    }

    #[test]
    fn fig7_optimal_lower_bounds_gwtf() {
        let r = run_fig7(2, 23).unwrap();
        for t in 1..=4 {
            let g = r.series[&format!("t{t}_gwtf_final")].last().unwrap().1;
            let o = r.series[&format!("t{t}_optimal_final")].last().unwrap().1;
            assert!(o <= g + 1e-6, "t{t}: optimal {o} above gwtf {g}");
        }
    }

    #[test]
    fn fig5_reports_all_policies() {
        // Uses a reduced setting via small runs to stay fast: patch the
        // runs count down and assert the series exist.
        let r = run_fig5(1, 9, false).unwrap();
        for p in ["gwtf", "capacity-first", "random", "optimal"] {
            assert!(r.series.contains_key(p), "missing {p}");
            assert_eq!(r.series[p].len(), 5, "5 settings");
        }
    }

    #[test]
    fn fig5_optimal_dominates() {
        let r = run_fig5(1, 13, false).unwrap();
        for i in 0..5 {
            let opt = r.series["optimal"][i].1;
            for p in ["gwtf", "capacity-first", "random"] {
                assert!(opt >= r.series[p][i].1 - 1e-9, "setting {i}: optimal below {p}");
            }
        }
    }
}
