//! Tables II, III and VI (paper §VI "Node Crashes" and "Optimality").

use std::sync::Arc;

use anyhow::Result;

use crate::baselines::{DtfmRouter, GaParams, SwarmRouter};
use crate::coordinator::GwtfRouter;
use crate::flow::FlowParams;
use crate::metrics::MetricsTable;
use crate::sim::engine::Engine;
use crate::sim::scenario::{build, Family, Scenario, ScenarioConfig};
use crate::sim::training::{
    BlockingPlanAdapter, PlanOutcome, PlanRequest, PlanTicket, RecoveryPolicy, RoutingPolicy,
};

/// Harness options for the table experiments.
#[derive(Debug, Clone)]
pub struct TableOpts {
    /// Independent repetitions per cell (paper: 25).
    pub reps: usize,
    /// Training iterations simulated per repetition (each iteration is a
    /// metric sample; churn state evolves across them).
    pub iters_per_rep: usize,
    pub seed: u64,
    /// Ablation: force GWTF to SWARM-style full-restart recovery.
    pub gwtf_restart_recovery: bool,
    /// Ablation: disable simulated annealing in the flow optimizer.
    pub no_anneal: bool,
    /// Ablation: sum-cost objective instead of min-max.
    pub sum_objective: bool,
    /// Use warm-start incremental re-planning after the first iteration
    /// (GWTF resumes from surviving chains; the single-shot baselines
    /// ignore the warm hint and cold-plan).  Off by default: the paper
    /// harness re-plans from scratch every iteration.
    pub warm_replan: bool,
}

impl Default for TableOpts {
    fn default() -> Self {
        TableOpts {
            reps: 25,
            iters_per_rep: 4,
            seed: 1,
            gwtf_restart_recovery: false,
            no_anneal: false,
            sum_objective: false,
            warm_replan: false,
        }
    }
}

impl TableOpts {
    fn flow_params(&self) -> FlowParams {
        let mut p = FlowParams::default();
        if self.no_anneal {
            p.temperature = 1e-12;
        }
        if self.sum_objective {
            p.minmax_objective = false;
        }
        p
    }
}

/// GWTF router with an optional recovery-policy override (ablation).
struct GwtfWithPolicy {
    inner: GwtfRouter,
    policy: RecoveryPolicy,
}

impl RoutingPolicy for GwtfWithPolicy {
    fn name(&self) -> String {
        self.inner.name()
    }
    fn request_plan(&mut self, req: &PlanRequest) -> PlanTicket {
        self.inner.request_plan(req)
    }
    fn commit_plan(
        &mut self,
        ticket: &PlanTicket,
        invalidated: &[crate::cost::NodeId],
    ) -> PlanOutcome {
        self.inner.commit_plan(ticket, invalidated)
    }
    fn on_crash(&mut self, node: crate::cost::NodeId) {
        self.inner.on_crash(node)
    }
    fn choose_replacement(
        &mut self,
        prev: crate::cost::NodeId,
        next: crate::cost::NodeId,
        candidates: &[crate::cost::NodeId],
    ) -> Option<crate::cost::NodeId> {
        self.inner.choose_replacement(prev, next, candidates)
    }
    fn last_plan_rounds(&self) -> usize {
        self.inner.last_plan_rounds()
    }
    fn on_gossip(&mut self, t: crate::sim::events::Time) {
        self.inner.on_gossip(t)
    }
    fn recovery(&self) -> RecoveryPolicy {
        self.policy
    }
}

/// Simulate `iters` iterations of `router` on a fresh copy of `scenario`'s
/// churn process (via the continuous-time [`Engine`]), pushing each
/// iteration's metrics into `push`.
fn simulate(
    sc: &Scenario,
    router: &mut dyn RoutingPolicy,
    iters: usize,
    seed: u64,
    warm_replan: bool,
    mut push: impl FnMut(&crate::sim::IterationMetrics),
) {
    let mut engine = Engine::from_scenario(sc, seed);
    engine.warm_replan = warm_replan;
    for _ in 0..iters {
        let m = engine.step(&sc.prob, router);
        push(&m);
    }
}

fn gwtf_router(sc: &Scenario, opts: &TableOpts, seed: u64) -> GwtfWithPolicy {
    let policy = if opts.gwtf_restart_recovery {
        RecoveryPolicy::RestartPipeline
    } else {
        RecoveryPolicy::RepairPath
    };
    GwtfWithPolicy { inner: GwtfRouter::from_scenario(sc, opts.flow_params(), seed), policy }
}

/// SWARM baseline wired from a scenario; shared with the continuous-time
/// scenario experiments.  SWARM wires to the *closest* next-stage node —
/// network proximity only ("sending to the next stage closest node",
/// SVI) — unlike GWTF's Eq. 1 cost, it is blind to compute heterogeneity.
pub(crate) fn swarm_router(sc: &Scenario, seed: u64) -> BlockingPlanAdapter<SwarmRouter> {
    let topo = sc.topo.clone();
    let payload = sc.sim_cfg.payload_bytes;
    let comm: crate::baselines::CostFn = Arc::new(move |i, j| topo.comm(i, j, payload));
    BlockingPlanAdapter::new(SwarmRouter::from_problem(&sc.prob, comm, seed))
}

/// DT-FM baseline wired from a scenario (full Eq. 1 cost closure); shared
/// with the continuous-time scenario experiments.
pub(crate) fn dtfm_router(
    sc: &Scenario,
    params: GaParams,
    seed: u64,
) -> BlockingPlanAdapter<DtfmRouter> {
    let topo = sc.topo.clone();
    let payload = sc.sim_cfg.payload_bytes;
    let cost: crate::baselines::CostFn = Arc::new(move |i, j| topo.cost(i, j, payload));
    BlockingPlanAdapter::new(DtfmRouter::new(
        sc.prob.graph.clone(),
        sc.prob.demand.clone(),
        cost,
        params,
        seed,
    ))
}

/// The Table II / Table III grid: {homogeneous, heterogeneous} x
/// {0%, 10%, 20%} churn, GWTF vs SWARM.
fn run_crash_table(family: Family, title: &str, opts: &TableOpts) -> Result<MetricsTable> {
    let mut table = MetricsTable::new(title);
    for &homogeneous in &[true, false] {
        for &churn in &[0.0, 0.1, 0.2] {
            let row = format!(
                "{} {:.0}%",
                if homogeneous { "homogeneous" } else { "heterogeneous" },
                churn * 100.0
            );
            for rep in 0..opts.reps {
                let seed = opts.seed + rep as u64 * 7919;
                let mut cfg = ScenarioConfig::table2(homogeneous, churn, seed);
                cfg.family = family;
                let sc = build(&cfg);
                {
                    let mut r = gwtf_router(&sc, opts, seed ^ 0xA);
                    let cell = table.cell(&row, "gwtf");
                    simulate(&sc, &mut r, opts.iters_per_rep, seed ^ 0x1, opts.warm_replan, |m| cell.push(m));
                }
                {
                    let mut r = swarm_router(&sc, seed ^ 0xB);
                    let cell = table.cell(&row, "swarm");
                    simulate(&sc, &mut r, opts.iters_per_rep, seed ^ 0x1, opts.warm_replan, |m| cell.push(m));
                }
            }
        }
    }
    Ok(table)
}

/// Table II: LLaMA-like model under churn, GWTF vs SWARM.
pub fn run_table2(opts: &TableOpts) -> Result<MetricsTable> {
    run_crash_table(Family::Llama, "Table II — LLaMA-like, crash-prone devices", opts)
}

/// Table III: GPT-like model under churn, GWTF vs SWARM.
pub fn run_table3(opts: &TableOpts) -> Result<MetricsTable> {
    run_crash_table(Family::Gpt, "Table III — GPT-like, crash-prone devices", opts)
}

/// Table VI: GWTF vs DT-FM's communication-optimal GPipe schedule
/// (3 data nodes, 15 relays, 6 stages, no churn).
pub fn run_table6(opts: &TableOpts) -> Result<MetricsTable> {
    let mut table = MetricsTable::new("Table VI — comparison against optimal schedule");
    for rep in 0..opts.reps {
        let seed = opts.seed + rep as u64 * 104729;
        let cfg = ScenarioConfig::table6(seed);
        let sc = build(&cfg);
        {
            let mut r = gwtf_router(&sc, opts, seed ^ 0xA);
            let cell = table.cell("0% homogeneous", "gwtf");
            simulate(&sc, &mut r, opts.iters_per_rep, seed ^ 0x1, opts.warm_replan, |m| cell.push(m));
        }
        {
            let mut r = dtfm_router(&sc, GaParams::default(), seed ^ 0xB);
            let cell = table.cell("0% homogeneous", "dtfm");
            simulate(&sc, &mut r, opts.iters_per_rep, seed ^ 0x1, opts.warm_replan, |m| cell.push(m));
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> TableOpts {
        TableOpts { reps: 2, iters_per_rep: 2, seed: 5, ..Default::default() }
    }

    #[test]
    fn table2_produces_all_cells() {
        let t = run_table2(&fast()).unwrap();
        assert_eq!(t.cells.len(), 12, "6 settings x 2 systems");
        for ((row, _), acc) in &t.cells {
            assert!(!acc.throughput.is_empty(), "{row}");
        }
    }

    #[test]
    fn table2_gwtf_wastes_less_gpu_under_churn() {
        // The paper's headline: SWARM wastes more GPU time when crashes
        // occur (full pipeline recomputation).
        let opts = TableOpts { reps: 6, iters_per_rep: 4, seed: 11, ..Default::default() };
        let t = run_table2(&opts).unwrap();
        let key = |sys: &str| ("heterogeneous 20%".to_string(), sys.to_string());
        let gwtf: f64 = t.cells[&key("gwtf")].wasted_gpu_min.iter().sum();
        let swarm: f64 = t.cells[&key("swarm")].wasted_gpu_min.iter().sum();
        assert!(gwtf <= swarm + 1e-9, "gwtf wasted {gwtf} vs swarm {swarm}");
    }

    #[test]
    fn table6_has_both_systems() {
        let opts = TableOpts { reps: 1, iters_per_rep: 1, seed: 3, ..Default::default() };
        let t = run_table6(&opts).unwrap();
        assert!(t.cells.contains_key(&("0% homogeneous".into(), "gwtf".into())));
        assert!(t.cells.contains_key(&("0% homogeneous".into(), "dtfm".into())));
    }

    #[test]
    fn ablation_flags_apply() {
        let o = TableOpts { no_anneal: true, sum_objective: true, ..Default::default() };
        let p = o.flow_params();
        assert!(p.temperature < 1e-6);
        assert!(!p.minmax_objective);
    }
}
