//! The paper's cost model (§IV, Eq. 1).
//!
//! The cost of exchanging one microbatch between nodes *i* and *j* is
//!
//! ```text
//! d(i,j) = (c_i + c_j)/2 + (λ_ij + λ_ji)/2 + 2·size / (β_ij + β_ji)
//! ```
//!
//! where `c` is per-microbatch computation time, `λ` one-way network
//! latency, `β` link bandwidth and `size` the activation payload.  Links
//! are asymmetric (λ_ij ≠ λ_ji in general) but each link is used once per
//! direction per iteration (forward + backward), so the paper averages the
//! two directions — Eq. 1 does exactly that.

pub mod activation;

pub use activation::ActivationProfile;

/// Identifier of a node in the system. Dense indices into topology tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Per-node compute/memory profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeProfile {
    /// Per-microbatch forward-pass computation time, seconds (the paper's `c_i`).
    pub compute_s: f64,
    /// Max number of microbatches resident at once (the paper's `cap_i`).
    pub capacity: usize,
}

impl NodeProfile {
    pub fn new(compute_s: f64, capacity: usize) -> Self {
        NodeProfile { compute_s, capacity }
    }

    /// Backward passes cost ~2x the forward (standard for transformer training).
    pub fn backward_s(&self) -> f64 {
        2.0 * self.compute_s
    }
}

/// One directed link's parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// One-way latency, seconds (the paper's `λ_ij`).
    pub latency_s: f64,
    /// Bandwidth, bytes/second (the paper's `β_ij`).
    pub bandwidth_bps: f64,
}

impl LinkParams {
    pub fn new(latency_s: f64, bandwidth_bps: f64) -> Self {
        LinkParams { latency_s, bandwidth_bps }
    }

    /// Time to push `size` bytes one-way over this link (latency + transfer).
    pub fn one_way_s(&self, size_bytes: f64) -> f64 {
        self.latency_s + size_bytes / self.bandwidth_bps
    }
}

/// Eq. 1: averaged bidirectional microbatch-exchange cost between two nodes.
///
/// `size_bytes` is the activation (forward) / gradient (backward) payload.
pub fn edge_cost(
    ci: &NodeProfile,
    cj: &NodeProfile,
    ij: &LinkParams,
    ji: &LinkParams,
    size_bytes: f64,
) -> f64 {
    let compute = (ci.compute_s + cj.compute_s) / 2.0;
    let latency = (ij.latency_s + ji.latency_s) / 2.0;
    let transfer = 2.0 * size_bytes / (ij.bandwidth_bps + ji.bandwidth_bps);
    compute + latency + transfer
}

/// Pure-communication variant of Eq. 1 (used when compute is accounted
/// separately by the event simulator, to avoid double counting).
pub fn comm_cost(ij: &LinkParams, ji: &LinkParams, size_bytes: f64) -> f64 {
    (ij.latency_s + ji.latency_s) / 2.0 + 2.0 * size_bytes / (ij.bandwidth_bps + ji.bandwidth_bps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mbps(m: f64) -> f64 {
        m * 1e6 / 8.0 // Mb/s -> bytes/s
    }

    #[test]
    fn eq1_matches_hand_computation() {
        // c_i = 1s, c_j = 3s, λ = 0.1/0.3s, β = 100/300 Mb/s, size = 1 MB
        let ci = NodeProfile::new(1.0, 4);
        let cj = NodeProfile::new(3.0, 4);
        let ij = LinkParams::new(0.1, mbps(100.0));
        let ji = LinkParams::new(0.3, mbps(300.0));
        let size = 1e6;
        let expect = (1.0 + 3.0) / 2.0 + (0.1 + 0.3) / 2.0 + 2.0 * size / (mbps(100.0) + mbps(300.0));
        let got = edge_cost(&ci, &cj, &ij, &ji, size);
        assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
    }

    #[test]
    fn eq1_symmetric_in_direction() {
        // Because both directions are averaged, d(i,j) == d(j,i).
        let ci = NodeProfile::new(1.0, 1);
        let cj = NodeProfile::new(2.0, 1);
        let ij = LinkParams::new(0.05, mbps(50.0));
        let ji = LinkParams::new(0.2, mbps(500.0));
        let a = edge_cost(&ci, &cj, &ij, &ji, 12345.0);
        let b = edge_cost(&cj, &ci, &ji, &ij, 12345.0);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn bigger_payload_costs_more() {
        let c = NodeProfile::new(0.0, 1);
        let l = LinkParams::new(0.0, mbps(100.0));
        assert!(edge_cost(&c, &c, &l, &l, 2e6) > edge_cost(&c, &c, &l, &l, 1e6));
    }

    #[test]
    fn comm_cost_excludes_compute() {
        let l = LinkParams::new(0.1, mbps(100.0));
        let c = comm_cost(&l, &l, 0.0);
        assert!((c - 0.1).abs() < 1e-12);
    }

    #[test]
    fn one_way_time() {
        let l = LinkParams::new(0.01, 1e6);
        assert!((l.one_way_s(5e5) - 0.51).abs() < 1e-12);
    }

    #[test]
    fn backward_is_double_forward() {
        let p = NodeProfile::new(1.5, 2);
        assert_eq!(p.backward_s(), 3.0);
    }
}
