//! The paper's cost model (§IV, Eq. 1) and its shared-capacity extension.
//!
//! The cost of exchanging one microbatch between nodes *i* and *j* is
//!
//! ```text
//! d(i,j) = (c_i + c_j)/2 + (λ_ij + λ_ji)/2 + 2·size / (β_ij + β_ji)
//! ```
//!
//! where `c` is per-microbatch computation time, `λ` one-way network
//! latency, `β` link bandwidth and `size` the activation payload.  Links
//! are asymmetric (λ_ij ≠ λ_ji in general) but each link is used once per
//! direction per iteration (forward + backward), so the paper averages the
//! two directions — Eq. 1 does exactly that.
//!
//! # Where the contention-free assumption is relaxed
//!
//! Eq. 1's transfer term charges each microbatch as if it had the link to
//! itself: eight microbatches fanning into one relay all "transmit"
//! simultaneously at full bandwidth.  Since the shared-capacity network
//! substrate landed, that fiction holds only in the *degenerate*
//! configuration ([`NicConfig::is_unlimited`], the default, bit-for-bit
//! the legacy model).  With finite NIC concurrency:
//!
//! - **Execution** serializes transmissions per NIC — the simulator books
//!   every payload transfer through per-node uplink/downlink queues
//!   ([`crate::sim::events::NicQueues`], the bandwidth analog of the
//!   compute `Slots`).  Transmission time queues; propagation latency
//!   still pipelines.
//! - **Planning** can stay honest about it — [`expected_queue_s`] is the
//!   expected-queueing term a congestion-aware planner adds per edge
//!   (`ScenarioConfig::congestion_aware_planning` routes the Eq. 1 cost
//!   closure through it), derived from the *same* substrate parameters
//!   ([`NicConfig`]) the simulator executes, so capacity-aware routing
//!   and the physical model never disagree about what a NIC can carry.
//!
//! The rate mapping from β to NIC capacity: β stays the per-transmission
//! bandwidth; the NIC concurrency cap `c` bounds how many transmissions
//! share the interface at once, so a NIC's aggregate drain rate is at
//! most `c·β` and a backlog of `k` queued transfers waits
//! `⌈k/c⌉ · size/β`.

pub mod activation;

pub use activation::ActivationProfile;

/// Identifier of a node in the system. Dense indices into topology tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Per-node compute/memory profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeProfile {
    /// Per-microbatch forward-pass computation time, seconds (the paper's `c_i`).
    pub compute_s: f64,
    /// Max number of microbatches resident at once (the paper's `cap_i`).
    pub capacity: usize,
}

impl NodeProfile {
    pub fn new(compute_s: f64, capacity: usize) -> Self {
        NodeProfile { compute_s, capacity }
    }

    /// Backward passes cost ~2x the forward (standard for transformer training).
    pub fn backward_s(&self) -> f64 {
        2.0 * self.compute_s
    }
}

/// One directed link's parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// One-way latency, seconds (the paper's `λ_ij`).
    pub latency_s: f64,
    /// Bandwidth, bytes/second (the paper's `β_ij`).
    pub bandwidth_bps: f64,
}

impl LinkParams {
    pub fn new(latency_s: f64, bandwidth_bps: f64) -> Self {
        LinkParams { latency_s, bandwidth_bps }
    }

    /// Time to push `size` bytes one-way over this link (latency + transfer).
    pub fn one_way_s(&self, size_bytes: f64) -> f64 {
        self.latency_s + size_bytes / self.bandwidth_bps
    }
}

/// Per-node NIC concurrency: how many transmissions one network
/// interface sustains at once, by link class (intra-region LAN vs
/// inter-region WAN — geo-distributed nodes typically have a fat local
/// interface and a thin WAN uplink).  `None` = unlimited, the legacy
/// contention-free model; the simulator and the congestion-aware cost
/// term both read their capacity law from this one struct.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NicConfig {
    /// Max concurrent inter-region (WAN) transmissions per NIC direction.
    pub wan_concurrency: Option<usize>,
    /// Max concurrent intra-region (LAN) transmissions per NIC direction.
    pub lan_concurrency: Option<usize>,
}

impl NicConfig {
    /// The legacy contention-free model (both classes unlimited).
    pub const UNLIMITED: NicConfig =
        NicConfig { wan_concurrency: None, lan_concurrency: None };

    /// Same finite concurrency for both link classes.
    pub fn uniform(concurrency: usize) -> Self {
        assert!(concurrency > 0, "NIC concurrency must be >= 1");
        NicConfig {
            wan_concurrency: Some(concurrency),
            lan_concurrency: Some(concurrency),
        }
    }

    /// True iff no class is capped — every transfer site then takes the
    /// legacy code path, bit for bit.
    pub fn is_unlimited(&self) -> bool {
        self.wan_concurrency.is_none() && self.lan_concurrency.is_none()
    }

    /// Concurrency cap for a transfer's link class.
    ///
    /// Panics on a configured cap of 0 (the fields are public, so a
    /// literal can bypass [`NicConfig::uniform`]'s check): a zero cap
    /// would silently turn every queueing term into inf/NaN and wedge
    /// the substrate, so it is rejected at the single lookup chokepoint
    /// every consumer goes through.
    pub fn cap(&self, same_region: bool) -> Option<usize> {
        let cap = if same_region {
            self.lan_concurrency
        } else {
            self.wan_concurrency
        };
        assert!(cap != Some(0), "NIC concurrency must be >= 1 (use None for unlimited)");
        cap
    }
}

/// Expected NIC-queueing seconds a planner should charge on edge
/// `i -> j` on top of Eq. 1, given the substrate's concurrency cap.
///
/// Rationale: node capacity `cap_i` bounds how many microbatches can be
/// resident at once, so up to `cap_i - 1` other transfers contend for
/// `i`'s uplink and `cap_j - 1` for `j`'s downlink; on average half of
/// them are ahead of a new arrival, served `nic_concurrency` at a time,
/// each occupying the NIC for the edge's transmission time `tx_s`
/// (Eq. 1's `2·size/(β_ij+β_ji)` term).  Zero when nothing else can
/// contend (`cap == 1`); grows linearly as the concurrency shrinks —
/// which is exactly what makes fan-in hotspots expensive to a
/// congestion-aware planner and invisible to a capacity-oblivious one.
pub fn expected_queue_s(cap_i: usize, cap_j: usize, tx_s: f64, nic_concurrency: usize) -> f64 {
    let contenders = (cap_i.saturating_sub(1) + cap_j.saturating_sub(1)) as f64;
    tx_s * contenders / (2.0 * nic_concurrency as f64)
}

/// Eq. 1: averaged bidirectional microbatch-exchange cost between two nodes.
///
/// `size_bytes` is the activation (forward) / gradient (backward) payload.
pub fn edge_cost(
    ci: &NodeProfile,
    cj: &NodeProfile,
    ij: &LinkParams,
    ji: &LinkParams,
    size_bytes: f64,
) -> f64 {
    let compute = (ci.compute_s + cj.compute_s) / 2.0;
    let latency = (ij.latency_s + ji.latency_s) / 2.0;
    let transfer = 2.0 * size_bytes / (ij.bandwidth_bps + ji.bandwidth_bps);
    compute + latency + transfer
}

/// Pure-communication variant of Eq. 1 (used when compute is accounted
/// separately by the event simulator, to avoid double counting).
pub fn comm_cost(ij: &LinkParams, ji: &LinkParams, size_bytes: f64) -> f64 {
    (ij.latency_s + ji.latency_s) / 2.0 + 2.0 * size_bytes / (ij.bandwidth_bps + ji.bandwidth_bps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mbps(m: f64) -> f64 {
        m * 1e6 / 8.0 // Mb/s -> bytes/s
    }

    #[test]
    fn eq1_matches_hand_computation() {
        // c_i = 1s, c_j = 3s, λ = 0.1/0.3s, β = 100/300 Mb/s, size = 1 MB
        let ci = NodeProfile::new(1.0, 4);
        let cj = NodeProfile::new(3.0, 4);
        let ij = LinkParams::new(0.1, mbps(100.0));
        let ji = LinkParams::new(0.3, mbps(300.0));
        let size = 1e6;
        let expect = (1.0 + 3.0) / 2.0 + (0.1 + 0.3) / 2.0 + 2.0 * size / (mbps(100.0) + mbps(300.0));
        let got = edge_cost(&ci, &cj, &ij, &ji, size);
        assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
    }

    #[test]
    fn eq1_symmetric_in_direction() {
        // Because both directions are averaged, d(i,j) == d(j,i).
        let ci = NodeProfile::new(1.0, 1);
        let cj = NodeProfile::new(2.0, 1);
        let ij = LinkParams::new(0.05, mbps(50.0));
        let ji = LinkParams::new(0.2, mbps(500.0));
        let a = edge_cost(&ci, &cj, &ij, &ji, 12345.0);
        let b = edge_cost(&cj, &ci, &ji, &ij, 12345.0);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn bigger_payload_costs_more() {
        let c = NodeProfile::new(0.0, 1);
        let l = LinkParams::new(0.0, mbps(100.0));
        assert!(edge_cost(&c, &c, &l, &l, 2e6) > edge_cost(&c, &c, &l, &l, 1e6));
    }

    #[test]
    fn comm_cost_excludes_compute() {
        let l = LinkParams::new(0.1, mbps(100.0));
        let c = comm_cost(&l, &l, 0.0);
        assert!((c - 0.1).abs() < 1e-12);
    }

    #[test]
    fn one_way_time() {
        let l = LinkParams::new(0.01, 1e6);
        assert!((l.one_way_s(5e5) - 0.51).abs() < 1e-12);
    }

    #[test]
    fn backward_is_double_forward() {
        let p = NodeProfile::new(1.5, 2);
        assert_eq!(p.backward_s(), 3.0);
    }

    #[test]
    fn nic_config_class_lookup() {
        assert!(NicConfig::default().is_unlimited());
        assert!(NicConfig::UNLIMITED.is_unlimited());
        let nic = NicConfig { wan_concurrency: Some(2), lan_concurrency: None };
        assert!(!nic.is_unlimited());
        assert_eq!(nic.cap(false), Some(2), "inter-region uses the WAN cap");
        assert_eq!(nic.cap(true), None, "intra-region stays unlimited");
        let u = NicConfig::uniform(3);
        assert_eq!(u.cap(true), Some(3));
        assert_eq!(u.cap(false), Some(3));
    }

    #[test]
    #[should_panic(expected = "NIC concurrency must be >= 1")]
    fn zero_nic_cap_rejected_at_lookup() {
        // The fields are public: a literal can bypass uniform()'s check,
        // but the class lookup every consumer routes through rejects it
        // before a zero cap can poison queueing terms with inf/NaN.
        NicConfig { wan_concurrency: Some(0), lan_concurrency: None }.cap(false);
    }

    #[test]
    fn expected_queue_term_scales_with_contenders_and_concurrency() {
        // cap 1 on both ends: nothing else can contend.
        assert_eq!(expected_queue_s(1, 1, 10.0, 1), 0.0);
        // (4-1) + (8-1) = 10 contenders, half ahead, served 1 at a time.
        let q1 = expected_queue_s(4, 8, 10.0, 1);
        assert!((q1 - 50.0).abs() < 1e-12, "{q1}");
        // Doubling the NIC concurrency halves the expected wait.
        let q2 = expected_queue_s(4, 8, 10.0, 2);
        assert!((q2 - 25.0).abs() < 1e-12, "{q2}");
        // No transmission time, no queueing (latency pipelines).
        assert_eq!(expected_queue_s(4, 8, 0.0, 1), 0.0);
    }
}
