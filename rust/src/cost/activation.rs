//! Activation payload sizing per model family.
//!
//! The paper's Node-Crashes experiments shrink bandwidth by 32x to mimic
//! activations 32x larger than its reduced models actually emit; we model
//! that directly as an `inflation` factor on the payload.  GPT-like models
//! carry a higher activation-communication overhead than LLaMA-like ones
//! (paper §VI observes >2x faster homogeneous iterations for GPT because
//! of this difference in the compute/comm ratio).

/// Bytes shipped between consecutive stages per microbatch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActivationProfile {
    /// Microbatch size (sequences).
    pub microbatch: usize,
    /// Sequence length (tokens).
    pub seq_len: usize,
    /// Model width.
    pub d_model: usize,
    /// Bytes per element (4 = f32).
    pub elem_bytes: usize,
    /// Simulated payload inflation (the paper's "bandwidth reduced by a
    /// factor 32, mimicking activations 32 times larger").
    pub inflation: f64,
}

impl ActivationProfile {
    /// The paper's LLaMA-like setting: microbatch 4, seq 512, d_model 1024.
    pub fn paper_llama() -> Self {
        ActivationProfile { microbatch: 4, seq_len: 512, d_model: 1024, elem_bytes: 4, inflation: 32.0 }
    }

    /// The paper's GPT-like setting (same dims, but a GPT block also ships
    /// the residual-stream duplicate in its KV/attn caches in naive
    /// pipelining — modelled as a 1.5x payload).
    pub fn paper_gpt() -> Self {
        ActivationProfile { microbatch: 4, seq_len: 512, d_model: 1024, elem_bytes: 4, inflation: 32.0 * 1.5 }
    }

    /// Payload of one forward activation (or backward gradient) transfer.
    pub fn bytes(&self) -> f64 {
        (self.microbatch * self.seq_len * self.d_model * self.elem_bytes) as f64 * self.inflation
    }

    /// From a runtime model config (no inflation — real tensors).
    pub fn from_dims(microbatch: usize, seq_len: usize, d_model: usize) -> Self {
        ActivationProfile { microbatch, seq_len, d_model, elem_bytes: 4, inflation: 1.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_payloads() {
        let a = ActivationProfile::paper_llama();
        // 4 * 512 * 1024 * 4 B = 8 MiB, inflated 32x = 256 MiB
        assert_eq!(a.bytes(), 4.0 * 512.0 * 1024.0 * 4.0 * 32.0);
        let g = ActivationProfile::paper_gpt();
        assert!(g.bytes() > a.bytes());
    }

    #[test]
    fn runtime_dims_uninflated() {
        let a = ActivationProfile::from_dims(4, 128, 256);
        assert_eq!(a.bytes(), (4 * 128 * 256 * 4) as f64);
    }
}
