//! Kademlia-style DHT for peer discovery (paper §IV / §V-B).
//!
//! Joining nodes discover other peers and the elected leader's identity
//! through a distributed hash table keyed by the XOR metric
//! (Maymounkov & Mazières).  This is the partial-membership substrate:
//! no node ever needs a global view — a joining node bootstraps from any
//! live contact, performs an iterative lookup towards its own id, and ends
//! up with O(k·log n) known peers.

use std::collections::BTreeMap;

use crate::cost::NodeId;
use crate::util::Rng;

const BUCKET_BITS: usize = 64;

/// One node's routing table: `k`-buckets by XOR-distance prefix.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    pub self_key: u64,
    pub k: usize,
    buckets: Vec<Vec<(u64, NodeId)>>,
}

impl RoutingTable {
    pub fn new(self_key: u64, k: usize) -> Self {
        RoutingTable { self_key, k, buckets: vec![Vec::new(); BUCKET_BITS] }
    }

    fn bucket_of(&self, key: u64) -> usize {
        let d = self.self_key ^ key;
        if d == 0 {
            0
        } else {
            (BUCKET_BITS - 1) - d.leading_zeros() as usize
        }
    }

    /// Insert a contact (LRU-ish: keep the first k seen, as classic Kademlia
    /// prefers long-lived contacts).
    pub fn insert(&mut self, key: u64, id: NodeId) {
        if key == self.self_key {
            return;
        }
        let b = self.bucket_of(key);
        let bucket = &mut self.buckets[b];
        if bucket.iter().any(|&(k2, _)| k2 == key) {
            return;
        }
        if bucket.len() < self.k {
            bucket.push((key, id));
        }
    }

    pub fn remove(&mut self, key: u64) {
        let b = self.bucket_of(key);
        self.buckets[b].retain(|&(k2, _)| k2 != key);
    }

    /// The `count` contacts closest (XOR) to `target` that this node knows.
    pub fn closest(&self, target: u64, count: usize) -> Vec<(u64, NodeId)> {
        let mut all: Vec<(u64, NodeId)> =
            self.buckets.iter().flatten().copied().collect();
        all.sort_by_key(|&(k2, _)| k2 ^ target);
        all.truncate(count);
        all
    }

    pub fn contacts(&self) -> Vec<(u64, NodeId)> {
        self.buckets.iter().flatten().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A whole-DHT simulation: per-node routing tables plus the stored records
/// (we store the leader pointer and stage directories under well-known keys).
#[derive(Debug, Clone)]
pub struct Dht {
    pub tables: BTreeMap<u64, RoutingTable>,
    pub keys: BTreeMap<NodeId, u64>,
    records: BTreeMap<u64, Vec<u8>>,
    k: usize,
}

impl Dht {
    pub fn new(k: usize) -> Self {
        Dht { tables: BTreeMap::new(), keys: BTreeMap::new(), records: BTreeMap::new(), k }
    }

    /// Hash a NodeId onto the key ring (splitmix of the index).
    pub fn key_for(id: NodeId) -> u64 {
        let mut z = (id.0 as u64).wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Join the DHT: bootstrap from `contact` (None for the first node),
    /// then iterative-lookup towards the joiner's own key, filling buckets.
    ///
    /// A contact that has itself crashed/left (no longer in `keys`) is
    /// ignored: the joiner comes up isolated and must retry through a
    /// live contact — it does not panic the simulation.
    pub fn join(&mut self, id: NodeId, contact: Option<NodeId>, _rng: &mut Rng) {
        let key = Self::key_for(id);
        let mut table = RoutingTable::new(key, self.k);
        let live_contact =
            contact.and_then(|c| self.keys.get(&c).copied().map(|ckey| (c, ckey)));
        if let Some((c, ckey)) = live_contact {
            table.insert(ckey, c);
            // Iterative lookup for our own key through the contact graph.
            let found = self.iterative_lookup_from(ckey, key);
            for (k2, nid) in found {
                table.insert(k2, nid);
            }
        }
        // Existing nodes learn about the joiner when it contacts them
        // (Kademlia's passive table maintenance).
        let learned: Vec<u64> = table.contacts().iter().map(|&(k2, _)| k2).collect();
        for k2 in learned {
            if let Some(t) = self.tables.get_mut(&k2) {
                t.insert(key, id);
            }
        }
        self.tables.insert(key, table);
        self.keys.insert(id, key);
    }

    /// A node leaves/crashes: other tables drop it lazily on lookup failure;
    /// here we expunge eagerly for simulation simplicity.
    pub fn leave(&mut self, id: NodeId) {
        if let Some(key) = self.keys.remove(&id) {
            self.tables.remove(&key);
            for t in self.tables.values_mut() {
                t.remove(key);
            }
        }
    }

    /// Reconcile with a liveness vector: expunge every dead member's key
    /// from all routing-table buckets (and drop its own table).  This is
    /// the churn-crash wiring the overlay relies on — without it, crashed
    /// peers' keys linger in buckets and bootstrap hands out dead
    /// contacts.
    pub fn evict_dead(&mut self, alive: &[bool]) {
        let dead: Vec<NodeId> = self
            .keys
            .keys()
            .copied()
            .filter(|n| !alive.get(n.0).copied().unwrap_or(true))
            .collect();
        for n in dead {
            self.leave(n);
        }
    }

    /// Iterative lookup: α=1 walk along closest-known contacts.
    fn iterative_lookup_from(&self, start: u64, target: u64) -> Vec<(u64, NodeId)> {
        let mut best: Vec<(u64, NodeId)> = Vec::new();
        let mut cursor = start;
        let mut visited = std::collections::BTreeSet::new();
        for _ in 0..BUCKET_BITS {
            if !visited.insert(cursor) {
                break;
            }
            let Some(t) = self.tables.get(&cursor) else { break };
            let near = t.closest(target, self.k);
            for &(k2, nid) in &near {
                if !best.iter().any(|&(b, _)| b == k2) {
                    best.push((k2, nid));
                }
            }
            best.sort_by_key(|&(k2, _)| k2 ^ target);
            best.truncate(self.k);
            match best.first() {
                Some(&(k2, _)) if k2 != cursor && (k2 ^ target) < (cursor ^ target) => cursor = k2,
                _ => break,
            }
        }
        best
    }

    /// Lookup the `count` live nodes closest to an arbitrary key, from the
    /// point of view of `asking` (partial knowledge only).
    pub fn lookup(&self, asking: NodeId, target: u64, count: usize) -> Vec<NodeId> {
        let Some(&akey) = self.keys.get(&asking) else { return vec![] };
        let mut out = self.iterative_lookup_from(akey, target);
        out.truncate(count);
        out.into_iter().map(|(_, id)| id).collect()
    }

    /// Store a record (e.g. the leader pointer) at the nodes closest to `key`.
    pub fn put(&mut self, key: u64, value: Vec<u8>) {
        self.records.insert(key, value);
    }

    pub fn get(&self, key: u64) -> Option<&Vec<u8>> {
        self.records.get(&key)
    }

    /// Known peers of a node (its partial membership view).
    pub fn peers_of(&self, id: NodeId) -> Vec<NodeId> {
        self.keys
            .get(&id)
            .and_then(|k| self.tables.get(k))
            .map(|t| t.contacts().into_iter().map(|(_, n)| n).collect())
            .unwrap_or_default()
    }

    pub fn contains(&self, id: NodeId) -> bool {
        self.keys.contains_key(&id)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

/// Well-known record key for the elected leader's identity.
pub const LEADER_KEY: u64 = 0x1EADE2;

#[cfg(test)]
mod tests {
    use super::*;

    fn build(n: usize) -> Dht {
        let mut dht = Dht::new(8);
        let mut rng = Rng::new(0);
        dht.join(NodeId(0), None, &mut rng);
        for i in 1..n {
            let contact = NodeId(i % i.max(1).min(i)); // always bootstrap from node 0..i
            dht.join(NodeId(i), Some(NodeId(contact.0 % i)), &mut rng);
        }
        dht
    }

    #[test]
    fn all_nodes_join() {
        let dht = build(32);
        assert_eq!(dht.len(), 32);
        for i in 0..32 {
            assert!(dht.contains(NodeId(i)));
        }
    }

    #[test]
    fn partial_views_bounded() {
        let dht = build(64);
        for i in 0..64 {
            let peers = dht.peers_of(NodeId(i));
            assert!(!peers.is_empty(), "node {i} isolated");
            // k=8 per bucket bounds the view well below global membership
            assert!(peers.len() < 64);
        }
    }

    #[test]
    fn lookup_returns_close_keys() {
        let dht = build(64);
        let target = Dht::key_for(NodeId(40));
        let found = dht.lookup(NodeId(3), target, 4);
        assert!(!found.is_empty());
    }

    #[test]
    fn leave_removes_node() {
        let mut dht = build(16);
        dht.leave(NodeId(5));
        assert!(!dht.contains(NodeId(5)));
        for i in 0..16 {
            if i == 5 {
                continue;
            }
            assert!(!dht.peers_of(NodeId(i)).contains(&NodeId(5)));
        }
    }

    #[test]
    fn evict_dead_purges_every_bucket() {
        let mut dht = build(24);
        let mut alive = vec![true; 24];
        for dead in [3usize, 11, 17] {
            alive[dead] = false;
        }
        dht.evict_dead(&alive);
        for dead in [3usize, 11, 17] {
            assert!(!dht.contains(NodeId(dead)));
            for i in 0..24 {
                if alive[i] {
                    assert!(
                        !dht.peers_of(NodeId(i)).contains(&NodeId(dead)),
                        "stale contact n{dead} lingers in n{i}'s buckets"
                    );
                }
            }
        }
        // idempotent
        dht.evict_dead(&alive);
        assert_eq!(dht.len(), 21);
    }

    #[test]
    fn join_through_dead_contact_is_isolated_not_panicking() {
        let mut dht = build(8);
        let mut rng = Rng::new(1);
        dht.leave(NodeId(3));
        dht.join(NodeId(20), Some(NodeId(3)), &mut rng);
        assert!(dht.contains(NodeId(20)));
        assert!(dht.peers_of(NodeId(20)).is_empty(), "dead contact bootstraps nothing");
        // a later join through a live contact works normally
        dht.join(NodeId(21), Some(NodeId(0)), &mut rng);
        assert!(!dht.peers_of(NodeId(21)).is_empty());
    }

    #[test]
    fn records_roundtrip() {
        let mut dht = build(4);
        dht.put(LEADER_KEY, vec![7]);
        assert_eq!(dht.get(LEADER_KEY), Some(&vec![7]));
    }

    #[test]
    fn keys_unique() {
        let keys: Vec<u64> = (0..100).map(|i| Dht::key_for(NodeId(i))).collect();
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), keys.len());
    }
}
