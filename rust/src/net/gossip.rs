//! SWIM-style partial-view membership state (the per-node half of the
//! gossip overlay; the network-wide orchestration lives in
//! [`super::overlay`]).
//!
//! Each relay keeps two **directed views** of bounded size
//! ([`GossipConfig::fanout`] peers each): `fwd` over the next pipeline
//! stage and `bwd` over the previous one — exactly the "peer view
//! (adjacent stages, from the DHT)" the flow protocol consumes — plus a
//! larger *passive* pool per view (HyParView's active/passive split) used
//! to repair the active view after evictions.
//!
//! Failure detection is suspicion-then-eviction, as in SWIM: a failed
//! probe increments a per-peer suspicion counter; only after
//! [`GossipConfig::suspicion_rounds`] consecutive failures is the peer
//! evicted and a passive member promoted in its place.  A transiently
//! unreachable peer that answers a later probe has its suspicion cleared.
//! Every few rounds ([`GossipConfig::shuffle_every`]) a view rotates one
//! active slot against a random passive member — the HyParView shuffle
//! collapsed to its effect — so the candidate sets the flow planner draws
//! from keep churning even without failures.
//!
//! Everything here is deterministic given the caller's [`Rng`]; the
//! overlay proptests assert byte-identical views across same-seed runs.

use std::collections::BTreeMap;

use crate::cost::NodeId;
use crate::util::Rng;

/// Tunables of the gossip overlay.
#[derive(Debug, Clone)]
pub struct GossipConfig {
    /// Active-view size per direction (the `k` in the planner's
    /// O(chains·k) bound; `ScenarioConfig::overlay_fanout`).
    pub fanout: usize,
    /// Passive-pool size per direction (repair candidates).
    pub passive_size: usize,
    /// Rotate one active slot against the passive pool every this many
    /// gossip rounds (0 disables shuffling).
    pub shuffle_every: u64,
    /// Failed probes before a suspected peer is evicted.
    pub suspicion_rounds: u32,
}

impl Default for GossipConfig {
    fn default() -> Self {
        GossipConfig { fanout: 8, passive_size: 16, shuffle_every: 2, suspicion_rounds: 2 }
    }
}

/// One bounded directed view (active + passive + suspicion state).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DirectedView {
    /// Peers the owner actively probes and offers to the flow planner.
    pub active: Vec<NodeId>,
    /// Known-but-unmonitored fallback peers (promotion pool).
    pub passive: Vec<NodeId>,
    /// Failed-probe counts for currently-suspected active peers.
    pub suspicion: BTreeMap<NodeId, u32>,
}

impl DirectedView {
    pub fn contains(&self, n: NodeId) -> bool {
        self.active.contains(&n)
    }

    /// Add to the passive pool (FIFO-bounded, no duplicates, never a peer
    /// already in the active view).
    pub fn insert_passive(&mut self, n: NodeId, cap: usize) {
        if cap == 0 || self.active.contains(&n) || self.passive.contains(&n) {
            return;
        }
        if self.passive.len() >= cap {
            self.passive.remove(0);
        }
        self.passive.push(n);
    }

    /// Remove a peer from every slot of this view.
    pub fn evict(&mut self, n: NodeId) {
        self.active.retain(|&m| m != n);
        self.passive.retain(|&m| m != n);
        self.suspicion.remove(&n);
    }

    /// Record a failed probe of `peer`.  Returns `true` when the peer
    /// crossed the suspicion threshold and was evicted.
    pub fn record_failure(&mut self, peer: NodeId, threshold: u32) -> bool {
        let s = self.suspicion.entry(peer).or_insert(0);
        *s += 1;
        if *s >= threshold {
            self.evict(peer);
            true
        } else {
            false
        }
    }

    /// A probe of `peer` succeeded: clear any suspicion.
    pub fn record_ok(&mut self, peer: NodeId) {
        self.suspicion.remove(&peer);
    }

    /// Promote alive passive members into the active view until it holds
    /// `cap` peers (or the pool runs dry).
    pub fn refill(&mut self, cap: usize, alive: &[bool]) {
        while self.active.len() < cap {
            let Some(pos) =
                self.passive.iter().position(|&m| alive.get(m.0).copied().unwrap_or(false))
            else {
                break;
            };
            let m = self.passive.remove(pos);
            if !self.active.contains(&m) {
                self.active.push(m);
            }
        }
    }

    /// Rotate one active slot against a random alive passive member
    /// (keeps planner candidate sets diverse under stable membership).
    pub fn shuffle(&mut self, rng: &mut Rng, alive: &[bool]) {
        if self.active.is_empty() || self.passive.is_empty() {
            return;
        }
        let pi = rng.index(self.passive.len());
        if !alive.get(self.passive[pi].0).copied().unwrap_or(false) {
            return;
        }
        let ai = rng.index(self.active.len());
        let demoted = self.active[ai];
        self.active[ai] = self.passive[pi];
        self.passive[pi] = demoted;
        // both parties start clean: the promoted peer is unprobed, the
        // demoted one is no longer monitored
        self.suspicion.remove(&demoted);
        self.suspicion.remove(&self.active[ai]);
    }

    /// Drop every peer the caller knows to be dead (reconciliation).
    pub fn drop_dead(&mut self, alive: &[bool]) {
        self.active.retain(|&m| alive.get(m.0).copied().unwrap_or(false));
        self.passive.retain(|&m| alive.get(m.0).copied().unwrap_or(false));
        self.suspicion.retain(|m, _| alive.get(m.0).copied().unwrap_or(false));
    }
}

/// A relay's complete overlay state: both directed views plus the
/// key-ring successor.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NodeViews {
    /// Next-stage peers (Request Flow / Change targets).
    pub fwd: DirectedView,
    /// Previous-stage peers (who can extend my chains towards the head).
    pub bwd: DirectedView,
    /// Successor on the XOR key ring over *alive* relays — the
    /// connectivity anchor: the union of all ring edges is a cycle over
    /// the alive membership, so the overlay graph can never partition
    /// even if every gossip-chosen peer is lost (repaired on reconcile,
    /// the way a Kademlia node re-resolves its own key neighbourhood).
    pub ring: Option<NodeId>,
}

impl NodeViews {
    /// Can the owner see `peer`? (union of both active views + ring)
    pub fn sees(&self, peer: NodeId) -> bool {
        self.ring == Some(peer) || self.fwd.contains(peer) || self.bwd.contains(peer)
    }

    /// Peers offered to the flow planner as this node's neighbor list.
    pub fn planning_peers(&self) -> Vec<NodeId> {
        let mut v = Vec::with_capacity(self.fwd.active.len() + self.bwd.active.len() + 1);
        v.extend_from_slice(&self.fwd.active);
        v.extend_from_slice(&self.bwd.active);
        if let Some(r) = self.ring {
            v.push(r);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alive(n: usize) -> Vec<bool> {
        vec![true; n]
    }

    #[test]
    fn passive_insert_bounded_and_deduped() {
        let mut v = DirectedView::default();
        v.active.push(NodeId(1));
        v.insert_passive(NodeId(1), 3); // already active: rejected
        assert!(v.passive.is_empty());
        for i in 2..8 {
            v.insert_passive(NodeId(i), 3);
            v.insert_passive(NodeId(i), 3); // dup: rejected
        }
        assert_eq!(v.passive.len(), 3, "FIFO-bounded");
        assert_eq!(v.passive, vec![NodeId(5), NodeId(6), NodeId(7)]);
    }

    #[test]
    fn suspicion_then_eviction() {
        let mut v = DirectedView {
            active: vec![NodeId(1), NodeId(2)],
            passive: vec![NodeId(3)],
            suspicion: BTreeMap::new(),
        };
        assert!(!v.record_failure(NodeId(1), 2), "first failure only suspects");
        assert!(v.contains(NodeId(1)));
        assert!(v.record_failure(NodeId(1), 2), "second failure evicts");
        assert!(!v.contains(NodeId(1)));
        v.refill(2, &alive(4));
        assert_eq!(v.active, vec![NodeId(2), NodeId(3)], "passive member promoted");
        assert!(v.passive.is_empty());
    }

    #[test]
    fn probe_ok_clears_suspicion() {
        let mut v = DirectedView { active: vec![NodeId(1)], ..Default::default() };
        v.record_failure(NodeId(1), 3);
        v.record_failure(NodeId(1), 3);
        v.record_ok(NodeId(1));
        // the counter restarted: two more failures still below threshold 3
        assert!(!v.record_failure(NodeId(1), 3));
        assert!(!v.record_failure(NodeId(1), 3));
        assert!(v.contains(NodeId(1)));
    }

    #[test]
    fn refill_skips_dead_passive_members() {
        let mut v = DirectedView {
            active: vec![],
            passive: vec![NodeId(0), NodeId(1), NodeId(2)],
            suspicion: BTreeMap::new(),
        };
        let mut a = alive(3);
        a[0] = false;
        v.refill(2, &a);
        assert_eq!(v.active, vec![NodeId(1), NodeId(2)]);
        assert_eq!(v.passive, vec![NodeId(0)], "dead member left in the pool");
    }

    #[test]
    fn shuffle_swaps_one_slot_and_preserves_bounds() {
        let mut v = DirectedView {
            active: vec![NodeId(0), NodeId(1)],
            passive: vec![NodeId(2), NodeId(3)],
            suspicion: BTreeMap::new(),
        };
        let mut rng = Rng::new(7);
        let before: Vec<NodeId> =
            v.active.iter().chain(v.passive.iter()).copied().collect();
        v.shuffle(&mut rng, &alive(4));
        assert_eq!(v.active.len(), 2);
        assert_eq!(v.passive.len(), 2);
        let mut after: Vec<NodeId> = v.active.iter().chain(v.passive.iter()).copied().collect();
        let mut want = before.clone();
        after.sort();
        want.sort();
        assert_eq!(after, want, "shuffle permutes, never invents or drops peers");
    }

    #[test]
    fn drop_dead_clears_all_slots() {
        let mut v = DirectedView {
            active: vec![NodeId(0), NodeId(1)],
            passive: vec![NodeId(2)],
            suspicion: [(NodeId(0), 1)].into_iter().collect(),
        };
        let mut a = alive(3);
        a[0] = false;
        a[2] = false;
        v.drop_dead(&a);
        assert_eq!(v.active, vec![NodeId(1)]);
        assert!(v.passive.is_empty());
        assert!(v.suspicion.is_empty());
    }

    #[test]
    fn node_views_sees_union() {
        let views = NodeViews {
            fwd: DirectedView { active: vec![NodeId(1)], ..Default::default() },
            bwd: DirectedView { active: vec![NodeId(2)], ..Default::default() },
            ring: Some(NodeId(3)),
        };
        for n in 1..=3 {
            assert!(views.sees(NodeId(n)));
        }
        assert!(!views.sees(NodeId(4)));
        let mut peers = views.planning_peers();
        peers.sort();
        assert_eq!(peers, vec![NodeId(1), NodeId(2), NodeId(3)]);
    }
}
