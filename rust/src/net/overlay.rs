//! Gossip-based partial-view overlay: the membership substrate behind
//! neighbor-scoped flow planning.
//!
//! GWTF's §V protocol claims no node needs a global view, yet the seed
//! planner let every relay scan every chain.  This module supplies the
//! missing substrate: each relay holds *bounded* directed views over its
//! adjacent pipeline stages ([`NodeViews`] from [`super::gossip`]),
//! bootstrapped from the Kademlia
//! [`RoutingTable`](super::dht::RoutingTable) contacts and maintained by
//! a SWIM-style probe / suspicion / eviction loop plus a periodic
//! shuffle.  The flow planner
//! ([`crate::flow::DecentralizedFlow::set_neighbors`]) then draws
//! Request Flow / Change / Redirect candidates exclusively from these
//! views, making a planning round O(chains·k) for view size `k`
//! (`ScenarioConfig::overlay_fanout`) instead of scanning the global
//! membership.
//!
//! Three liveness paths keep the views honest:
//!
//! - **Gossip rounds** run on the engine's continuous clock (the
//!   `gossip_ticks` of a [`crate::sim::WorldSchedule`], emitted by
//!   [`crate::sim::sources::GossipCadenceSource`] and delivered through
//!   `RoutingPolicy::on_gossip`): each alive relay probes one peer per directed
//!   view; dead peers accumulate suspicion and are evicted after
//!   [`GossipConfig::suspicion_rounds`] failures, with passive members
//!   promoted in their place.
//! - **Crash events** ([`Overlay::on_crash`], fired when churn kills a
//!   node mid-iteration) immediately expunge the victim's key from every
//!   DHT routing-table bucket, so overlay bootstrap never hands out dead
//!   contacts — view eviction still waits for detection, as in a real
//!   deployment.
//! - **Reconciliation** ([`Overlay::reconcile`], called by
//!   `GwtfRouter::{plan,replan}` with the start-of-iteration liveness):
//!   dead members are dropped everywhere, rejoiners re-bootstrap through
//!   the DHT, underfull views are repaired from the passive pool and then
//!   the stage directory (a DHT stage-record lookup, simulated directly
//!   like the rest of [`super::dht`]), and the XOR key ring over alive
//!   relays is re-linked.  The ring makes the union of active views
//!   provably connected after every reconcile — the overlay cannot
//!   partition the planner.
//!
//! With `fanout >= max stage size` every directed view holds its whole
//! adjacent stage and the overlay reproduces the legacy global-visibility
//! planner bit for bit (the `k = n-1` parity test in
//! `rust/tests/overlay.rs`).

use std::collections::BTreeMap;

use crate::cost::NodeId;
use crate::flow::graph::StageGraph;
use crate::util::Rng;

use super::dht::Dht;
use super::gossip::{DirectedView, GossipConfig, NodeViews};

/// The simulated overlay network: per-relay bounded views + the DHT they
/// bootstrap from.
#[derive(Debug, Clone)]
pub struct Overlay {
    pub cfg: GossipConfig,
    /// Peer-discovery substrate; crashed peers are evicted from its
    /// buckets the moment their crash event fires.
    pub dht: Dht,
    /// Views of currently-alive relays only.
    views: BTreeMap<NodeId, NodeViews>,
    /// Stage directory (the content of the well-known DHT stage records).
    stages: Vec<Vec<NodeId>>,
    data_nodes: Vec<NodeId>,
    relays: Vec<NodeId>,
    stage_of: BTreeMap<NodeId, usize>,
    /// Liveness at the last reconcile.
    alive: Vec<bool>,
    rng: Rng,
    /// Gossip rounds run so far (drives the shuffle cadence).
    pub rounds: u64,
    /// Eclipse attackers (set from the adversary roster via
    /// [`Overlay::set_eclipse_liars`]); empty = the lie hook is inert
    /// and [`Overlay::gossip_round`] is exactly the honest protocol.
    eclipse_liars: Vec<NodeId>,
    /// `(liar, victim)` pairs from the most recent gossip round.  The
    /// overlay has no clock, so the router reads these back and emits
    /// the `EclipseLie` trace instants with its own timestamp.
    last_lies: Vec<(NodeId, NodeId)>,
}

impl Overlay {
    /// Build the overlay over a stage graph: join everyone to the DHT,
    /// then seed each relay's directed views from its routing-table
    /// neighbourhood (XOR-nearest adjacent-stage members).
    pub fn build(graph: &StageGraph, n_nodes: usize, cfg: GossipConfig, seed: u64) -> Overlay {
        assert!(cfg.fanout >= 2, "overlay fanout must be at least 2");
        let data_nodes = graph.data_nodes.clone();
        let relays: Vec<NodeId> = graph.stages.iter().flatten().copied().collect();
        let mut stage_of = BTreeMap::new();
        for (s, members) in graph.stages.iter().enumerate() {
            for &m in members {
                stage_of.insert(m, s);
            }
        }
        let mut rng = Rng::new(seed);
        let mut dht = Dht::new(cfg.fanout.max(4));
        let mut contact: Option<NodeId> = None;
        for &n in data_nodes.iter().chain(relays.iter()) {
            dht.join(n, contact, &mut rng);
            contact = contact.or(Some(n));
        }
        let mut ov = Overlay {
            cfg,
            dht,
            views: BTreeMap::new(),
            stages: graph.stages.clone(),
            data_nodes,
            relays,
            stage_of,
            alive: vec![true; n_nodes],
            rng,
            rounds: 0,
            eclipse_liars: Vec::new(),
            last_lies: Vec::new(),
        };
        let all_alive = vec![true; n_nodes];
        for &r in &ov.relays.clone() {
            let views = ov.bootstrap_views(r, &all_alive);
            ov.views.insert(r, views);
        }
        ov.relink_ring(&all_alive);
        ov
    }

    /// Adjacent-stage member lists for a relay: (previous, next).  Stage-0
    /// relays have no `bwd` peers and last-stage relays no `fwd` peers —
    /// both talk to the (always-visible) data nodes instead.
    fn adjacent(&self, r: NodeId) -> (&[NodeId], &[NodeId]) {
        let s = self.stage_of[&r];
        let bwd: &[NodeId] = if s == 0 { &[] } else { &self.stages[s - 1] };
        let fwd: &[NodeId] =
            if s + 1 < self.stages.len() { &self.stages[s + 1] } else { &[] };
        (bwd, fwd)
    }

    /// Seed one directed view deterministically: XOR-nearest alive
    /// members first (what an iterative DHT lookup towards the owner's
    /// key surfaces), active up to `fanout`, the rest passive.
    fn seeded_view(&self, owner: NodeId, members: &[NodeId], alive: &[bool]) -> DirectedView {
        let ok = Dht::key_for(owner);
        let mut sorted: Vec<NodeId> = members
            .iter()
            .copied()
            .filter(|&m| m != owner && alive.get(m.0).copied().unwrap_or(false))
            .collect();
        sorted.sort_by_key(|&m| Dht::key_for(m) ^ ok);
        let active: Vec<NodeId> = sorted.iter().copied().take(self.cfg.fanout).collect();
        let passive: Vec<NodeId> = sorted
            .iter()
            .copied()
            .skip(self.cfg.fanout)
            .take(self.cfg.passive_size)
            .collect();
        DirectedView { active, passive, suspicion: BTreeMap::new() }
    }

    fn bootstrap_views(&self, r: NodeId, alive: &[bool]) -> NodeViews {
        let (bwd, fwd) = self.adjacent(r);
        let (bwd, fwd) = (bwd.to_vec(), fwd.to_vec());
        NodeViews {
            fwd: self.seeded_view(r, &fwd, alive),
            bwd: self.seeded_view(r, &bwd, alive),
            ring: None, // relink_ring fills this in
        }
    }

    /// Re-link the XOR key ring over alive relays (connectivity anchor).
    fn relink_ring(&mut self, alive: &[bool]) {
        let mut ring: Vec<NodeId> = self
            .relays
            .iter()
            .copied()
            .filter(|&r| alive.get(r.0).copied().unwrap_or(false))
            .collect();
        ring.sort_by_key(|&r| Dht::key_for(r));
        for (i, &r) in ring.iter().enumerate() {
            let succ = if ring.len() < 2 { None } else { Some(ring[(i + 1) % ring.len()]) };
            if let Some(v) = self.views.get_mut(&r) {
                v.ring = succ;
            }
        }
    }

    /// Can `viewer` see `peer`?  Data nodes are persistent, well-known
    /// anchors (every relay learns them when it joins, §V-B): they are
    /// always visible as peers, and as viewers they hold effectively full
    /// membership (every join handshake passes through them), so they see
    /// everyone.
    pub fn sees(&self, viewer: NodeId, peer: NodeId) -> bool {
        if self.data_nodes.contains(&peer) || self.data_nodes.contains(&viewer) {
            return true;
        }
        self.views.get(&viewer).map(|v| v.sees(peer)).unwrap_or(false)
    }

    /// Per-relay neighbor lists for
    /// [`crate::flow::DecentralizedFlow::set_neighbors`]: each alive
    /// relay's planning peers plus the data nodes.
    pub fn neighbor_map(&self) -> BTreeMap<NodeId, Vec<NodeId>> {
        let mut map = BTreeMap::new();
        for (&r, v) in &self.views {
            let mut peers = v.planning_peers();
            peers.extend_from_slice(&self.data_nodes);
            map.insert(r, peers);
        }
        map
    }

    /// Stream every `(viewer, peer)` planning edge of
    /// [`neighbor_map`](Self::neighbor_map) — same viewers, same peers —
    /// without materializing the map or the per-relay peer vectors.
    /// Feeds [`crate::flow::DecentralizedFlow::set_neighbor_edges`]
    /// directly on every (re)plan.
    pub fn for_each_planning_edge(&self, mut f: impl FnMut(NodeId, NodeId)) {
        for (&r, v) in &self.views {
            for &p in v.fwd.active.iter().chain(&v.bwd.active) {
                f(r, p);
            }
            if let Some(p) = v.ring {
                f(r, p);
            }
            for &d in &self.data_nodes {
                f(r, d);
            }
        }
    }

    /// Active view of one relay (tests / diagnostics).
    pub fn views_of(&self, r: NodeId) -> Option<&NodeViews> {
        self.views.get(&r)
    }

    /// Was `n` part of the overlay membership at the last reconcile?
    /// A node unknown to the overlay mid-iteration is a fresh joiner —
    /// its §V-B join announcement (leader handshake + DHT record) is how
    /// peers learn of it before any view refresh, so visibility filters
    /// must exempt it rather than veto it.
    pub fn knows(&self, n: NodeId) -> bool {
        self.views.contains_key(&n) || self.data_nodes.contains(&n)
    }

    pub fn alive_relays(&self) -> Vec<NodeId> {
        self.relays
            .iter()
            .copied()
            .filter(|&r| self.alive.get(r.0).copied().unwrap_or(false))
            .collect()
    }

    /// A churn crash event fired for `node`: expunge its key from every
    /// routing-table bucket right away (stale-contact fix — bootstrap must
    /// never hand out dead contacts).  Its entries in other relays' views
    /// survive until the failure detector or the next reconcile removes
    /// them, as in a real deployment.
    pub fn on_crash(&mut self, node: NodeId) {
        self.dht.leave(node);
    }

    /// One SWIM round for every alive relay: probe a random active peer
    /// per directed view against the caller's ground-truth liveness,
    /// escalate suspicion on failure, promote passive members after
    /// evictions, and periodically shuffle a slot for view diversity.
    pub fn gossip_round(&mut self, truth: &[bool]) {
        self.rounds += 1;
        self.last_lies.clear();
        let shuffle = self.cfg.shuffle_every > 0 && self.rounds % self.cfg.shuffle_every == 0;
        for i in 0..self.relays.len() {
            let r = self.relays[i];
            if !truth.get(r.0).copied().unwrap_or(false) {
                continue;
            }
            let Some(v) = self.views.get_mut(&r) else { continue };
            for dir in [&mut v.bwd, &mut v.fwd] {
                if dir.active.is_empty() {
                    continue;
                }
                let probe = dir.active[self.rng.index(dir.active.len())];
                if truth.get(probe.0).copied().unwrap_or(false) {
                    dir.record_ok(probe);
                    if shuffle {
                        dir.shuffle(&mut self.rng, truth);
                    }
                } else if dir.record_failure(probe, self.cfg.suspicion_rounds) {
                    dir.refill(self.cfg.fanout, truth);
                }
            }
        }
        if !self.eclipse_liars.is_empty() {
            self.apply_eclipse_lies(truth);
        }
    }

    /// Mark `liars` as eclipse attackers: after every honest gossip
    /// round they overwrite one active-view slot of each adjacent-stage
    /// victim with themselves (the shuffle-lie attack collapsed to its
    /// steady-state effect — each lie displaces a legitimate peer into
    /// the passive pool, so repeated rounds keep the liar resident in
    /// every neighbor's planning view).
    pub fn set_eclipse_liars(&mut self, liars: Vec<NodeId>) {
        self.eclipse_liars = liars;
    }

    /// `(liar, victim)` pairs manipulated in the most recent round.
    pub fn last_lies(&self) -> &[(NodeId, NodeId)] {
        &self.last_lies
    }

    /// Post-process a gossip round with the eclipse attackers' shuffle
    /// lies.  RNG-free and purely view-local: the honest protocol above
    /// consumes exactly the same randomness whether or not this runs,
    /// so attaching liars never perturbs other relays' probe draws.
    fn apply_eclipse_lies(&mut self, truth: &[bool]) {
        let passive_cap = self.cfg.passive_size;
        let liars = self.eclipse_liars.clone();
        for liar in liars {
            if !truth.get(liar.0).copied().unwrap_or(false) {
                continue;
            }
            let Some(&s) = self.stage_of.get(&liar) else { continue };
            // Stage s-1 relays look *forward* at the liar's stage; stage
            // s+1 relays look *backward* at it.
            let prev: Vec<NodeId> =
                if s > 0 { self.stages[s - 1].clone() } else { Vec::new() };
            let next: Vec<NodeId> =
                if s + 1 < self.stages.len() { self.stages[s + 1].clone() } else { Vec::new() };
            for (victims, fwd_dir) in [(prev, true), (next, false)] {
                for victim in victims {
                    if !truth.get(victim.0).copied().unwrap_or(false) {
                        continue;
                    }
                    let Some(v) = self.views.get_mut(&victim) else { continue };
                    let dir = if fwd_dir { &mut v.fwd } else { &mut v.bwd };
                    if dir.active.is_empty() || dir.active.contains(&liar) {
                        continue;
                    }
                    // The lie: the liar claims the last active slot,
                    // demoting the legitimate peer to the passive pool.
                    let last = dir.active.len() - 1;
                    let demoted = std::mem::replace(&mut dir.active[last], liar);
                    dir.suspicion.remove(&demoted);
                    dir.suspicion.remove(&liar);
                    dir.passive.retain(|&m| m != liar);
                    dir.insert_passive(demoted, passive_cap);
                    self.last_lies.push((liar, victim));
                }
            }
        }
    }

    /// Reconcile the overlay with the start-of-iteration liveness (called
    /// by `GwtfRouter::{plan,replan}`): evict the dead from the DHT and
    /// every view, re-admit rejoiners through a fresh DHT bootstrap,
    /// repair underfull active views from the passive pool and then the
    /// stage directory, and re-link the key ring.
    pub fn reconcile(&mut self, alive: &[bool]) {
        self.dht.evict_dead(alive);
        let relays = self.relays.clone();
        for &r in &relays {
            let up = alive.get(r.0).copied().unwrap_or(false);
            if !up {
                self.views.remove(&r);
                continue;
            }
            if !self.dht.contains(r) {
                // Rejoiner: bootstrap from a persistent data node.
                let contact =
                    self.data_nodes.first().copied().filter(|&d| self.dht.contains(d));
                self.dht.join(r, contact, &mut self.rng);
            }
            if !self.views.contains_key(&r) {
                let views = self.bootstrap_views(r, alive);
                self.views.insert(r, views);
                continue;
            }
            // Existing member: drop dead peers, repair from passive, then
            // top up from the stage directory (DHT stage-record lookup).
            let (bwd_members, fwd_members) = {
                let (b, f) = self.adjacent(r);
                (b.to_vec(), f.to_vec())
            };
            let fanout = self.cfg.fanout;
            let passive_size = self.cfg.passive_size;
            let v = self.views.get_mut(&r).expect("view just checked");
            for (dir, members) in
                [(&mut v.bwd, &bwd_members), (&mut v.fwd, &fwd_members)]
            {
                dir.drop_dead(alive);
                dir.refill(fanout, alive);
                if dir.active.len() < fanout || dir.passive.len() < passive_size {
                    let ok = Dht::key_for(r);
                    let mut candidates: Vec<NodeId> = members
                        .iter()
                        .copied()
                        .filter(|&m| {
                            m != r
                                && alive.get(m.0).copied().unwrap_or(false)
                                && !dir.active.contains(&m)
                                && !dir.passive.contains(&m)
                        })
                        .collect();
                    candidates.sort_by_key(|&m| Dht::key_for(m) ^ ok);
                    for m in candidates {
                        if dir.active.len() < fanout {
                            dir.active.push(m);
                        } else if dir.passive.len() < passive_size {
                            dir.insert_passive(m, passive_size);
                        } else {
                            break;
                        }
                    }
                }
            }
        }
        self.relink_ring(alive);
        self.alive = alive.to_vec();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(n_data: usize, per_stage: usize, stages: usize) -> (StageGraph, usize) {
        let data_nodes: Vec<NodeId> = (0..n_data).map(NodeId).collect();
        let mut next = n_data;
        let stages: Vec<Vec<NodeId>> = (0..stages)
            .map(|_| {
                (0..per_stage)
                    .map(|_| {
                        let id = NodeId(next);
                        next += 1;
                        id
                    })
                    .collect()
            })
            .collect();
        (StageGraph { stages, data_nodes }, next)
    }

    fn build(per_stage: usize, fanout: usize, seed: u64) -> (Overlay, usize) {
        let (g, n) = graph(2, per_stage, 4);
        (Overlay::build(&g, n, GossipConfig { fanout, ..Default::default() }, seed), n)
    }

    #[test]
    fn eclipse_liar_claims_one_slot_in_every_adjacent_view() {
        // 4 relays per stage, fanout 2: views are strict subsets, so
        // the liar is not automatically everywhere.
        let (mut ov, n) = build(4, 2, 5);
        let truth = vec![true; n];
        let liar = ov.stages[1][0];
        ov.set_eclipse_liars(vec![liar]);
        ov.gossip_round(&truth);
        assert!(!ov.last_lies().is_empty(), "some view lacked the liar");
        for &victim in &ov.stages[0].clone() {
            let v = ov.views_of(victim).unwrap();
            assert!(v.fwd.active.contains(&liar), "stage-0 fwd view eclipsed");
            assert!(v.fwd.active.len() <= 2, "lies replace, never grow, the view");
        }
        for &victim in &ov.stages[2].clone() {
            assert!(ov.views_of(victim).unwrap().bwd.active.contains(&liar));
        }
        // Once resident, further rounds stop reporting lies for those
        // views (the replace is idempotent).
        ov.gossip_round(&truth);
        for &victim in &ov.stages[0].clone() {
            let lied_again =
                ov.last_lies().iter().any(|&(l, v)| l == liar && v == victim);
            let v = ov.views_of(victim).unwrap();
            assert!(v.fwd.active.contains(&liar));
            // A shuffle may rotate the liar out; only then is it re-lied in.
            assert!(!lied_again || v.fwd.active.contains(&liar));
        }
    }

    #[test]
    fn no_liars_means_no_lie_buffer_growth() {
        let (mut ov, n) = build(4, 2, 5);
        let truth = vec![true; n];
        ov.gossip_round(&truth);
        assert!(ov.last_lies().is_empty());
    }

    #[test]
    fn views_bounded_by_fanout_and_stage_adjacent() {
        let (ov, _) = build(6, 3, 1);
        for &r in &ov.relays.clone() {
            let v = ov.views_of(r).unwrap();
            assert!(v.fwd.active.len() <= 3);
            assert!(v.bwd.active.len() <= 3);
            let s = ov.stage_of[&r];
            for &m in &v.fwd.active {
                assert_eq!(ov.stage_of[&m], s + 1, "fwd peers live in the next stage");
            }
            for &m in &v.bwd.active {
                assert_eq!(ov.stage_of[&m], s - 1, "bwd peers live in the previous stage");
            }
        }
    }

    #[test]
    fn full_fanout_views_cover_whole_adjacent_stages() {
        let (ov, _) = build(4, 16, 2);
        for &r in &ov.relays.clone() {
            let v = ov.views_of(r).unwrap();
            let s = ov.stage_of[&r];
            if s + 1 < ov.stages.len() {
                assert_eq!(v.fwd.active.len(), 4, "fanout >= stage size: full view");
            }
            if s > 0 {
                assert_eq!(v.bwd.active.len(), 4);
            }
        }
    }

    #[test]
    fn data_nodes_always_visible() {
        let (ov, _) = build(4, 2, 3);
        for &r in &ov.relays.clone() {
            assert!(ov.sees(r, NodeId(0)));
            assert!(ov.sees(r, NodeId(1)));
        }
    }

    #[test]
    fn ring_links_all_alive_relays() {
        let (mut ov, n) = build(5, 2, 4);
        let mut alive = vec![true; n];
        // kill a third of the relays
        for &r in ov.relays.clone().iter().step_by(3) {
            alive[r.0] = false;
        }
        ov.reconcile(&alive);
        let alive_relays = ov.alive_relays();
        let mut seen = std::collections::BTreeSet::new();
        let mut cur = alive_relays[0];
        for _ in 0..alive_relays.len() {
            seen.insert(cur);
            cur = ov.views_of(cur).unwrap().ring.expect("ring successor");
            assert!(alive.get(cur.0).copied().unwrap(), "ring points at a dead relay");
        }
        assert_eq!(seen.len(), alive_relays.len(), "ring is a full cycle");
    }

    #[test]
    fn crash_evicts_dht_contacts_immediately() {
        let (mut ov, _) = build(4, 3, 5);
        let victim = ov.relays[3];
        assert!(ov.dht.contains(victim));
        ov.on_crash(victim);
        assert!(!ov.dht.contains(victim));
        for &r in &ov.relays.clone() {
            if r != victim {
                assert!(
                    !ov.dht.peers_of(r).contains(&victim),
                    "stale contact for {victim} lingers at {r}"
                );
            }
        }
        // views still hold the victim until detection/reconcile
        let holders = ov
            .relays
            .clone()
            .iter()
            .filter(|&&r| r != victim && ov.sees(r, victim))
            .count();
        assert!(holders > 0, "view eviction must wait for the failure detector");
    }

    #[test]
    fn gossip_detects_and_evicts_dead_peer() {
        let (mut ov, n) = build(4, 16, 6); // full views: everyone monitors everyone adjacent
        let victim = ov.stages[1][0];
        let mut truth = vec![true; n];
        truth[victim.0] = false;
        // enough rounds for every view to probe the victim past the threshold
        for _ in 0..64 {
            ov.gossip_round(&truth);
        }
        for &r in &ov.stages[0].clone() {
            assert!(
                !ov.views_of(r).unwrap().fwd.contains(victim),
                "{r} still lists the dead {victim} after suspicion rounds"
            );
        }
    }

    #[test]
    fn reconcile_readmits_rejoiners() {
        let (mut ov, n) = build(4, 3, 7);
        let victim = ov.relays[5];
        let mut alive = vec![true; n];
        alive[victim.0] = false;
        ov.on_crash(victim);
        ov.reconcile(&alive);
        assert!(ov.views_of(victim).is_none());
        assert!(!ov.dht.contains(victim));
        // rejoin
        alive[victim.0] = true;
        ov.reconcile(&alive);
        assert!(ov.dht.contains(victim), "rejoiner re-bootstraps the DHT");
        let v = ov.views_of(victim).expect("rejoiner gets fresh views");
        assert!(!v.fwd.active.is_empty() || !v.bwd.active.is_empty());
    }

    #[test]
    fn deterministic_from_seed() {
        let (mut a, n) = build(5, 3, 9);
        let (mut b, _) = build(5, 3, 9);
        assert_eq!(a.neighbor_map(), b.neighbor_map());
        let mut alive = vec![true; n];
        alive[a.relays[2].0] = false;
        for _ in 0..5 {
            a.gossip_round(&alive);
            b.gossip_round(&alive);
        }
        a.reconcile(&alive);
        b.reconcile(&alive);
        assert_eq!(a.neighbor_map(), b.neighbor_map());
    }
}
