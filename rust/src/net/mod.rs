//! Simulated geo-distributed volunteer network.
//!
//! The paper's testbed hosts logical nodes on 5 GPUs and throttles the
//! links to mimic 10 geographic locations (50–500 Mb/s between regions).
//! We reproduce that envelope with a deterministic topology generator, a
//! Kademlia-style DHT for peer discovery, and a gossip-based partial-view
//! overlay ([`overlay`]/[`gossip`]) that gives every relay a bounded
//! neighbor list for neighbor-scoped flow planning
//! (DESIGN.md §Substitutions).  [`reputation`] layers a peer trust
//! book on top: observed-vs-promised service scores published at the
//! gossip cadence and fed into the planner's edge costs.

pub mod dht;
pub mod gossip;
pub mod overlay;
pub mod reputation;
pub mod topology;

pub use dht::Dht;
pub use gossip::{DirectedView, GossipConfig, NodeViews};
pub use overlay::Overlay;
pub use reputation::{ReputationBook, REP_ALPHA, REP_PENALTY_WEIGHT};
pub use topology::{
    CongestionCache, LinkGen, LinkStore, ProceduralLinks, Topology, TopologyConfig,
    DENSE_CACHE_MAX_NODES, PROCEDURAL_MIN_NODES,
};
