//! Simulated geo-distributed volunteer network.
//!
//! The paper's testbed hosts logical nodes on 5 GPUs and throttles the
//! links to mimic 10 geographic locations (50–500 Mb/s between regions).
//! We reproduce that envelope with a deterministic topology generator plus
//! a Kademlia-style DHT for partial-membership peer discovery
//! (DESIGN.md §Substitutions).

pub mod dht;
pub mod topology;

pub use dht::Dht;
pub use topology::{Topology, TopologyConfig};
