//! Peer reputation book (ISSUE 9 tentpole, part 2).
//!
//! Tracks observed-vs-promised service per peer and feeds an Eq. 1
//! penalty term into the planner's cost closure, so reputation-aware
//! GWTF routes around liars the way congestion-aware GWTF routes around
//! hotspots.
//!
//! **Observation sites** (the same handler sites the critical-path
//! tiles instrument):
//!
//! - `TrainingSim::send` credits each *delivered hop* with a 1.0 sample
//!   for the receiver;
//! - `handle_relay_compute`'s DENY branch charges a 0.0 sample to the
//!   refusing relay (covers both genuine overload and DENY storms —
//!   from the observer's seat they are indistinguishable, which is the
//!   point);
//! - `handle_relay_compute`'s success branch charges the
//!   promised/observed compute-time ratio, so deliberate stragglers
//!   earn scores near `1/factor`.
//!
//! **Update rule**: samples accumulate lock-free between gossip rounds;
//! at each round [`ReputationBook::publish`] folds the pending mean
//! into a per-peer EWMA `r' = (1 - α) r + α · mean`, clamped to [0, 1].
//! Publishing at gossip cadence is the piggyback: scores ride the
//! existing shuffle tick (`GwtfRouter::on_gossip`), costing zero extra
//! messages in the simulated network.
//!
//! **Eq. 1 penalty**: [`ReputationBook::penalty`] returns
//! `1 + w · ((1 - rᵢ) + (1 - rⱼ))` and the router multiplies it into
//! the edge cost.  At the all-honest prior (r ≡ 1) the penalty is
//! exactly `1.0`, and `x * 1.0` is bit-for-bit `x` for finite IEEE-754
//! `x` — plus `publish` skips the store when the folded value equals
//! the prior — so enabling reputation on a clean fleet reproduces the
//! oblivious arm bit for bit.
//!
//! The book shares the `CongestionCache` concurrency pattern: a shared
//! `Arc`, `AtomicU64` cells holding `f64::to_bits`, `Relaxed` ordering
//! (single-threaded engine; atomics are for interior mutability through
//! `&self`, not cross-thread contention).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::cost::NodeId;
use crate::trace::{self, TraceKind, TraceRecord};

/// EWMA smoothing factor for published scores.
pub const REP_ALPHA: f64 = 0.2;

/// Eq. 1 penalty weight `w`: a peer at score 0 multiplies its incident
/// edge costs by `1 + w` (both endpoints dishonest: `1 + 2w`).
pub const REP_PENALTY_WEIGHT: f64 = 4.0;

/// Lock-free per-peer reputation scores with deferred (gossip-cadence)
/// EWMA publication.
pub struct ReputationBook {
    alpha: f64,
    weight: f64,
    /// Published scores, `f64::to_bits`, one per node, init 1.0.
    score: Vec<AtomicU64>,
    /// Pending sample sums since the last publish, `f64::to_bits`.
    pend_sum: Vec<AtomicU64>,
    /// Pending sample counts since the last publish.
    pend_n: Vec<AtomicU64>,
}

impl ReputationBook {
    /// Fresh book over `n` nodes: everyone starts fully trusted (1.0).
    pub fn new(n: usize, alpha: f64, weight: f64) -> Self {
        ReputationBook {
            alpha,
            weight,
            score: (0..n).map(|_| AtomicU64::new(1.0f64.to_bits())).collect(),
            pend_sum: (0..n).map(|_| AtomicU64::new(0.0f64.to_bits())).collect(),
            pend_n: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Published score of node `n` in [0, 1] (1 = fully trusted).
    pub fn score(&self, n: NodeId) -> f64 {
        f64::from_bits(self.score[n.0].load(Ordering::Relaxed))
    }

    /// Eq. 1 multiplicative penalty for edge `(i, j)`:
    /// `1 + w · ((1 - rᵢ) + (1 - rⱼ))`.  Exactly 1.0 at the all-honest
    /// prior, so `base * penalty` is bitwise-transparent there.
    pub fn penalty(&self, i: NodeId, j: NodeId) -> f64 {
        1.0 + self.weight * ((1.0 - self.score(i)) + (1.0 - self.score(j)))
    }

    fn push_sample(&self, n: NodeId, s: f64) {
        let _ = self.pend_sum[n.0].fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            Some((f64::from_bits(bits) + s).to_bits())
        });
        self.pend_n[n.0].fetch_add(1, Ordering::Relaxed);
    }

    /// A peer refused a microbatch (§V-D DENY): worst sample.
    pub fn observe_deny(&self, n: NodeId) {
        self.push_sample(n, 0.0);
    }

    /// A peer finished a compute hop: charge the promised/observed
    /// service-time ratio (1.0 when on schedule, `1/factor` for a
    /// deliberate straggler).
    pub fn observe_service(&self, n: NodeId, promised_s: f64, observed_s: f64) {
        let ratio = if observed_s > 0.0 { (promised_s / observed_s).clamp(0.0, 1.0) } else { 1.0 };
        self.push_sample(n, ratio);
    }

    /// A hop was delivered to `n` over the network: full credit.
    pub fn observe_delivery(&self, n: NodeId) {
        self.push_sample(n, 1.0);
    }

    /// Fold pending samples into the published EWMA scores.  Called
    /// from `GwtfRouter::on_gossip` so publication rides the existing
    /// shuffle cadence.  Skips nodes with no pending samples and skips
    /// the store when the fold is a fixed point (keeps the all-honest
    /// prior bitwise-stable).  Emits a [`TraceKind::RepUpdate`] instant
    /// per changed score when tracing is armed.
    pub fn publish(&self, t: f64) {
        for i in 0..self.score.len() {
            let k = self.pend_n[i].swap(0, Ordering::Relaxed);
            if k == 0 {
                continue;
            }
            let sum = f64::from_bits(self.pend_sum[i].swap(0.0f64.to_bits(), Ordering::Relaxed));
            let mean = (sum / k as f64).clamp(0.0, 1.0);
            let old = f64::from_bits(self.score[i].load(Ordering::Relaxed));
            if mean == old {
                continue;
            }
            let new = ((1.0 - self.alpha) * old + self.alpha * mean).clamp(0.0, 1.0);
            self.score[i].store(new.to_bits(), Ordering::Relaxed);
            trace::emit(|| {
                TraceRecord::instant(
                    t,
                    Some(NodeId(i)),
                    None,
                    // Score in thousandths: 873 = 0.873.
                    TraceKind::RepUpdate { score_milli: (new * 1000.0) as u32 },
                )
            });
        }
    }

    /// Number of peers tracked.
    pub fn len(&self) -> usize {
        self.score.len()
    }

    /// True when the book tracks no peers.
    pub fn is_empty(&self) -> bool {
        self.score.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_is_fully_trusted_and_penalty_is_identity() {
        let book = ReputationBook::new(4, REP_ALPHA, REP_PENALTY_WEIGHT);
        for i in 0..4 {
            assert_eq!(book.score(NodeId(i)).to_bits(), 1.0f64.to_bits());
        }
        assert_eq!(book.penalty(NodeId(0), NodeId(1)).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn all_good_samples_keep_the_prior_bitwise_stable() {
        let book = ReputationBook::new(2, REP_ALPHA, REP_PENALTY_WEIGHT);
        for _ in 0..7 {
            book.observe_delivery(NodeId(0));
            book.observe_service(NodeId(0), 3.0, 3.0);
        }
        book.publish(10.0);
        // mean == old == 1.0 → fixed-point skip, no EWMA rounding drift.
        assert_eq!(book.score(NodeId(0)).to_bits(), 1.0f64.to_bits());
        assert_eq!(book.penalty(NodeId(0), NodeId(1)).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn denies_drag_the_score_down_and_raise_the_penalty() {
        let book = ReputationBook::new(2, REP_ALPHA, REP_PENALTY_WEIGHT);
        book.observe_deny(NodeId(1));
        book.publish(1.0);
        let s1 = book.score(NodeId(1));
        assert!((s1 - 0.8).abs() < 1e-12, "one publish: (1-α)·1 + α·0 = 0.8");
        assert!(book.penalty(NodeId(0), NodeId(1)) > 1.0);
        book.observe_deny(NodeId(1));
        book.publish(2.0);
        assert!(book.score(NodeId(1)) < s1, "repeated denies keep decaying");
    }

    #[test]
    fn straggler_ratio_converges_toward_inverse_factor() {
        let book = ReputationBook::new(1, REP_ALPHA, REP_PENALTY_WEIGHT);
        for round in 0..200 {
            book.observe_service(NodeId(0), 1.0, 2.5);
            book.publish(round as f64);
        }
        let s = book.score(NodeId(0));
        assert!((s - 0.4).abs() < 1e-6, "EWMA limit is the 1/2.5 ratio, got {s}");
    }

    #[test]
    fn scores_stay_in_unit_interval_under_mixed_samples() {
        let book = ReputationBook::new(1, REP_ALPHA, REP_PENALTY_WEIGHT);
        for round in 0..50 {
            book.observe_deny(NodeId(0));
            book.observe_delivery(NodeId(0));
            book.observe_service(NodeId(0), 5.0, 1.0); // early: ratio clamps at 1
            book.publish(round as f64);
            let s = book.score(NodeId(0));
            assert!((0.0..=1.0).contains(&s), "score escaped [0,1]: {s}");
        }
    }
}
