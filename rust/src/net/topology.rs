//! Topology generator: nodes in geographic regions, asymmetric links.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

use crate::cost::{
    comm_cost, edge_cost, expected_queue_s, LinkParams, NicConfig, NodeId, NodeProfile,
};
use crate::util::Rng;

/// Parameters of the generated network.
#[derive(Debug, Clone)]
pub struct TopologyConfig {
    /// Number of nodes.
    pub n_nodes: usize,
    /// Number of geographic regions (the paper uses 10 locations).
    pub n_regions: usize,
    /// Inter-region bandwidth range, Mb/s (paper: 50–500 Mb/s).
    pub inter_bw_mbps: (f64, f64),
    /// Intra-region bandwidth range, Mb/s.
    pub intra_bw_mbps: (f64, f64),
    /// Inter-region one-way latency range, seconds.
    pub inter_lat_s: (f64, f64),
    /// Intra-region one-way latency range, seconds.
    pub intra_lat_s: (f64, f64),
    /// Per-node NIC transmission concurrency by link class (intra-region
    /// vs WAN).  Unlimited (the default) is the legacy contention-free
    /// model; finite caps make the simulator serialize transmissions per
    /// NIC (`sim::events::NicQueues`).
    pub nic: NicConfig,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        TopologyConfig {
            n_nodes: 18,
            n_regions: 10,
            inter_bw_mbps: (50.0, 500.0),
            intra_bw_mbps: (700.0, 1000.0),
            inter_lat_s: (0.020, 0.200),
            intra_lat_s: (0.001, 0.005),
            nic: NicConfig::UNLIMITED,
        }
    }
}

/// The full (simulated) network state: regions, directed links, profiles.
#[derive(Debug, Clone)]
pub struct Topology {
    pub region: Vec<usize>,
    /// `links[i][j]` = params of the directed link i -> j.
    pub links: Vec<Vec<LinkParams>>,
    pub profiles: Vec<NodeProfile>,
    /// NIC transmission-concurrency caps the simulator's shared-capacity
    /// substrate enforces (unlimited = legacy contention-free model).
    pub nic: NicConfig,
}

impl Topology {
    /// Deterministically generate a topology from a seed.
    pub fn generate(cfg: &TopologyConfig, rng: &mut Rng) -> Topology {
        let n = cfg.n_nodes;
        let region: Vec<usize> = (0..n).map(|_| rng.index(cfg.n_regions.max(1))).collect();
        let mut links = vec![vec![LinkParams::new(0.0, f64::INFINITY); n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let same = region[i] == region[j];
                let (bw_lo, bw_hi) = if same { cfg.intra_bw_mbps } else { cfg.inter_bw_mbps };
                let (lat_lo, lat_hi) = if same { cfg.intra_lat_s } else { cfg.inter_lat_s };
                // Each direction sampled independently: links are asymmetric.
                links[i][j] = LinkParams::new(
                    rng.uniform(lat_lo, lat_hi),
                    rng.uniform(bw_lo, bw_hi) * 1e6 / 8.0,
                );
            }
        }
        let profiles = vec![NodeProfile::new(1.0, 1); n];
        Topology { region, links, profiles, nic: cfg.nic }
    }

    pub fn n(&self) -> usize {
        self.region.len()
    }

    /// Eq. 1 cost between two nodes for a given payload.
    pub fn cost(&self, i: NodeId, j: NodeId, size_bytes: f64) -> f64 {
        edge_cost(
            &self.profiles[i.0],
            &self.profiles[j.0],
            &self.links[i.0][j.0],
            &self.links[j.0][i.0],
            size_bytes,
        )
    }

    /// Communication-only cost (compute accounted separately).
    pub fn comm(&self, i: NodeId, j: NodeId, size_bytes: f64) -> f64 {
        comm_cost(&self.links[i.0][j.0], &self.links[j.0][i.0], size_bytes)
    }

    /// Congestion-aware Eq. 1: the base cost plus the expected
    /// NIC-queueing term ([`expected_queue_s`]) for the edge's link
    /// class.  Reads `self.nic` — the *same* substrate parameters the
    /// simulator executes — so a planner charging this can never
    /// disagree with the physical model about what an interface carries
    /// (one source of truth, no caller-supplied copy to drift).  With an
    /// unlimited class this *is* [`Topology::cost`], bit for bit.
    pub fn congestion_cost(&self, i: NodeId, j: NodeId, size_bytes: f64) -> f64 {
        let base = self.cost(i, j, size_bytes);
        let same_region = self.region[i.0] == self.region[j.0];
        let Some(cap) = self.nic.cap(same_region) else {
            return base;
        };
        let tx = 2.0 * size_bytes
            / (self.links[i.0][j.0].bandwidth_bps + self.links[j.0][i.0].bandwidth_bps);
        base + expected_queue_s(
            self.profiles[i.0].capacity,
            self.profiles[j.0].capacity,
            tx,
            cap,
        )
    }

    /// One-way message delay i -> j for `size_bytes`.
    pub fn delay(&self, i: NodeId, j: NodeId, size_bytes: f64) -> f64 {
        self.links[i.0][j.0].one_way_s(size_bytes)
    }

    /// Set every node's compute profile (homogeneous case).
    pub fn with_uniform_profiles(mut self, p: NodeProfile) -> Self {
        for q in self.profiles.iter_mut() {
            *q = p;
        }
        self
    }

    /// Assign per-node profiles.
    pub fn set_profile(&mut self, i: NodeId, p: NodeProfile) {
        self.profiles[i.0] = p;
    }
}

/// Memo over [`Topology::congestion_cost`] for one fixed payload size —
/// the planner's cost closure evaluates the same edges thousands of
/// times per round, and `expected_queue_s` is by far the most expensive
/// term in them.
///
/// The memo stores the *full* edge value: the queueing term does not
/// decompose per endpoint bit-exactly in IEEE arithmetic, so splitting
/// it would change cost bits and break the golden traces.  Entries are
/// keyed by `(i, j)` and stamped with the pair of per-(endpoint,
/// link-class) generation counters they were computed at; the booking
/// path ([`crate::sim::TrainingSim`]) bumps an endpoint's class
/// generation whenever a transmission actually queues behind its NIC
/// cap, forcing affected edges to recompute.  Today every recompute
/// returns identical bits — the topology behind the `Arc` is immutable —
/// so the invalidation rule is a correctness-neutral hook for future
/// measured-backlog cost terms; it is also exactly why the cache is
/// race-benign under `Relaxed` atomics: any interleaving of stores
/// writes the same value.
#[derive(Debug)]
pub struct CongestionCache {
    topo: Arc<Topology>,
    size_bytes: f64,
    n: usize,
    /// Cached edge-cost bit patterns, row-major by `(i, j)`.
    vals: Vec<AtomicU64>,
    /// `(gen_i << 32) | gen_j` at which `vals[k]` was computed; 0 = never
    /// (generations start at 1).
    stamps: Vec<AtomicU64>,
    /// Per-(node, link-class) generations: `gens[2 * node + class]`,
    /// class 0 = intra-region, 1 = WAN.
    gens: Vec<AtomicU32>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CongestionCache {
    pub fn new(topo: Arc<Topology>, size_bytes: f64) -> CongestionCache {
        let n = topo.n();
        CongestionCache {
            topo,
            size_bytes,
            n,
            vals: (0..n * n).map(|_| AtomicU64::new(0)).collect(),
            stamps: (0..n * n).map(|_| AtomicU64::new(0)).collect(),
            gens: (0..2 * n).map(|_| AtomicU32::new(1)).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn topo(&self) -> &Arc<Topology> {
        &self.topo
    }

    fn class(&self, i: NodeId, j: NodeId) -> usize {
        usize::from(self.topo.region[i.0] != self.topo.region[j.0])
    }

    /// [`Topology::congestion_cost`] for the cache's payload size —
    /// bit-identical to the uncached call, served from the memo when the
    /// entry's generation stamp is current.
    pub fn cost(&self, i: NodeId, j: NodeId) -> f64 {
        let cls = self.class(i, j);
        let gi = self.gens[2 * i.0 + cls].load(Relaxed) as u64;
        let gj = self.gens[2 * j.0 + cls].load(Relaxed) as u64;
        let want = (gi << 32) | gj;
        let k = i.0 * self.n + j.0;
        if self.stamps[k].load(Relaxed) == want {
            self.hits.fetch_add(1, Relaxed);
            return f64::from_bits(self.vals[k].load(Relaxed));
        }
        self.misses.fetch_add(1, Relaxed);
        let v = self.topo.congestion_cost(i, j, self.size_bytes);
        self.vals[k].store(v.to_bits(), Relaxed);
        self.stamps[k].store(want, Relaxed);
        v
    }

    /// Booking-path invalidation: a transmission on `node`'s NIC queued
    /// behind the given link class, so every cached edge touching that
    /// (endpoint, class) must recompute on next read.
    pub fn invalidate(&self, node: NodeId, same_region: bool) {
        self.gens[2 * node.0 + usize::from(!same_region)].fetch_add(1, Relaxed);
    }

    /// (hits, misses) observed so far — the scale bench reports these.
    pub fn hit_miss(&self) -> (u64, u64) {
        (self.hits.load(Relaxed), self.misses.load(Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(seed: u64) -> Topology {
        Topology::generate(&TopologyConfig::default(), &mut Rng::new(seed))
    }

    #[test]
    fn deterministic_generation() {
        let a = topo(5);
        let b = topo(5);
        assert_eq!(a.region, b.region);
        assert_eq!(a.links[0][1], b.links[0][1]);
    }

    #[test]
    fn intra_region_faster_than_inter() {
        let t = topo(1);
        let n = t.n();
        let mut intra: Vec<f64> = vec![];
        let mut inter: Vec<f64> = vec![];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let l = t.links[i][j].latency_s;
                if t.region[i] == t.region[j] {
                    intra.push(l);
                } else {
                    inter.push(l);
                }
            }
        }
        if !intra.is_empty() && !inter.is_empty() {
            let ai = intra.iter().sum::<f64>() / intra.len() as f64;
            let ae = inter.iter().sum::<f64>() / inter.len() as f64;
            assert!(ai < ae, "intra {ai} should beat inter {ae}");
        }
    }

    #[test]
    fn bandwidth_within_paper_envelope() {
        let t = topo(2);
        for i in 0..t.n() {
            for j in 0..t.n() {
                if i == j || t.region[i] == t.region[j] {
                    continue;
                }
                let mbps = t.links[i][j].bandwidth_bps * 8.0 / 1e6;
                assert!((50.0..=500.0).contains(&mbps), "{mbps}");
            }
        }
    }

    #[test]
    fn asymmetric_links_exist() {
        let t = topo(3);
        let mut any_asym = false;
        for i in 0..t.n() {
            for j in (i + 1)..t.n() {
                if (t.links[i][j].latency_s - t.links[j][i].latency_s).abs() > 1e-9 {
                    any_asym = true;
                }
            }
        }
        assert!(any_asym);
    }

    #[test]
    fn congestion_cost_unlimited_is_eq1_bit_for_bit() {
        let t = topo(6); // default TopologyConfig: unlimited NICs
        let (i, j) = (NodeId(0), NodeId(2));
        assert_eq!(t.congestion_cost(i, j, 1e6).to_bits(), t.cost(i, j, 1e6).to_bits());
    }

    #[test]
    fn congestion_cost_penalizes_tight_nics_and_fat_endpoints() {
        let mut t = topo(6);
        let (i, j) = (NodeId(0), NodeId(1));
        t.set_profile(i, NodeProfile::new(1.0, 4));
        t.set_profile(j, NodeProfile::new(1.0, 8));
        let base = t.cost(i, j, 1e6);
        t.nic = NicConfig::uniform(2);
        let c2 = t.congestion_cost(i, j, 1e6);
        t.nic = NicConfig::uniform(1);
        let c1 = t.congestion_cost(i, j, 1e6);
        assert!(c2 > base, "finite NICs must add a queueing term");
        assert!(c1 > c2, "halving the concurrency must raise the penalty");
        // Capacity-1 endpoints cannot contend: penalty vanishes.
        t.set_profile(i, NodeProfile::new(1.0, 1));
        t.set_profile(j, NodeProfile::new(1.0, 1));
        assert_eq!(t.congestion_cost(i, j, 1e6).to_bits(), t.cost(i, j, 1e6).to_bits());
    }

    #[test]
    fn congestion_cache_serves_identical_bits_and_counts() {
        let mut t = topo(6);
        t.nic = NicConfig::uniform(2);
        t.set_profile(NodeId(0), NodeProfile::new(1.0, 4));
        t.set_profile(NodeId(1), NodeProfile::new(1.0, 8));
        let t = Arc::new(t);
        let cache = CongestionCache::new(t.clone(), 1e6);
        for _ in 0..3 {
            for i in 0..t.n() {
                for j in 0..t.n() {
                    if i == j {
                        continue;
                    }
                    let (i, j) = (NodeId(i), NodeId(j));
                    assert_eq!(
                        cache.cost(i, j).to_bits(),
                        t.congestion_cost(i, j, 1e6).to_bits(),
                        "{i}->{j}"
                    );
                }
            }
        }
        let pairs = (t.n() * (t.n() - 1)) as u64;
        let (hits, misses) = cache.hit_miss();
        assert_eq!(misses, pairs, "each pair computed exactly once");
        assert_eq!(hits, 2 * pairs, "passes 2 and 3 fully served from the memo");
    }

    #[test]
    fn congestion_cache_invalidation_is_per_endpoint_and_class() {
        let mut t = topo(7);
        t.nic = NicConfig::uniform(2);
        let t = Arc::new(t);
        // pick a WAN pair and a pair not touching node 0
        let i = NodeId(0);
        let j = NodeId((1..t.n()).find(|&j| t.region[j] != t.region[0]).unwrap());
        let k = NodeId((1..t.n()).find(|&k| k != j.0).unwrap());
        let cache = CongestionCache::new(t.clone(), 1e6);
        cache.cost(i, j);
        cache.cost(k, j);
        // invalidating i's WAN class recomputes (i, j) but not (k, j)
        let (_, m0) = cache.hit_miss();
        cache.invalidate(i, false);
        cache.cost(i, j);
        cache.cost(k, j);
        let (_, m1) = cache.hit_miss();
        assert_eq!(m1 - m0, 1, "only the touched endpoint's edge recomputes");
        // invalidating the *other* class leaves the WAN entry warm
        cache.invalidate(i, true);
        cache.cost(i, j);
        let (_, m2) = cache.hit_miss();
        assert_eq!(m2, m1, "intra-region generation must not stamp WAN edges");
    }

    #[test]
    fn cost_consistent_with_eq1() {
        let t = topo(4);
        let (i, j) = (NodeId(0), NodeId(1));
        let c = t.cost(i, j, 1e6);
        let manual = edge_cost(
            &t.profiles[0],
            &t.profiles[1],
            &t.links[0][1],
            &t.links[1][0],
            1e6,
        );
        assert_eq!(c, manual);
        assert!((t.cost(i, j, 1e6) - t.cost(j, i, 1e6)).abs() < 1e-12);
    }
}
