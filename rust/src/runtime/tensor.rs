//! Host-side tensors exchanged with PJRT.
//!
//! The runtime moves three dtypes across the PJRT boundary (everything the
//! AOT-lowered stage functions consume or produce): `f32` activations /
//! gradients / params, `i32` tokens / targets, and `u32` seeds / steps.

use anyhow::{anyhow, bail, Result};

/// Element type of a [`HostTensor`] (mirrors the manifest dtype strings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    /// Parse a numpy-style dtype string from `manifest.json`.
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            "uint32" | "u32" => Ok(DType::U32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::U32 => "uint32",
        }
    }
}

/// Dense host tensor (row-major) with one of the supported dtypes.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape, data }
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::U32 { shape, data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_u32(v: u32) -> Self {
        HostTensor::U32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } | HostTensor::U32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
            HostTensor::U32 { .. } => DType::U32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
            HostTensor::U32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => Err(anyhow!("expected f32 tensor, got {:?}", other.dtype())),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => Err(anyhow!("expected f32 tensor, got {:?}", other.dtype())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            other => Err(anyhow!("expected i32 tensor, got {:?}", other.dtype())),
        }
    }

    /// Upload to a device buffer on `client`'s default device.
    ///
    /// Uses `buffer_from_host_buffer` (kImmutableOnlyDuringCall: the data
    /// is copied before the call returns), so the tensor can be freed
    /// immediately — unlike literal-based uploads, whose host->device
    /// transfer is asynchronous.
    pub fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let dims = self.shape().to_vec();
        let buf = match self {
            HostTensor::F32 { data, .. } => client.buffer_from_host_buffer(data, &dims, None)?,
            HostTensor::I32 { data, .. } => client.buffer_from_host_buffer(data, &dims, None)?,
            HostTensor::U32 { data, .. } => client.buffer_from_host_buffer(data, &dims, None)?,
        };
        Ok(buf)
    }

    /// Convert to an XLA literal of the right shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read back from an XLA literal (shape taken from the literal).
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        use xla::ElementType as ET;
        match shape.ty() {
            ET::F32 => Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            ET::S32 => Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            ET::U32 => Ok(HostTensor::U32 { shape: dims, data: lit.to_vec::<u32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Elementwise `self += other` (f32 only; used for gradient accumulation).
    pub fn add_assign(&mut self, other: &HostTensor) -> Result<()> {
        let b = other.as_f32()?.to_vec();
        let a = self.as_f32_mut()?;
        if a.len() != b.len() {
            bail!("add_assign length mismatch: {} vs {}", a.len(), b.len());
        }
        for (x, y) in a.iter_mut().zip(b) {
            *x += y;
        }
        Ok(())
    }

    /// Elementwise scale (f32 only; used for gradient averaging).
    pub fn scale(&mut self, k: f32) -> Result<()> {
        for x in self.as_f32_mut()? {
            *x *= k;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_roundtrip() {
        for s in ["float32", "int32", "uint32"] {
            assert_eq!(DType::parse(s).unwrap().as_str(), s);
        }
        assert!(DType::parse("float64").is_err());
    }

    #[test]
    fn shape_and_len() {
        let t = HostTensor::zeros_f32(vec![2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    fn accumulate_and_scale() {
        let mut a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = HostTensor::f32(vec![3], vec![0.5, 0.5, 0.5]);
        a.add_assign(&b).unwrap();
        a.scale(2.0).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[3.0, 5.0, 7.0]);
    }

    #[test]
    fn type_mismatch_errors() {
        let mut a = HostTensor::i32(vec![1], vec![1]);
        let b = HostTensor::f32(vec![1], vec![1.0]);
        assert!(a.add_assign(&b).is_err());
        assert!(a.as_f32().is_err());
        assert!(b.as_i32().is_err());
    }

    #[test]
    fn scalar_shapes() {
        assert_eq!(HostTensor::scalar_f32(1.0).shape(), &[] as &[usize]);
        assert_eq!(HostTensor::scalar_u32(7).len(), 1);
    }
}
