//! PJRT runtime client: load HLO-text artifacts, compile once, execute.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, which sidesteps the 64-bit-id protos that jax >= 0.5
//! emits and xla_extension 0.5.1 rejects.
//!
//! Executables are cached per artifact, so each stage function is compiled
//! exactly once per process regardless of how many logical nodes execute
//! it (the simulated volunteers all share one CPU PJRT client).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::manifest::ArtifactEntry;
use super::tensor::HostTensor;

/// Cumulative execution statistics (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_s: f64,
    pub executions: usize,
    pub execute_s: f64,
}

/// A compiled stage function ready to run.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub n_inputs: usize,
    /// Which of the logical inputs the compiled program takes (jax prunes
    /// arguments the computation never reads — see `manifest.rs`).
    pub kept_inputs: Vec<usize>,
    pub n_outputs: usize,
}

impl Executable {
    /// Run with the full logical argument list; prunes to the kept inputs
    /// and returns the flattened output leaves.
    pub fn run(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = args.iter().collect();
        self.run_refs(&refs)
    }

    /// Borrowed-argument variant: the hot path passes parameter leaves by
    /// reference, avoiding a full parameter memcpy per stage call.
    pub fn run_refs(&self, args: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if args.len() != self.n_inputs {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.n_inputs,
                args.len()
            ));
        }
        // Stage through caller-owned PjRtBuffers + execute_b: the crate's
        // literal-taking `execute` leaks its internal input buffers (they
        // are `release()`d into the C call and never freed), which an
        // earlier revision hit at ~7 MB per stage call.  Owned buffers are
        // freed by Drop.
        let client = self.exe.client();
        let mut buffers = Vec::with_capacity(self.kept_inputs.len());
        for &i in &self.kept_inputs {
            buffers.push(args[i].to_buffer(client)?);
        }
        let out = self.exe.execute_b(&buffers)?;
        let tuple = out[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: one tuple of output leaves.
        let leaves = tuple.to_tuple()?;
        let mut res = Vec::with_capacity(leaves.len());
        for l in &leaves {
            res.push(HostTensor::from_literal(l)?);
        }
        if res.len() != self.n_outputs {
            return Err(anyhow!(
                "{}: manifest promises {} outputs, got {}",
                self.name,
                self.n_outputs,
                res.len()
            ));
        }
        Ok(res)
    }
}

/// Shared PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()), stats: Mutex::new(RuntimeStats::default()) })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact (cached by name).
    pub fn load(&self, entry: &ArtifactEntry) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(&entry.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .with_context(|| format!("loading HLO text {:?}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", entry.name))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.lock().unwrap();
            s.compiles += 1;
            s.compile_s += dt;
        }
        let executable = std::sync::Arc::new(Executable {
            name: entry.name.clone(),
            exe,
            n_inputs: entry.inputs.len(),
            kept_inputs: entry.kept_inputs.clone(),
            n_outputs: entry.outputs.len(),
        });
        self.cache.lock().unwrap().insert(entry.name.clone(), executable.clone());
        Ok(executable)
    }

    /// Load + run in one call, tracking execute time.
    pub fn run(&self, entry: &ArtifactEntry, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = args.iter().collect();
        self.run_refs(entry, &refs)
    }

    /// Borrowed-argument variant (see [`Executable::run_refs`]).
    pub fn run_refs(&self, entry: &ArtifactEntry, args: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self.load(entry)?;
        let t0 = Instant::now();
        let out = exe.run_refs(args)?;
        let dt = t0.elapsed().as_secs_f64();
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.execute_s += dt;
        Ok(out)
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.lock().unwrap()
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
