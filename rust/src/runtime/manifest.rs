//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! The manifest records, per model family, the exact positional
//! input/output specs of every lowered stage function (pytrees are
//! flattened in `jax.tree_util` order on the Python side), the parameter
//! leaf names in that order, and the model configuration the artifacts
//! were lowered for.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::tensor::DType;

/// Shape + dtype of one positional argument or result.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    fn parse(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("spec missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered function: HLO file + its flattened signature.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub input_names: Vec<String>,
    /// Indices (into `inputs`) of arguments the compiled program actually
    /// takes — jax prunes args the computation never reads.
    pub kept_inputs: Vec<usize>,
    pub outputs: Vec<TensorSpec>,
}

/// Model configuration the family was lowered at (mirrors `ModelConfig`).
#[derive(Debug, Clone)]
pub struct FamilyConfig {
    pub family: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub microbatch: usize,
    pub blocks_per_stage: usize,
    pub n_stages: usize,
    pub param_count: usize,
    pub activation_bytes: usize,
}

/// One family's artifacts, keyed by function name (`stage_fwd`, ...).
#[derive(Debug, Clone)]
pub struct FamilyArtifacts {
    pub config: FamilyConfig,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl FamilyArtifacts {
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries.get(name).ok_or_else(|| anyhow!("no artifact {name:?}"))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub fingerprint: String,
    pub families: BTreeMap<String, FamilyArtifacts>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {path:?}: {e}"))?;

        let fingerprint =
            j.get("fingerprint").and_then(Json::as_str).unwrap_or_default().to_string();
        let mut families = BTreeMap::new();
        let fam_obj = j
            .get("families")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing families"))?;
        for (fam_name, fam) in fam_obj {
            let cfg = fam.get("config").ok_or_else(|| anyhow!("family missing config"))?;
            let gu = |k: &str| -> Result<usize> {
                cfg.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config missing {k}"))
            };
            let config = FamilyConfig {
                family: fam_name.clone(),
                vocab_size: gu("vocab_size")?,
                d_model: gu("d_model")?,
                n_heads: gu("n_heads")?,
                n_layers: gu("n_layers")?,
                d_ff: gu("d_ff")?,
                seq_len: gu("seq_len")?,
                microbatch: gu("microbatch")?,
                blocks_per_stage: gu("blocks_per_stage")?,
                n_stages: fam.get("n_stages").and_then(Json::as_usize).unwrap_or(0),
                param_count: fam.get("param_count").and_then(Json::as_usize).unwrap_or(0),
                activation_bytes: fam.get("activation_bytes").and_then(Json::as_usize).unwrap_or(0),
            };
            let mut entries = BTreeMap::new();
            let arts = fam
                .get("artifacts")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("family missing artifacts"))?;
            for (name, e) in arts {
                let file = e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
                let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                    e.get(key)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
                        .iter()
                        .map(TensorSpec::parse)
                        .collect()
                };
                let input_names = e
                    .get("input_names")
                    .and_then(Json::as_arr)
                    .map(|v| {
                        v.iter().filter_map(|s| s.as_str().map(str::to_string)).collect::<Vec<_>>()
                    })
                    .unwrap_or_default();
                let inputs = parse_specs("inputs")?;
                let kept_inputs = e
                    .get("kept_inputs")
                    .and_then(Json::as_arr)
                    .map(|v| v.iter().filter_map(Json::as_usize).collect::<Vec<_>>())
                    .unwrap_or_else(|| (0..inputs.len()).collect());
                entries.insert(
                    name.clone(),
                    ArtifactEntry {
                        name: name.clone(),
                        file: dir.join(file),
                        inputs,
                        input_names,
                        kept_inputs,
                        outputs: parse_specs("outputs")?,
                    },
                );
            }
            families.insert(fam_name.clone(), FamilyArtifacts { config, entries });
        }
        Ok(Manifest { dir, fingerprint, families })
    }

    pub fn family(&self, name: &str) -> Result<&FamilyArtifacts> {
        self.families
            .get(name)
            .ok_or_else(|| anyhow!("family {name:?} not in manifest (have: {:?})", self.families.keys()))
    }

    /// Default artifacts directory (env `GWTF_ARTIFACTS` or `./artifacts`).
    pub fn default_dir() -> PathBuf {
        std::env::var("GWTF_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
          "fingerprint": "abc",
          "families": {
            "llama": {
              "config": {"family": "llama", "vocab_size": 256, "d_model": 64,
                         "n_heads": 4, "n_layers": 4, "d_ff": 192, "seq_len": 32,
                         "microbatch": 2, "blocks_per_stage": 2, "norm_eps": 1e-5,
                         "rope_theta": 10000.0, "use_pallas": true, "init_std": 0.02},
              "param_count": 12345,
              "activation_bytes": 16384,
              "n_stages": 2,
              "artifacts": {
                "stage_fwd": {
                  "file": "llama_stage_fwd.hlo.txt",
                  "inputs": [{"shape": [2, 64, 64], "dtype": "float32"},
                             {"shape": [2, 32, 64], "dtype": "float32"}],
                  "input_names": ["0.attn_norm", "1"],
                  "outputs": [{"shape": [2, 32, 64], "dtype": "float32"}],
                  "sha256": "x", "hlo_bytes": 10
                }
              }
            }
          }
        }"#
    }

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("gwtf_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.fingerprint, "abc");
        let fam = m.family("llama").unwrap();
        assert_eq!(fam.config.d_model, 64);
        assert_eq!(fam.config.n_stages, 2);
        let e = fam.entry("stage_fwd").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![2, 64, 64]);
        assert_eq!(e.inputs[0].dtype, DType::F32);
        assert_eq!(e.input_names[0], "0.attn_norm");
        assert!(e.file.ends_with("llama_stage_fwd.hlo.txt"));
        assert!(m.family("gpt").is_err());
        assert!(fam.entry("nope").is_err());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn spec_elements() {
        let s = TensorSpec { shape: vec![2, 3, 4], dtype: DType::F32 };
        assert_eq!(s.elements(), 24);
    }
}
