//! Stage executors: typed wrappers over the AOT-lowered stage functions.
//!
//! The pipeline decomposes exactly as in the paper (§II): the *data node*
//! holds the embedding + head/loss stages (first and last stage colocated),
//! and each *relay stage* holds `blocks_per_stage` transformer blocks.
//! Every executor owns its flattened parameter leaves (in the manifest's
//! pytree order) and drives the corresponding `*_init` / `*_fwd` / `*_bwd`
//! / `*_update` artifacts through the shared [`super::Runtime`].

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::client::Runtime;
use super::manifest::FamilyArtifacts;
use super::tensor::HostTensor;

/// Flattened parameter (or gradient) leaves in manifest order.
pub type Leaves = Vec<HostTensor>;

/// Accumulates gradient leaves and averages them (DP aggregation math).
#[derive(Debug, Clone, Default)]
pub struct GradAccumulator {
    sum: Option<Leaves>,
    count: usize,
}

impl GradAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, grads: Leaves) -> Result<()> {
        match &mut self.sum {
            None => self.sum = Some(grads),
            Some(acc) => {
                if acc.len() != grads.len() {
                    return Err(anyhow!("grad leaf count mismatch: {} vs {}", acc.len(), grads.len()));
                }
                for (a, g) in acc.iter_mut().zip(&grads) {
                    a.add_assign(g)?;
                }
            }
        }
        self.count += 1;
        Ok(())
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Average of everything added so far; resets the accumulator.
    pub fn take_mean(&mut self) -> Result<Leaves> {
        let mut acc = self.sum.take().ok_or_else(|| anyhow!("no gradients accumulated"))?;
        let k = 1.0 / self.count as f32;
        for a in acc.iter_mut() {
            a.scale(k)?;
        }
        self.count = 0;
        Ok(acc)
    }
}

/// One relay stage: `blocks_per_stage` transformer blocks.
pub struct BlockStage {
    rt: Arc<Runtime>,
    fam: FamilyArtifacts,
    pub params: Leaves,
    /// Pipeline position (0-based relay stage index), for diagnostics.
    pub index: usize,
}

impl BlockStage {
    /// Initialize stage parameters from a seed (the `stage_init` artifact).
    pub fn init(rt: Arc<Runtime>, fam: &FamilyArtifacts, index: usize, seed: u32) -> Result<Self> {
        let params = rt.run(fam.entry("stage_init")?, &[HostTensor::scalar_u32(seed)])?;
        Ok(BlockStage { rt, fam: fam.clone(), params, index })
    }

    /// Forward: activations in, activations out.
    pub fn forward(&self, x: &HostTensor) -> Result<HostTensor> {
        let mut args: Vec<&HostTensor> = self.params.iter().collect();
        args.push(x);
        let mut out = self.rt.run_refs(self.fam.entry("stage_fwd")?, &args)?;
        out.pop().ok_or_else(|| anyhow!("stage_fwd returned nothing"))
    }

    /// Backward: (saved input, upstream grad) -> (param grads, input grad).
    pub fn backward(&self, x: &HostTensor, dy: &HostTensor) -> Result<(Leaves, HostTensor)> {
        let mut args: Vec<&HostTensor> = self.params.iter().collect();
        args.push(x);
        args.push(dy);
        let mut out = self.rt.run_refs(self.fam.entry("stage_bwd")?, &args)?;
        let dx = out.pop().ok_or_else(|| anyhow!("stage_bwd returned nothing"))?;
        Ok((out, dx))
    }

    /// SGD step with (averaged) gradient leaves.
    pub fn update(&mut self, grads: &Leaves, lr: f32) -> Result<()> {
        let lr = HostTensor::scalar_f32(lr);
        let mut args: Vec<&HostTensor> = self.params.iter().collect();
        args.extend(grads.iter());
        args.push(&lr);
        self.params = self.rt.run_refs(self.fam.entry("stage_update")?, &args)?;
        Ok(())
    }
}

/// The data node's model shards: embedding (first stage) + head/loss (last
/// stage), colocated as in the paper.
pub struct DataNodeModel {
    rt: Arc<Runtime>,
    fam: FamilyArtifacts,
    pub embed_params: Leaves,
    pub head_params: Leaves,
}

impl DataNodeModel {
    pub fn init(rt: Arc<Runtime>, fam: &FamilyArtifacts, seed: u32) -> Result<Self> {
        let embed_params = rt.run(fam.entry("embed_init")?, &[HostTensor::scalar_u32(seed)])?;
        let head_params =
            rt.run(fam.entry("head_init")?, &[HostTensor::scalar_u32(seed ^ 0x9E37)])?;
        Ok(DataNodeModel { rt, fam: fam.clone(), embed_params, head_params })
    }

    /// Embed a microbatch of tokens: (B, S) i32 -> (B, S, D) f32.
    pub fn embed(&self, tokens: &HostTensor) -> Result<HostTensor> {
        let mut args: Vec<&HostTensor> = self.embed_params.iter().collect();
        args.push(tokens);
        let mut out = self.rt.run_refs(self.fam.entry("embed_fwd")?, &args)?;
        out.pop().ok_or_else(|| anyhow!("embed_fwd returned nothing"))
    }

    /// Loss only (evaluation).
    pub fn loss(&self, x: &HostTensor, targets: &HostTensor) -> Result<f32> {
        let mut args: Vec<&HostTensor> = self.head_params.iter().collect();
        args.push(x);
        args.push(targets);
        let out = self.rt.run_refs(self.fam.entry("head_loss")?, &args)?;
        Ok(out[0].as_f32()?[0])
    }

    /// Head backward: returns (head param grads, dx for the last relay
    /// stage, scalar loss).
    pub fn head_backward(
        &self,
        x: &HostTensor,
        targets: &HostTensor,
    ) -> Result<(Leaves, HostTensor, f32)> {
        let mut args: Vec<&HostTensor> = self.head_params.iter().collect();
        args.push(x);
        args.push(targets);
        let mut out = self.rt.run_refs(self.fam.entry("head_bwd")?, &args)?;
        let loss = out.pop().ok_or_else(|| anyhow!("head_bwd returned nothing"))?;
        let dx = out.pop().ok_or_else(|| anyhow!("head_bwd missing dx"))?;
        Ok((out, dx, loss.as_f32()?[0]))
    }

    /// Embedding backward: gradient leaves for the embedding table.
    pub fn embed_backward(&self, tokens: &HostTensor, dx: &HostTensor) -> Result<Leaves> {
        let mut args: Vec<&HostTensor> = self.embed_params.iter().collect();
        args.push(tokens);
        args.push(dx);
        self.rt.run_refs(self.fam.entry("embed_bwd")?, &args)
    }

    pub fn update_embed(&mut self, grads: &Leaves, lr: f32) -> Result<()> {
        let lr = HostTensor::scalar_f32(lr);
        let mut args: Vec<&HostTensor> = self.embed_params.iter().collect();
        args.extend(grads.iter());
        args.push(&lr);
        self.embed_params = self.rt.run_refs(self.fam.entry("embed_update")?, &args)?;
        Ok(())
    }

    pub fn update_head(&mut self, grads: &Leaves, lr: f32) -> Result<()> {
        let lr = HostTensor::scalar_f32(lr);
        let mut args: Vec<&HostTensor> = self.head_params.iter().collect();
        args.extend(grads.iter());
        args.push(&lr);
        self.head_params = self.rt.run_refs(self.fam.entry("head_update")?, &args)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_accumulator_averages() {
        let mut acc = GradAccumulator::new();
        acc.add(vec![HostTensor::f32(vec![2], vec![2.0, 4.0])]).unwrap();
        acc.add(vec![HostTensor::f32(vec![2], vec![4.0, 8.0])]).unwrap();
        assert_eq!(acc.count(), 2);
        let mean = acc.take_mean().unwrap();
        assert_eq!(mean[0].as_f32().unwrap(), &[3.0, 6.0]);
        assert_eq!(acc.count(), 0);
        assert!(acc.take_mean().is_err());
    }

    #[test]
    fn grad_accumulator_rejects_mismatch() {
        let mut acc = GradAccumulator::new();
        acc.add(vec![HostTensor::f32(vec![1], vec![1.0])]).unwrap();
        let err = acc.add(vec![
            HostTensor::f32(vec![1], vec![1.0]),
            HostTensor::f32(vec![1], vec![1.0]),
        ]);
        assert!(err.is_err());
    }
}
