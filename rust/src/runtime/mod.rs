//! PJRT runtime: load + execute the AOT-compiled stage computations.
//!
//! Python lowers the L2/L1 model code to HLO text once (`make artifacts`);
//! at runtime the Rust coordinator drives *real* stage computation through
//! this module — Python is never on the training path.
//!
//! - [`manifest`] — the artifact contract (`artifacts/manifest.json`).
//! - [`tensor`]   — host tensors crossing the PJRT boundary.
//! - [`client`]   — PJRT CPU client with a compile-once executable cache.
//! - [`stage`]    — typed executors: relay block stages and the data-node
//!   embed+head shard, plus gradient-averaging (the DP aggregation math).

pub mod client;
pub mod manifest;
pub mod stage;
pub mod tensor;

pub use client::{Executable, Runtime, RuntimeStats};
pub use manifest::{ArtifactEntry, FamilyArtifacts, FamilyConfig, Manifest, TensorSpec};
pub use stage::{BlockStage, DataNodeModel, GradAccumulator, Leaves};
pub use tensor::{DType, HostTensor};

/// Quick connectivity check used by `gwtf doctor`.
pub fn smoke() -> anyhow::Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}
