//! Bounded flight recorder: the last N trace records, dumped on panic.
//!
//! The CI guards (`scale_guard`, `plan_lag`, `congestion_guard`,
//! `async_guard`) arm one of these around their gated sweeps.  While
//! the run is healthy it costs a ring-buffer push per record; when a
//! gate assertion fails, the guard's `Drop` observes
//! `std::thread::panicking()` and dumps the tail to stderr *and* to
//! `<results_dir>/flightrec_<name>.log`, which CI uploads as a workflow
//! artifact — an unarmed-baseline mystery becomes a postmortem with the
//! last seconds of virtual time attached.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::rc::Rc;

use crate::trace::{arm, ArmGuard, TraceRecord, TraceSink};

type Ring = Rc<RefCell<VecDeque<TraceRecord>>>;

struct RingSink {
    ring: Ring,
    cap: usize,
}

impl TraceSink for RingSink {
    fn record(&mut self, rec: &TraceRecord) {
        let mut ring = self.ring.borrow_mut();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(*rec);
    }
}

/// RAII flight recorder; see [`arm_flight_recorder`].
pub struct FlightRecorder {
    name: String,
    cap: usize,
    ring: Ring,
    _arm: ArmGuard,
}

/// Arm a flight recorder named `name` keeping the last `cap` records.
/// Nothing is written anywhere unless the arming thread panics while
/// the recorder is live.
pub fn arm_flight_recorder(name: &str, cap: usize) -> FlightRecorder {
    let ring: Ring = Rc::new(RefCell::new(VecDeque::with_capacity(cap)));
    let _arm = arm(Box::new(RingSink { ring: Rc::clone(&ring), cap }));
    FlightRecorder { name: name.to_string(), cap, ring, _arm }
}

impl FlightRecorder {
    /// Records currently in the ring (tail of the run), oldest first.
    pub fn tail(&self) -> Vec<TraceRecord> {
        self.ring.borrow().iter().copied().collect()
    }

    fn render(&self) -> String {
        let ring = self.ring.borrow();
        let mut out = String::new();
        let _ = writeln!(
            out,
            "=== flight recorder '{}': last {} of up to {} records ===",
            self.name,
            ring.len(),
            self.cap
        );
        for rec in ring.iter() {
            let node = rec.node.map_or("engine".to_string(), |n| format!("n{}", n.0));
            let mb = rec.mb.map_or(String::new(), |m| format!(" mb{m}"));
            let _ = writeln!(
                out,
                "iter {:>3} t={:>12.6}s dur={:>10.6}s {:<9}{} {:?}",
                rec.iter, rec.t, rec.dur, node, mb, rec.kind
            );
        }
        out
    }
}

impl Drop for FlightRecorder {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return;
        }
        let dump = self.render();
        eprintln!("{dump}");
        let dir = crate::experiments::results_dir();
        // Best-effort inside a panic unwind: failing to persist the
        // dump must not turn the gate failure into an abort.
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(dir.join(format!("flightrec_{}.log", self.name)), dump);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::NodeId;
    use crate::trace::{emit, TraceKind};

    #[test]
    fn ring_keeps_only_the_tail() {
        let rec = arm_flight_recorder("test", 3);
        for i in 0..10 {
            emit(|| TraceRecord::instant(i as f64, Some(NodeId(i)), None, TraceKind::Crash));
        }
        let tail = rec.tail();
        assert_eq!(tail.len(), 3);
        assert_eq!(tail[0].t, 7.0, "oldest surviving record");
        assert_eq!(tail[2].t, 9.0, "newest record");
    }

    #[test]
    fn clean_drop_disarms_without_dumping() {
        let rec = arm_flight_recorder("clean_drop_test", 4);
        emit(|| TraceRecord::instant(1.0, None, None, TraceKind::GossipTick));
        assert_eq!(rec.tail().len(), 1);
        drop(rec); // not panicking: must neither dump nor leave a sink armed
        assert!(!crate::trace::enabled());
        assert!(!crate::experiments::results_dir()
            .join("flightrec_clean_drop_test.log")
            .exists());
    }

    #[test]
    fn render_names_the_recorder_and_rows() {
        let rec = arm_flight_recorder("render_test", 2);
        emit(|| TraceRecord::instant(2.5, Some(NodeId(4)), Some(1), TraceKind::Deny));
        let text = rec.render();
        assert!(text.contains("flight recorder 'render_test'"));
        assert!(text.contains("n4"));
        assert!(text.contains("Deny"));
    }
}
