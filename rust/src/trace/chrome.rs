//! Chrome-trace-event exporter (`chrome://tracing` / Perfetto).
//!
//! Maps the virtual-time record stream onto the trace-event JSON
//! format: one *process* per engine iteration (`pid` = iteration), one
//! *track* per node (`tid` = `NodeId.0 + 1`; track 0 is the engine
//! itself — plan lifecycle, gossip, churn transitions, barriers), spans
//! (`ph: "X"`) for compute/transfer/wait occupancy and instants
//! (`ph: "i"`) for transitions.  Timestamps are virtual seconds scaled
//! to the format's microseconds.  Events are sorted by
//! `(pid, tid, ts)`, so per-track timestamps are monotone by
//! construction — asserted by the shape test in
//! `rust/tests/trace_determinism.rs`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::trace::{TraceKind, TraceRecord};
use crate::util::json::Json;

const US_PER_S: f64 = 1e6;

/// Track id for a record: node tracks start at 1; 0 is the engine.
fn tid(rec: &TraceRecord) -> usize {
    rec.node.map_or(0, |n| n.0 + 1)
}

fn event(rec: &TraceRecord) -> Json {
    let mut ev = BTreeMap::new();
    ev.insert("name".into(), Json::Str(rec.kind.name().into()));
    ev.insert("cat".into(), Json::Str("sim".into()));
    ev.insert("pid".into(), Json::Num(rec.iter as f64));
    ev.insert("tid".into(), Json::Num(tid(rec) as f64));
    ev.insert("ts".into(), Json::Num(rec.t * US_PER_S));
    if rec.dur > 0.0 {
        ev.insert("ph".into(), Json::Str("X".into()));
        ev.insert("dur".into(), Json::Num(rec.dur * US_PER_S));
    } else {
        ev.insert("ph".into(), Json::Str("i".into()));
        ev.insert("s".into(), Json::Str("t".into()));
    }
    let mut args = BTreeMap::new();
    if let Some(mb) = rec.mb {
        args.insert("mb".into(), Json::Num(mb as f64));
    }
    match rec.kind {
        TraceKind::Compute { hop, .. } => {
            args.insert("hop".into(), Json::Num(hop as f64));
        }
        TraceKind::StageAgg { stage } => {
            args.insert("stage".into(), Json::Num(stage as f64));
        }
        TraceKind::PlanRequest { rounds } | TraceKind::PlanCommit { rounds, .. } => {
            args.insert("rounds".into(), Json::Num(rounds as f64));
        }
        _ => {}
    }
    if let TraceKind::PlanCommit { stale, .. } = rec.kind {
        args.insert("stale".into(), Json::Bool(stale));
    }
    if !args.is_empty() {
        ev.insert("args".into(), Json::Obj(args));
    }
    Json::Obj(ev)
}

/// Render records as a Chrome-trace JSON document
/// (`{"traceEvents": [...]}`, the object form Perfetto ingests).
pub fn chrome_trace_json(records: &[TraceRecord]) -> Json {
    let mut sorted: Vec<&TraceRecord> = records.iter().collect();
    sorted.sort_by(|a, b| {
        (a.iter, tid(a)).cmp(&(b.iter, tid(b))).then(a.t.total_cmp(&b.t))
    });
    let events: Vec<Json> = sorted.into_iter().map(event).collect();
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    Json::Obj(root)
}

/// Write the Chrome-trace document for `records` to `path`.
pub fn write_chrome_trace(path: &Path, records: &[TraceRecord]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, format!("{}\n", chrome_trace_json(records)))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::NodeId;
    use crate::trace::TraceKind;

    #[test]
    fn export_is_valid_sorted_trace_events() {
        let mk = |iter, t, dur, node: Option<usize>, kind| TraceRecord {
            iter,
            t,
            dur,
            node: node.map(NodeId),
            mb: Some(0),
            kind,
        };
        // Deliberately out of order across tracks and time.
        let recs = vec![
            mk(0, 5.0, 1.0, Some(1), TraceKind::Compute { hop: 0, fwd: true }),
            mk(0, 2.0, 0.5, Some(1), TraceKind::NicQueueWait),
            mk(0, 1.0, 0.0, None, TraceKind::PlanRequest { rounds: 3 }),
            mk(1, 0.0, 0.0, Some(2), TraceKind::Crash),
        ];
        let doc = chrome_trace_json(&recs);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4);
        // Every event is a well-formed trace-event object.
        for ev in events {
            assert!(ev.get("name").unwrap().as_str().is_some());
            let ph = ev.get("ph").unwrap().as_str().unwrap();
            assert!(ph == "X" || ph == "i");
            assert!(ev.get("ts").unwrap().as_f64().is_some());
            assert!(ev.get("pid").unwrap().as_f64().is_some());
            assert!(ev.get("tid").unwrap().as_f64().is_some());
            if ph == "X" {
                assert!(ev.get("dur").unwrap().as_f64().unwrap() > 0.0);
            }
        }
        // Monotone per-(pid, tid) timestamps.
        let key = |ev: &Json| {
            (
                ev.get("pid").unwrap().as_usize().unwrap(),
                ev.get("tid").unwrap().as_usize().unwrap(),
            )
        };
        for w in events.windows(2) {
            if key(&w[0]) == key(&w[1]) {
                let (a, b) = (
                    w[0].get("ts").unwrap().as_f64().unwrap(),
                    w[1].get("ts").unwrap().as_f64().unwrap(),
                );
                assert!(a <= b, "track timestamps must be monotone: {a} > {b}");
            }
        }
        // The document survives a serialize/parse roundtrip.
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back, doc);
    }
}
