//! Flight-recorder tracing for the continuous-time engine.
//!
//! The simulator's scalar metrics (`IterationMetrics` → `mean ± std`
//! table cells) answer *how slow*; this subsystem answers *where the
//! time went*.  The engine, the microbatch handlers, the NIC substrate
//! and the plan lifecycle emit typed [`TraceRecord`] span/instant
//! events on the virtual clock; consumers turn the stream into a
//! Chrome-trace timeline ([`chrome`]), a bounded postmortem ring
//! ([`flight`]), or per-bucket critical-path seconds
//! (`IterationMetrics::crit_path`, accounted inline by the handlers).
//!
//! **Zero-overhead contract.**  Tracing is strictly observational: no
//! emission site draws randomness, mutates a timestamp, or reorders an
//! event.  The sink is ambient (thread-local) so no simulator signature
//! carries it, and [`emit`] takes a *closure* — when no sink is armed
//! (the default, and the only state the parity tests and golden traces
//! ever see) the closure is never evaluated, so the disabled path costs
//! one thread-local flag load and moves no bits.  With a sink armed the
//! record stream is a pure function of the run, hence deterministic per
//! seed (asserted by `rust/tests/trace_determinism.rs`).
//!
//! Arming is scoped: [`arm`] / [`arm_collector`] /
//! [`flight::arm_flight_recorder`] return RAII guards that restore the
//! previous sink on drop, so nested scopes and `#[test]` bodies cannot
//! leak a sink into later code on the same thread.

pub mod chrome;
pub mod flight;

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use crate::cost::NodeId;
use crate::sim::events::Time;

/// What a [`TraceRecord`] describes.  Payload-free by design (`Copy`,
/// no heap): the record stream stays cheap to buffer and compare.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceKind {
    /// A relay's forward/backward stage compute (span).
    Compute { hop: usize, fwd: bool },
    /// The data node's loss + head-gradient compute (span).
    LossCompute,
    /// Final backward hop landing the gradient at the data node (span).
    FinishCompute,
    /// NIC-serialized transmission occupancy (span).
    Transmission,
    /// Pipelined propagation latency (span).
    Propagation,
    /// Waiting for a NIC transmission slot (span; zero-length when the
    /// interface was free).
    NicQueueWait,
    /// Waiting for a compute slot at a busy relay (span).
    SlotWait,
    /// Bounded-staleness admission catch-up before the fan-out (span).
    StalenessCatchUp,
    /// Plan session opened (instant; `rounds` = the ticket's estimate).
    PlanRequest { rounds: usize },
    /// One planning protocol round delivered on the clock (instant).
    PlanRound,
    /// Plan committed (instant; `stale` = mid-flight crash repaired).
    PlanCommit { rounds: usize, stale: bool },
    /// Planning seconds not hidden behind training (span).
    PlanStall,
    /// Gossip overlay cadence tick (instant).
    GossipTick,
    /// Node crash transition (instant).
    Crash,
    /// Node join/rejoin transition (instant).
    Join,
    /// Rolling per-stage weight exchange (span).
    StageAgg { stage: usize },
    /// Synchronous §V-E aggregation barrier (span).
    AggBarrier,
    /// §V-D forward recovery rerouted a microbatch (instant).
    FwdRecovery,
    /// §V-D backward recovery (instant; `restart` = whole pipeline).
    BwdRecovery { restart: bool },
    /// Crash-detection timeout + candidate wait (span).
    RecoveryWait,
    /// Relay refused residency (§V-D DENY; instant).
    Deny,
    /// Microbatch dropped (deadline or no candidate; instant).
    Drop,
    /// Free-rider advertised phantom capacity to the planner (instant;
    /// `advertised` = the lied slot count).
    PhantomAdvert { advertised: usize },
    /// DENY-storm relay refused a microbatch it had accepted at
    /// planning time (instant; the adversarial flavor of [`Deny`]).
    DenyStorm,
    /// Reputation book published a changed peer score (instant;
    /// `score_milli` = the new score in thousandths).
    RepUpdate { score_milli: u32 },
    /// Eclipse attacker overwrote a victim's gossip view slot (instant;
    /// `node` = the liar, `mb` = the victim's node id).
    EclipseLie,
}

impl TraceKind {
    /// Stable display name (Chrome-trace `name` field).
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Compute { fwd: true, .. } => "compute_fwd",
            TraceKind::Compute { fwd: false, .. } => "compute_bwd",
            TraceKind::LossCompute => "loss",
            TraceKind::FinishCompute => "finish",
            TraceKind::Transmission => "tx",
            TraceKind::Propagation => "prop",
            TraceKind::NicQueueWait => "nic_queue",
            TraceKind::SlotWait => "slot_wait",
            TraceKind::StalenessCatchUp => "stale_catchup",
            TraceKind::PlanRequest { .. } => "plan_request",
            TraceKind::PlanRound => "plan_round",
            TraceKind::PlanCommit { .. } => "plan_commit",
            TraceKind::PlanStall => "plan_stall",
            TraceKind::GossipTick => "gossip",
            TraceKind::Crash => "crash",
            TraceKind::Join => "join",
            TraceKind::StageAgg { .. } => "stage_agg",
            TraceKind::AggBarrier => "agg_barrier",
            TraceKind::FwdRecovery => "fwd_recovery",
            TraceKind::BwdRecovery { .. } => "bwd_recovery",
            TraceKind::RecoveryWait => "recovery_wait",
            TraceKind::Deny => "deny",
            TraceKind::Drop => "drop",
            TraceKind::PhantomAdvert { .. } => "phantom_advert",
            TraceKind::DenyStorm => "deny_storm",
            TraceKind::RepUpdate { .. } => "rep_update",
            TraceKind::EclipseLie => "eclipse_lie",
        }
    }
}

/// One traced event on the virtual clock.  `dur == 0.0` is an instant;
/// anything else is a span `[t, t + dur)`.  `iter` is stamped by
/// [`emit`] from the ambient iteration counter (see [`set_iter`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    pub iter: usize,
    pub t: Time,
    pub dur: f64,
    pub node: Option<NodeId>,
    pub mb: Option<usize>,
    pub kind: TraceKind,
}

impl TraceRecord {
    /// Span helper (the common case at emission sites).
    pub fn span(t: Time, dur: f64, node: Option<NodeId>, mb: Option<usize>, kind: TraceKind) -> Self {
        TraceRecord { iter: 0, t, dur, node, mb, kind }
    }

    /// Instant helper (`dur = 0`).
    pub fn instant(t: Time, node: Option<NodeId>, mb: Option<usize>, kind: TraceKind) -> Self {
        TraceRecord { iter: 0, t, dur: 0.0, node, mb, kind }
    }
}

/// A consumer of the record stream.  Sinks are thread-local (armed via
/// [`arm`]) and must not observe anything but the records — emission
/// sites hand them a finished `TraceRecord` and nothing else.
pub trait TraceSink {
    fn record(&mut self, rec: &TraceRecord);
}

thread_local! {
    /// Fast-path flag: `emit` reads only this when tracing is off.
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
    static SINK: RefCell<Option<Box<dyn TraceSink>>> = const { RefCell::new(None) };
    /// Ambient iteration counter stamped onto every record.
    static ITER: Cell<usize> = const { Cell::new(0) };
}

/// Is a sink armed on this thread?
#[inline]
pub fn enabled() -> bool {
    ACTIVE.with(|a| a.get())
}

/// Emit a record.  The closure is evaluated only when a sink is armed,
/// so disabled tracing never constructs the record (one flag load).
#[inline]
pub fn emit(f: impl FnOnce() -> TraceRecord) {
    if !enabled() {
        return;
    }
    let mut rec = f();
    rec.iter = ITER.with(|c| c.get());
    SINK.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            sink.record(&rec);
        }
    });
}

/// Set the ambient iteration stamp (`Engine::step` calls this; a bare
/// `run_schedule` leaves it at 0).  No-op when tracing is off.
#[inline]
pub fn set_iter(iter: usize) {
    if enabled() {
        ITER.with(|c| c.set(iter));
    }
}

/// RAII scope for an armed sink; dropping restores whatever was armed
/// before (usually nothing).
pub struct ArmGuard {
    prev_sink: Option<Box<dyn TraceSink>>,
    prev_active: bool,
    prev_iter: usize,
}

/// Arm `sink` on the current thread for the guard's lifetime.
pub fn arm(sink: Box<dyn TraceSink>) -> ArmGuard {
    let prev_sink = SINK.with(|s| s.borrow_mut().replace(sink));
    let prev_active = ACTIVE.with(|a| a.replace(true));
    let prev_iter = ITER.with(|c| c.replace(0));
    ArmGuard { prev_sink, prev_active, prev_iter }
}

impl Drop for ArmGuard {
    fn drop(&mut self) {
        ACTIVE.with(|a| a.set(self.prev_active));
        ITER.with(|c| c.set(self.prev_iter));
        SINK.with(|s| *s.borrow_mut() = self.prev_sink.take());
    }
}

/// Shared handle to records collected by [`arm_collector`].
pub type SharedRecords = Rc<RefCell<Vec<TraceRecord>>>;

/// The simplest sink: append every record to a shared `Vec`.  Serves
/// both the determinism tests and the Chrome exporter.
pub struct VecSink {
    out: SharedRecords,
}

impl TraceSink for VecSink {
    fn record(&mut self, rec: &TraceRecord) {
        self.out.borrow_mut().push(*rec);
    }
}

/// Arm a collecting sink; the returned handle outlives the guard and
/// holds everything recorded while it was armed.
pub fn arm_collector() -> (ArmGuard, SharedRecords) {
    let out: SharedRecords = Rc::new(RefCell::new(Vec::new()));
    let guard = arm(Box::new(VecSink { out: Rc::clone(&out) }));
    (guard, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_emit_never_builds_the_record() {
        let mut built = false;
        emit(|| {
            built = true;
            TraceRecord::instant(0.0, None, None, TraceKind::GossipTick)
        });
        assert!(!built, "disabled tracing must not evaluate the closure");
    }

    #[test]
    fn collector_scopes_and_restores() {
        assert!(!enabled());
        {
            let (_guard, recs) = arm_collector();
            assert!(enabled());
            set_iter(3);
            emit(|| TraceRecord::instant(1.5, Some(NodeId(2)), Some(0), TraceKind::Crash));
            let recs = recs.borrow();
            assert_eq!(recs.len(), 1);
            assert_eq!(recs[0].iter, 3, "emit stamps the ambient iteration");
            assert_eq!(recs[0].node, Some(NodeId(2)));
        }
        assert!(!enabled(), "dropping the guard disarms");
        emit(|| unreachable!("disarmed again"));
    }

    #[test]
    fn nested_arms_restore_the_outer_sink() {
        let (_outer, outer_recs) = arm_collector();
        emit(|| TraceRecord::instant(0.0, None, None, TraceKind::GossipTick));
        {
            let (_inner, inner_recs) = arm_collector();
            emit(|| TraceRecord::instant(1.0, None, None, TraceKind::Crash));
            assert_eq!(inner_recs.borrow().len(), 1);
        }
        emit(|| TraceRecord::instant(2.0, None, None, TraceKind::Join));
        let recs = outer_recs.borrow();
        assert_eq!(recs.len(), 2, "inner scope must not swallow outer records");
        assert_eq!(recs[1].kind, TraceKind::Join);
    }
}
