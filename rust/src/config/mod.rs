//! Launcher configuration: CLI flag parsing + experiment config files.
//!
//! The offline build has no `clap`/`serde`, so this module provides a
//! small, well-tested substitute: [`Args`] parses `--key value` /
//! `--flag` style options, and [`load_overrides`] merges a JSON config
//! file (parsed with [`crate::util::json`]) under the same keys.  Every
//! binary (`gwtf`, the examples, the bench targets) uses this so runs are
//! reproducible from a single command line or config file.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Parsed command line: positional arguments + `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without the binary name).
    ///
    /// `--key value` binds; a `--flag` followed by another `--...` (or end
    /// of input) becomes a boolean `"true"`.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let is_flag = it.peek().map(|n| n.starts_with("--")).unwrap_or(true);
                let val = if is_flag { "true".to_string() } else { it.next().unwrap() };
                args.options.insert(key.to_string(), val);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process's own arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Merge options from a JSON object file; CLI options win on conflict.
    pub fn with_config_file(mut self, path: impl AsRef<Path>) -> Result<Args> {
        for (k, v) in load_overrides(path)? {
            self.options.entry(k).or_insert(v);
        }
        Ok(self)
    }
}

/// Flat `{"key": scalar}` JSON object -> string map.
pub fn load_overrides(path: impl AsRef<Path>) -> Result<BTreeMap<String, String>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("parse {path:?}: {e}"))?;
    let obj = j.as_obj().ok_or_else(|| anyhow!("{path:?}: expected a JSON object"))?;
    let mut out = BTreeMap::new();
    for (k, v) in obj {
        let s = match v {
            Json::Str(s) => s.clone(),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Json::Bool(b) => b.to_string(),
            other => return Err(anyhow!("{path:?}: key {k} has non-scalar value {other:?}")),
        };
        out.insert(k.clone(), s);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = args("bench table2 --seed 7 --homogeneous --churn 0.1");
        assert_eq!(a.positional, vec!["bench", "table2"]);
        assert_eq!(a.get("seed"), Some("7"));
        assert!(a.flag("homogeneous"));
        assert_eq!(a.f64_or("churn", 0.0).unwrap(), 0.1);
    }

    #[test]
    fn defaults_apply() {
        let a = args("run");
        assert_eq!(a.usize_or("reps", 25).unwrap(), 25);
        assert_eq!(a.str_or("family", "llama"), "llama");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = args("--seed abc");
        assert!(a.usize_or("seed", 0).is_err());
        assert!(a.f64_or("seed", 0.0).is_err());
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = args("--check");
        assert!(a.flag("check"));
    }

    #[test]
    fn config_file_merges_under_cli() {
        let dir = std::env::temp_dir().join("gwtf_config_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"seed": 9, "family": "gpt", "deep": true}"#).unwrap();
        let a = args("--seed 7").with_config_file(&p).unwrap();
        assert_eq!(a.get("seed"), Some("7"), "CLI wins");
        assert_eq!(a.get("family"), Some("gpt"), "file fills gaps");
        assert!(a.flag("deep"));
    }

    #[test]
    fn non_object_config_rejected() {
        let dir = std::env::temp_dir().join("gwtf_config_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, "[1,2,3]").unwrap();
        assert!(load_overrides(&p).is_err());
    }
}
