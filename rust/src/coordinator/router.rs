//! GWTF's routing policy for the training simulator.
//!
//! Wraps the decentralized flow optimizer (§V-A/§V-C) behind the
//! [`RoutingPolicy`] plan lifecycle: the engine *requests* a plan at
//! iteration start ([`RoutingPolicy::request_plan`] runs the flow
//! protocol over the currently-alive membership — warm-starting from the
//! previous plan's surviving chains when asked — and stashes the result
//! under a [`PlanTicket`] naming the protocol rounds it took), and the
//! plan *commits* at the virtual time those rounds converge on the
//! engine clock ([`RoutingPolicy::commit_plan`]).  A crash landing while
//! the session is in flight marks the ticket stale: the commit performs
//! a §V-D local repair of the affected flows (the same min
//! `d(prev,m) + d(m,next)` replacement rule recovery uses) instead of a
//! silent restart, charging one extra protocol round per repaired crash
//! site.
//!
//! Planning cost on the timeline: the flow algorithm exchanges only
//! small control messages and "converges ... significantly faster than a
//! training iteration" while running *in parallel* with training (§V-C).
//! Under the degenerate commit-at-request lifecycle the ticket claims
//! the legacy charge (cold start pays `rounds * round_ctrl_s`, every
//! later (re)plan is free); under
//! [`crate::sim::engine::PlanLifecycle::RoundLatency`] the claim is
//! ignored and the commit instant — rounds delivered as engine events —
//! decides what overlaps and what stalls (`gwtf bench planlag`).
//!
//! During the iteration the router serves crash-recovery replacement
//! queries ([`RoutingPolicy::choose_replacement`]).  With a gossip
//! overlay attached ([`GwtfRouter::attach_overlay`] /
//! `ScenarioConfig::overlay_fanout`), every (re)plan first reconciles
//! the overlay with the start-of-iteration liveness and then hands the
//! per-node neighbor lists to the flow optimizer
//! ([`DecentralizedFlow::set_neighbors`]): candidates come only from
//! bounded views, crash events evict DHT contacts immediately, and
//! engine gossip ticks ([`RoutingPolicy::on_gossip`]) drive the SWIM
//! failure detector between plans.

use std::collections::HashSet;
use std::sync::Arc;

use crate::cost::NodeId;
use crate::flow::decentralized::{Chain, DecentralizedFlow, FlowParams};
use crate::flow::graph::{FlowPath, FlowProblem, StageGraph};
use crate::net::gossip::GossipConfig;
use crate::net::overlay::Overlay;
use crate::net::reputation::ReputationBook;
use crate::sim::events::Time;
use crate::sim::scenario::Scenario;
use crate::sim::training::{PlanOutcome, PlanRequest, PlanTicket, RecoveryPolicy, RoutingPolicy};
use crate::trace::{self, TraceKind, TraceRecord};

/// Cost closure shared by router and rebuilt problems.
pub type CostFn = Arc<dyn Fn(NodeId, NodeId) -> f64 + Send + Sync>;

pub struct GwtfRouter {
    pub graph: Arc<StageGraph>,
    pub cap: Vec<usize>,
    pub demand: Vec<usize>,
    pub cost: CostFn,
    pub params: FlowParams,
    /// Max protocol rounds per (re)plan and the control RTT charged per
    /// round on the cold-start plan (the degenerate lifecycle's blocking
    /// claim; under `PlanLifecycle::RoundLatency` the engine's round
    /// cadence decides instead).
    pub max_rounds: usize,
    pub round_ctrl_s: f64,
    /// Round budget for a warm-start re-plan (§V-D local repair +
    /// refinement; far fewer rounds than a cold plan needs).
    pub warm_max_rounds: usize,
    seed: u64,
    plans: u64,
    dead: HashSet<NodeId>,
    /// Chains + annealer temperature of the most recent plan — the warm
    /// state a warm re-plan resumes from.
    warm_state: Option<(Vec<Chain>, f64)>,
    /// Rounds used by the most recent plan (diagnostics / Fig. 7).
    pub last_rounds: usize,
    pub last_cost: f64,
    /// Optional gossip-overlay substrate (partial-view planning).
    overlay: Option<Overlay>,
    /// Liveness at the most recent (re)plan — the ground truth gossip
    /// probes run against (refined by `dead` as crashes land).
    last_alive: Vec<bool>,
    /// Scratch edge list reused across (re)plans when streaming the
    /// overlay's planning edges into the flow optimizer.
    edge_buf: Vec<(NodeId, NodeId)>,
    /// Shared reputation book (reputation-aware scenarios): scores are
    /// charged by the simulator's handler sites and *published* here at
    /// each gossip tick, piggybacked on the shuffle cadence.  The Eq. 1
    /// penalty is already folded into `cost`, so planning and §V-D
    /// replacement both price reputation automatically.
    reputation: Option<Arc<ReputationBook>>,
    /// Ticket-id source for the plan lifecycle.
    next_ticket: u64,
    /// The open planning session: result computed at request, delivered
    /// (after any commit-time §V-D repair) at commit.
    pending: Option<PendingPlan>,
}

/// A requested-but-uncommitted plan.
struct PendingPlan {
    id: u64,
    paths: Vec<FlowPath>,
    rounds: usize,
    charge_s: f64,
    /// Liveness the plan was computed against (the repair's base view).
    alive: Vec<bool>,
}

impl GwtfRouter {
    pub fn new(
        graph: Arc<StageGraph>,
        cap: Vec<usize>,
        demand: Vec<usize>,
        cost: CostFn,
        params: FlowParams,
        seed: u64,
    ) -> Self {
        GwtfRouter {
            graph,
            cap,
            demand,
            cost,
            params,
            max_rounds: 120,
            round_ctrl_s: 0.05,
            warm_max_rounds: 40,
            seed,
            plans: 0,
            dead: HashSet::new(),
            warm_state: None,
            last_rounds: 0,
            last_cost: f64::NAN,
            overlay: None,
            last_alive: Vec::new(),
            edge_buf: Vec::new(),
            reputation: None,
            next_ticket: 0,
            pending: None,
        }
    }

    /// Build from a scenario (shares its Eq. 1 cost closure).  Scenarios
    /// with `overlay_fanout` set get a gossip overlay attached, seeded
    /// from the scenario seed so every router over the same scenario
    /// bootstraps identical views.  Scenarios with
    /// `congestion_aware_planning` route the closure through the
    /// scenario's shared [`crate::net::CongestionCache`] over
    /// [`crate::net::Topology::congestion_cost`]: every edge additionally
    /// charges the expected NIC-queueing term derived from the same
    /// shared-capacity substrate parameters (`ScenarioConfig::nic`) the
    /// simulator executes — the planner prices fan-in backlogs instead of
    /// discovering them at runtime, and repeated planner probes of the
    /// same edge hit the memo instead of re-deriving the queueing series.
    pub fn from_scenario(sc: &Scenario, params: FlowParams, seed: u64) -> Self {
        let topo = sc.topo.clone();
        let payload = sc.sim_cfg.payload_bytes;
        let base: CostFn = if let Some(cache) = &sc.cost_cache {
            // The shared topology carries `ScenarioConfig::nic`: the
            // queueing term reads the very parameters the engine's
            // substrate executes.  The memo serves identical bits to a
            // direct `congestion_cost` call, so plans are unchanged.
            let cache = cache.clone();
            Arc::new(move |i, j| cache.cost(i, j))
        } else {
            Arc::new(move |i, j| topo.cost(i, j, payload))
        };
        // Reputation-aware scenarios multiply the Eq. 1 penalty into
        // every edge.  The closure is only wrapped when the book exists:
        // reputation-off scenarios keep the unwrapped closure, and on a
        // clean fleet the all-honest prior makes the factor exactly 1.0
        // (`x * 1.0` is bitwise `x`), so both arms reproduce the legacy
        // planner bit for bit until someone actually misbehaves.
        let cost: CostFn = match &sc.reputation {
            Some(book) => {
                let book = book.clone();
                Arc::new(move |i, j| base(i, j) * book.penalty(i, j))
            }
            None => base,
        };
        let mut router = GwtfRouter::new(
            sc.prob.graph.clone(),
            sc.prob.cap.clone(),
            sc.prob.demand.clone(),
            cost,
            params,
            seed,
        );
        if let Some(fanout) = sc.cfg.overlay_fanout {
            router.attach_overlay(Overlay::build(
                &sc.prob.graph,
                sc.topo.n(),
                GossipConfig { fanout, ..Default::default() },
                sc.cfg.seed ^ 0x0E12_1AB5,
            ));
        }
        router.reputation = sc.reputation.clone();
        // Eclipse attackers manipulate the overlay's shuffle; the hook
        // is inert (and the lie buffer never allocated into) when the
        // roster has no eclipse nodes or there is no overlay to poison.
        if let (Some(roster), Some(ov)) = (&sc.adversary, router.overlay.as_mut()) {
            ov.set_eclipse_liars(roster.eclipse_nodes());
        }
        router
    }

    /// Attach a gossip overlay: from now on every (re)plan is
    /// neighbor-scoped and gossip ticks drive its failure detector.
    pub fn attach_overlay(&mut self, overlay: Overlay) {
        self.overlay = Some(overlay);
    }

    /// The attached overlay, if any (diagnostics / tests).
    pub fn overlay(&self) -> Option<&Overlay> {
        self.overlay.as_ref()
    }

    /// Reconcile the overlay with `alive`; returns whether planning is
    /// neighbor-scoped (false without an overlay = global visibility).
    fn reconcile_overlay(&mut self, alive: &[bool]) -> bool {
        self.last_alive.clear();
        self.last_alive.extend_from_slice(alive);
        match self.overlay.as_mut() {
            Some(ov) => {
                ov.reconcile(alive);
                true
            }
            None => false,
        }
    }

    /// Stream the reconciled overlay's planning edges into the flow
    /// optimizer's visibility bitmap — no per-plan `BTreeMap` of
    /// neighbor `Vec`s on the hot path (scale scenarios re-plan every
    /// iteration).
    fn scope_to_overlay(&mut self, flow: &mut DecentralizedFlow<'_>) {
        let ov = self.overlay.as_ref().expect("scoped plan requires an overlay");
        let edges = &mut self.edge_buf;
        edges.clear();
        ov.for_each_planning_edge(|v, p| edges.push((v, p)));
        flow.set_neighbor_edges(self.edge_buf.drain(..));
    }

    fn problem_with_liveness(&self, alive: &[bool]) -> FlowProblem {
        let mut cap = self.cap.clone();
        for (i, c) in cap.iter_mut().enumerate() {
            if !alive.get(i).copied().unwrap_or(true) || self.dead.contains(&NodeId(i)) {
                *c = 0;
            }
        }
        let cost = Arc::clone(&self.cost);
        FlowProblem {
            // The graph is immutable and shared: rebuilding the problem
            // per (re)plan must not deep-clone it (scale hot path).
            graph: Arc::clone(&self.graph),
            cap,
            demand: self.demand.clone(),
            cost: Box::new(move |i, j| (cost)(i, j)),
        }
    }

    /// Cold plan over `alive` from scratch.  Returns the paths and the
    /// blocking charge (only the very first plan pays its control rounds;
    /// §V-C overlaps everything later).
    fn cold_plan(&mut self, alive: &[bool]) -> (Vec<FlowPath>, f64) {
        self.dead.clear();
        let scoped = self.reconcile_overlay(alive);
        let prob = self.problem_with_liveness(alive);
        let mut flow = DecentralizedFlow::new(&prob, self.params.clone(), self.seed ^ self.plans);
        if scoped {
            self.scope_to_overlay(&mut flow);
        }
        let stats = flow.run(self.max_rounds, 8);
        self.last_rounds = stats.len();
        self.last_cost = flow.total_cost();
        self.warm_state = Some((flow.chains.clone(), flow.temperature()));
        self.plans += 1;
        // Cold-start plan is charged; later replans overlap training.
        let planning_s = if self.plans == 1 {
            stats.len() as f64 * self.round_ctrl_s
        } else {
            0.0
        };
        (flow.established_paths(), planning_s)
    }

    /// Warm-start re-plan (§V-A/§V-D): resume from the surviving chains
    /// of the previous plan, tear down / locally repair only the flows
    /// through dead nodes, and refine for a few rounds with the carried
    /// (cooled) annealing temperature.  Falls back to a cold plan on the
    /// first call.
    ///
    /// `dirty` (the request's invalidation set, seeded into the ticket)
    /// names the nodes newly dead since the previous plan; the rebuild
    /// additionally sweeps the full liveness view so callers passing an
    /// incomplete diff stay correct, and so every dead flow neighbour is
    /// marked before any repair (a stand-in's visibility check must
    /// exempt all of them, whatever the removal order).
    fn warm_plan(&mut self, alive: &[bool], dirty: &[NodeId]) -> (Vec<FlowPath>, f64) {
        let Some((chains, temperature)) = self.warm_state.take() else {
            return self.cold_plan(alive);
        };
        self.dead.clear();
        // Views are reconciled before the warm start so crash repair and
        // refinement below already negotiate over the post-churn overlay.
        let scoped = self.reconcile_overlay(alive);
        let prob = self.problem_with_liveness(alive);
        let mut flow = DecentralizedFlow::warm_start(
            &prob,
            self.params.clone(),
            self.seed ^ self.plans,
            chains,
            temperature,
        );
        if scoped {
            self.scope_to_overlay(&mut flow);
        }
        debug_assert!(
            dirty.iter().all(|d| !alive.get(d.0).copied().unwrap_or(false)),
            "invalidation set must name dead nodes"
        );
        for (i, &up) in alive.iter().enumerate() {
            if !up {
                flow.mark_dead(NodeId(i));
            }
        }
        for (i, &up) in alive.iter().enumerate() {
            if !up {
                flow.remove_node(NodeId(i));
            }
        }
        let stats = flow.run(self.warm_max_rounds, 4);
        self.last_rounds = stats.len();
        self.last_cost = flow.total_cost();
        self.warm_state = Some((flow.chains.clone(), flow.temperature()));
        self.plans += 1;
        // Re-plans run in parallel with training (§V-C): no charge.
        (flow.established_paths(), 0.0)
    }

    /// Blocking convenience: request and immediately commit a cold plan
    /// (the degenerate lifecycle, what benches and the churn trainer
    /// drive directly).  Returns the paths and the blocking charge.
    pub fn plan(&mut self, alive: &[bool]) -> (Vec<FlowPath>, f64) {
        let req =
            PlanRequest { alive, dirty: &[], warm: false, requested_at: 0.0, iter: 0 };
        let ticket = self.request_plan(&req);
        let charge = ticket.ready_after_s;
        let out = self.commit_plan(&ticket, &[]);
        (out.paths, charge)
    }

    /// Blocking convenience: request and immediately commit a warm
    /// re-plan with `dirty` as the invalidation set.
    pub fn replan(&mut self, alive: &[bool], dirty: &[NodeId]) -> (Vec<FlowPath>, f64) {
        let req = PlanRequest { alive, dirty, warm: true, requested_at: 0.0, iter: 0 };
        let ticket = self.request_plan(&req);
        let charge = ticket.ready_after_s;
        let out = self.commit_plan(&ticket, &[]);
        (out.paths, charge)
    }
}

impl RoutingPolicy for GwtfRouter {
    fn name(&self) -> String {
        "gwtf".into()
    }

    fn request_plan(&mut self, req: &PlanRequest) -> PlanTicket {
        let (paths, charge) = if req.warm {
            self.warm_plan(req.alive, req.dirty)
        } else {
            self.cold_plan(req.alive)
        };
        let id = self.next_ticket;
        self.next_ticket += 1;
        self.pending = Some(PendingPlan {
            id,
            paths,
            rounds: self.last_rounds,
            charge_s: charge,
            alive: req.alive.to_vec(),
        });
        PlanTicket {
            id,
            rounds: self.last_rounds,
            ready_after_s: charge,
            requested_at: req.requested_at,
            invalidated: req.dirty.to_vec(),
        }
    }

    /// Deliver the stashed plan.  If `invalidated` names nodes that
    /// crashed while the session was converging, the plan is stale: every
    /// affected flow gets the §V-D local repair (cheapest alive
    /// same-stage stand-in by `d(prev,m) + d(m,next)`, capacity
    /// respected) and each repaired crash site charges one extra protocol
    /// round — no restart, exactly the paper's crash-during-planning
    /// story.  A flow nobody can absorb keeps its dead relay; the
    /// runtime's recovery machinery then handles it like any other stale
    /// route.
    fn commit_plan(&mut self, ticket: &PlanTicket, invalidated: &[NodeId]) -> PlanOutcome {
        let PendingPlan { id, mut paths, mut rounds, charge_s, alive } =
            self.pending.take().expect("commit_plan without a matching request_plan");
        assert_eq!(id, ticket.id, "plan tickets must commit in request order");
        let mut stale = false;
        if !invalidated.is_empty() {
            stale = true;
            let dead_now: HashSet<NodeId> = invalidated.iter().copied().collect();
            let mut usage = vec![0usize; self.cap.len()];
            for path in &paths {
                for &r in &path.relays {
                    usage[r.0] += 1;
                }
            }
            let mut repaired_sites: HashSet<NodeId> = HashSet::new();
            for pi in 0..paths.len() {
                for hop in 0..paths[pi].relays.len() {
                    let victim = paths[pi].relays[hop];
                    if !dead_now.contains(&victim) {
                        continue;
                    }
                    let sink = paths[pi].source;
                    let prev = if hop == 0 { sink } else { paths[pi].relays[hop - 1] };
                    let next = if hop + 1 < paths[pi].relays.len() {
                        paths[pi].relays[hop + 1]
                    } else {
                        sink
                    };
                    let candidates: Vec<NodeId> = self.graph.stages[hop]
                        .iter()
                        .filter(|&&m| {
                            m != victim
                                && !dead_now.contains(&m)
                                && alive.get(m.0).copied().unwrap_or(false)
                                && usage[m.0] < self.cap[m.0]
                        })
                        .copied()
                        .collect();
                    if let Some(m) = self.choose_replacement(prev, next, &candidates) {
                        usage[victim.0] = usage[victim.0].saturating_sub(1);
                        usage[m.0] += 1;
                        paths[pi].relays[hop] = m;
                        repaired_sites.insert(victim);
                    }
                }
            }
            // One Request Change negotiation per repaired crash site.
            rounds += repaired_sites.len();
            self.last_rounds = rounds;
        }
        PlanOutcome { paths, committed_at: ticket.requested_at + charge_s, rounds, stale }
    }

    fn last_plan_rounds(&self) -> usize {
        self.last_rounds
    }

    fn on_crash(&mut self, node: NodeId) {
        self.dead.insert(node);
        // Crash events expunge the victim from DHT buckets immediately
        // (stale-contact fix); view eviction waits for the detector.
        if let Some(ov) = self.overlay.as_mut() {
            ov.on_crash(node);
        }
    }

    fn on_gossip(&mut self, t: Time) {
        // Reputation scores publish at the shuffle cadence (the
        // piggyback: no extra protocol messages) — before the overlay
        // early-returns, so overlay-free reputation scenarios still
        // fold their pending observations.
        if let Some(book) = &self.reputation {
            book.publish(t);
        }
        let Some(ov) = self.overlay.as_mut() else { return };
        if self.last_alive.is_empty() {
            return;
        }
        // Probe ground truth: start-of-iteration liveness minus the
        // crashes the router has learned of since.
        let mut truth = self.last_alive.clone();
        for d in &self.dead {
            if let Some(t) = truth.get_mut(d.0) {
                *t = false;
            }
        }
        ov.gossip_round(&truth);
        if trace::enabled() {
            for &(liar, victim) in ov.last_lies() {
                trace::emit(|| {
                    TraceRecord::instant(t, Some(liar), Some(victim.0), TraceKind::EclipseLie)
                });
            }
        }
    }

    fn choose_replacement(
        &mut self,
        prev: NodeId,
        next: NodeId,
        candidates: &[NodeId],
    ) -> Option<NodeId> {
        // §V-D: the repair is initiated by the peer holding the stored
        // activation/gradient (`prev`); with an overlay it can only offer
        // the job to replacements inside its own bounded view.  A
        // candidate the overlay does not know yet is a mid-iteration
        // joiner (views refresh at reconcile): its §V-B join
        // announcement is what made it a candidate at all, so it is
        // exempt — vetoing it would disable joiner recovery and break
        // k >= n-1 parity under Poisson churn.
        candidates
            .iter()
            .filter(|&&m| {
                !self.dead.contains(&m)
                    && self
                        .overlay
                        .as_ref()
                        .map(|ov| ov.sees(prev, m) || !ov.knows(m))
                        .unwrap_or(true)
            })
            .min_by(|&&a, &&b| {
                let ca = (self.cost)(prev, a) + (self.cost)(a, next);
                let cb = (self.cost)(prev, b) + (self.cost)(b, next);
                ca.partial_cmp(&cb).unwrap()
            })
            .copied()
    }

    fn recovery(&self) -> RecoveryPolicy {
        RecoveryPolicy::RepairPath
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::scenario::{build, ScenarioConfig};

    fn router() -> (GwtfRouter, usize) {
        let sc = build(&ScenarioConfig::table2(true, 0.0, 5));
        let n = sc.topo.n();
        (GwtfRouter::from_scenario(&sc, FlowParams::default(), 5), n)
    }

    #[test]
    fn plans_full_demand_when_everyone_alive() {
        let (mut r, n) = router();
        let alive = vec![true; n];
        let (paths, planning) = r.plan(&alive);
        assert_eq!(paths.len(), 8, "2 data nodes x 4 microbatches");
        assert!(planning > 0.0, "cold start charged");
        let (_paths2, planning2) = r.plan(&alive);
        assert_eq!(planning2, 0.0, "replan overlaps training");
    }

    #[test]
    fn dead_nodes_excluded_from_plan() {
        let (mut r, n) = router();
        let mut alive = vec![true; n];
        // Kill one entire stage except one node: flows must use the survivor.
        let stage0 = r.graph.stages[0].clone();
        for &m in &stage0[1..] {
            alive[m.0] = false;
        }
        let (paths, _) = r.plan(&alive);
        for p in &paths {
            assert_eq!(p.relays[0], stage0[0]);
        }
    }

    #[test]
    fn replacement_prefers_cheapest() {
        let (mut r, n) = router();
        let alive = vec![true; n];
        r.plan(&alive);
        let stage1 = r.graph.stages[1].clone();
        let prev = r.graph.stages[0][0];
        let next = r.graph.stages[2][0];
        let pick = r.choose_replacement(prev, next, &stage1).unwrap();
        let best = stage1
            .iter()
            .min_by(|&&a, &&b| {
                let ca = (r.cost)(prev, a) + (r.cost)(a, next);
                let cb = (r.cost)(prev, b) + (r.cost)(b, next);
                ca.partial_cmp(&cb).unwrap()
            })
            .copied()
            .unwrap();
        assert_eq!(pick, best);
    }

    #[test]
    fn crashed_node_never_chosen() {
        let (mut r, n) = router();
        let alive = vec![true; n];
        r.plan(&alive);
        let stage1 = r.graph.stages[1].clone();
        r.on_crash(stage1[0]);
        let pick =
            r.choose_replacement(r.graph.stages[0][0], r.graph.stages[2][0], &stage1);
        assert_ne!(pick, Some(stage1[0]));
    }

    #[test]
    fn recovery_policy_is_repair() {
        let (r, _) = router();
        assert_eq!(r.recovery(), RecoveryPolicy::RepairPath);
    }

    #[test]
    fn replan_without_prior_plan_cold_starts() {
        let (mut r, n) = router();
        let alive = vec![true; n];
        let (paths, planning) = r.replan(&alive, &[]);
        assert_eq!(paths.len(), 8);
        assert!(planning > 0.0, "first plan is the charged cold start");
    }

    #[test]
    fn warm_replan_keeps_flows_and_avoids_dead_nodes() {
        let (mut r, n) = router();
        let mut alive = vec![true; n];
        let (paths, _) = r.plan(&alive);
        let cold_rounds = r.last_rounds;
        assert_eq!(paths.len(), 8);
        let victim = paths[0].relays[2];
        alive[victim.0] = false;
        let (warm_paths, planning) = r.replan(&alive, &[victim]);
        assert_eq!(planning, 0.0, "replans overlap training");
        assert_eq!(warm_paths.len(), 8, "repair keeps the routed demand");
        for p in &warm_paths {
            assert!(!p.relays.contains(&victim), "dead node still routed");
        }
        assert!(
            r.last_rounds < cold_rounds,
            "warm replan {} rounds vs cold {}",
            r.last_rounds,
            cold_rounds
        );
        // surviving flows should mostly be kept: at least one path from
        // the cold plan survives verbatim
        assert!(
            warm_paths.iter().any(|p| paths.contains(p)),
            "warm start must keep surviving chains"
        );
    }

    #[test]
    fn overlay_scenario_router_plans_and_gossips() {
        // A scale-style scenario attaches the overlay automatically; the
        // neighbor-scoped plan must still route the full demand, and
        // gossip rounds must advance the detector without disturbing it.
        let sc = build(&ScenarioConfig::scale(48, 0.0, 17));
        let mut r = GwtfRouter::from_scenario(&sc, FlowParams::default(), 17);
        assert!(r.overlay().is_some(), "overlay_fanout must attach the overlay");
        let alive = vec![true; sc.topo.n()];
        let (paths, _) = r.plan(&alive);
        assert_eq!(paths.len(), 16, "2 data nodes x 8 microbatches");
        let rounds_before = r.overlay().unwrap().rounds;
        r.on_gossip(1.0);
        r.on_gossip(2.0);
        assert_eq!(r.overlay().unwrap().rounds, rounds_before + 2);
        let (paths2, _) = r.replan(&alive, &[]);
        assert_eq!(paths2.len(), 16);
    }

    #[test]
    fn overlay_replan_evicts_crashed_relay_from_dht() {
        let sc = build(&ScenarioConfig::scale(48, 0.0, 23));
        let mut r = GwtfRouter::from_scenario(&sc, FlowParams::default(), 23);
        let mut alive = vec![true; sc.topo.n()];
        let (paths, _) = r.plan(&alive);
        let victim = paths[0].relays[1];
        r.on_crash(victim);
        assert!(
            !r.overlay().unwrap().dht.contains(victim),
            "crash event must expunge the victim's DHT key immediately"
        );
        alive[victim.0] = false;
        let (warm, _) = r.replan(&alive, &[victim]);
        for p in &warm {
            assert!(!p.relays.contains(&victim));
        }
        assert!(r.overlay().unwrap().views_of(victim).is_none());
    }

    #[test]
    fn stale_commit_repairs_in_flight_plan_locally() {
        use crate::sim::training::PlanRequest;
        let (mut r, n) = router();
        let alive = vec![true; n];
        let req = PlanRequest { alive: &alive, dirty: &[], warm: false, requested_at: 0.0, iter: 0 };
        let ticket = r.request_plan(&req);
        let planned_rounds = ticket.rounds;
        // Peek at the stashed plan to pick a genuinely routed victim.
        let victim = r.pending.as_ref().unwrap().paths[0].relays[1];
        r.on_crash(victim); // what the engine does when the crash event fires
        let out = r.commit_plan(&ticket, &[victim]);
        assert!(out.stale, "mid-planning crash must mark the outcome stale");
        assert!(
            out.rounds > planned_rounds,
            "§V-D repair must charge extra rounds: {} vs {}",
            out.rounds,
            planned_rounds
        );
        for p in &out.paths {
            assert!(!p.relays.contains(&victim), "repaired plan still routes the dead relay");
            for (stage, relay) in p.relays.iter().enumerate() {
                assert!(r.graph.stages[stage].contains(relay), "repair broke stage validity");
            }
        }
        // Capacity stays respected after the local repair.
        let mut usage = vec![0usize; n];
        for p in &out.paths {
            for &relay in &p.relays {
                usage[relay.0] += 1;
            }
        }
        for (i, &u) in usage.iter().enumerate() {
            assert!(u <= r.cap[i], "node n{i} over capacity after repair: {u}");
        }
    }

    #[test]
    fn commit_without_invalidation_is_clean() {
        use crate::sim::training::PlanRequest;
        let (mut r, n) = router();
        let alive = vec![true; n];
        let req = PlanRequest { alive: &alive, dirty: &[], warm: false, requested_at: 0.0, iter: 0 };
        let t0 = r.request_plan(&req);
        let out = r.commit_plan(&t0, &[]);
        assert!(!out.stale);
        assert_eq!(out.rounds, t0.rounds);
        assert_eq!(out.committed_at, t0.ready_after_s, "blocking claim: request + charge");
        let t1 = r.request_plan(&req);
        assert!(t1.id > t0.id, "ticket ids strictly increase");
        assert_eq!(t1.ready_after_s, 0.0, "only the cold start is charged");
        r.commit_plan(&t1, &[]);
    }

    #[test]
    fn congestion_aware_router_parity_under_unlimited_nics() {
        // ISSUE 5: unlimited-NIC mode must pin the congestion-aware
        // closure to the legacy Eq. 1 planner bit for bit (router level).
        let blind = build(&ScenarioConfig::congestion(None, false, 31));
        let aware = build(&ScenarioConfig::congestion(None, true, 31));
        let mut rb = GwtfRouter::from_scenario(&blind, FlowParams::default(), 31);
        let mut ra = GwtfRouter::from_scenario(&aware, FlowParams::default(), 31);
        let alive = vec![true; blind.topo.n()];
        let (pb, chb) = rb.plan(&alive);
        let (pa, cha) = ra.plan(&alive);
        assert_eq!(pb, pa, "identical plans under the degenerate substrate");
        assert_eq!(chb.to_bits(), cha.to_bits(), "identical cold-start charge");
        assert_eq!(rb.last_rounds, ra.last_rounds);
    }

    #[test]
    fn congestion_aware_router_spreads_off_the_hub() {
        // At WAN concurrency 1 the expected-queueing term must price the
        // fan-in hub's backlog high enough that the aware plan books less
        // of the demand through it than the capacity-oblivious plan.
        let blind_sc = build(&ScenarioConfig::congestion(Some(1), false, 31));
        let aware_sc = build(&ScenarioConfig::congestion(Some(1), true, 31));
        let mut rb = GwtfRouter::from_scenario(&blind_sc, FlowParams::default(), 31);
        let mut ra = GwtfRouter::from_scenario(&aware_sc, FlowParams::default(), 31);
        let alive = vec![true; blind_sc.topo.n()];
        let (pb, _) = rb.plan(&alive);
        let (pa, _) = ra.plan(&alive);
        assert_eq!(pb.len(), 8, "full demand routed");
        assert_eq!(pa.len(), 8, "aware planning must still route the full demand");
        let hub_hops = |paths: &[crate::flow::graph::FlowPath],
                        sc: &crate::sim::scenario::Scenario| {
            paths
                .iter()
                .flat_map(|p| p.relays.iter().enumerate())
                .filter(|&(s, &r)| sc.prob.graph.stages[s][0] == r)
                .count()
        };
        let blind_hub = hub_hops(&pb, &blind_sc);
        let aware_hub = hub_hops(&pa, &aware_sc);
        assert!(
            aware_hub < blind_hub,
            "aware plan must shift load off the hubs: {aware_hub} vs {blind_hub} hub hops"
        );
    }

    #[test]
    fn warm_replan_is_deterministic() {
        let run = || {
            let (mut r, n) = router();
            let mut alive = vec![true; n];
            let (paths, _) = r.plan(&alive);
            let victim = paths[0].relays[0];
            alive[victim.0] = false;
            let (p1, _) = r.replan(&alive, &[victim]);
            p1
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn repeated_warm_replans_stay_valid() {
        let (mut r, n) = router();
        let mut alive = vec![true; n];
        r.plan(&alive);
        // progressively kill one relay per stage 0..2 across replans
        for s in 0..3 {
            let victim = r.graph.stages[s][1];
            alive[victim.0] = false;
            let (paths, _) = r.replan(&alive, &[victim]);
            for p in &paths {
                for (stage, &relay) in p.relays.iter().enumerate() {
                    assert!(alive[relay.0], "dead relay {relay} in stage {stage}");
                    assert!(r.graph.stages[stage].contains(&relay));
                }
            }
        }
    }
}
