//! Leader election among data nodes (paper §IV: "An elected leader from
//! the data nodes periodically adds new nodes...  the leader can be
//! elected in a robust way [Garcia-Molina 82; Raft]").
//!
//! We implement the bully algorithm over the data-node set: the live data
//! node with the highest id wins; any node detecting leader failure
//! triggers re-election.  The elected identity is published in the DHT
//! under [`crate::net::dht::LEADER_KEY`] so joiners can find it.

use crate::cost::NodeId;

/// Bully election state over a fixed candidate set.
#[derive(Debug, Clone)]
pub struct Election {
    pub candidates: Vec<NodeId>,
    pub leader: Option<NodeId>,
}

impl Election {
    pub fn new(candidates: Vec<NodeId>) -> Self {
        Election { candidates, leader: None }
    }

    /// Run an election given current liveness; returns the winner.
    /// Deterministic: highest-id live candidate (bully rule).
    pub fn elect(&mut self, alive: impl Fn(NodeId) -> bool) -> Option<NodeId> {
        self.leader = self.candidates.iter().copied().filter(|&c| alive(c)).max_by_key(|c| c.0);
        self.leader
    }

    /// Called when the current leader is detected dead.
    pub fn on_leader_failure(&mut self, alive: impl Fn(NodeId) -> bool) -> Option<NodeId> {
        let old = self.leader;
        let new = self.elect(|c| alive(c) && Some(c) != old);
        self.leader = new;
        new
    }

    pub fn is_leader(&self, n: NodeId) -> bool {
        self.leader == Some(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn highest_id_wins() {
        let mut e = Election::new(vec![NodeId(0), NodeId(3), NodeId(7)]);
        assert_eq!(e.elect(|_| true), Some(NodeId(7)));
        assert!(e.is_leader(NodeId(7)));
    }

    #[test]
    fn dead_candidates_skipped() {
        let mut e = Election::new(vec![NodeId(0), NodeId(3), NodeId(7)]);
        assert_eq!(e.elect(|c| c.0 != 7), Some(NodeId(3)));
    }

    #[test]
    fn reelection_after_failure() {
        let mut e = Election::new(vec![NodeId(0), NodeId(3), NodeId(7)]);
        e.elect(|_| true);
        let new = e.on_leader_failure(|_| true);
        assert_eq!(new, Some(NodeId(3)));
    }

    #[test]
    fn no_live_candidates() {
        let mut e = Election::new(vec![NodeId(1)]);
        assert_eq!(e.elect(|_| false), None);
        assert!(!e.is_leader(NodeId(1)));
    }
}
