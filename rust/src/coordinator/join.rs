//! Node-join procedure (paper §V-B "Inserting Joining Nodes").
//!
//! The elected leader ranks stages by **utilization** — flows routed
//! through the stage divided by its total capacity — discovered through a
//! flooding query that travels stage by stage, each node appending its
//! (capacity, flows) pair.  Joining candidates announce their capacity;
//! periodically the leader matches the highest-capacity candidate to the
//! most-utilized (bottleneck) stage, the second-highest to the second, and
//! so on — expanding the system bottleneck first (Fig. 3).

use crate::cost::NodeId;
use crate::flow::graph::FlowProblem;

/// Which placement rule to use (GWTF vs the Fig. 5 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinPolicy {
    /// GWTF: utilization-ranked stages x capacity-ranked candidates.
    UtilizationRanked,
    /// Baseline ("adding highest capacity first", Fig. 5): candidates in
    /// capacity order, stages round-robin — the baseline orders *which
    /// node* joins next but has no utilization view to target the
    /// bottleneck stage (that view is GWTF's SV-B contribution).
    CapacityFirst,
    /// Baseline: uniform random placement.
    Random,
}

/// Per-stage utilization snapshot assembled by the flooding query.
#[derive(Debug, Clone, PartialEq)]
pub struct StageUtilization {
    pub stage: usize,
    pub capacity: usize,
    pub flows: usize,
}

impl StageUtilization {
    /// Utilized ratio (flows / capacity); saturates at capacity 0.
    pub fn ratio(&self) -> f64 {
        if self.capacity == 0 {
            f64::INFINITY
        } else {
            self.flows as f64 / self.capacity as f64
        }
    }
}

/// Simulate the §V-B flooding query: walk the stages front-to-back,
/// accumulating (capacity, flows) per stage.  `flows_through[s]` is the
/// number of flow units currently routed through stage `s`.
pub fn utilization_query(prob: &FlowProblem, flows_through: &[usize]) -> Vec<StageUtilization> {
    (0..prob.graph.n_stages())
        .map(|s| StageUtilization {
            stage: s,
            capacity: prob.stage_capacity(s),
            flows: flows_through.get(s).copied().unwrap_or(0),
        })
        .collect()
}

/// The leader: collects join candidates, runs the placement rule.
#[derive(Debug, Clone)]
pub struct Leader {
    pub id: NodeId,
    pub policy: JoinPolicy,
    /// (candidate, announced capacity) waiting for placement.
    pub candidates: Vec<(NodeId, usize)>,
}

impl Leader {
    pub fn new(id: NodeId, policy: JoinPolicy) -> Self {
        Leader { id, policy, candidates: Vec::new() }
    }

    /// A candidate's JoinRequest arrived.
    pub fn on_join_request(&mut self, candidate: NodeId, capacity: usize) {
        if !self.candidates.iter().any(|&(c, _)| c == candidate) {
            self.candidates.push((candidate, capacity));
        }
    }

    /// Periodic placement round: assign all pending candidates to stages.
    /// Returns (candidate, stage) assignments in placement order.
    pub fn place(
        &mut self,
        utilization: &[StageUtilization],
        rng: &mut crate::util::Rng,
    ) -> Vec<(NodeId, usize)> {
        if self.candidates.is_empty() || utilization.is_empty() {
            return Vec::new();
        }
        let mut cands = std::mem::take(&mut self.candidates);
        let mut out = Vec::new();
        match self.policy {
            JoinPolicy::UtilizationRanked => {
                // highest capacity -> most utilized stage, 2nd -> 2nd, ...
                // At most one candidate per stage per placement round: the
                // leader runs *periodically* (SV-B), refreshing the
                // utilization snapshot between rounds, so surplus
                // candidates wait rather than landing on stale rankings.
                cands.sort_by(|a, b| b.1.cmp(&a.1));
                let mut stages: Vec<&StageUtilization> = utilization.iter().collect();
                stages.sort_by(|a, b| b.ratio().partial_cmp(&a.ratio()).unwrap());
                let round = stages.len().min(cands.len());
                for (i, (cand, _cap)) in cands.drain(..round).enumerate() {
                    out.push((cand, stages[i].stage));
                }
                self.candidates = cands; // remainder waits for the next round
            }
            JoinPolicy::CapacityFirst => {
                cands.sort_by(|a, b| b.1.cmp(&a.1));
                for (i, (cand, _cap)) in cands.iter().enumerate() {
                    out.push((*cand, utilization[i % utilization.len()].stage));
                }
            }
            JoinPolicy::Random => {
                for (cand, _cap) in cands.iter() {
                    out.push((*cand, rng.index(utilization.len())));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::graph::{random_problem};
    use crate::util::Rng;

    fn prob() -> FlowProblem {
        let mut rng = Rng::new(0);
        random_problem(1, 12, 4, (1.0, 3.0), (1.0, 20.0), &mut rng)
    }

    #[test]
    fn utilization_ratio() {
        let u = StageUtilization { stage: 0, capacity: 4, flows: 3 };
        assert!((u.ratio() - 0.75).abs() < 1e-12);
        let z = StageUtilization { stage: 0, capacity: 0, flows: 1 };
        assert!(z.ratio().is_infinite());
    }

    #[test]
    fn query_covers_all_stages() {
        let p = prob();
        let q = utilization_query(&p, &[1, 2, 3, 4]);
        assert_eq!(q.len(), 4);
        assert_eq!(q[2].flows, 3);
        assert_eq!(q[2].capacity, p.stage_capacity(2));
    }

    #[test]
    fn utilization_ranked_pairs_best_to_worst() {
        let mut leader = Leader::new(NodeId(0), JoinPolicy::UtilizationRanked);
        leader.on_join_request(NodeId(100), 5);
        leader.on_join_request(NodeId(101), 20);
        leader.on_join_request(NodeId(102), 1);
        let util = vec![
            StageUtilization { stage: 0, capacity: 10, flows: 2 },  // 0.2
            StageUtilization { stage: 1, capacity: 10, flows: 9 },  // 0.9  <- hottest
            StageUtilization { stage: 2, capacity: 10, flows: 5 },  // 0.5
        ];
        let mut rng = Rng::new(0);
        let placed = leader.place(&util, &mut rng);
        // capacity 20 -> stage 1 (hottest), 5 -> stage 2, 1 -> stage 0
        assert_eq!(placed, vec![(NodeId(101), 1), (NodeId(100), 2), (NodeId(102), 0)]);
        assert!(leader.candidates.is_empty());
    }

    #[test]
    fn duplicate_join_requests_ignored() {
        let mut leader = Leader::new(NodeId(0), JoinPolicy::UtilizationRanked);
        leader.on_join_request(NodeId(5), 3);
        leader.on_join_request(NodeId(5), 3);
        assert_eq!(leader.candidates.len(), 1);
    }

    #[test]
    fn capacity_first_is_stage_blind_round_robin() {
        let mut leader = Leader::new(NodeId(0), JoinPolicy::CapacityFirst);
        leader.on_join_request(NodeId(100), 9);
        leader.on_join_request(NodeId(101), 20);
        let util = vec![
            StageUtilization { stage: 0, capacity: 4, flows: 4 },
            StageUtilization { stage: 1, capacity: 2, flows: 0 },
        ];
        let mut rng = Rng::new(0);
        let placed = leader.place(&util, &mut rng);
        // capacity order decides WHO joins first; stages cycle in order
        assert_eq!(placed, vec![(NodeId(101), 0), (NodeId(100), 1)]);
    }

    #[test]
    fn random_policy_places_everything() {
        let mut leader = Leader::new(NodeId(0), JoinPolicy::Random);
        for i in 0..10 {
            leader.on_join_request(NodeId(100 + i), i);
        }
        let util = utilization_query(&prob(), &[0; 4]);
        let mut rng = Rng::new(1);
        let placed = leader.place(&util, &mut rng);
        assert_eq!(placed.len(), 10);
        for (_, s) in placed {
            assert!(s < 4);
        }
    }

    #[test]
    fn fig3_bottleneck_expansion() {
        // Paper Fig. 3: stages with capacity 2,3,4; a joining node of
        // capacity 5 goes to stage 0 (cap 2, fully utilized), making stage 1
        // the new bottleneck.
        let mut leader = Leader::new(NodeId(0), JoinPolicy::UtilizationRanked);
        leader.on_join_request(NodeId(50), 5);
        let util = vec![
            StageUtilization { stage: 0, capacity: 2, flows: 2 },
            StageUtilization { stage: 1, capacity: 3, flows: 2 },
            StageUtilization { stage: 2, capacity: 4, flows: 2 },
        ];
        let mut rng = Rng::new(0);
        let placed = leader.place(&util, &mut rng);
        assert_eq!(placed, vec![(NodeId(50), 0)]);
    }
}
