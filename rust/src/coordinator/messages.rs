//! GWTF wire protocol (paper §V).
//!
//! Every coordination interaction in the paper maps to one variant here.
//! The protocol-level tests drive [`crate::coordinator::node`] state
//! machines by exchanging these messages over a simulated bus.

use crate::cost::NodeId;

/// Unique identifier of one microbatch flow.
pub type FlowId = u64;

/// Batch identifier (iteration-scoped).
pub type BatchId = u64;

#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    // --- §V-C flow construction ---
    /// Ask `to` to pair our capacity with its unpaired outflow towards
    /// `sink` at the advertised `cost_to_sink`.
    RequestFlow { flow: FlowId, sink: NodeId, cost_to_sink: f64 },
    /// Approve a RequestFlow: the requester becomes our upstream peer.
    ApproveFlow { flow: FlowId },
    /// Reject, reporting our actual current cost to that sink (infinite if
    /// we have no unpaired outflow towards it).
    RejectFlow { flow: FlowId, actual_cost: f64 },
    /// Broadcast (to previous stages) of our new cost to `sink`.
    AdvertiseCost { sink: NodeId, cost_to_sink: f64 },

    // --- §V-C refinement ---
    /// Propose swapping next-stage peers for two flows to the same sink.
    RequestChange { flow_a: FlowId, flow_b: FlowId, new_cost: f64 },
    AcceptChange { flow_a: FlowId, flow_b: FlowId },
    /// A spare node proposes replacing `victim` on `flow`.
    RequestRedirect { flow: FlowId, victim: NodeId, new_cost: f64 },
    AcceptRedirect { flow: FlowId },

    // --- §V-D crash tolerance ---
    /// Batch finished downstream; allows upstream latency estimation.
    Complete { batch: BatchId },
    /// No capacity / no alternate peer: upstream must redistribute.
    Deny { batch: BatchId },
    /// Liveness probe along a microbatch path.
    Ping { batch: BatchId },
    Pong { batch: BatchId },
    /// Forward activations to a replacement node after a crash.
    ForwardActivation { batch: BatchId, stage: usize },
    /// Resume a backward pass from a stored gradient.
    ResumeBackward { batch: BatchId, stage: usize },

    // --- §V-E aggregation synchronization ---
    BeginAggregation { iteration: u64 },
    /// Stage-internal weight exchange payload marker.
    ShareWeights { iteration: u64, stage: usize },
    /// Downstream finished aggregating; ready for new microbatches.
    CanTake { iteration: u64 },

    // --- §V-B joining ---
    /// Candidate announces its capacity to the leader.
    JoinRequest { capacity: usize },
    /// Leader assigns the candidate to a stage.
    AssignStage { stage: usize },
    /// Leader's flooding query for stage utilization; each stage appends
    /// (capacity, flows) and forwards.
    UtilizationQuery { acc: Vec<(usize, usize)> },
    UtilizationReply { acc: Vec<(usize, usize)> },

    // --- leader election (bully) ---
    Election { candidate: NodeId },
    Coordinator { leader: NodeId },
}

/// An addressed message in flight.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub from: NodeId,
    pub to: NodeId,
    pub msg: Message,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_roundtrip() {
        let e = Envelope {
            from: NodeId(1),
            to: NodeId(2),
            msg: Message::RequestFlow { flow: 7, sink: NodeId(0), cost_to_sink: 3.5 },
        };
        assert_eq!(e.from, NodeId(1));
        match &e.msg {
            Message::RequestFlow { flow, sink, cost_to_sink } => {
                assert_eq!(*flow, 7);
                assert_eq!(*sink, NodeId(0));
                assert!((cost_to_sink - 3.5).abs() < 1e-12);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn utilization_query_accumulates() {
        let mut acc = vec![(10usize, 5usize)];
        acc.push((8, 8));
        let m = Message::UtilizationQuery { acc: acc.clone() };
        if let Message::UtilizationQuery { acc } = m {
            assert_eq!(acc.len(), 2);
            assert_eq!(acc[1], (8, 8));
        }
    }
}
