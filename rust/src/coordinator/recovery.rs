//! Crash-recovery protocol (paper §V-D "Tolerating Crashes").
//!
//! Forward-pass crashes: the upstream node times out on the missing
//! COMPLETE, re-sends its stored activation to an alternate next-stage
//! peer (chosen by the flow rule), or DENYs upstream when none exists.
//!
//! Backward-pass crashes: the data node pings the nodes along the
//! microbatch's recorded path; at the first ping failure, the last alive
//! node forwards its stored activation to a replacement, which recomputes
//! that stage's forward and resumes the backward pass from the stored
//! gradient — "far cheaper than rebuilding the pipeline from scratch".
//!
//! This module implements the *path-level* repair planning shared by the
//! simulator and the protocol tests: given a recorded path, the liveness
//! view and per-node spare capacity, compute the ping sequence, the repair
//! plan (which nodes replace which), and its cost in recomputed forwards.

use crate::cost::NodeId;
use crate::flow::graph::{FlowPath, StageGraph};

/// Replacement of one crashed relay.
#[derive(Debug, Clone, PartialEq)]
pub struct Replacement {
    pub stage: usize,
    pub dead: NodeId,
    pub replacement: NodeId,
    /// Node holding the stored activation the replacement recomputes from
    /// (the last alive node before the crash, or the data node).
    pub activation_source: NodeId,
}

/// Outcome of planning a backward-pass repair.
#[derive(Debug, Clone, PartialEq)]
pub enum RepairPlan {
    /// Path intact: no crash found by the ping sweep.
    Intact,
    /// Repaired: the fixed path plus the individual replacements.
    Repaired { path: FlowPath, replacements: Vec<Replacement>, pings: usize },
    /// No spare node in some crashed stage: the microbatch must be
    /// deferred (DENY reaches the source).
    Unrecoverable { failed_stage: usize, pings: usize },
}

impl RepairPlan {
    /// Number of stage forwards the plan recomputes (the repair's cost,
    /// vs. `path.relays.len()` for a SWARM-style full restart).
    pub fn recomputed_forwards(&self) -> usize {
        match self {
            RepairPlan::Repaired { replacements, .. } => replacements.len(),
            _ => 0,
        }
    }
}

/// Plan a §V-D backward-pass repair.
///
/// `alive(n)` is the liveness oracle (a ping); `spare(n)` tells whether a
/// candidate has a free slot; `cost(i, j)` ranks replacement candidates by
/// `d(prev, m) + d(m, next)` exactly like the flow algorithm.
pub fn plan_repair(
    path: &FlowPath,
    graph: &StageGraph,
    alive: impl Fn(NodeId) -> bool,
    spare: impl Fn(NodeId) -> bool,
    cost: impl Fn(NodeId, NodeId) -> f64,
) -> RepairPlan {
    let mut pings = 0;
    let mut dead_stages: Vec<usize> = Vec::new();
    // The data node "pings the first node on the microbatch path. Nodes
    // ping downstream peers along this path" — one sweep front to back.
    for (s, &r) in path.relays.iter().enumerate() {
        pings += 1;
        if !alive(r) {
            dead_stages.push(s);
        }
    }
    if dead_stages.is_empty() {
        return RepairPlan::Intact;
    }

    let mut new_path = path.clone();
    let mut replacements = Vec::new();
    for &s in &dead_stages {
        // last alive node before the crash (walk back over other dead stages)
        let activation_source = (0..s)
            .rev()
            .map(|p| new_path.relays[p])
            .find(|&n| alive(n))
            .unwrap_or(path.source);
        let prev = activation_source;
        let next = (s + 1..path.relays.len())
            .map(|p| new_path.relays[p])
            .find(|&n| alive(n))
            .unwrap_or(path.source);
        let candidates: Vec<NodeId> = graph.stages[s]
            .iter()
            .filter(|&&m| m != path.relays[s] && alive(m) && spare(m))
            .copied()
            .collect();
        let best = candidates.iter().min_by(|&&a, &&b| {
            let ca = cost(prev, a) + cost(a, next);
            let cb = cost(prev, b) + cost(b, next);
            ca.partial_cmp(&cb).unwrap()
        });
        match best {
            Some(&m) => {
                replacements.push(Replacement {
                    stage: s,
                    dead: new_path.relays[s],
                    replacement: m,
                    activation_source,
                });
                new_path.relays[s] = m;
            }
            None => return RepairPlan::Unrecoverable { failed_stage: s, pings },
        }
    }
    RepairPlan::Repaired { path: new_path, replacements, pings }
}

/// Compare the §V-D repair cost against SWARM's full-restart cost for the
/// same crash (in recomputed stage-forward units) — the quantity behind
/// Table II's "wasted GPU time" gap.
pub fn repair_vs_restart_cost(plan: &RepairPlan, n_stages: usize) -> (usize, usize) {
    let repair = plan.recomputed_forwards();
    // A restart recomputes every stage forward (and re-sends from scratch).
    let restart = match plan {
        RepairPlan::Intact => 0,
        _ => n_stages,
    };
    (repair, restart)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// stages: {1,2}, {3,4}, {5,6}; data node 0.
    fn graph() -> StageGraph {
        StageGraph {
            stages: vec![
                vec![NodeId(1), NodeId(2)],
                vec![NodeId(3), NodeId(4)],
                vec![NodeId(5), NodeId(6)],
            ],
            data_nodes: vec![NodeId(0)],
        }
    }

    fn path() -> FlowPath {
        FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3), NodeId(5)] }
    }

    fn unit_cost(_i: NodeId, _j: NodeId) -> f64 {
        1.0
    }

    #[test]
    fn intact_path_needs_nothing() {
        let plan = plan_repair(&path(), &graph(), |_| true, |_| true, unit_cost);
        assert_eq!(plan, RepairPlan::Intact);
        assert_eq!(plan.recomputed_forwards(), 0);
    }

    #[test]
    fn single_crash_repaired_in_place() {
        let plan = plan_repair(&path(), &graph(), |n| n != NodeId(3), |_| true, unit_cost);
        match &plan {
            RepairPlan::Repaired { path: p, replacements, pings } => {
                assert_eq!(p.relays, vec![NodeId(1), NodeId(4), NodeId(5)]);
                assert_eq!(replacements.len(), 1);
                assert_eq!(replacements[0].dead, NodeId(3));
                assert_eq!(replacements[0].replacement, NodeId(4));
                // activation comes from the last alive node before the crash
                assert_eq!(replacements[0].activation_source, NodeId(1));
                assert_eq!(*pings, 3);
            }
            p => panic!("expected repair, got {p:?}"),
        }
        assert_eq!(plan.recomputed_forwards(), 1);
    }

    #[test]
    fn first_stage_crash_pulls_activation_from_data_node() {
        let plan = plan_repair(&path(), &graph(), |n| n != NodeId(1), |_| true, unit_cost);
        match plan {
            RepairPlan::Repaired { replacements, .. } => {
                assert_eq!(replacements[0].activation_source, NodeId(0));
                assert_eq!(replacements[0].replacement, NodeId(2));
            }
            p => panic!("expected repair, got {p:?}"),
        }
    }

    #[test]
    fn consecutive_crashes_chain_through_survivors() {
        // relays 3 and 5 both dead: stage-1 repair reads its activation
        // from node 1; stage-2 repair reads from the *new* stage-1 node.
        let dead = [NodeId(3), NodeId(5)];
        let plan =
            plan_repair(&path(), &graph(), |n| !dead.contains(&n), |_| true, unit_cost);
        match plan {
            RepairPlan::Repaired { path: p, replacements, .. } => {
                assert_eq!(p.relays, vec![NodeId(1), NodeId(4), NodeId(6)]);
                assert_eq!(replacements.len(), 2);
                assert_eq!(replacements[1].activation_source, NodeId(4));
            }
            p => panic!("expected repair, got {p:?}"),
        }
    }

    #[test]
    fn whole_stage_dead_is_unrecoverable() {
        let dead = [NodeId(3), NodeId(4)];
        let plan =
            plan_repair(&path(), &graph(), |n| !dead.contains(&n), |_| true, unit_cost);
        assert!(matches!(plan, RepairPlan::Unrecoverable { failed_stage: 1, .. }));
    }

    #[test]
    fn no_spare_capacity_is_unrecoverable() {
        let plan = plan_repair(&path(), &graph(), |n| n != NodeId(3), |_| false, unit_cost);
        assert!(matches!(plan, RepairPlan::Unrecoverable { failed_stage: 1, .. }));
    }

    #[test]
    fn replacement_ranked_by_flow_rule() {
        // make node 6 much closer than node 5's default replacement choice
        let g = StageGraph {
            stages: vec![
                vec![NodeId(1)],
                vec![NodeId(3), NodeId(4)],
                vec![NodeId(5), NodeId(6)],
            ],
            data_nodes: vec![NodeId(0)],
        };
        let p = FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3), NodeId(5)] };
        let cost = |i: NodeId, j: NodeId| {
            if i == NodeId(4) || j == NodeId(4) {
                10.0 // node 4 is far from everything
            } else {
                1.0
            }
        };
        let plan = plan_repair(&p, &g, |n| n != NodeId(3), |_| true, cost);
        match plan {
            RepairPlan::Repaired { replacements, .. } => {
                // stage 1 has only node 4 as a candidate — forced pick
                assert_eq!(replacements[0].replacement, NodeId(4));
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn repair_is_cheaper_than_restart() {
        let plan = plan_repair(&path(), &graph(), |n| n != NodeId(3), |_| true, unit_cost);
        let (repair, restart) = repair_vs_restart_cost(&plan, 3);
        assert_eq!((repair, restart), (1, 3));
    }
}
