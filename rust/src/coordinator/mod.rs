//! The GWTF coordinator: the paper's system contribution as node logic.
//!
//! - [`messages`] — the full wire protocol (§V): Request Flow / Change /
//!   Redirect, COMPLETE / DENY, ping-based path repair, BEGIN AGGREGATION
//!   / CAN TAKE, join handshake.
//! - [`leader`]  — bully-style leader election among the data nodes.
//! - [`join`]    — §V-B: stage-utilization ranking (flooding query) and
//!   capacity-ranked candidate placement.
//! - [`aggregation`] — §V-E: training/aggregation synchronization.
//! - [`recovery`] — §V-D: ping-sweep path repair planning.
//! - [`node`]    — a message-driven GWTF node state machine tying the
//!   pieces together (used by the protocol-level tests).
//! - [`router`]  — the [`crate::sim::RoutingPolicy`] implementation backed by the
//!   decentralized flow optimizer; this is what the experiment harness
//!   plugs into the training simulator.

pub mod aggregation;
pub mod join;
pub mod leader;
pub mod messages;
pub mod node;
pub mod recovery;
pub mod router;

pub use join::{JoinPolicy, Leader};
pub use recovery::{plan_repair, RepairPlan, Replacement};
pub use router::GwtfRouter;
