//! Training/aggregation synchronization (paper §V-E).
//!
//! Nodes in the same stage must hold identical parameters when an
//! iteration's microbatches are processed, so GWTF alternates training and
//! aggregation phases: the data-node leader emits BEGIN AGGREGATION, which
//! floods front-to-back; each stage then broadcasts/collects weights
//! internally; once a node finished aggregating *and* sees a downstream
//! peer finished, it sends CAN TAKE upstream (last stage sends it
//! unconditionally).  When CAN TAKE reaches the data nodes a new iteration
//! begins.  This module implements that state machine per node.

use crate::cost::NodeId;

/// Phase of a node in the §V-E cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Training,
    /// Received BEGIN AGGREGATION; exchanging weights within the stage.
    Aggregating,
    /// Finished weight exchange; waiting for downstream CAN TAKE.
    WaitingDownstream,
    /// Sent CAN TAKE upstream; ready for the next iteration's microbatches.
    Ready,
}

/// Per-node aggregation state machine.
#[derive(Debug, Clone)]
pub struct AggregationFsm {
    pub id: NodeId,
    /// Stage index (None for data nodes, which bracket the pipeline).
    pub stage: Option<usize>,
    /// Number of same-stage peers we must exchange weights with.
    pub peers_in_stage: usize,
    pub phase: Phase,
    pub iteration: u64,
    weights_received: usize,
    downstream_ready: bool,
    is_last_stage: bool,
}

/// Actions the FSM asks its host to perform.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Flood BEGIN AGGREGATION to known next-stage peers.
    ForwardBegin,
    /// Broadcast our weights to same-stage peers.
    BroadcastWeights,
    /// Send CAN TAKE to known previous-stage peers.
    SendCanTake,
    /// Start accepting microbatches for `iteration`.
    StartIteration(u64),
}

impl AggregationFsm {
    pub fn new(id: NodeId, stage: Option<usize>, peers_in_stage: usize, is_last_stage: bool) -> Self {
        AggregationFsm {
            id,
            stage,
            peers_in_stage,
            phase: Phase::Training,
            iteration: 0,
            weights_received: 0,
            downstream_ready: false,
            is_last_stage,
        }
    }

    /// BEGIN AGGREGATION received (or emitted by the leader itself).
    pub fn on_begin_aggregation(&mut self, iteration: u64) -> Vec<Action> {
        if self.phase != Phase::Training || iteration < self.iteration {
            return vec![]; // duplicate flood copies are ignored
        }
        self.iteration = iteration;
        self.phase = Phase::Aggregating;
        self.weights_received = 0;
        self.downstream_ready = false;
        let mut acts = vec![Action::ForwardBegin, Action::BroadcastWeights];
        if self.peers_in_stage == 0 {
            acts.extend(self.finish_exchange());
        }
        acts
    }

    /// A same-stage peer's weights arrived.
    pub fn on_weights(&mut self, iteration: u64) -> Vec<Action> {
        if self.phase != Phase::Aggregating || iteration != self.iteration {
            return vec![];
        }
        self.weights_received += 1;
        if self.weights_received >= self.peers_in_stage {
            self.finish_exchange()
        } else {
            vec![]
        }
    }

    fn finish_exchange(&mut self) -> Vec<Action> {
        self.phase = Phase::WaitingDownstream;
        // "Nodes in the last stage send this without waiting."
        if self.is_last_stage || self.downstream_ready {
            self.send_can_take()
        } else {
            vec![]
        }
    }

    /// Downstream peer's CAN TAKE arrived.
    pub fn on_can_take(&mut self, iteration: u64) -> Vec<Action> {
        if iteration != self.iteration {
            return vec![];
        }
        self.downstream_ready = true;
        if self.phase == Phase::WaitingDownstream {
            self.send_can_take()
        } else {
            vec![]
        }
    }

    fn send_can_take(&mut self) -> Vec<Action> {
        self.phase = Phase::Ready;
        vec![Action::SendCanTake, Action::StartIteration(self.iteration + 1)]
    }

    /// New iteration's first microbatch observed: back to Training.
    pub fn on_training_start(&mut self) {
        self.phase = Phase::Training;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn relay(peers: usize, last: bool) -> AggregationFsm {
        AggregationFsm::new(NodeId(1), Some(0), peers, last)
    }

    #[test]
    fn begin_triggers_flood_and_broadcast() {
        let mut f = relay(2, false);
        let acts = f.on_begin_aggregation(1);
        assert!(acts.contains(&Action::ForwardBegin));
        assert!(acts.contains(&Action::BroadcastWeights));
        assert_eq!(f.phase, Phase::Aggregating);
    }

    #[test]
    fn duplicate_begin_ignored() {
        let mut f = relay(2, false);
        f.on_begin_aggregation(1);
        assert!(f.on_begin_aggregation(1).is_empty());
    }

    #[test]
    fn waits_for_all_peer_weights() {
        let mut f = relay(2, false);
        f.on_begin_aggregation(1);
        assert!(f.on_weights(1).is_empty());
        let acts = f.on_weights(1);
        // finished exchange but downstream not ready and not last stage
        assert!(acts.is_empty());
        assert_eq!(f.phase, Phase::WaitingDownstream);
    }

    #[test]
    fn last_stage_sends_can_take_without_waiting() {
        let mut f = relay(1, true);
        f.on_begin_aggregation(3);
        let acts = f.on_weights(3);
        assert!(acts.contains(&Action::SendCanTake));
        assert!(acts.contains(&Action::StartIteration(4)));
        assert_eq!(f.phase, Phase::Ready);
    }

    #[test]
    fn can_take_unblocks_waiting_node() {
        let mut f = relay(1, false);
        f.on_begin_aggregation(1);
        f.on_weights(1);
        assert_eq!(f.phase, Phase::WaitingDownstream);
        let acts = f.on_can_take(1);
        assert!(acts.contains(&Action::SendCanTake));
        assert_eq!(f.phase, Phase::Ready);
    }

    #[test]
    fn can_take_before_exchange_finishes_is_remembered() {
        let mut f = relay(1, false);
        f.on_begin_aggregation(2);
        assert!(f.on_can_take(2).is_empty()); // arrives early
        let acts = f.on_weights(2);
        assert!(acts.contains(&Action::SendCanTake)); // promptly forwarded
    }

    #[test]
    fn lone_node_in_stage_aggregates_instantly() {
        let mut f = AggregationFsm::new(NodeId(2), Some(1), 0, true);
        let acts = f.on_begin_aggregation(1);
        assert!(acts.contains(&Action::SendCanTake));
    }

    #[test]
    fn full_cycle_returns_to_training() {
        let mut f = relay(1, true);
        f.on_begin_aggregation(1);
        f.on_weights(1);
        f.on_training_start();
        assert_eq!(f.phase, Phase::Training);
        // next iteration works again
        let acts = f.on_begin_aggregation(2);
        assert!(!acts.is_empty());
    }

    #[test]
    fn stale_iteration_messages_dropped() {
        let mut f = relay(1, false);
        f.on_begin_aggregation(5);
        assert!(f.on_weights(3).is_empty());
        assert!(f.on_can_take(4).is_empty());
    }
}
