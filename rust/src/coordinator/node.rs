//! A message-driven GWTF node (relay or data node) state machine.
//!
//! Ties the §V protocols together at the wire level: flow pairing
//! (Request Flow approve/reject with cost advertisement), crash detection
//! (COMPLETE bookkeeping, ping/pong), the §V-E aggregation FSM, and the
//! join handshake.  The simulator and experiment harness use the
//! higher-level [`crate::flow::DecentralizedFlow`] optimizer directly;
//! this state machine exists so the *protocol* itself (who says what to
//! whom) is implemented and testable end-to-end over a simulated bus.

use std::collections::BTreeMap;

use crate::cost::NodeId;

use super::aggregation::{Action, AggregationFsm};
use super::messages::{BatchId, Envelope, FlowId, Message};

/// One direction of a paired flow at this node.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowEnd {
    pub flow: FlowId,
    pub sink: NodeId,
    /// Advertised cost from here to the sink along this flow.
    pub cost_to_sink: f64,
    /// Peer on the other end (upstream for inflow, downstream for outflow).
    pub peer: Option<NodeId>,
}

/// Role of the node in the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Holds training data; first + last pipeline stage (embed + head).
    Data,
    /// Hosts one relay stage of transformer blocks.
    Relay { stage: usize },
}

/// The GWTF node automaton.
pub struct Node {
    pub id: NodeId,
    pub role: Role,
    pub capacity: usize,
    /// Outflows towards the next stage, keyed by flow id.
    pub outflows: BTreeMap<FlowId, FlowEnd>,
    /// Inflows from the previous stage, keyed by flow id.
    pub inflows: BTreeMap<FlowId, FlowEnd>,
    /// Unpaired outflow budget (data nodes start with their demand).
    pub unpaired_out: usize,
    /// Aggregation-phase state machine (§V-E).
    pub agg: AggregationFsm,
    /// Batches we forwarded and are awaiting a COMPLETE for:
    /// batch -> (downstream peer, send timestamp).
    pub awaiting_complete: BTreeMap<BatchId, (NodeId, f64)>,
    /// Observed per-peer round-trip estimates from COMPLETE latencies.
    pub rtt_estimate: BTreeMap<NodeId, f64>,
    /// Peers currently considered dead (missed COMPLETE past timeout).
    pub suspected: Vec<NodeId>,
    pub timeout_s: f64,
}

impl Node {
    pub fn new(id: NodeId, role: Role, capacity: usize, peers_in_stage: usize, is_last_stage: bool) -> Self {
        let stage = match role {
            Role::Data => None,
            Role::Relay { stage } => Some(stage),
        };
        Node {
            id,
            role,
            capacity,
            outflows: BTreeMap::new(),
            inflows: BTreeMap::new(),
            unpaired_out: 0,
            agg: AggregationFsm::new(id, stage, peers_in_stage, is_last_stage),
            awaiting_complete: BTreeMap::new(),
            rtt_estimate: BTreeMap::new(),
            suspected: Vec::new(),
            timeout_s: 5.0,
        }
    }

    /// Remaining capacity after current pairings (offering an outflow is
    /// free; capacity is consumed when a pairing is established).
    pub fn capacity_left(&self) -> usize {
        let paired_out = self.outflows.values().filter(|f| f.peer.is_some()).count();
        let paired_in = self.inflows.values().filter(|f| f.peer.is_some()).count();
        self.capacity.saturating_sub(paired_out.max(paired_in))
    }

    /// Our advertised cost to `sink` (minimum over unpaired outflows to it;
    /// infinite if none — this is what a RejectFlow reports).
    pub fn cost_to(&self, sink: NodeId) -> f64 {
        self.outflows
            .values()
            .filter(|f| f.sink == sink && f.peer.is_none())
            .map(|f| f.cost_to_sink)
            .fold(f64::INFINITY, f64::min)
    }

    /// Register an unpaired outflow we can offer to previous-stage nodes.
    pub fn offer_outflow(&mut self, flow: FlowId, sink: NodeId, cost_to_sink: f64) {
        self.outflows.insert(flow, FlowEnd { flow, sink, cost_to_sink, peer: None });
    }

    /// Handle one incoming message; returns the messages to send.
    pub fn handle(&mut self, env: &Envelope, now: f64) -> Vec<Envelope> {
        let from = env.from;
        match &env.msg {
            Message::RequestFlow { flow, sink, cost_to_sink } => {
                // §V-C: approve iff we do hold that unpaired outflow at that cost.
                let ok = self
                    .outflows
                    .get(flow)
                    .map(|f| {
                        f.peer.is_none()
                            && f.sink == *sink
                            && (f.cost_to_sink - cost_to_sink).abs() < 1e-9
                    })
                    .unwrap_or(false);
                if ok && self.capacity_left() > 0 {
                    self.outflows.get_mut(flow).unwrap().peer = Some(from);
                    vec![self.send(from, Message::ApproveFlow { flow: *flow })]
                } else {
                    let actual = self.cost_to(*sink);
                    vec![self.send(from, Message::RejectFlow { flow: *flow, actual_cost: actual })]
                }
            }
            Message::ApproveFlow { flow } => {
                // We become the upstream end: record the inflow pairing and
                // advertise our new cost to previous stages (the caller
                // computes + broadcasts AdvertiseCost from the return).
                if let Some(f) = self.inflows.get_mut(flow) {
                    f.peer = Some(from);
                }
                vec![]
            }
            Message::RejectFlow { flow, actual_cost } => {
                // Update our view of that peer's cost; drop the speculative inflow.
                if let Some(f) = self.inflows.remove(flow) {
                    let _ = f;
                }
                if actual_cost.is_finite() {
                    self.rtt_estimate.insert(from, *actual_cost);
                }
                vec![]
            }
            Message::Complete { batch } => {
                if let Some((peer, sent_at)) = self.awaiting_complete.remove(batch) {
                    // latency estimation (§V-D)
                    let rtt = now - sent_at;
                    let e = self.rtt_estimate.entry(peer).or_insert(rtt);
                    *e = 0.8 * *e + 0.2 * rtt;
                }
                vec![]
            }
            Message::Deny { batch } => {
                // Downstream has no capacity: drop expectation, caller reroutes.
                self.awaiting_complete.remove(batch);
                if !self.suspected.contains(&from) {
                    self.suspected.push(from);
                }
                vec![]
            }
            Message::Ping { batch } => vec![self.send(from, Message::Pong { batch: *batch })],
            Message::Pong { .. } => {
                self.suspected.retain(|&p| p != from);
                vec![]
            }
            Message::BeginAggregation { iteration } => {
                let acts = self.agg.on_begin_aggregation(*iteration);
                self.actions_to_messages(acts)
            }
            Message::ShareWeights { iteration, .. } => {
                let acts = self.agg.on_weights(*iteration);
                self.actions_to_messages(acts)
            }
            Message::CanTake { iteration } => {
                let acts = self.agg.on_can_take(*iteration);
                self.actions_to_messages(acts)
            }
            Message::JoinRequest { .. }
            | Message::AssignStage { .. }
            | Message::UtilizationQuery { .. }
            | Message::UtilizationReply { .. }
            | Message::Election { .. }
            | Message::Coordinator { .. }
            | Message::RequestChange { .. }
            | Message::AcceptChange { .. }
            | Message::RequestRedirect { .. }
            | Message::AcceptRedirect { .. }
            | Message::AdvertiseCost { .. }
            | Message::ForwardActivation { .. }
            | Message::ResumeBackward { .. } => vec![],
        }
    }

    /// Record that we forwarded `batch` to `peer` at `now` and expect a
    /// COMPLETE within the timeout.
    pub fn sent_batch(&mut self, batch: BatchId, peer: NodeId, now: f64) {
        self.awaiting_complete.insert(batch, (peer, now));
    }

    /// Which awaited batches have timed out at `now` (suspects their peer).
    pub fn timed_out(&mut self, now: f64) -> Vec<(BatchId, NodeId)> {
        let expired: Vec<(BatchId, NodeId)> = self
            .awaiting_complete
            .iter()
            .filter(|(_, (_, t))| now - t > self.timeout_s)
            .map(|(&b, &(p, _))| (b, p))
            .collect();
        for (b, p) in &expired {
            self.awaiting_complete.remove(b);
            if !self.suspected.contains(p) {
                self.suspected.push(*p);
            }
        }
        expired
    }

    fn actions_to_messages(&self, acts: Vec<Action>) -> Vec<Envelope> {
        // The host (bus/simulator) expands Forward/Broadcast actions to the
        // actual peer sets; here we emit markers addressed to self that the
        // bus fans out.  Stage-peer topology lives outside the node.
        acts.into_iter()
            .filter_map(|a| match a {
                Action::ForwardBegin => {
                    Some(self.send(self.id, Message::BeginAggregation { iteration: self.agg.iteration }))
                }
                Action::BroadcastWeights => Some(self.send(
                    self.id,
                    Message::ShareWeights {
                        iteration: self.agg.iteration,
                        stage: match self.role {
                            Role::Relay { stage } => stage,
                            Role::Data => 0,
                        },
                    },
                )),
                Action::SendCanTake => {
                    Some(self.send(self.id, Message::CanTake { iteration: self.agg.iteration }))
                }
                Action::StartIteration(_) => None,
            })
            .collect()
    }

    fn send(&self, to: NodeId, msg: Message) -> Envelope {
        Envelope { from: self.id, to, msg }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn relay(id: usize, stage: usize, cap: usize) -> Node {
        Node::new(NodeId(id), Role::Relay { stage }, cap, 1, false)
    }

    fn env(from: usize, to: usize, msg: Message) -> Envelope {
        Envelope { from: NodeId(from), to: NodeId(to), msg }
    }

    #[test]
    fn request_flow_approved_when_matching() {
        let mut n = relay(2, 1, 2);
        n.offer_outflow(7, NodeId(0), 3.5);
        let out = n.handle(
            &env(1, 2, Message::RequestFlow { flow: 7, sink: NodeId(0), cost_to_sink: 3.5 }),
            0.0,
        );
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].msg, Message::ApproveFlow { flow: 7 });
        assert_eq!(n.outflows[&7].peer, Some(NodeId(1)));
    }

    #[test]
    fn request_flow_rejected_reports_actual_cost() {
        let mut n = relay(2, 1, 2);
        n.offer_outflow(7, NodeId(0), 3.5);
        // wrong advertised cost -> reject with our real cost
        let out = n.handle(
            &env(1, 2, Message::RequestFlow { flow: 7, sink: NodeId(0), cost_to_sink: 9.9 }),
            0.0,
        );
        match &out[0].msg {
            Message::RejectFlow { actual_cost, .. } => assert!((actual_cost - 3.5).abs() < 1e-9),
            m => panic!("expected reject, got {m:?}"),
        }
    }

    #[test]
    fn reject_for_unknown_sink_is_infinite() {
        let mut n = relay(2, 1, 2);
        let out = n.handle(
            &env(1, 2, Message::RequestFlow { flow: 1, sink: NodeId(9), cost_to_sink: 1.0 }),
            0.0,
        );
        match &out[0].msg {
            Message::RejectFlow { actual_cost, .. } => assert!(actual_cost.is_infinite()),
            m => panic!("expected reject, got {m:?}"),
        }
    }

    #[test]
    fn capacity_exhaustion_rejects() {
        let mut n = relay(2, 1, 1);
        n.offer_outflow(1, NodeId(0), 1.0);
        n.offer_outflow(2, NodeId(0), 2.0);
        let a = n.handle(
            &env(1, 2, Message::RequestFlow { flow: 1, sink: NodeId(0), cost_to_sink: 1.0 }),
            0.0,
        );
        assert_eq!(a[0].msg, Message::ApproveFlow { flow: 1 });
        // capacity 1 used up: second pairing refused even though it matches
        let b = n.handle(
            &env(3, 2, Message::RequestFlow { flow: 2, sink: NodeId(0), cost_to_sink: 2.0 }),
            0.0,
        );
        assert!(matches!(b[0].msg, Message::RejectFlow { .. }));
    }

    #[test]
    fn complete_updates_rtt_estimate() {
        let mut n = relay(1, 0, 2);
        n.sent_batch(42, NodeId(2), 10.0);
        n.handle(&env(2, 1, Message::Complete { batch: 42 }), 11.5);
        assert!(n.awaiting_complete.is_empty());
        assert!((n.rtt_estimate[&NodeId(2)] - 1.5).abs() < 1e-9);
    }

    #[test]
    fn timeout_suspects_peer() {
        let mut n = relay(1, 0, 2);
        n.timeout_s = 5.0;
        n.sent_batch(42, NodeId(2), 0.0);
        assert!(n.timed_out(4.0).is_empty());
        let t = n.timed_out(6.0);
        assert_eq!(t, vec![(42, NodeId(2))]);
        assert!(n.suspected.contains(&NodeId(2)));
    }

    #[test]
    fn ping_answered_with_pong_and_pong_clears_suspicion() {
        let mut n = relay(1, 0, 2);
        n.suspected.push(NodeId(3));
        let out = n.handle(&env(3, 1, Message::Ping { batch: 9 }), 0.0);
        assert_eq!(out[0].msg, Message::Pong { batch: 9 });
        n.handle(&env(3, 1, Message::Pong { batch: 9 }), 0.0);
        assert!(n.suspected.is_empty());
    }

    #[test]
    fn deny_suspects_and_clears_waiting() {
        let mut n = relay(1, 0, 2);
        n.sent_batch(5, NodeId(2), 0.0);
        n.handle(&env(2, 1, Message::Deny { batch: 5 }), 0.1);
        assert!(n.awaiting_complete.is_empty());
        assert!(n.suspected.contains(&NodeId(2)));
    }

    #[test]
    fn aggregation_cycle_over_messages() {
        let mut last = Node::new(NodeId(4), Role::Relay { stage: 2 }, 2, 0, true);
        let out = last.handle(&env(0, 4, Message::BeginAggregation { iteration: 1 }), 0.0);
        // lone last-stage node: forwards BEGIN, broadcasts weights, CAN TAKE
        assert!(out.iter().any(|e| matches!(e.msg, Message::BeginAggregation { .. })));
        assert!(out.iter().any(|e| matches!(e.msg, Message::CanTake { .. })));
    }
}
