//! Experiment metric accumulation + report writers.
//!
//! Every bench target funnels its per-repetition
//! [`crate::sim::IterationMetrics`] through a [`MetricsTable`] and emits
//! the paper-style `mean ± std` rows as Markdown and CSV under
//! `bench_results/` (the tables in EXPERIMENTS.md are generated this way).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::sim::IterationMetrics;
use crate::util::Summary;

/// Accumulates per-iteration samples for one (system, setting) cell.
#[derive(Debug, Clone, Default)]
pub struct CellAccumulator {
    pub time_per_microbatch_min: Vec<f64>,
    pub throughput: Vec<f64>,
    pub comm_time_min: Vec<f64>,
    pub wasted_gpu_min: Vec<f64>,
    pub makespan_min: Vec<f64>,
    pub fwd_recoveries: Vec<f64>,
    pub bwd_recoveries: Vec<f64>,
    /// §V-E barrier re-exchanges after mid-aggregation crashes.
    pub agg_recoveries: Vec<f64>,
    /// Flow-protocol rounds per iteration's (re)plan (warm-replan
    /// diagnostics; 0 for routers without a round-based protocol).
    pub replan_rounds: Vec<f64>,
    /// Planning minutes hidden behind training per iteration (the plan
    /// lifecycle's overlap window; 0 under the degenerate
    /// commit-at-request lifecycle).
    pub plan_overlap_min: Vec<f64>,
    /// Plan tickets invalidated by mid-planning churn per iteration
    /// (commit-time §V-D repairs instead of clean convergences).
    pub stale_replans: Vec<f64>,
    /// Minutes transfers spent queued for a NIC transmission slot per
    /// iteration (shared-capacity substrate; 0 under unlimited NICs).
    pub queue_min: Vec<f64>,
    /// Busiest NIC's demanded-transmission load per iteration (its
    /// busier direction's tx seconds over the makespan, max over nodes;
    /// >1 = oversubscribed under unlimited concurrency).
    pub nic_util_max: Vec<f64>,
    /// Mean weight staleness (generations behind) microbatches trained
    /// against per iteration (bounded-staleness mode; 0 under the
    /// synchronous barrier).
    pub staleness_mean: Vec<f64>,
    /// Microbatches deferred past t=0 by the staleness admission rule
    /// per iteration.
    pub deferred: Vec<f64>,
    /// §V-D memory-overload DENYs per iteration (adversarial DENY
    /// storms and phantom-capacity bounces land here too).
    pub denies: Vec<f64>,
    /// Kernel events dispatched per makespan second — the engine's
    /// event throughput for the iteration.
    pub events_per_s: Vec<f64>,
    /// Peak resident set of the measuring process, MiB
    /// (`util::mem::peak_rss_mib`).  Stamped by the bench drivers only —
    /// the engine itself never sets it (the probe is monotone within a
    /// process, which would break bit-parity comparisons between runs);
    /// 0 values (engine-only cells, platforms without `/proc`) are
    /// skipped.
    pub peak_rss_mib: Vec<f64>,
    /// Critical-path attribution (minutes): where the makespan went,
    /// bucket by bucket ([`crate::sim::CritPath`]; the seven buckets sum
    /// to the makespan).
    pub crit_compute_min: Vec<f64>,
    pub crit_tx_min: Vec<f64>,
    pub crit_prop_min: Vec<f64>,
    pub crit_queue_min: Vec<f64>,
    pub crit_plan_min: Vec<f64>,
    pub crit_agg_min: Vec<f64>,
    pub crit_stale_min: Vec<f64>,
}

/// One report column: the stable CSV key, the human Markdown label, and
/// the accumulator series backing it.  [`CellAccumulator::row`],
/// [`MetricsTable::to_markdown`] and [`MetricsTable::to_csv`] all derive
/// from this one table, so the three surfaces cannot drift (the
/// `columns_schema_covers_every_series_and_surface` test pins the
/// schema against the accumulator's fields).
pub struct Column {
    pub key: &'static str,
    pub label: &'static str,
    pub samples: fn(&CellAccumulator) -> &Vec<f64>,
}

/// The shared column schema, in Markdown presentation order.
pub const COLUMNS: &[Column] = &[
    Column {
        key: "time_per_microbatch_min",
        label: "Time per microbatch (min)",
        samples: |a| &a.time_per_microbatch_min,
    },
    Column {
        key: "throughput",
        label: "Throughput (#microb/iteration)",
        samples: |a| &a.throughput,
    },
    Column {
        key: "comm_time_min",
        label: "Communication time (min)",
        samples: |a| &a.comm_time_min,
    },
    Column {
        key: "wasted_gpu_min",
        label: "Wasted GPU time (min)",
        samples: |a| &a.wasted_gpu_min,
    },
    Column { key: "makespan_min", label: "Iteration makespan (min)", samples: |a| &a.makespan_min },
    Column {
        key: "fwd_recoveries",
        label: "Forward recoveries (#/iteration)",
        samples: |a| &a.fwd_recoveries,
    },
    Column {
        key: "bwd_recoveries",
        label: "Backward recoveries (#/iteration)",
        samples: |a| &a.bwd_recoveries,
    },
    Column {
        key: "agg_recoveries",
        label: "Aggregation-barrier recoveries (#/iteration)",
        samples: |a| &a.agg_recoveries,
    },
    Column {
        key: "replan_rounds",
        label: "Flow re-plan rounds (#/iteration)",
        samples: |a| &a.replan_rounds,
    },
    Column {
        key: "plan_overlap_min",
        label: "Plan overlap (min, hidden behind training)",
        samples: |a| &a.plan_overlap_min,
    },
    Column {
        key: "stale_replans",
        label: "Stale re-plans (#/iteration)",
        samples: |a| &a.stale_replans,
    },
    Column { key: "queue_min", label: "NIC queueing time (min)", samples: |a| &a.queue_min },
    Column {
        key: "nic_util_max",
        label: "Peak NIC load (tx-s per makespan-s; >1 = oversubscribed)",
        samples: |a| &a.nic_util_max,
    },
    Column {
        key: "staleness_mean",
        label: "Weight staleness (generations behind, mean)",
        samples: |a| &a.staleness_mean,
    },
    Column {
        key: "deferred",
        label: "Deferred microbatches (#/iteration)",
        samples: |a| &a.deferred,
    },
    Column {
        key: "denies",
        label: "Memory-overload DENYs (#/iteration)",
        samples: |a| &a.denies,
    },
    Column {
        key: "events_per_s",
        label: "Kernel event throughput (events/sec)",
        samples: |a| &a.events_per_s,
    },
    Column {
        key: "peak_rss_mib",
        label: "Peak RSS (MiB)",
        samples: |a| &a.peak_rss_mib,
    },
    Column {
        key: "crit_compute_min",
        label: "Critical path: compute (min)",
        samples: |a| &a.crit_compute_min,
    },
    Column {
        key: "crit_tx_min",
        label: "Critical path: transmission (min)",
        samples: |a| &a.crit_tx_min,
    },
    Column {
        key: "crit_prop_min",
        label: "Critical path: propagation (min)",
        samples: |a| &a.crit_prop_min,
    },
    Column {
        key: "crit_queue_min",
        label: "Critical path: waiting (min)",
        samples: |a| &a.crit_queue_min,
    },
    Column {
        key: "crit_plan_min",
        label: "Critical path: planning (min)",
        samples: |a| &a.crit_plan_min,
    },
    Column {
        key: "crit_agg_min",
        label: "Critical path: aggregation (min)",
        samples: |a| &a.crit_agg_min,
    },
    Column {
        key: "crit_stale_min",
        label: "Critical path: staleness catch-up (min)",
        samples: |a| &a.crit_stale_min,
    },
];

impl CellAccumulator {
    /// Record one iteration's outcome (seconds are converted to minutes —
    /// the unit Tables II/III report).
    pub fn push(&mut self, m: &IterationMetrics) {
        if m.completed > 0 {
            self.time_per_microbatch_min.push(m.time_per_microbatch_s() / 60.0);
        }
        self.throughput.push(m.completed as f64);
        self.comm_time_min.push(m.comm_s / 60.0);
        self.wasted_gpu_min.push(m.wasted_gpu_s / 60.0);
        self.makespan_min.push(m.makespan_s / 60.0);
        self.fwd_recoveries.push(m.fwd_recoveries as f64);
        self.bwd_recoveries.push(m.bwd_recoveries as f64);
        self.agg_recoveries.push(m.agg_recoveries as f64);
        self.replan_rounds.push(m.replan_rounds as f64);
        self.plan_overlap_min.push(m.plan_overlap_s / 60.0);
        self.stale_replans.push(m.stale_replans as f64);
        self.queue_min.push(m.queue_s / 60.0);
        self.nic_util_max.push(m.nic_util_max);
        self.staleness_mean.push(m.staleness_mean);
        self.deferred.push(m.deferred as f64);
        self.denies.push(m.denies as f64);
        if m.makespan_s > 0.0 {
            self.events_per_s.push(m.events as f64 / m.makespan_s);
        }
        if m.peak_rss_mib > 0.0 {
            self.peak_rss_mib.push(m.peak_rss_mib);
        }
        self.crit_compute_min.push(m.crit_path.compute_s / 60.0);
        self.crit_tx_min.push(m.crit_path.tx_s / 60.0);
        self.crit_prop_min.push(m.crit_path.prop_s / 60.0);
        self.crit_queue_min.push(m.crit_path.queue_s / 60.0);
        self.crit_plan_min.push(m.crit_path.plan_s / 60.0);
        self.crit_agg_min.push(m.crit_path.agg_s / 60.0);
        self.crit_stale_min.push(m.crit_path.stale_s / 60.0);
    }

    pub fn row(&self) -> BTreeMap<&'static str, Summary> {
        COLUMNS.iter().map(|c| (c.key, Summary::of((c.samples)(self)))).collect()
    }
}

/// A named grid of result cells: (row label, column label) -> samples.
#[derive(Debug, Default)]
pub struct MetricsTable {
    pub title: String,
    pub cells: BTreeMap<(String, String), CellAccumulator>,
}

impl MetricsTable {
    pub fn new(title: impl Into<String>) -> Self {
        MetricsTable { title: title.into(), cells: BTreeMap::new() }
    }

    pub fn cell(&mut self, row: &str, col: &str) -> &mut CellAccumulator {
        self.cells.entry((row.to_string(), col.to_string())).or_default()
    }

    fn rows(&self) -> Vec<String> {
        let mut v: Vec<String> = self.cells.keys().map(|(r, _)| r.clone()).collect();
        v.dedup();
        v.sort();
        v.dedup();
        v
    }

    fn cols(&self) -> Vec<String> {
        let mut v: Vec<String> = self.cells.keys().map(|(_, c)| c.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Paper-style Markdown: one block per [`COLUMNS`] metric, systems
    /// as columns.
    pub fn to_markdown(&self) -> String {
        let rows = self.rows();
        let cols = self.cols();
        let mut s = format!("## {}\n\n", self.title);
        for Column { key, label, .. } in COLUMNS {
            s.push_str(&format!("### {label}\n\n| setting |"));
            for c in &cols {
                s.push_str(&format!(" {c} |"));
            }
            s.push_str("\n|---|");
            for _ in &cols {
                s.push_str("---|");
            }
            s.push('\n');
            for r in &rows {
                s.push_str(&format!("| {r} |"));
                for c in &cols {
                    match self.cells.get(&(r.clone(), c.clone())) {
                        Some(acc) => {
                            let summ = acc.row()[key];
                            s.push_str(&format!(" {} |", summ.pm(2)));
                        }
                        None => s.push_str(" - |"),
                    }
                }
                s.push('\n');
            }
            s.push('\n');
        }
        s
    }

    /// Flat CSV: one line per (row, col, metric).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("setting,system,metric,mean,std,n\n");
        for ((r, c), acc) in &self.cells {
            for (metric, summ) in acc.row() {
                s.push_str(&format!("{r},{c},{metric},{:.6},{:.6},{}\n", summ.mean, summ.std, summ.n));
            }
        }
        s
    }

    /// Write `<dir>/<name>.md` and `<dir>/<name>.csv`.
    pub fn write(&self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        let mut md = std::fs::File::create(dir.join(format!("{name}.md")))?;
        md.write_all(self.to_markdown().as_bytes())?;
        let mut csv = std::fs::File::create(dir.join(format!("{name}.csv")))?;
        csv.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }
}

/// Simple (x, y-series) plot data writer for the figure benches
/// (Fig. 5 improvements, Fig. 6 loss curves, Fig. 7 cost-per-round).
#[derive(Debug, Default)]
pub struct SeriesReport {
    pub title: String,
    pub x_label: String,
    /// series name -> (x, y) points
    pub series: BTreeMap<String, Vec<(f64, f64)>>,
}

impl SeriesReport {
    pub fn new(title: impl Into<String>, x_label: impl Into<String>) -> Self {
        SeriesReport { title: title.into(), x_label: x_label.into(), series: BTreeMap::new() }
    }

    pub fn push(&mut self, series: &str, x: f64, y: f64) {
        self.series.entry(series.to_string()).or_default().push((x, y));
    }

    pub fn to_csv(&self) -> String {
        let mut s = format!("series,{},y\n", self.x_label);
        for (name, pts) in &self.series {
            for (x, y) in pts {
                s.push_str(&format!("{name},{x},{y}\n"));
            }
        }
        s
    }

    /// ASCII rendering (final y per series, ranked) for terminal output.
    pub fn to_text(&self) -> String {
        let mut s = format!("# {}\n", self.title);
        let finals: Vec<(String, f64)> = self
            .series
            .iter()
            .filter_map(|(n, pts)| pts.last().map(|&(_, y)| (n.clone(), y)))
            .collect();
        let max = finals.iter().map(|&(_, y)| y.abs()).fold(1e-12, f64::max);
        for (name, y) in finals {
            let bars = ((y.abs() / max) * 40.0).round() as usize;
            s.push_str(&format!("{name:<24} {y:>12.4} {}\n", "#".repeat(bars)));
        }
        s
    }

    pub fn write(&self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{name}.csv")), self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric(completed: usize, makespan: f64) -> IterationMetrics {
        IterationMetrics {
            makespan_s: makespan,
            completed,
            scheduled: completed,
            comm_s: 10.0,
            wasted_gpu_s: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn accumulates_and_summarizes() {
        let mut t = MetricsTable::new("test");
        t.cell("homog 0%", "gwtf").push(&metric(8, 240.0));
        t.cell("homog 0%", "gwtf").push(&metric(8, 260.0));
        t.cell("homog 0%", "swarm").push(&metric(7, 300.0));
        let md = t.to_markdown();
        assert!(md.contains("Time per microbatch"));
        assert!(md.contains("gwtf"));
        assert!(md.contains("swarm"));
        let csv = t.to_csv();
        assert!(csv.lines().count() > 5);
        assert!(csv.contains("homog 0%,gwtf,throughput,8.0"));
    }

    #[test]
    fn markdown_and_csv_carry_recovery_and_replan_columns() {
        // ROADMAP item: agg_recoveries and warm-replan round counts must
        // show up in the Markdown report, not just the CSV.
        let mut t = MetricsTable::new("cols");
        let m = IterationMetrics {
            agg_recoveries: 2,
            replan_rounds: 7,
            plan_overlap_s: 180.0,
            stale_replans: 1,
            queue_s: 120.0,
            nic_util_max: 0.75,
            staleness_mean: 1.5,
            deferred: 3,
            denies: 5,
            ..metric(4, 100.0)
        };
        t.cell("poisson 10%", "gwtf").push(&m);
        let md = t.to_markdown();
        assert!(md.contains("Aggregation-barrier recoveries"), "{md}");
        assert!(md.contains("Flow re-plan rounds"), "{md}");
        assert!(md.contains("Plan overlap"), "{md}");
        assert!(md.contains("Stale re-plans"), "{md}");
        assert!(md.contains("NIC queueing time"), "{md}");
        assert!(md.contains("Peak NIC load"), "{md}");
        assert!(md.contains("Weight staleness"), "{md}");
        assert!(md.contains("Deferred microbatches"), "{md}");
        assert!(md.contains("Memory-overload DENYs"), "{md}");
        assert!(md.contains("1.50 ± 0.00"), "{md}");
        assert!(md.contains("0.75 ± 0.00"), "{md}");
        assert!(md.contains("2.00 ± 0.00"), "{md}");
        assert!(md.contains("7.00 ± 0.00"), "{md}");
        assert!(md.contains("3.00 ± 0.00"), "{md}"); // 180s overlap = 3 min
        let csv = t.to_csv();
        assert!(csv.contains("poisson 10%,gwtf,agg_recoveries,2.0"), "{csv}");
        assert!(csv.contains("poisson 10%,gwtf,replan_rounds,7.0"), "{csv}");
        assert!(csv.contains("poisson 10%,gwtf,plan_overlap_min,3.0"), "{csv}");
        assert!(csv.contains("poisson 10%,gwtf,stale_replans,1.0"), "{csv}");
        assert!(csv.contains("poisson 10%,gwtf,queue_min,2.0"), "{csv}"); // 120 s = 2 min
        assert!(csv.contains("poisson 10%,gwtf,nic_util_max,0.75"), "{csv}");
        assert!(csv.contains("poisson 10%,gwtf,staleness_mean,1.5"), "{csv}");
        assert!(csv.contains("poisson 10%,gwtf,deferred,3.0"), "{csv}");
        assert!(csv.contains("poisson 10%,gwtf,denies,5.0"), "{csv}");
    }

    #[test]
    fn columns_schema_covers_every_series_and_surface() {
        // Exhaustive destructuring: adding a CellAccumulator series
        // without registering it in COLUMNS (or vice versa) fails the
        // count below; two columns aliasing one series fail the pointer
        // set.  This is the writer/accumulator field-parity guard.
        let acc = CellAccumulator::default();
        let CellAccumulator {
            time_per_microbatch_min,
            throughput,
            comm_time_min,
            wasted_gpu_min,
            makespan_min,
            fwd_recoveries,
            bwd_recoveries,
            agg_recoveries,
            replan_rounds,
            plan_overlap_min,
            stale_replans,
            queue_min,
            nic_util_max,
            staleness_mean,
            deferred,
            denies,
            events_per_s,
            peak_rss_mib,
            crit_compute_min,
            crit_tx_min,
            crit_prop_min,
            crit_queue_min,
            crit_plan_min,
            crit_agg_min,
            crit_stale_min,
        } = &acc;
        let fields: Vec<*const Vec<f64>> = vec![
            time_per_microbatch_min,
            throughput,
            comm_time_min,
            wasted_gpu_min,
            makespan_min,
            fwd_recoveries,
            bwd_recoveries,
            agg_recoveries,
            replan_rounds,
            plan_overlap_min,
            stale_replans,
            queue_min,
            nic_util_max,
            staleness_mean,
            deferred,
            denies,
            events_per_s,
            peak_rss_mib,
            crit_compute_min,
            crit_tx_min,
            crit_prop_min,
            crit_queue_min,
            crit_plan_min,
            crit_agg_min,
            crit_stale_min,
        ]
        .into_iter()
        .map(|v| v as *const Vec<f64>)
        .collect();
        assert_eq!(COLUMNS.len(), fields.len(), "schema out of sync with the accumulator");
        let keys: std::collections::BTreeSet<&str> = COLUMNS.iter().map(|c| c.key).collect();
        assert_eq!(keys.len(), COLUMNS.len(), "duplicate column key");
        let series: std::collections::BTreeSet<*const Vec<f64>> =
            COLUMNS.iter().map(|c| (c.samples)(&acc) as *const Vec<f64>).collect();
        let field_set: std::collections::BTreeSet<*const Vec<f64>> =
            fields.into_iter().collect();
        assert_eq!(series, field_set, "columns must map 1:1 onto series");

        // Both writer surfaces carry every schema entry.
        let mut t = MetricsTable::new("parity");
        let m = IterationMetrics { events: 500, ..metric(4, 100.0) };
        t.cell("r", "sys").push(&m);
        let md = t.to_markdown();
        let csv = t.to_csv();
        for c in COLUMNS {
            assert!(md.contains(c.label), "markdown lost {}", c.key);
            assert!(csv.contains(&format!(",{},", c.key)), "csv lost {}", c.key);
        }
        // events/sec surfaces (satellite: IterationMetrics::events).
        assert!(csv.contains("r,sys,events_per_s,5.0"), "{csv}");
    }

    #[test]
    fn zero_completed_skips_time_metric() {
        let mut acc = CellAccumulator::default();
        acc.push(&metric(0, 100.0));
        assert!(acc.time_per_microbatch_min.is_empty());
        assert_eq!(acc.throughput, vec![0.0]);
    }

    #[test]
    fn series_csv_and_text() {
        let mut r = SeriesReport::new("fig", "round");
        r.push("gwtf", 1.0, 10.0);
        r.push("gwtf", 2.0, 8.0);
        r.push("swarm", 1.0, 12.0);
        let csv = r.to_csv();
        assert!(csv.starts_with("series,round,y"));
        assert!(csv.contains("gwtf,2,8"));
        let txt = r.to_text();
        assert!(txt.contains("gwtf"));
    }

    #[test]
    fn write_creates_files() {
        let dir = std::env::temp_dir().join("gwtf_metrics_test");
        let mut t = MetricsTable::new("t");
        t.cell("a", "b").push(&metric(1, 1.0));
        t.write(&dir, "unit").unwrap();
        assert!(dir.join("unit.md").exists());
        assert!(dir.join("unit.csv").exists());
    }
}
