//! Deterministic synthetic token corpus + batch iterator.

use crate::runtime::HostTensor;
use crate::util::Rng;

/// Parameters of the synthetic language.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub vocab_size: usize,
    /// Zipf exponent for the unigram distribution (natural text ≈ 1.0).
    pub zipf_s: f64,
    /// Number of Markov states shaping local structure.
    pub n_states: usize,
    /// Tokens in the generated corpus.
    pub length: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { vocab_size: 2048, zipf_s: 1.0, n_states: 64, length: 1 << 18, seed: 0 }
    }
}

/// The generated corpus: a flat token stream.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    pub tokens: Vec<i32>,
    pub vocab_size: usize,
}

impl SyntheticCorpus {
    /// Generate deterministically from the config.
    ///
    /// Construction: an order-2 Markov chain over `n_states` hidden states;
    /// each state owns a Zipf-sampled emission table over a slice of the
    /// vocabulary.  This yields text-like statistics: a handful of
    /// very-frequent tokens, a long tail, and predictable local context —
    /// enough signal for cross-entropy to fall well below `ln(V)`.
    pub fn generate(cfg: &CorpusConfig) -> SyntheticCorpus {
        assert!(cfg.vocab_size >= 4 && cfg.n_states >= 1);
        let mut rng = Rng::new(cfg.seed);

        // Zipf CDF over the vocabulary (shared shape; per-state permutation).
        let weights: Vec<f64> = (1..=cfg.vocab_size).map(|r| 1.0 / (r as f64).powf(cfg.zipf_s)).collect();
        let total: f64 = weights.iter().sum();
        let mut cdf = Vec::with_capacity(cfg.vocab_size);
        let mut acc = 0.0;
        for w in &weights {
            acc += w / total;
            cdf.push(acc);
        }

        // Each state: a vocabulary permutation (its "topic") + transitions.
        let mut state_perm: Vec<Vec<i32>> = Vec::with_capacity(cfg.n_states);
        let mut trans: Vec<Vec<usize>> = Vec::with_capacity(cfg.n_states);
        for _ in 0..cfg.n_states {
            let mut perm: Vec<i32> = (0..cfg.vocab_size as i32).collect();
            rng.shuffle(&mut perm);
            state_perm.push(perm);
            // sparse transitions: each state can reach 4 successors
            let succ: Vec<usize> = (0..4).map(|_| rng.index(cfg.n_states)).collect();
            trans.push(succ);
        }

        let sample_zipf = |rng: &mut Rng, cdf: &[f64]| -> usize {
            let u = rng.f64();
            match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(i) => i,
                Err(i) => i.min(cdf.len() - 1),
            }
        };

        let mut tokens = Vec::with_capacity(cfg.length);
        let mut state = 0usize;
        for _ in 0..cfg.length {
            let rank = sample_zipf(&mut rng, &cdf);
            tokens.push(state_perm[state][rank]);
            state = trans[state][rng.index(4)];
        }
        SyntheticCorpus { tokens, vocab_size: cfg.vocab_size }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// One microbatch: tokens + next-token targets, both (B, S) i32.
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub tokens: HostTensor,
    pub targets: HostTensor,
}

/// Sequential batch iterator with wraparound (epoch boundary ignored, as
/// in standard LM training on a token stream).
#[derive(Debug, Clone)]
pub struct BatchIterator {
    corpus: SyntheticCorpus,
    pub microbatch: usize,
    pub seq_len: usize,
    cursor: usize,
}

impl BatchIterator {
    pub fn new(corpus: SyntheticCorpus, microbatch: usize, seq_len: usize) -> Self {
        assert!(corpus.len() > microbatch * (seq_len + 1), "corpus too small");
        BatchIterator { corpus, microbatch, seq_len, cursor: 0 }
    }

    /// Next microbatch (deterministic sequence).
    pub fn next_batch(&mut self) -> TokenBatch {
        let (b, s) = (self.microbatch, self.seq_len);
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        for _ in 0..b {
            if self.cursor + s + 1 > self.corpus.len() {
                self.cursor = 0;
            }
            let window = &self.corpus.tokens[self.cursor..self.cursor + s + 1];
            tokens.extend_from_slice(&window[..s]);
            targets.extend_from_slice(&window[1..]);
            self.cursor += s;
        }
        TokenBatch {
            tokens: HostTensor::i32(vec![b, s], tokens),
            targets: HostTensor::i32(vec![b, s], targets),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CorpusConfig {
        CorpusConfig { vocab_size: 64, n_states: 8, length: 4096, seed: 3, ..Default::default() }
    }

    #[test]
    fn deterministic_generation() {
        let a = SyntheticCorpus::generate(&small());
        let b = SyntheticCorpus::generate(&small());
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.len(), 4096);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = SyntheticCorpus::generate(&small());
        assert!(c.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn zipf_skew_present() {
        // the most frequent token should dominate the median one
        let c = SyntheticCorpus::generate(&CorpusConfig { length: 1 << 16, ..small() });
        let mut counts = vec![0usize; 64];
        for &t in &c.tokens {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        assert!(counts[0] > counts[31] * 3, "top {} vs median {}", counts[0], counts[31]);
    }

    #[test]
    fn batches_shift_targets_by_one() {
        let c = SyntheticCorpus::generate(&small());
        let mut it = BatchIterator::new(c.clone(), 2, 16);
        let b = it.next_batch();
        assert_eq!(b.tokens.shape(), &[2, 16]);
        assert_eq!(b.targets.shape(), &[2, 16]);
        let toks = b.tokens.as_i32().unwrap();
        let tgts = b.targets.as_i32().unwrap();
        // within the first row, target[i] == token[i+1]
        for i in 0..15 {
            assert_eq!(tgts[i], toks[i + 1]);
        }
        // and the first row matches the corpus head
        assert_eq!(&toks[..16], &c.tokens[..16]);
    }

    #[test]
    fn iterator_wraps_around() {
        let c = SyntheticCorpus::generate(&CorpusConfig { length: 200, ..small() });
        let mut it = BatchIterator::new(c, 1, 32);
        for _ in 0..20 {
            let b = it.next_batch();
            assert_eq!(b.tokens.len(), 32);
        }
    }

    #[test]
    fn batches_advance() {
        let c = SyntheticCorpus::generate(&small());
        let mut it = BatchIterator::new(c, 2, 16);
        let a = it.next_batch();
        let b = it.next_batch();
        assert_ne!(a.tokens.as_i32().unwrap(), b.tokens.as_i32().unwrap());
    }
}
