//! Training data substrate.
//!
//! The paper's convergence experiment (Fig. 6) trains on the Wikipedia
//! English dump; that corpus is not available offline, so we substitute a
//! deterministic synthetic language with the statistical structure an LM
//! actually learns from text (DESIGN.md §Substitutions): Zipf-distributed
//! unigrams shaped by an order-2 Markov chain, so both unigram frequency
//! and local n-gram structure are learnable signals.  Both the GWTF run
//! and the centralized baseline read the identical token stream, which is
//! what the Fig. 6 claim needs.

pub mod corpus;

pub use corpus::{BatchIterator, CorpusConfig, SyntheticCorpus, TokenBatch};
