//! The pipeline trainer: real stage computation through PJRT.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::{BatchIterator, CorpusConfig, SyntheticCorpus, TokenBatch};
use crate::flow::FlowParams;
use crate::runtime::{
    BlockStage, DataNodeModel, GradAccumulator, HostTensor, Manifest, Runtime,
};
use crate::coordinator::GwtfRouter;
use crate::sim::scenario::{build, Scenario, ScenarioConfig};
use crate::sim::training::TrainingSim;
use crate::sim::IterationMetrics;
use crate::util::Rng;

/// One optimizer step's outcome.
#[derive(Debug, Clone, Copy)]
pub struct StepMetrics {
    pub step: usize,
    /// Mean cross-entropy over the step's microbatches.
    pub loss: f64,
    /// Microbatches contributing to the update.
    pub microbatches: usize,
    /// Simulated iteration makespan, seconds (0 for the centralized run).
    pub sim_makespan_s: f64,
    /// Simulated recoveries this iteration.
    pub fwd_recoveries: usize,
    pub bwd_recoveries: usize,
    /// Extra (recomputed) stage forwards charged by crash repairs.
    pub recomputed_forwards: usize,
}

/// Real pipelined training: one parameter replica per stage, gradient
/// averaging over microbatches (the DP aggregation-phase math).
pub struct PipelineTrainer {
    pub rt: Arc<Runtime>,
    pub data_node: DataNodeModel,
    pub stages: Vec<BlockStage>,
    pub batches: BatchIterator,
    pub lr: f32,
    pub microbatches_per_step: usize,
    step: usize,
}

impl PipelineTrainer {
    /// Build from the artifacts directory: loads + compiles the family's
    /// stage functions, initializes parameters from `seed`, generates the
    /// synthetic corpus.
    pub fn new(
        artifacts_dir: impl AsRef<Path>,
        family: &str,
        seed: u64,
        lr: f32,
        microbatches_per_step: usize,
    ) -> Result<PipelineTrainer> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let fam = manifest.family(family)?.clone();
        let cfg = &fam.config;
        let rt = Arc::new(Runtime::cpu()?);

        let data_node = DataNodeModel::init(rt.clone(), &fam, seed as u32)
            .context("initializing embed/head params")?;
        let mut stages = Vec::with_capacity(cfg.n_stages);
        for s in 0..cfg.n_stages {
            stages.push(
                BlockStage::init(rt.clone(), &fam, s, seed as u32 + 1 + s as u32)
                    .with_context(|| format!("initializing stage {s}"))?,
            );
        }

        let corpus = SyntheticCorpus::generate(&CorpusConfig {
            vocab_size: cfg.vocab_size,
            length: 1 << 17,
            seed: seed ^ 0xDA7A,
            ..Default::default()
        });
        let batches = BatchIterator::new(corpus, cfg.microbatch, cfg.seq_len);

        Ok(PipelineTrainer { rt, data_node, stages, batches, lr, microbatches_per_step, step: 0 })
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Forward + backward for one microbatch; returns (loss, grads per
    /// stage, embed grads, head grads).
    #[allow(clippy::type_complexity)]
    fn microbatch_pass(
        &mut self,
        batch: &TokenBatch,
    ) -> Result<(f64, Vec<crate::runtime::Leaves>, crate::runtime::Leaves, crate::runtime::Leaves)>
    {
        // --- forward ---
        let mut acts: Vec<HostTensor> = Vec::with_capacity(self.stages.len() + 1);
        let x0 = self.data_node.embed(&batch.tokens)?;
        acts.push(x0);
        for s in 0..self.stages.len() {
            let y = self.stages[s].forward(&acts[s])?;
            acts.push(y);
        }
        // --- head backward (loss + dx) ---
        let (head_grads, mut dy, loss) =
            self.data_node.head_backward(acts.last().unwrap(), &batch.targets)?;
        // --- relay backward chain (reverse order, rematerializing) ---
        let mut stage_grads: Vec<crate::runtime::Leaves> = vec![Vec::new(); self.stages.len()];
        for s in (0..self.stages.len()).rev() {
            let (gs, dx) = self.stages[s].backward(&acts[s], &dy)?;
            stage_grads[s] = gs;
            dy = dx;
        }
        // --- embedding backward ---
        let embed_grads = self.data_node.embed_backward(&batch.tokens, &dy)?;
        Ok((loss as f64, stage_grads, embed_grads, head_grads))
    }

    /// One optimizer step: `microbatches_per_step` passes, averaged grads,
    /// SGD update on every stage (the §V-E aggregation + update phases).
    pub fn step(&mut self) -> Result<StepMetrics> {
        let batches: Vec<TokenBatch> =
            (0..self.microbatches_per_step).map(|_| self.batches.next_batch()).collect();
        self.step_on(&batches)
    }

    /// One optimizer step on a caller-provided microbatch set (used by the
    /// overfit tests and by drivers that replay a fixed schedule).
    pub fn step_on(&mut self, batches: &[TokenBatch]) -> Result<StepMetrics> {
        let mut stage_acc: Vec<GradAccumulator> =
            (0..self.stages.len()).map(|_| GradAccumulator::new()).collect();
        let mut embed_acc = GradAccumulator::new();
        let mut head_acc = GradAccumulator::new();
        let mut loss_sum = 0.0;
        for batch in batches {
            let (loss, sg, eg, hg) = self.microbatch_pass(batch)?;
            loss_sum += loss;
            for (acc, g) in stage_acc.iter_mut().zip(sg) {
                acc.add(g)?;
            }
            embed_acc.add(eg)?;
            head_acc.add(hg)?;
        }
        // aggregation phase: average, then update phase
        for (s, acc) in stage_acc.iter_mut().enumerate() {
            let g = acc.take_mean()?;
            self.stages[s].update(&g, self.lr)?;
        }
        self.data_node.update_embed(&embed_acc.take_mean()?, self.lr)?;
        self.data_node.update_head(&head_acc.take_mean()?, self.lr)?;
        self.step += 1;
        Ok(StepMetrics {
            step: self.step,
            loss: loss_sum / batches.len().max(1) as f64,
            microbatches: batches.len(),
            sim_makespan_s: 0.0,
            fwd_recoveries: 0,
            bwd_recoveries: 0,
            recomputed_forwards: 0,
        })
    }

    /// Held-out loss on the next batch without updating parameters.
    pub fn eval_loss(&mut self) -> Result<f64> {
        let batch = self.batches.next_batch();
        let mut x = self.data_node.embed(&batch.tokens)?;
        for s in 0..self.stages.len() {
            x = self.stages[s].forward(&x)?;
        }
        Ok(self.data_node.loss(&x, &batch.targets)? as f64)
    }
}

/// GWTF-under-churn training: the same numerics as [`PipelineTrainer`]
/// plus one simulated decentralized iteration per step.
pub struct ChurnTrainer {
    pub trainer: PipelineTrainer,
    pub scenario: Scenario,
    sim: TrainingSim,
    router: GwtfRouter,
    rng: Rng,
}

impl ChurnTrainer {
    pub fn new(trainer: PipelineTrainer, scenario_cfg: &ScenarioConfig) -> ChurnTrainer {
        let scenario = build(scenario_cfg);
        let sim = TrainingSim::new(scenario.topo.clone(), scenario.sim_cfg);
        let router =
            GwtfRouter::from_scenario(&scenario, FlowParams::default(), scenario_cfg.seed ^ 0xF1);
        let rng = Rng::new(scenario_cfg.seed ^ 0x51);
        ChurnTrainer { trainer, scenario, sim, router, rng }
    }

    /// One training step + one simulated iteration.
    ///
    /// Backward-pass repairs recompute the crashed stage's forward from the
    /// stored upstream activation (§V-D); we charge that by *actually*
    /// re-executing a stage forward per repair, so wall-clock and runtime
    /// stats reflect the recovery work while the update math is untouched.
    pub fn step(&mut self) -> Result<StepMetrics> {
        // Simulate iterations until the batch gets through: an iteration
        // that completes nothing (a fully-dead stage) defers the batch to
        // the next iteration (SV-D DENY), costing wall time but never
        // changing the update math.
        let mut sim_total = IterationMetrics::default();
        for _attempt in 0..64 {
            let churn = self.scenario.churn.sample_iteration();
            let alive = self.scenario.churn.planning_view(&churn);
            let (paths, planning_s) = self.router.plan(&alive);
            let m: IterationMetrics = self.sim.run_iteration(
                &self.scenario.prob,
                &mut self.router,
                &churn,
                &self.scenario.churn,
                planning_s,
                paths,
                &mut self.rng,
            );
            sim_total.makespan_s += m.makespan_s;
            sim_total.fwd_recoveries += m.fwd_recoveries;
            sim_total.bwd_recoveries += m.bwd_recoveries;
            sim_total.completed += m.completed;
            if m.completed > 0 {
                break;
            }
        }

        let mut m = self.trainer.step()?;
        m.sim_makespan_s = sim_total.makespan_s;
        m.fwd_recoveries = sim_total.fwd_recoveries;
        m.bwd_recoveries = sim_total.bwd_recoveries;

        // Charge the recomputed forwards for backward-path repairs.  Use a
        // detached batch cursor: wasted work must not advance the training
        // data stream (the centralized baseline sees the same batches).
        let n_stages = self.trainer.n_stages();
        if sim_total.bwd_recoveries > 0 {
            let mut scratch = self.trainer.batches.clone();
            for r in 0..sim_total.bwd_recoveries {
                let s = r % n_stages;
                let batch = scratch.next_batch();
                let x = self.trainer.data_node.embed(&batch.tokens)?;
                let _ = self.trainer.stages[s].forward(&x)?;
                m.recomputed_forwards += 1;
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/runtime_integration.rs (they
    // need `make artifacts`); this module only hosts pure logic tests.
    use super::*;

    #[test]
    fn step_metrics_shape() {
        let m = StepMetrics {
            step: 1,
            loss: 2.0,
            microbatches: 4,
            sim_makespan_s: 0.0,
            fwd_recoveries: 0,
            bwd_recoveries: 0,
            recomputed_forwards: 0,
        };
        assert_eq!(m.step, 1);
        assert!(m.loss > 0.0);
    }
}
