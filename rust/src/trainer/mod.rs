//! End-to-end training over the PJRT runtime (the Fig. 6 experiment).
//!
//! - [`PipelineTrainer`] — drives the real pipeline math: embed -> relay
//!   stages -> head/loss -> backward chain -> gradient averaging -> SGD,
//!   entirely through the AOT artifacts (Python never runs here).
//! - [`ChurnTrainer`] — couples a `PipelineTrainer` with the decentralized
//!   simulator: every optimizer step also executes one *simulated* GWTF
//!   iteration (routing, churn, recovery) and charges the recomputed
//!   stage forwards that backward-pass repairs require.  Because GWTF
//!   always executes the full model ("the entire model is ran as in a
//!   centralized solution", §VI Training Convergence), the loss sequence
//!   is bit-identical to the centralized baseline — the experiment
//!   verifies exactly that, plus the simulated iteration times.

pub mod pipeline;

pub use pipeline::{ChurnTrainer, PipelineTrainer, StepMetrics};
