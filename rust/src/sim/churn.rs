//! Node churn process (paper §VI Node Crashes).
//!
//! "Join-leave chance varies from 0% (no churn) to 10%/20% (nodes may
//! randomly crash or rejoin each iteration)."  Each relay node flips a
//! Bernoulli coin per iteration: an alive node crashes at a uniform random
//! instant of the iteration; a dead node rejoins at iteration start (after
//! re-downloading its stage weights — accounted by the coordinator).
//! Data nodes are persistent, as in the paper.

use crate::cost::NodeId;
use crate::util::Rng;

/// One iteration's churn events.
#[derive(Debug, Clone, Default)]
pub struct ChurnEvents {
    /// (node, fraction of the iteration at which it dies in [0,1)).
    pub crashes: Vec<(NodeId, f64)>,
    /// Nodes rejoining at the start of this iteration.
    pub rejoins: Vec<NodeId>,
}

/// Per-iteration Bernoulli churn over the relay population.
#[derive(Debug, Clone)]
pub struct ChurnProcess {
    /// Join-leave probability per node per iteration (the paper's 0/10/20%).
    pub p: f64,
    /// Current liveness per node id.
    pub alive: Vec<bool>,
    /// Relay nodes subject to churn (data nodes are persistent).
    pub relays: Vec<NodeId>,
    rng: Rng,
}

impl ChurnProcess {
    pub fn new(n_nodes: usize, relays: Vec<NodeId>, p: f64, seed: u64) -> Self {
        ChurnProcess { p, alive: vec![true; n_nodes], relays, rng: Rng::new(seed) }
    }

    pub fn is_alive(&self, n: NodeId) -> bool {
        self.alive[n.0]
    }

    pub fn alive_count(&self) -> usize {
        self.relays.iter().filter(|&&r| self.alive[r.0]).count()
    }

    /// Liveness as seen by the router at iteration start: nodes crashing
    /// *during* `ev` are still up when flows are planned (the simulator
    /// kills them mid-iteration at their sampled instant) — without this,
    /// planners would be clairvoyant about future crashes.
    pub fn planning_view(&self, ev: &ChurnEvents) -> Vec<bool> {
        let mut alive = self.alive.clone();
        for &(n, _) in &ev.crashes {
            alive[n.0] = true;
        }
        alive
    }

    /// Sample one iteration of churn and apply it to the liveness state.
    pub fn sample_iteration(&mut self) -> ChurnEvents {
        let mut ev = ChurnEvents::default();
        for &r in &self.relays.clone() {
            if !self.rng.chance(self.p) {
                continue;
            }
            if self.alive[r.0] {
                // Keep at least one alive node per stage is the caller's
                // concern (the paper assumes one node per stage survives);
                // we crash unconditionally and let recovery handle it.
                self.alive[r.0] = false;
                ev.crashes.push((r, self.rng.f64()));
            } else {
                self.alive[r.0] = true;
                ev.rejoins.push(r);
            }
        }
        ev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn relays(n: usize) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn zero_churn_never_crashes() {
        let mut c = ChurnProcess::new(10, relays(10), 0.0, 1);
        for _ in 0..100 {
            let ev = c.sample_iteration();
            assert!(ev.crashes.is_empty() && ev.rejoins.is_empty());
        }
        assert_eq!(c.alive_count(), 10);
    }

    #[test]
    fn crash_rate_matches_probability() {
        let mut c = ChurnProcess::new(1000, relays(1000), 0.1, 2);
        let ev = c.sample_iteration();
        let flips = ev.crashes.len() + ev.rejoins.len();
        assert!((50..=150).contains(&flips), "{flips}");
    }

    #[test]
    fn crashed_nodes_can_rejoin() {
        let mut c = ChurnProcess::new(50, relays(50), 0.5, 3);
        let mut saw_rejoin = false;
        for _ in 0..20 {
            let ev = c.sample_iteration();
            saw_rejoin |= !ev.rejoins.is_empty();
            for (n, frac) in &ev.crashes {
                assert!(!c.is_alive(*n));
                assert!((0.0..1.0).contains(frac));
            }
            for n in &ev.rejoins {
                assert!(c.is_alive(*n));
            }
        }
        assert!(saw_rejoin);
    }

    #[test]
    fn deterministic_with_seed() {
        let mut a = ChurnProcess::new(20, relays(20), 0.3, 7);
        let mut b = ChurnProcess::new(20, relays(20), 0.3, 7);
        for _ in 0..10 {
            let ea = a.sample_iteration();
            let eb = b.sample_iteration();
            assert_eq!(ea.crashes.len(), eb.crashes.len());
            assert_eq!(ea.rejoins, eb.rejoins);
        }
    }
}
