//! Node churn models (paper §VI Node Crashes) and the liveness authority.
//!
//! "Join-leave chance varies from 0% (no churn) to 10%/20% (nodes may
//! randomly crash or rejoin each iteration)."  Two models implement that
//! stress, selected by [`ChurnModel`]:
//!
//! - [`ChurnModel::Bernoulli`] — the paper's literal reading and the
//!   legacy default: each relay flips a coin per iteration; an alive node
//!   crashes at a uniform random instant of the iteration, a dead node
//!   rejoins at iteration start.  Kept bit-for-bit identical to the
//!   pre-engine simulator (the parity tests in `sim::engine` and
//!   `rust/tests/churn_stats.rs` assert it).
//! - [`ChurnModel::Poisson`] — the continuous-clock refinement: each
//!   relay's crash/rejoin transitions arrive from exponential
//!   inter-arrival clocks ([`super::churn_process::PoissonChurn`]) whose
//!   residuals carry across iteration boundaries.  Rate mapping: a legacy
//!   join-leave chance `p` becomes a hazard of `p` expected transitions
//!   per relay-iteration, so the 0%/10%/20% configs keep their expected
//!   churn per iteration (see the `churn_process` module docs for the
//!   induced per-iteration transition and net-flip probabilities).
//!   Crashes land
//!   mid-iteration; rejoins surface as planner-invisible mid-iteration
//!   `joins` that recovery can route onto immediately and that become
//!   full membership the next iteration.
//!
//! Either way, [`ChurnProcess`] is the *liveness authority*: it owns the
//! `alive` vector the planner, the aggregation barrier and the recovery
//! paths consult.  It feeds the engine through the standard
//! [`EventSource`] contract — churn is just another world-event source on
//! the continuous timeline (see `Engine::step` for why it is sampled
//! before planning).  Data nodes are persistent, as in the paper.

use crate::cost::NodeId;
use crate::util::Rng;

use super::churn_process::PoissonChurn;
use super::engine::{EventSource, WorldSchedule};
use super::events::Time;

/// Which churn model drives crash/rejoin sampling (module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChurnModel {
    /// Per-iteration Bernoulli coin (legacy, bit-for-bit stable).
    #[default]
    Bernoulli,
    /// Continuous-clock exponential inter-arrival process.
    Poisson,
}

/// One iteration's churn events in the legacy fraction-based form
/// (Bernoulli only; the engine path speaks [`WorldSchedule`] instead).
#[derive(Debug, Clone, Default)]
pub struct ChurnEvents {
    /// (node, fraction of the iteration at which it dies in [0,1)).
    pub crashes: Vec<(NodeId, f64)>,
    /// Nodes rejoining at the start of this iteration.
    pub rejoins: Vec<NodeId>,
}

/// Churn sampling + liveness authority over the relay population.
#[derive(Debug, Clone)]
pub struct ChurnProcess {
    /// Join-leave probability per node per iteration (the paper's 0/10/20%);
    /// under [`ChurnModel::Poisson`] the equivalent per-iteration hazard.
    pub p: f64,
    /// Sampling model (rate-equivalent; module docs).
    pub model: ChurnModel,
    /// Current liveness per node id.
    pub alive: Vec<bool>,
    /// Relay nodes subject to churn (data nodes are persistent).
    pub relays: Vec<NodeId>,
    rng: Rng,
    /// Continuous-clock state (Poisson model only).
    poisson: Option<PoissonChurn>,
}

impl ChurnProcess {
    /// Legacy constructor: Bernoulli model.
    pub fn new(n_nodes: usize, relays: Vec<NodeId>, p: f64, seed: u64) -> Self {
        Self::with_model(ChurnModel::Bernoulli, n_nodes, relays, p, seed)
    }

    pub fn with_model(
        model: ChurnModel,
        n_nodes: usize,
        relays: Vec<NodeId>,
        p: f64,
        seed: u64,
    ) -> Self {
        let poisson = match model {
            ChurnModel::Bernoulli => None,
            ChurnModel::Poisson => Some(PoissonChurn::new(
                relays.clone(),
                PoissonChurn::rate_for_chance(p),
                seed ^ 0x5019_55C1,
            )),
        };
        ChurnProcess { p, model, alive: vec![true; n_nodes], relays, rng: Rng::new(seed), poisson }
    }

    pub fn is_alive(&self, n: NodeId) -> bool {
        self.alive[n.0]
    }

    pub fn alive_count(&self) -> usize {
        self.relays.iter().filter(|&&r| self.alive[r.0]).count()
    }

    /// The planner-clairvoyance rule shared by both planning views:
    /// nodes crashing *during* the iteration are still up when flows are
    /// planned (the simulator kills them mid-iteration at their sampled
    /// instant) — without this, planners would foresee future crashes.
    fn view_resurrecting(&self, crashing: impl Iterator<Item = NodeId>) -> Vec<bool> {
        let mut alive = self.alive.clone();
        for n in crashing {
            alive[n.0] = true;
        }
        alive
    }

    /// Liveness as seen by the router at iteration start (legacy
    /// [`ChurnEvents`] form).
    pub fn planning_view(&self, ev: &ChurnEvents) -> Vec<bool> {
        self.view_resurrecting(ev.crashes.iter().map(|&(n, _)| n))
    }

    /// [`ChurnProcess::planning_view`] over an engine [`WorldSchedule`]:
    /// crash targets die mid-iteration so the planner still sees them up;
    /// mid-iteration `joins` stay invisible until the next iteration.
    pub fn planning_view_for(&self, sched: &WorldSchedule) -> Vec<bool> {
        self.view_resurrecting(sched.crashes.iter().map(|&(n, _)| n))
    }

    /// Sample one iteration of Bernoulli churn and apply it to the
    /// liveness state.  Legacy fraction-based entry point, kept for the
    /// pre-engine `TrainingSim::run_iteration` path, the benches and the
    /// bit-for-bit parity tests; the engine consumes the same draws
    /// through [`EventSource::sample`].
    pub fn sample_iteration(&mut self) -> ChurnEvents {
        assert!(
            self.model == ChurnModel::Bernoulli,
            "sample_iteration is the legacy Bernoulli API; \
             the Poisson model only speaks EventSource::sample"
        );
        let mut ev = ChurnEvents::default();
        for &r in &self.relays.clone() {
            if !self.rng.chance(self.p) {
                continue;
            }
            if self.alive[r.0] {
                // Keep at least one alive node per stage is the caller's
                // concern (the paper assumes one node per stage survives);
                // we crash unconditionally and let recovery handle it.
                self.alive[r.0] = false;
                ev.crashes.push((r, self.rng.f64()));
            } else {
                self.alive[r.0] = true;
                ev.rejoins.push(r);
            }
        }
        ev
    }

    /// Poisson-model sampling: advance the continuous clocks one
    /// iteration and collapse each relay's transitions to the engine's
    /// one-liveness-window-per-iteration representation.  The net state
    /// change is decided by transition parity; the *first* transition
    /// supplies the instant.  An even transition count (a within-iteration
    /// blip: crash-and-rejoin or rejoin-and-crash) is invisible at
    /// iteration granularity and is dropped — the raw stream stays exact
    /// (`churn_process` statistical tests), only the window projection
    /// coarsens.
    fn sample_poisson(&mut self, horizon: Time) -> WorldSchedule {
        let process = self.poisson.as_mut().expect("poisson model state");
        // Other event sources may have killed or revived relays since the
        // last sample (the engine applies their crashes/joins to the
        // authority post-iteration); adopt the authoritative state so the
        // next transition of an externally-killed relay is a rejoin.
        process.sync_liveness(&self.alive);
        let transitions = process.advance_iteration();
        let mut sched = WorldSchedule::default();
        // Transitions arrive grouped per relay (advance_iteration visits
        // relays in order), so one pass over runs suffices.
        let mut i = 0;
        while i < transitions.len() {
            let node = transitions[i].node;
            let first = transitions[i];
            let mut j = i;
            while j < transitions.len() && transitions[j].node == node {
                j += 1;
            }
            if (j - i) % 2 == 1 {
                if first.crash {
                    debug_assert!(self.alive[node.0], "crash transition on a dead node");
                    self.alive[node.0] = false;
                    sched.crashes.push((node, first.at * horizon));
                } else {
                    // Mid-iteration rejoin: recovery may route onto it from
                    // its instant; the engine promotes it to full membership
                    // after the iteration (planner-invisible now).
                    debug_assert!(!self.alive[node.0], "rejoin transition on an alive node");
                    sched.joins.push((node, first.at * horizon));
                }
            }
            i = j;
        }
        sched
    }
}

impl EventSource for ChurnProcess {
    fn name(&self) -> &str {
        match self.model {
            ChurnModel::Bernoulli => "bernoulli-churn",
            ChurnModel::Poisson => "poisson-churn",
        }
    }

    /// One iteration of churn as a [`WorldSchedule`], instants on the
    /// absolute virtual timeline (`horizon` is the iteration-length
    /// reference, exactly as for every other source).
    fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
        match self.model {
            ChurnModel::Bernoulli => {
                let ev = self.sample_iteration();
                WorldSchedule {
                    crashes: ev.crashes.into_iter().map(|(n, frac)| (n, frac * horizon)).collect(),
                    rejoins: ev.rejoins,
                    ..Default::default()
                }
            }
            ChurnModel::Poisson => self.sample_poisson(horizon),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn relays(n: usize) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn zero_churn_never_crashes() {
        let mut c = ChurnProcess::new(10, relays(10), 0.0, 1);
        for _ in 0..100 {
            let ev = c.sample_iteration();
            assert!(ev.crashes.is_empty() && ev.rejoins.is_empty());
        }
        assert_eq!(c.alive_count(), 10);
    }

    #[test]
    fn crash_rate_matches_probability() {
        let mut c = ChurnProcess::new(1000, relays(1000), 0.1, 2);
        let ev = c.sample_iteration();
        let flips = ev.crashes.len() + ev.rejoins.len();
        assert!((50..=150).contains(&flips), "{flips}");
    }

    #[test]
    fn crashed_nodes_can_rejoin() {
        let mut c = ChurnProcess::new(50, relays(50), 0.5, 3);
        let mut saw_rejoin = false;
        for _ in 0..20 {
            let ev = c.sample_iteration();
            saw_rejoin |= !ev.rejoins.is_empty();
            for (n, frac) in &ev.crashes {
                assert!(!c.is_alive(*n));
                assert!((0.0..1.0).contains(frac));
            }
            for n in &ev.rejoins {
                assert!(c.is_alive(*n));
            }
        }
        assert!(saw_rejoin);
    }

    #[test]
    fn deterministic_with_seed() {
        let mut a = ChurnProcess::new(20, relays(20), 0.3, 7);
        let mut b = ChurnProcess::new(20, relays(20), 0.3, 7);
        for _ in 0..10 {
            let ea = a.sample_iteration();
            let eb = b.sample_iteration();
            assert_eq!(ea.crashes.len(), eb.crashes.len());
            assert_eq!(ea.rejoins, eb.rejoins);
        }
    }

    #[test]
    fn bernoulli_event_source_scales_fractions_by_horizon() {
        // The EventSource view must consume the exact same RNG draws as
        // the legacy sample_iteration and place each crash at
        // frac * horizon.
        let horizon = 240.0;
        let mut legacy = ChurnProcess::new(30, relays(30), 0.4, 12);
        let mut source = ChurnProcess::new(30, relays(30), 0.4, 12);
        for iter in 0..8 {
            let ev = legacy.sample_iteration();
            let sched = EventSource::sample(&mut source, iter, horizon);
            assert_eq!(sched.rejoins, ev.rejoins);
            assert_eq!(sched.crashes.len(), ev.crashes.len());
            for (&(n, t), &(m, frac)) in sched.crashes.iter().zip(&ev.crashes) {
                assert_eq!(n, m);
                assert_eq!(t.to_bits(), (frac * horizon).to_bits());
            }
            assert_eq!(legacy.alive, source.alive);
            assert!(sched.joins.is_empty() && sched.agg_crashes.is_empty());
        }
    }

    #[test]
    fn poisson_schedule_respects_liveness_windows() {
        let mut c = ChurnProcess::with_model(ChurnModel::Poisson, 12, relays(12), 0.8, 5);
        let horizon = 100.0;
        let mut saw_crash = false;
        let mut saw_join = false;
        for iter in 0..60 {
            let before = c.alive.clone();
            let sched = EventSource::sample(&mut c, iter, horizon);
            assert!(sched.rejoins.is_empty(), "poisson rejoins are timestamped joins");
            for &(n, t) in &sched.crashes {
                saw_crash = true;
                assert!(before[n.0], "crash must target a node alive at iteration start");
                assert!(!c.alive[n.0], "authority updated at sample time");
                assert!(t.is_finite() && (0.0..horizon).contains(&t), "{t}");
            }
            for &(n, t) in &sched.joins {
                saw_join = true;
                assert!(!before[n.0], "join must target a node dead at iteration start");
                assert!(!c.alive[n.0], "joins apply only after the iteration");
                assert!(t.is_finite() && (0.0..horizon).contains(&t), "{t}");
            }
            // What the engine does after the iteration.
            for &(n, _) in &sched.joins {
                c.alive[n.0] = true;
            }
        }
        assert!(saw_crash, "rate 0.8 over 12x60 node-iterations must crash someone");
        assert!(saw_join, "…and someone must come back");
    }

    #[test]
    fn poisson_planning_view_resurrects_crash_targets_only() {
        let mut c = ChurnProcess::with_model(ChurnModel::Poisson, 8, relays(8), 1.2, 9);
        for iter in 0..40 {
            let sched = EventSource::sample(&mut c, iter, 50.0);
            let view = c.planning_view_for(&sched);
            for &(n, _) in &sched.crashes {
                assert!(view[n.0], "planner must still see the crashing node as up");
            }
            for &(n, _) in &sched.joins {
                assert!(!view[n.0], "mid-iteration joiners stay planner-invisible");
            }
            for &(n, _) in &sched.joins {
                c.alive[n.0] = true;
            }
        }
    }

    #[test]
    fn poisson_reconciles_with_externally_applied_liveness() {
        // The engine's plugin contract lets other sources kill or revive
        // relays behind the churn model's back (their crashes/joins are
        // applied to the authority post-iteration).  The Poisson clocks
        // must adopt that state at the next sample: no crash of an
        // already-dead node, no join of an alive one, ever.
        let mut c = ChurnProcess::with_model(ChurnModel::Poisson, 6, relays(6), 1.5, 21);
        for iter in 0..40 {
            // External world event: flip one node out from under the model,
            // exactly like a source-scheduled crash/join would.
            let victim = iter % 6;
            c.alive[victim] = !c.alive[victim];
            let before = c.alive.clone();
            let sched = EventSource::sample(&mut c, iter, 10.0);
            for &(n, _) in &sched.crashes {
                assert!(before[n.0], "crash on externally-dead node {n}");
            }
            for &(n, _) in &sched.joins {
                assert!(!before[n.0], "join on externally-alive node {n}");
            }
            for &(n, _) in &sched.joins {
                c.alive[n.0] = true;
            }
        }
    }

    #[test]
    #[should_panic(expected = "legacy Bernoulli API")]
    fn poisson_rejects_legacy_sample_iteration() {
        let mut c = ChurnProcess::with_model(ChurnModel::Poisson, 4, relays(4), 0.1, 1);
        let _ = c.sample_iteration();
    }
}
