//! Training-simulation surface: the plan-lifecycle routing contract,
//! configuration, per-iteration metrics, and the [`TrainingSim`] physical
//! model.
//!
//! Reproduces the paper's measurement methodology (§VI): each iteration,
//! every data node pushes its microbatches along the routed flows; the
//! simulator executes forward hops, loss, backward hops and the
//! aggregation barrier with per-node concurrency slots (`cap_i`), link
//! delays from the topology, per-NIC transmission queues when the
//! shared-capacity substrate is enabled
//! ([`crate::cost::NicConfig`]/[`super::events::NicQueues`] — unlimited
//! NICs reproduce the contention-free model bit for bit), node crashes
//! mid-iteration, and the recovery protocols (GWTF path repair vs SWARM
//! full-pipeline restart).
//!
//! # The plan lifecycle ([`RoutingPolicy`])
//!
//! The paper's §V-C efficiency claim is that flow planning "converges ...
//! significantly faster than a training iteration" while running *in
//! parallel* with training.  The routing contract therefore models
//! planning as a **lifecycle on the engine's continuous clock** rather
//! than a synchronous call:
//!
//! 1. the engine *requests* a plan at iteration start —
//!    [`RoutingPolicy::request_plan`] returns a [`PlanTicket`] naming the
//!    protocol rounds the session needs to converge;
//! 2. planning rounds are delivered as engine events
//!    (`WorldSchedule::plan_rounds`, emitted by
//!    [`crate::sim::sources::PlanningSource`] at the configured
//!    round-RTT) and tracked by a [`crate::sim::engine::PlanSession`];
//! 3. the plan *commits* at the virtual time its rounds actually converge
//!    — [`RoutingPolicy::commit_plan`] returns the [`PlanOutcome`].  A
//!    crash landing while the session is in flight marks the ticket
//!    *stale*: the policy performs a §V-D local repair of the in-flight
//!    plan at commit instead of silently restarting.
//!
//! Cold-start charge (no previous plan: the iteration blocks until the
//! commit), warm-replan overlap (the session converges while training
//! runs) and mid-planning churn invalidation all fall out of the
//! timeline.  The degenerate configuration —
//! [`crate::sim::engine::PlanLifecycle::CommitAtRequest`], the default —
//! commits at the request instant with the ticket's blocking charge and
//! reproduces the pre-lifecycle simulator bit for bit.
//!
//! Single-shot planners (SWARM's greedy wiring, DT-FM's GA) implement the
//! narrower [`BlockingPlanner`] hook and ride the lifecycle through
//! [`BlockingPlanAdapter`], which stays one-commit-per-request.
//!
//! The continuous-time event kernel that executes an iteration lives in
//! [`super::engine`] (the dispatch loop over the [`super::events`] queue)
//! and [`super::handlers`] (the per-event microbatch handlers); this
//! module keeps the physical model — liveness windows, link/compute
//! timing with jitter and straggler factors, and the §V-E aggregation
//! barrier — plus [`TrainingSim::run_iteration`], the compatibility entry
//! point that converts one iteration's [`super::churn::ChurnEvents`] into
//! a [`super::engine::WorldSchedule`] and runs it.
//!
//! Reported metrics match the paper's Table II/III rows:
//! - *time per microbatch* — iteration makespan (slowest data node) divided
//!   by microbatches processed,
//! - *throughput* — microbatches completing both passes in the iteration,
//! - *communication time* — total payload transfer seconds (split into
//!   transmission / propagation / NIC-queueing: `tx_s`/`prop_s`/`queue_s`,
//!   plus per-node link-utilization aggregates),
//! - *wasted GPU time* — compute spent on work excluded from aggregation
//!   (crashed mid-task, orphaned by a broken flow, or recomputed),
//! plus the lifecycle diagnostics `plan_overlap_s` (planning seconds
//! hidden behind training) and `stale_replans` (tickets invalidated by
//! mid-planning churn).

use std::sync::Arc;

use crate::cost::NodeId;
use crate::flow::graph::{FlowPath, FlowProblem};
use crate::net::{CongestionCache, ReputationBook, Topology};
use crate::trace::{self, TraceKind, TraceRecord};
use crate::util::Rng;

use super::adversary::AdversaryRoster;
use super::churn::{ChurnEvents, ChurnProcess};
use super::engine::{JitterWindow, Slowdown, WorldSchedule};
use super::events::{NicQueues, Time};

/// Backward-pass crash recovery policy (the paper's key GWTF/SWARM split).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// GWTF: repair the broken flow in place and resume from the stored
    /// gradient (§V-D "Crashes during the backward pass").
    RepairPath,
    /// SWARM: recompute the entire pipeline for the microbatch.
    RestartPipeline,
}

/// A plan request issued by the engine at virtual time `requested_at`.
#[derive(Debug, Clone, Copy)]
pub struct PlanRequest<'a> {
    /// Start-of-iteration liveness (`alive[n.0]`), the planner's view.
    pub alive: &'a [bool],
    /// Invalidation set carried over from the previous plan: nodes that
    /// died since it was requested.  Flows through them must be torn down
    /// and repaired; surviving flows should be kept.  Seeds the ticket's
    /// invalidation set ([`PlanTicket::invalidated`]).
    pub dirty: &'a [NodeId],
    /// Whether a warm start from the previous plan's surviving chains is
    /// requested (§V-A Request Flow / Change / Redirect re-run locally
    /// around the crash sites).  Single-shot planners ignore this and
    /// cold-plan — the SWARM/DT-FM baseline behavior.
    pub warm: bool,
    /// Virtual time of the request on the iteration timeline.
    pub requested_at: Time,
    /// Engine iteration issuing the request (diagnostics).
    pub iter: usize,
}

/// Handle to an in-flight planning session, returned by
/// [`RoutingPolicy::request_plan`].
#[derive(Debug, Clone)]
pub struct PlanTicket {
    /// Session id; strictly increasing per policy.  Exactly one
    /// [`RoutingPolicy::commit_plan`] per ticket, in request order.
    pub id: u64,
    /// Protocol rounds the session needs to converge.  `0` marks a
    /// single-shot planner with no round-based protocol (the engine then
    /// commits at the request using `ready_after_s`).
    pub rounds: usize,
    /// Blocking-mode convergence latency after the request: the wall-time
    /// the plan costs when nothing overlaps it (GWTF charges the cold
    /// start's control rounds here, DT-FM its GA compute; warm re-plans
    /// and SWARM's on-the-fly wiring claim `0.0`).
    pub ready_after_s: f64,
    /// Echo of [`PlanRequest::requested_at`].
    pub requested_at: Time,
    /// The request-time half of the ticket's invalidation set: a copy of
    /// [`PlanRequest::dirty`], already incorporated by the planner at
    /// request time.  Crashes landing while the session is in flight are
    /// tracked engine-side (by the
    /// [`PlanSession`](crate::sim::engine::PlanSession)) and arrive as
    /// [`RoutingPolicy::commit_plan`]'s separate `invalidated` argument —
    /// do not expect them here.
    pub invalidated: Vec<NodeId>,
}

/// The committed outcome of a planning session.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    /// The routed flows (one per microbatch).
    pub paths: Vec<FlowPath>,
    /// Virtual time the plan became usable.  Blocking policies claim
    /// `requested_at + ready_after_s`; when a
    /// [`PlanSession`](crate::sim::engine::PlanSession) drives the rounds
    /// on the engine clock, the session overwrites this with the instant
    /// the last round converged.
    pub committed_at: Time,
    /// Total protocol rounds consumed, including any commit-time §V-D
    /// repair rounds.
    pub rounds: usize,
    /// True iff churn invalidated the ticket while the session was in
    /// flight: the delivered paths went through a commit-time local
    /// repair rather than a clean convergence.
    pub stale: bool,
}

/// Routing policy plugged into the simulator (GWTF, SWARM, DT-FM, ...):
/// the plan lifecycle (see the module docs) plus the mid-iteration
/// recovery hooks.
pub trait RoutingPolicy {
    fn name(&self) -> String;

    /// Open a planning session for `req` and return its ticket.  The
    /// policy computes the candidate plan here (planning is CPU work; the
    /// *timeline* cost is modeled by when the commit lands), stashing it
    /// until [`commit_plan`](RoutingPolicy::commit_plan).
    fn request_plan(&mut self, req: &PlanRequest) -> PlanTicket;

    /// Close the session opened by `ticket` and deliver its outcome.
    /// `invalidated` lists nodes that crashed *after* the request while
    /// the session was in flight (beyond `ticket.invalidated`, which the
    /// request already incorporated); a non-empty set obliges the policy
    /// to locally repair the in-flight plan (§V-D) and mark the outcome
    /// stale.  Exactly one commit per ticket, in request order.
    fn commit_plan(&mut self, ticket: &PlanTicket, invalidated: &[NodeId]) -> PlanOutcome;

    /// Protocol rounds consumed by the most recent planning session, for
    /// the warm-replan diagnostics column in the experiment tables.
    /// Policies without a round-based protocol (SWARM's greedy wiring,
    /// DT-FM's GA) report 0.
    fn last_plan_rounds(&self) -> usize {
        0
    }

    /// Notify of a mid-iteration crash so internal state can adapt.
    fn on_crash(&mut self, node: NodeId);

    /// A gossip-overlay round fires at virtual time `t`
    /// (`WorldSchedule::gossip_ticks`, emitted by
    /// [`crate::sim::sources::GossipCadenceSource`]): probe peers,
    /// escalate suspicion, repair views.  Policies without an overlay
    /// ignore it.
    fn on_gossip(&mut self, t: Time) {
        let _ = t;
    }

    /// Choose a replacement relay for a flow `prev -> X -> next` whose X
    /// crashed. `candidates` are alive same-stage nodes with a free slot;
    /// the pick must come from them.
    fn choose_replacement(
        &mut self,
        prev: NodeId,
        next: NodeId,
        candidates: &[NodeId],
    ) -> Option<NodeId>;

    fn recovery(&self) -> RecoveryPolicy;
}

/// Single-shot planner hook for policies with no incremental or
/// round-based protocol: one fresh plan per call, no session state.
/// Wrap in a [`BlockingPlanAdapter`] to plug into the engine.
pub trait BlockingPlanner {
    fn name(&self) -> String;

    /// Plan from scratch over `alive`.  Returns the routed paths and the
    /// blocking wall-time the plan costs (0.0 for on-the-fly wiring).
    fn plan_once(&mut self, alive: &[bool]) -> (Vec<FlowPath>, f64);

    fn on_crash(&mut self, node: NodeId);

    fn choose_replacement(
        &mut self,
        prev: NodeId,
        next: NodeId,
        candidates: &[NodeId],
    ) -> Option<NodeId>;

    fn recovery(&self) -> RecoveryPolicy;
}

/// Adapts a [`BlockingPlanner`] to the [`RoutingPolicy`] plan lifecycle:
/// every request runs the single-shot planner immediately and the commit
/// delivers that result — one commit per request, `rounds = 0`, never
/// stale (there is no in-flight window for churn to invalidate).  The
/// engine treats `rounds == 0` tickets as blocking even under
/// [`crate::sim::engine::PlanLifecycle::RoundLatency`], so baselines keep
/// their paper semantics in every lifecycle mode.
pub struct BlockingPlanAdapter<P: BlockingPlanner> {
    inner: P,
    next_ticket: u64,
    pending: Option<(u64, Vec<FlowPath>, f64)>,
}

impl<P: BlockingPlanner> BlockingPlanAdapter<P> {
    pub fn new(inner: P) -> Self {
        BlockingPlanAdapter { inner, next_ticket: 0, pending: None }
    }

    pub fn inner(&self) -> &P {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut P {
        &mut self.inner
    }
}

impl<P: BlockingPlanner> RoutingPolicy for BlockingPlanAdapter<P> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn request_plan(&mut self, req: &PlanRequest) -> PlanTicket {
        let (paths, charge) = self.inner.plan_once(req.alive);
        let id = self.next_ticket;
        self.next_ticket += 1;
        self.pending = Some((id, paths, charge));
        PlanTicket {
            id,
            rounds: 0,
            ready_after_s: charge,
            requested_at: req.requested_at,
            invalidated: req.dirty.to_vec(),
        }
    }

    fn commit_plan(&mut self, ticket: &PlanTicket, _invalidated: &[NodeId]) -> PlanOutcome {
        let (id, paths, charge) =
            self.pending.take().expect("commit_plan without a matching request_plan");
        assert_eq!(id, ticket.id, "plan tickets must commit in request order");
        PlanOutcome {
            paths,
            committed_at: ticket.requested_at + charge,
            rounds: 0,
            stale: false,
        }
    }

    fn on_crash(&mut self, node: NodeId) {
        self.inner.on_crash(node)
    }

    fn choose_replacement(
        &mut self,
        prev: NodeId,
        next: NodeId,
        candidates: &[NodeId],
    ) -> Option<NodeId> {
        self.inner.choose_replacement(prev, next, candidates)
    }

    fn recovery(&self) -> RecoveryPolicy {
        self.inner.recovery()
    }
}

/// Simulation configuration.  Plain scalars — `Copy`, so engines and
/// benches pass it by value instead of cloning.
#[derive(Debug, Clone, Copy)]
pub struct TrainingSimConfig {
    /// Activation/gradient payload per hop, bytes (Eq. 1 `size`).
    pub payload_bytes: f64,
    /// Per-stage weight payload exchanged during aggregation, bytes.
    pub stage_param_bytes: f64,
    /// Crash-detection timeout (missing COMPLETE), seconds.
    pub timeout_s: f64,
    /// Maximum pipeline restarts per microbatch before it is dropped.
    pub max_restarts: usize,
    /// Reference iteration length used to place mid-iteration crash
    /// instants (updated online from the previous iteration's makespan).
    pub initial_iter_estimate_s: f64,
    /// Backward compute multiplier (bwd ~ 2x fwd for transformers).
    pub bwd_factor: f64,
    /// Aggregation cutoff: microbatches not home within
    /// `deadline_factor x` the running iteration estimate are "excluded
    /// from aggregation" (the paper's wasted-GPU definition) — data nodes
    /// do not stall the update phase for stragglers.
    pub deadline_factor: f64,
    /// Bounded-staleness asynchronous training (ATOM-style): a microbatch
    /// of generation `g` may train against stage weights from `g-s..=g`.
    /// `Some(s >= 1)` replaces the global §V-E barrier with rolling
    /// per-stage aggregation events on the engine clock; `None` or
    /// `Some(0)` keep the synchronous simulator bit for bit.
    pub staleness_bound: Option<usize>,
}

impl Default for TrainingSimConfig {
    fn default() -> Self {
        TrainingSimConfig {
            payload_bytes: 4.0 * 512.0 * 1024.0 * 4.0 * 32.0, // paper LLaMA inflated
            stage_param_bytes: 50e6 * 4.0,
            timeout_s: 5.0,
            max_restarts: 3,
            initial_iter_estimate_s: 240.0,
            bwd_factor: 2.0,
            deadline_factor: 2.0,
            staleness_bound: None,
        }
    }
}

/// Per-iteration outcome (one row sample for Tables II/III).
#[derive(Debug, Clone, Copy, Default)]
pub struct IterationMetrics {
    pub makespan_s: f64,
    pub completed: usize,
    pub scheduled: usize,
    /// Total payload-transfer seconds (transmission + propagation — the
    /// legacy communication-time column; queueing is *not* included, see
    /// `queue_s`).
    pub comm_s: f64,
    /// Seconds transfers spent queued for a NIC transmission slot
    /// (shared-capacity substrate; exactly 0 under unlimited NICs and
    /// whenever no two transmissions ever contend).
    pub queue_s: f64,
    /// Transmission component of `comm_s` (`size/β`, jitter applied) —
    /// the part that occupies a NIC.
    pub tx_s: f64,
    /// Propagation component of `comm_s` (latency, jitter applied) —
    /// pipelines, occupies nothing.
    pub prop_s: f64,
    /// Busiest NIC's demanded transmission seconds (the node's busier
    /// direction) over the iteration makespan — per-node link load, max
    /// over nodes.  Can exceed 1 under unlimited concurrency: it is
    /// oversubscription, not wall-clock occupancy.
    pub nic_util_max: f64,
    /// Mean per-node NIC transmission-load fraction of the makespan.
    pub nic_util_mean: f64,
    pub wasted_gpu_s: f64,
    pub agg_s: f64,
    pub planning_s: f64,
    pub fwd_recoveries: usize,
    pub bwd_recoveries: usize,
    pub restarts: usize,
    pub dropped: usize,
    /// Memory-overload DENYs (§V-D): a microbatch reached a node whose
    /// `cap_i` concurrent-residency budget was exhausted and was rerouted
    /// or deferred.  Capacity-oblivious routing (SWARM) pays these.
    pub denies: usize,
    /// Stage-weight re-exchanges forced by crashes landing *inside* the
    /// aggregation barrier (§V-E) — expressible only by the
    /// continuous-time schedule (`WorldSchedule::agg_crashes`).
    pub agg_recoveries: usize,
    /// Flow-protocol rounds the iteration's (re)plan took
    /// ([`RoutingPolicy::last_plan_rounds`]); warm re-plans resume
    /// surviving chains and should need far fewer rounds than a cold plan.
    pub replan_rounds: usize,
    /// Planning seconds hidden behind training: the part of the plan
    /// session's convergence window that overlapped the iteration
    /// (`min(committed_at, makespan)`).  0 under the degenerate
    /// commit-at-request lifecycle, which does not put planning on the
    /// timeline.
    pub plan_overlap_s: f64,
    /// Plan tickets invalidated by churn while in flight this iteration
    /// ([`PlanOutcome::stale`]): the plan went through a commit-time
    /// §V-D local repair instead of a clean convergence.
    pub stale_replans: usize,
    /// Kernel events dispatched while executing this iteration's schedule
    /// — the numerator of the scale bench's events/sec throughput column.
    pub events: usize,
    /// Mean weight staleness (generations behind the iteration's stamp)
    /// microbatches trained against, after any catch-up exchanges.  0
    /// under the synchronous barrier and whenever every stage aggregated
    /// last iteration.
    pub staleness_mean: f64,
    /// Microbatches whose admission was deferred past t=0 because some
    /// stage's weights lagged beyond the staleness bound and had to
    /// replay missed exchanges first.
    pub deferred: usize,
    /// Peak resident set of the measuring process, MiB.  Stamped by the
    /// bench drivers (`experiments::scenarios`) *after* `Engine::step`
    /// returns — never by the engine itself: the probe is monotone
    /// within a process, so an engine-side stamp would differ between
    /// two otherwise bit-identical runs and break every metric-parity
    /// test.  0 = not measured.
    pub peak_rss_mib: f64,
    /// Critical-path attribution: where the makespan went, bucket by
    /// bucket (see [`CritPath`]).  The buckets sum to `makespan_s`
    /// within float rounding (guarded at 1e-6 relative by
    /// `rust/tests/trace_determinism.rs`).
    pub crit_path: CritPath,
}

impl IterationMetrics {
    pub fn time_per_microbatch_s(&self) -> f64 {
        if self.completed == 0 {
            f64::INFINITY
        } else {
            self.makespan_s / self.completed as f64
        }
    }
}

/// Critical-path attribution buckets, in seconds.
///
/// Every microbatch's virtual timeline is contiguous — from admission
/// to its gradient landing, each segment is compute, a transfer phase,
/// or some form of waiting — so the handlers account each segment into
/// a per-microbatch `CritPath` as they advance it.  At iteration tally
/// the engine takes the chain of the *makespan-ending* microbatch
/// (the argmax of `done_at`: the path the iteration actually waited
/// for), adds the iteration-level planning charge, and attributes the
/// post-tail residue (aggregation barrier / rolling-exchange overhang /
/// §V-E crash recovery) to `agg_s` — by construction the buckets sum to
/// the iteration makespan up to per-bucket float rounding.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CritPath {
    /// Forward/backward/loss compute on the chain.
    pub compute_s: f64,
    /// NIC transmission occupancy on the chain.
    pub tx_s: f64,
    /// Pipelined propagation latency on the chain.
    pub prop_s: f64,
    /// All waiting: NIC queueing, compute-slot waits, crash-detection
    /// timeouts and recovery candidate waits.
    pub queue_s: f64,
    /// Blocking planning charge + planning stalls (iteration-level).
    pub plan_s: f64,
    /// Aggregation residue past the microbatch tail: barrier control
    /// floods + weight exchange, rolling-exchange overhang, §V-E crash
    /// recovery (iteration-level).
    pub agg_s: f64,
    /// Bounded-staleness admission catch-up before the fan-out.
    pub stale_s: f64,
}

impl CritPath {
    /// Sum of every bucket — compare against `makespan_s`.
    pub fn total_s(&self) -> f64 {
        self.compute_s
            + self.tx_s
            + self.prop_s
            + self.queue_s
            + self.plan_s
            + self.agg_s
            + self.stale_s
    }
}

/// The training simulator: physical model of the volunteer network over
/// one iteration's virtual timeline.
pub struct TrainingSim {
    /// Shared, immutable network state (scenario, planner closure and
    /// simulator all point at the same allocation — the `links` matrix is
    /// O(n²) and used to be deep-cloned per engine).
    pub topo: Arc<Topology>,
    pub cfg: TrainingSimConfig,
    /// Planner-side congestion memo to invalidate from the booking path
    /// (None when the scenario plans contention-blind).
    cost_cache: Option<Arc<CongestionCache>>,
    /// Misbehaving-relay roster consulted by the admission predicate in
    /// `handle_relay_compute` (None = every relay honest; the predicate
    /// reduces to the legacy form).
    pub(crate) adversary: Option<Arc<AdversaryRoster>>,
    /// Peer reputation book charged at the handler observation sites
    /// (None = reputation off; no observation code runs).
    pub(crate) reputation: Option<Arc<ReputationBook>>,
    /// Virtual availability window per node: usable while
    /// `birth_at <= t < death_at`.  A node alive at iteration start has
    /// `birth_at = 0`; one joining mid-iteration gets its join instant;
    /// a dead node keeps `birth_at = INFINITY`.
    pub(crate) death_at: Vec<Time>,
    pub(crate) birth_at: Vec<Time>,
    /// Piecewise-constant link-delay multiplier windows (engine-supplied).
    pub(crate) jitter: Vec<JitterWindow>,
    /// Straggler windows: per-node compute multipliers (engine-supplied).
    pub(crate) slowdowns: Vec<Slowdown>,
    pub(crate) iter_estimate: f64,
    /// Per-stage weight generations for bounded-staleness mode
    /// ([`TrainingSimConfig::staleness_bound`]); lazily initialised on the
    /// first asynchronous iteration and persisted across iterations so
    /// stage lag carries over.  `None` on the synchronous path.
    pub(crate) versioned: Option<VersionedWeights>,
}

/// Per-stage versioned weight store for bounded-staleness asynchronous
/// training (ATOM-style, PAPERS.md): stage `st`'s weights sit at
/// generation `gen[st]`, and every microbatch of an iteration carries the
/// stamp `iter_gen`.  The admission rule in `run_schedule` keeps
/// `iter_gen - gen[st] <= s` by replaying missed exchanges before
/// admitting new work.
#[derive(Debug, Clone)]
pub struct VersionedWeights {
    /// Weight generation currently installed on each pipeline stage.
    pub gen: Vec<u64>,
    /// Generation stamp the next iteration's microbatches carry.
    pub iter_gen: u64,
}

/// One iteration's rolling per-stage aggregation state (bounded-staleness
/// mode).  Tracks gradients home per stage and tells the caller when a
/// stage's §V-E weight exchange should fire — no global barrier: each
/// stage aggregates the moment its last expected gradient lands.
pub(crate) struct StageAggTracker {
    /// Microbatches admitted this iteration (each traverses every stage).
    pub(crate) expected: usize,
    /// Gradients home per stage so far.
    pub(crate) home: Vec<usize>,
    /// Latest gradient-home instant per stage.
    pub(crate) last_home: Vec<Time>,
    /// Per-stage §V-E exchange duration among the stage's alive members
    /// (precomputed at iteration start via `stage_exchange_s`).
    pub(crate) exchange: Vec<f64>,
    /// Whether the stage's exchange event has been scheduled/completed.
    pub(crate) fired: Vec<bool>,
    /// Exchange completion instant per fired stage.
    pub(crate) done_at: Vec<Time>,
    /// (microbatch, stage) pairs already counted: a §V-D full-pipeline
    /// restart re-clears stages its first backward pass already cleared,
    /// but only the first clear contributes a gradient.
    seen: Vec<bool>,
}

impl StageAggTracker {
    pub(crate) fn new(n_stages: usize, expected: usize, exchange: Vec<f64>) -> Self {
        StageAggTracker {
            expected,
            home: vec![0; n_stages],
            last_home: vec![0.0; n_stages],
            exchange,
            fired: vec![false; n_stages],
            done_at: vec![0.0; n_stages],
            seen: vec![false; expected * n_stages],
        }
    }

    /// Microbatch `mi`'s backward compute cleared stage `st` at `end`: its
    /// gradient contribution is home.  Returns the exchange completion
    /// instant to put on the event queue when this was the last expected
    /// gradient for the stage.
    pub(crate) fn grad_home(&mut self, mi: usize, st: usize, end: Time) -> Option<Time> {
        let k = mi * self.home.len() + st;
        if self.seen[k] {
            return None;
        }
        self.seen[k] = true;
        self.home[st] += 1;
        if end > self.last_home[st] {
            self.last_home[st] = end;
        }
        if self.home[st] == self.expected && !self.fired[st] {
            return Some(end + self.exchange[st]);
        }
        None
    }
}

impl TrainingSim {
    /// Accepts an owned [`Topology`] (tests, standalone use) or an
    /// already-shared `Arc<Topology>` (scenario/engine path — no clone).
    pub fn new(topo: impl Into<Arc<Topology>>, cfg: TrainingSimConfig) -> Self {
        let topo = topo.into();
        let n = topo.n();
        let iter_estimate = cfg.initial_iter_estimate_s;
        TrainingSim {
            topo,
            cfg,
            cost_cache: None,
            adversary: None,
            reputation: None,
            death_at: vec![f64::INFINITY; n],
            birth_at: vec![0.0; n],
            jitter: Vec::new(),
            slowdowns: Vec::new(),
            iter_estimate,
            versioned: None,
        }
    }

    /// Attach the planner's congestion-cost memo so the booking path can
    /// invalidate the (endpoint, link-class) generations it dirties.
    pub fn set_cost_cache(&mut self, cache: Option<Arc<CongestionCache>>) {
        self.cost_cache = cache;
    }

    /// Attach the scenario's misbehaving-relay roster (None = all
    /// honest; the handler predicates reduce to their legacy forms).
    pub fn set_adversary(&mut self, roster: Option<Arc<AdversaryRoster>>) {
        self.adversary = roster;
    }

    /// Attach the shared reputation book so the handler sites charge
    /// delivery / DENY / service-ratio observations.
    pub fn set_reputation(&mut self, book: Option<Arc<ReputationBook>>) {
        self.reputation = book;
    }

    /// The running iteration-length estimate (the crash-instant and
    /// deadline reference; event sources use it as their horizon).
    pub fn current_iter_estimate(&self) -> f64 {
        self.iter_estimate
    }

    /// Link-delay multiplier in effect at virtual time `t`.
    ///
    /// `jitter` is kept sorted by window start (see
    /// [`run_schedule`](TrainingSim::run_schedule)) and windows are
    /// treated as non-overlapping (the built-in sources emit contiguous
    /// tiles): only the latest-starting window at or before `t` is
    /// consulted, making every lookup O(log n) on this hot path.
    fn link_factor_at(&self, t: Time) -> f64 {
        if self.jitter.is_empty() {
            return 1.0;
        }
        let idx = self.jitter.partition_point(|w| w.from <= t);
        match idx.checked_sub(1).map(|i| &self.jitter[i]) {
            Some(w) if t < w.until => w.factor,
            _ => 1.0,
        }
    }

    /// Compute multiplier for `n` at virtual time `t` (straggler windows).
    fn compute_factor(&self, n: NodeId, t: Time) -> f64 {
        for s in &self.slowdowns {
            if s.node == n && t >= s.from && t < s.until {
                return s.factor;
            }
        }
        1.0
    }

    /// Payload transfer time for a hop starting at virtual time `t`
    /// (contention-free: propagation + transmission, jitter applied).
    pub(crate) fn transfer_s(&self, from: NodeId, to: NodeId, t: Time) -> f64 {
        self.topo.delay(from, to, self.cfg.payload_bytes) * self.link_factor_at(t)
    }

    /// One payload transfer `from -> to` with the data ready at `t`,
    /// booked through the shared-capacity NIC substrate: the transmission
    /// serializes through `from`'s uplink and `to`'s downlink
    /// ([`NicQueues::acquire`]), propagation pipelines on top.  Returns
    /// the arrival instant and accumulates the communication split
    /// (`comm_s`/`tx_s`/`prop_s`/`queue_s`) into `metrics`, the same
    /// split into microbatch `mb`'s critical-path buckets (`crit`), and
    /// emits queue-wait/transmission/propagation trace spans when a
    /// sink is armed (observation only — no timing changes).
    ///
    /// With unlimited NICs the start instant is `t` and the arrival is
    /// `t + transfer_s(from, to, t)` — the exact legacy arithmetic, so
    /// every pre-substrate trace reproduces bit for bit.
    ///
    /// Modeling choice: the jitter factor (and hence the transmission
    /// duration) is sampled at the *ready* instant `t`, as the legacy
    /// model did, even when queueing pushes the actual start later.
    /// Sampling at the start would make the duration depend on the slot
    /// found, which itself depends on the duration; jitter windows are
    /// long (tens of seconds) relative to single transmissions, so the
    /// frozen factor is a second-order inaccuracy.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn send(
        &self,
        net: &mut NicQueues,
        from: NodeId,
        to: NodeId,
        t: Time,
        mb: usize,
        metrics: &mut IterationMetrics,
        crit: &mut CritPath,
    ) -> Time {
        let dt = self.transfer_s(from, to, t);
        // Propagation = the zero-byte delay: derived from the same
        // LinkParams::one_way_s the total uses, so the tx/prop split
        // tracks any future change to the delay formula.
        let prop = self.topo.delay(from, to, 0.0) * self.link_factor_at(t);
        let tx = (dt - prop).max(0.0);
        let start = net.acquire(from, to, t, tx);
        if start > t {
            // The transmission queued behind a NIC cap: dirty both
            // endpoints' link-class generation in the planner's
            // congestion memo (the booking-path invalidation rule).
            if let Some(cache) = &self.cost_cache {
                let same = self.topo.region[from.0] == self.topo.region[to.0];
                cache.invalidate(from, same);
                cache.invalidate(to, same);
            }
        }
        if let Some(book) = &self.reputation {
            // Delivered hop: full credit for the receiving peer (the
            // EWMA denominator that keeps honest busy relays near 1.0).
            book.observe_delivery(to);
        }
        metrics.comm_s += dt;
        metrics.queue_s += start - t;
        metrics.tx_s += tx;
        metrics.prop_s += prop;
        crit.queue_s += start - t;
        crit.tx_s += tx;
        crit.prop_s += prop;
        if trace::enabled() {
            if start > t {
                trace::emit(|| {
                    TraceRecord::span(t, start - t, Some(from), Some(mb), TraceKind::NicQueueWait)
                });
            }
            trace::emit(|| {
                TraceRecord::span(start, tx, Some(from), Some(mb), TraceKind::Transmission)
            });
            trace::emit(|| {
                TraceRecord::span(start + tx, prop, Some(to), Some(mb), TraceKind::Propagation)
            });
        }
        start + dt
    }

    pub(crate) fn fwd_compute_s(&self, n: NodeId, t: Time) -> f64 {
        self.topo.profiles[n.0].compute_s * self.compute_factor(n, t)
    }

    pub(crate) fn bwd_compute_s(&self, n: NodeId, t: Time) -> f64 {
        self.fwd_compute_s(n, t) * self.cfg.bwd_factor
    }

    pub(crate) fn is_up(&self, n: NodeId, t: Time) -> bool {
        t >= self.birth_at[n.0] && t < self.death_at[n.0]
    }

    /// Convert one iteration's churn sample into an absolute-time world
    /// schedule (crash fractions are relative to the running estimate).
    pub fn schedule_from_churn(&self, ev: &ChurnEvents) -> WorldSchedule {
        WorldSchedule {
            crashes: ev.crashes.iter().map(|&(n, frac)| (n, frac * self.iter_estimate)).collect(),
            rejoins: ev.rejoins.clone(),
            ..Default::default()
        }
    }

    /// Run one full training iteration from a per-iteration churn sample.
    ///
    /// Compatibility entry point: converts `churn` into a
    /// [`WorldSchedule`] and defers to
    /// [`run_schedule`](TrainingSim::run_schedule) — byte-identical
    /// behavior to the pre-engine simulator for churn-only schedules.
    #[allow(clippy::too_many_arguments)]
    pub fn run_iteration(
        &mut self,
        prob: &FlowProblem,
        router: &mut dyn RoutingPolicy,
        churn: &ChurnEvents,
        churn_state: &ChurnProcess,
        planning_s: f64,
        paths: Vec<FlowPath>,
        rng: &mut Rng,
    ) -> IterationMetrics {
        let schedule = self.schedule_from_churn(churn);
        self.run_schedule(prob, router, &schedule, churn_state, planning_s, paths, None, rng)
    }

    /// §V-E intra-stage weight-exchange duration among `members`.
    ///
    /// Legacy (unlimited NICs): pairs exchange fully in parallel, so the
    /// barrier waits for the worst pairwise one-way delay — preserved bit
    /// for bit.  With finite NIC concurrency the broadcast serializes:
    /// each member pushes its shard to every peer through its uplink and
    /// drains every peer's shard through its downlink, `cap`
    /// transmissions at a time per link class; a member's exchange time
    /// is its worst peer latency plus its largest serialized backlog, and
    /// the stage waits for its slowest member.  The barrier stays
    /// closed-form — it charges the *same* NIC capacity law
    /// ([`crate::cost::NicConfig`]) the microbatch phase executes
    /// event-by-event, just analytically.
    pub(crate) fn stage_exchange_s(&self, members: &[NodeId]) -> f64 {
        // Legacy pairwise worst (unlimited NICs: this IS the answer).
        let mut worst: f64 = 0.0;
        for &a in members {
            for &b in members {
                if a != b {
                    worst = worst.max(self.topo.delay(a, b, self.cfg.stage_param_bytes));
                }
            }
        }
        let nic = self.topo.nic;
        if nic.is_unlimited() {
            return worst;
        }
        // Serialization overflow: each member's per-interface backlog
        // (sum of its transmissions, drained `cap` at a time) beyond the
        // single worst transmission already inside `worst`.  Exactly 0
        // when no interface ever has to serialize — finite-but-ample caps
        // stay bit-identical to the legacy barrier.
        let mut overflow: f64 = 0.0;
        for &a in members {
            // (sum, max) transmission backlog per [WAN, LAN] class and
            // direction; uplink and downlink are separate interfaces.
            let mut out = [(0.0f64, 0.0f64); 2];
            let mut inn = [(0.0f64, 0.0f64); 2];
            for &b in members {
                if a == b {
                    continue;
                }
                let k = (self.topo.region[a.0] == self.topo.region[b.0]) as usize;
                let tx_out =
                    self.cfg.stage_param_bytes / self.topo.link(a.0, b.0).bandwidth_bps;
                let tx_in =
                    self.cfg.stage_param_bytes / self.topo.link(b.0, a.0).bandwidth_bps;
                out[k].0 += tx_out;
                out[k].1 = out[k].1.max(tx_out);
                inn[k].0 += tx_in;
                inn[k].1 = inn[k].1.max(tx_in);
            }
            let class_overflow = |(sum, max): (f64, f64), same: bool| -> f64 {
                match nic.cap(same) {
                    Some(c) => (sum / c as f64 - max).max(0.0),
                    None => 0.0,
                }
            };
            overflow = overflow
                .max(class_overflow(out[0], false))
                .max(class_overflow(out[1], true))
                .max(class_overflow(inn[0], false))
                .max(class_overflow(inn[1], true));
        }
        if overflow == 0.0 {
            worst
        } else {
            worst + overflow
        }
    }

    /// §V-E training/aggregation synchronization barrier duration, plus
    /// the recovery count for crashes landing inside the barrier.
    ///
    /// Base barrier: BEGIN AGGREGATION propagates forward, stages exchange
    /// weights internally, CAN TAKE propagates back.  Each entry of
    /// `agg_crashes` is a `(node, frac)` pair: `node` dies after `frac` of
    /// the barrier has elapsed, so its stage re-runs the exchanged
    /// fraction among the survivors after one detection timeout.
    pub(crate) fn aggregation_time(
        &self,
        prob: &FlowProblem,
        churn: &ChurnProcess,
        agg_crashes: &[(NodeId, f64)],
    ) -> (f64, usize) {
        const CTRL_BYTES: f64 = 1024.0;
        let mut fwd_ctrl: f64 = 0.0;
        let mut back_ctrl: f64 = 0.0;
        let mut exchange: f64 = 0.0;
        // BEGIN AGGREGATION floods forward from *every* data node (each
        // initiates the barrier for its own microbatches; the barrier
        // waits for the slowest initiator's control message).
        let mut prev_stage: Vec<NodeId> = prob.graph.data_nodes.clone();
        for s in 0..prob.graph.n_stages() {
            let members: Vec<NodeId> = prob.graph.stages[s]
                .iter()
                .filter(|&&m| churn.is_alive(m))
                .copied()
                .collect();
            if members.is_empty() {
                continue;
            }
            // BEGIN AGGREGATION flood: worst link from any previous-stage node.
            let fwd_hop = prev_stage
                .iter()
                .flat_map(|&p| members.iter().map(move |&m| self.topo.delay(p, m, CTRL_BYTES)))
                .fold(0.0f64, f64::max);
            // CAN TAKE answers across the same stage boundary, but the
            // links matrix is directional: the backward control hop is
            // the worst *reverse*-direction delay, not a reuse of the
            // forward one.  (Symmetric links make the two coincide, so
            // single-data-node symmetric topologies keep the old number
            // bit for bit.)
            let back_hop = prev_stage
                .iter()
                .flat_map(|&p| members.iter().map(move |&m| self.topo.delay(m, p, CTRL_BYTES)))
                .fold(0.0f64, f64::max);
            fwd_ctrl += fwd_hop;
            back_ctrl += back_hop;
            // Intra-stage weight broadcast (pairs exchange in parallel
            // under unlimited NICs; serialized per interface otherwise).
            exchange = exchange.max(self.stage_exchange_s(&members));
            prev_stage = members;
        }
        let base = fwd_ctrl + exchange + back_ctrl;
        if agg_crashes.is_empty() {
            return (base, 0);
        }
        let (extra, recoveries) = self.agg_crash_extra(prob, churn, agg_crashes);
        (base + extra, recoveries)
    }

    /// Mid-aggregation crashes: the victim's stage detects the failure
    /// (one COMPLETE timeout) and redoes the fraction of its weight
    /// exchange the crash invalidated, now among the survivors.  Shared
    /// between the synchronous barrier and the rolling bounded-staleness
    /// exchanges — a crash landing inside an exchange forces the same
    /// §V-E redo either way.
    pub(crate) fn agg_crash_extra(
        &self,
        prob: &FlowProblem,
        churn: &ChurnProcess,
        agg_crashes: &[(NodeId, f64)],
    ) -> (f64, usize) {
        let mut extra = 0.0;
        let mut recoveries = 0usize;
        for &(node, frac) in agg_crashes {
            if !churn.is_alive(node) {
                continue; // already out of the barrier membership
            }
            let Some(stage) = prob.graph.stage_of(node) else { continue };
            let survivors: Vec<NodeId> = prob.graph.stages[stage]
                .iter()
                .filter(|&&m| m != node && churn.is_alive(m))
                .copied()
                .collect();
            let worst = self.stage_exchange_s(&survivors);
            extra += self.cfg.timeout_s + frac.clamp(0.0, 1.0) * worst;
            recoveries += 1;
        }
        (extra, recoveries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::NodeProfile;
    use crate::flow::graph::StageGraph;
    use crate::net::TopologyConfig;
    use crate::sim::engine::{JitterWindow, Slowdown, WorldSchedule};

    /// Trivial fixed single-shot planner for tests: static paths,
    /// first-candidate reroute; exercises the [`BlockingPlanAdapter`] on
    /// every engine path.
    struct FixedRouter {
        paths: Vec<FlowPath>,
        policy: RecoveryPolicy,
        plans: usize,
    }

    impl FixedRouter {
        fn new(paths: Vec<FlowPath>, policy: RecoveryPolicy) -> BlockingPlanAdapter<FixedRouter> {
            BlockingPlanAdapter::new(FixedRouter { paths, policy, plans: 0 })
        }
    }

    impl BlockingPlanner for FixedRouter {
        fn name(&self) -> String {
            "fixed".into()
        }
        fn plan_once(&mut self, _alive: &[bool]) -> (Vec<FlowPath>, f64) {
            self.plans += 1;
            (self.paths.clone(), 0.0)
        }
        fn on_crash(&mut self, _node: NodeId) {}
        fn choose_replacement(
            &mut self,
            _prev: NodeId,
            _next: NodeId,
            candidates: &[NodeId],
        ) -> Option<NodeId> {
            candidates.first().copied()
        }
        fn recovery(&self) -> RecoveryPolicy {
            self.policy
        }
    }

    fn setup() -> (Topology, FlowProblem, Vec<FlowPath>) {
        // data node 0; stage0 {1,2}; stage1 {3,4}; 2 microbatches
        let mut rng = Rng::new(42);
        let mut topo = Topology::generate(
            &TopologyConfig { n_nodes: 5, ..Default::default() },
            &mut rng,
        );
        for i in 0..5 {
            topo.set_profile(NodeId(i), NodeProfile::new(2.0, 2));
        }
        let graph = std::sync::Arc::new(StageGraph {
            stages: vec![vec![NodeId(1), NodeId(2)], vec![NodeId(3), NodeId(4)]],
            data_nodes: vec![NodeId(0)],
        });
        let prob = FlowProblem {
            graph,
            cap: vec![4, 2, 2, 2, 2],
            demand: vec![2],
            cost: Box::new(|_i, _j| 1.0),
        };
        let paths = vec![
            FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3)] },
            FlowPath { source: NodeId(0), relays: vec![NodeId(2), NodeId(4)] },
        ];
        (topo, prob, paths)
    }

    fn small_cfg() -> TrainingSimConfig {
        TrainingSimConfig {
            payload_bytes: 1e6,
            stage_param_bytes: 1e6,
            timeout_s: 1.0,
            max_restarts: 3,
            initial_iter_estimate_s: 30.0,
            bwd_factor: 2.0,
            deadline_factor: 4.0,
            staleness_bound: None,
        }
    }

    fn run_once(policy: RecoveryPolicy, crashes: Vec<(NodeId, f64)>) -> IterationMetrics {
        let (topo, prob, paths) = setup();
        let mut sim = TrainingSim::new(topo, small_cfg());
        let mut router = FixedRouter::new(paths.clone(), policy);
        let churn_state = ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        let churn = ChurnEvents { crashes, rejoins: vec![] };
        let mut rng = Rng::new(0);
        sim.run_iteration(&prob, &mut router, &churn, &churn_state, 0.0, paths, &mut rng)
    }

    fn run_schedule_once(sched: &WorldSchedule) -> IterationMetrics {
        let (topo, prob, paths) = setup();
        let mut sim = TrainingSim::new(topo, small_cfg());
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let churn_state = ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        let mut rng = Rng::new(0);
        sim.run_schedule(&prob, &mut router, sched, &churn_state, 0.0, paths, None, &mut rng)
    }

    #[test]
    fn fault_free_completes_everything() {
        let m = run_once(RecoveryPolicy::RepairPath, vec![]);
        assert_eq!(m.completed, 2);
        assert_eq!(m.dropped, 0);
        assert_eq!(m.wasted_gpu_s, 0.0);
        assert!(m.makespan_s > 0.0);
        assert!(m.comm_s > 0.0);
        assert!(m.agg_s > 0.0);
        assert!(m.time_per_microbatch_s().is_finite());
    }

    #[test]
    fn fwd_crash_recovers_via_reroute() {
        // Node 1 dies immediately: microbatch 0 must reroute to node 2.
        let m = run_once(RecoveryPolicy::RepairPath, vec![(NodeId(1), 0.0)]);
        assert_eq!(m.completed, 2);
        assert!(m.fwd_recoveries >= 1);
    }

    #[test]
    fn bwd_crash_repair_cheaper_than_restart() {
        // Node dies late (during backward pass window).
        let frac = 0.4;
        let repair = run_once(RecoveryPolicy::RepairPath, vec![(NodeId(3), frac)]);
        let restart = run_once(RecoveryPolicy::RestartPipeline, vec![(NodeId(3), frac)]);
        assert_eq!(repair.completed, 2);
        assert_eq!(restart.completed, 2);
        assert!(
            repair.makespan_s <= restart.makespan_s + 1e-9,
            "repair {} vs restart {}",
            repair.makespan_s,
            restart.makespan_s
        );
        assert!(repair.wasted_gpu_s <= restart.wasted_gpu_s + 1e-9);
    }

    #[test]
    fn whole_stage_dead_drops_microbatch() {
        let m = run_once(
            RecoveryPolicy::RepairPath,
            vec![(NodeId(1), 0.0), (NodeId(2), 0.0)],
        );
        assert_eq!(m.completed, 0);
        assert_eq!(m.dropped, 2);
    }

    #[test]
    fn restart_counts_wasted_gpu() {
        let m = run_once(RecoveryPolicy::RestartPipeline, vec![(NodeId(3), 0.4)]);
        assert!(m.restarts >= 1);
        assert!(m.wasted_gpu_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_once(RecoveryPolicy::RepairPath, vec![(NodeId(1), 0.3)]);
        let b = run_once(RecoveryPolicy::RepairPath, vec![(NodeId(1), 0.3)]);
        assert_eq!(a.completed, b.completed);
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-12);
    }

    #[test]
    fn makespan_includes_aggregation_and_planning() {
        let (topo, prob, paths) = setup();
        let mut sim = TrainingSim::new(topo, small_cfg());
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let churn_state = ChurnProcess::new(5, vec![], 0.0, 7);
        let churn = ChurnEvents::default();
        let mut rng = Rng::new(0);
        let m = sim.run_iteration(&prob, &mut router, &churn, &churn_state, 3.0, paths, &mut rng);
        assert!(m.makespan_s >= m.agg_s + 3.0);
        assert_eq!(m.planning_s, 3.0);
    }

    #[test]
    fn blocking_adapter_is_one_commit_per_request() {
        let (_, _, paths) = setup();
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let alive = vec![true; 5];
        let req = PlanRequest {
            alive: &alive,
            dirty: &[NodeId(3)],
            warm: true, // single-shot planners ignore the warm hint
            requested_at: 0.0,
            iter: 0,
        };
        let t0 = router.request_plan(&req);
        assert_eq!(t0.rounds, 0, "single-shot planners have no round protocol");
        assert_eq!(t0.invalidated, vec![NodeId(3)], "dirty seeds the ticket");
        let out = router.commit_plan(&t0, &[]);
        assert_eq!(out.paths, paths);
        assert!(!out.stale);
        assert_eq!(out.committed_at, 0.0, "zero charge commits at the request");
        assert_eq!(router.inner().plans, 1, "one plan_once per request");

        let t1 = router.request_plan(&req);
        assert!(t1.id > t0.id, "ticket ids strictly increase");
        assert_eq!(router.inner().plans, 2, "every request re-plans from scratch");
        router.commit_plan(&t1, &[]);
    }

    #[test]
    fn schedule_from_churn_scales_by_estimate() {
        let (topo, _, _) = setup();
        let sim = TrainingSim::new(topo, small_cfg());
        let ev = ChurnEvents {
            crashes: vec![(NodeId(1), 0.5)],
            rejoins: vec![NodeId(2)],
        };
        let s = sim.schedule_from_churn(&ev);
        assert_eq!(s.crashes, vec![(NodeId(1), 0.5 * 30.0)]);
        assert_eq!(s.rejoins, vec![NodeId(2)]);
        assert!(s.jitter.is_empty() && s.slowdowns.is_empty() && s.agg_crashes.is_empty());
    }

    #[test]
    fn link_jitter_stretches_makespan() {
        let base = run_schedule_once(&WorldSchedule::default());
        let jittered = run_schedule_once(&WorldSchedule {
            jitter: vec![JitterWindow { from: 0.0, until: 1e6, factor: 3.0 }],
            ..Default::default()
        });
        assert_eq!(jittered.completed, base.completed);
        assert!(
            jittered.comm_s > base.comm_s * 2.0,
            "3x link jitter must inflate comm time: {} vs {}",
            jittered.comm_s,
            base.comm_s
        );
        assert!(jittered.makespan_s > base.makespan_s);
    }

    #[test]
    fn straggler_slowdown_stretches_makespan() {
        let base = run_schedule_once(&WorldSchedule::default());
        let slowed = run_schedule_once(&WorldSchedule {
            slowdowns: vec![Slowdown { node: NodeId(3), from: 0.0, until: 1e6, factor: 5.0 }],
            ..Default::default()
        });
        assert_eq!(slowed.completed, base.completed);
        assert!(
            slowed.makespan_s > base.makespan_s,
            "5x straggler must slow the iteration: {} vs {}",
            slowed.makespan_s,
            base.makespan_s
        );
    }

    #[test]
    fn mid_aggregation_crash_charges_barrier_recovery() {
        let base = run_schedule_once(&WorldSchedule::default());
        assert_eq!(base.agg_recoveries, 0);
        let crashed = run_schedule_once(&WorldSchedule {
            agg_crashes: vec![(NodeId(3), 0.5)],
            ..Default::default()
        });
        assert_eq!(crashed.agg_recoveries, 1);
        assert!(
            crashed.agg_s > base.agg_s,
            "mid-aggregation crash must lengthen the barrier: {} vs {}",
            crashed.agg_s,
            base.agg_s
        );
        // the microbatch phase itself is untouched
        assert_eq!(crashed.completed, base.completed);
        assert_eq!(crashed.wasted_gpu_s, base.wasted_gpu_s);
    }

    #[test]
    fn nic_zero_contention_conserves_comm_split_and_makespan() {
        // Conservation (ISSUE 5 satellite): with NICs capped but ample
        // (no two transmissions ever queue), queue_s is exactly 0, the
        // makespan/comm numbers are bit-identical to the contention-free
        // model, and comm_s decomposes into transmission + propagation.
        let base = run_schedule_once(&WorldSchedule::default());
        assert_eq!(base.queue_s, 0.0, "unlimited NICs never queue");

        let (mut topo, prob, paths) = setup();
        topo.nic = crate::cost::NicConfig::uniform(64);
        let mut sim = TrainingSim::new(topo, small_cfg());
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let churn_state =
            ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        let mut rng = Rng::new(0);
        let ample = sim.run_schedule(
            &prob,
            &mut router,
            &WorldSchedule::default(),
            &churn_state,
            0.0,
            paths,
            None,
            &mut rng,
        );
        assert_eq!(ample.queue_s, 0.0, "ample NICs must not queue");
        assert_eq!(ample.makespan_s.to_bits(), base.makespan_s.to_bits());
        assert_eq!(ample.comm_s.to_bits(), base.comm_s.to_bits());
        assert_eq!(ample.agg_s.to_bits(), base.agg_s.to_bits());
        assert!(
            (ample.comm_s - (ample.tx_s + ample.prop_s)).abs() < 1e-9 * ample.comm_s.max(1.0),
            "comm must decompose: {} vs tx {} + prop {}",
            ample.comm_s,
            ample.tx_s,
            ample.prop_s
        );
        assert!(ample.nic_util_max > 0.0, "utilization columns must populate");
        assert!(ample.nic_util_mean <= ample.nic_util_max);
    }

    #[test]
    fn nic_contention_queues_and_stretches_makespan() {
        let base = run_schedule_once(&WorldSchedule::default());
        let (mut topo, prob, paths) = setup();
        topo.nic = crate::cost::NicConfig::uniform(1);
        // One region: every transfer shares the LAN interface class, so
        // the data node's two t=0 sends must serialize regardless of how
        // the generator scattered regions.  (Link params stay as drawn —
        // only the class lookup changes, and the contention-free `base`
        // run never consults it.)
        topo.region = vec![0; topo.n()];
        let mut sim = TrainingSim::new(topo, small_cfg());
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let churn_state =
            ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        let mut rng = Rng::new(0);
        let tight = sim.run_schedule(
            &prob,
            &mut router,
            &WorldSchedule::default(),
            &churn_state,
            0.0,
            paths,
            None,
            &mut rng,
        );
        // Two microbatches leave data node 0 at t=0: concurrency 1 must
        // serialize them through its uplink.
        assert!(tight.queue_s > 0.0, "fan-out through one NIC must queue");
        assert_eq!(tight.completed, base.completed, "contention delays, never drops here");
        assert!(
            tight.makespan_s > base.makespan_s,
            "queueing must stretch the makespan: {} vs {}",
            tight.makespan_s,
            base.makespan_s
        );
        // comm_s counts transfer time only; waiting lands in queue_s.
        // (Same per-hop delays, but event reordering can change the float
        // summation order — compare up to rounding, not bitwise.)
        assert!(
            (tight.comm_s - base.comm_s).abs() < 1e-9 * base.comm_s.max(1.0),
            "queueing must not inflate comm_s: {} vs {}",
            tight.comm_s,
            base.comm_s
        );
        assert!(
            tight.agg_s >= base.agg_s,
            "serialized weight exchange can only lengthen the barrier"
        );
    }

    #[test]
    fn repair_recompute_books_replacement_compute_slots() {
        // Regression (§V-D backward repair): the replacement's forward
        // recompute used to be charged as pure time without booking a
        // compute slot, so a cap-1 replacement absorbed unboundedly many
        // concurrent recomputes for free.  Two microbatches repairing
        // onto a cap-1 node must serialize their ~50 s recomputes; the
        // same repairs onto a cap-2 node run in parallel.
        fn run(replacement_cap: usize) -> IterationMetrics {
            let (mut topo, _, _) = setup();
            // Slow data node: stretches the loss phase so the crash at
            // t=20 lands cleanly between the forward pass clearing node 3
            // (well under 10 s) and the gradients returning (past 40 s).
            topo.set_profile(NodeId(0), NodeProfile::new(40.0, 8));
            // The replacement's recompute dominates every other charge.
            topo.set_profile(NodeId(4), NodeProfile::new(50.0, replacement_cap));
            let graph = std::sync::Arc::new(StageGraph {
                stages: vec![vec![NodeId(1), NodeId(2)], vec![NodeId(3), NodeId(4)]],
                data_nodes: vec![NodeId(0)],
            });
            let prob = FlowProblem {
                graph,
                cap: vec![8, 2, 2, 2, replacement_cap],
                demand: vec![2],
                cost: Box::new(|_i, _j| 1.0),
            };
            // Both microbatches traverse node 3, which dies at t=20.
            let paths = vec![
                FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3)] },
                FlowPath { source: NodeId(0), relays: vec![NodeId(2), NodeId(3)] },
            ];
            let cfg = TrainingSimConfig {
                payload_bytes: 1e6,
                stage_param_bytes: 1e6,
                timeout_s: 1.0,
                max_restarts: 3,
                initial_iter_estimate_s: 1000.0,
                // Tiny backward factor: the recompute is the only large
                // charge at the replacement, so slot contention there is
                // what the makespan difference measures.
                bwd_factor: 0.01,
                deadline_factor: 4.0,
                staleness_bound: None,
            };
            let mut sim = TrainingSim::new(topo, cfg);
            let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
            let churn_state =
                ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
            let sched =
                WorldSchedule { crashes: vec![(NodeId(3), 20.0)], ..Default::default() };
            let mut rng = Rng::new(0);
            sim.run_schedule(&prob, &mut router, &sched, &churn_state, 0.0, paths, None, &mut rng)
        }
        let serial = run(1);
        let parallel = run(2);
        assert_eq!(serial.completed, 2);
        assert_eq!(parallel.completed, 2);
        assert_eq!(serial.bwd_recoveries, 2);
        assert_eq!(parallel.bwd_recoveries, 2);
        assert!(
            serial.makespan_s > parallel.makespan_s + 25.0,
            "a cap-1 replacement must serialize the two ~50 s recomputes: {} vs {}",
            serial.makespan_s,
            parallel.makespan_s
        );
    }

    #[test]
    fn aggregation_charges_reverse_direction_can_take_hop() {
        // Regression: the CAN TAKE hop used to reuse the forward-direction
        // delay although the links matrix is directional.  Slowing ONLY
        // the reverse link 1 -> 0 (stage 0 answering the data node) must
        // lengthen the barrier; the forward flood never touches it.
        let (topo, prob, _) = setup();
        let churn = ChurnProcess::new(5, vec![], 0.0, 7);
        let base =
            TrainingSim::new(topo.clone(), small_cfg()).aggregation_time(&prob, &churn, &[]).0;
        let mut slowed_topo = topo;
        slowed_topo.links_mut()[1][0] = crate::cost::LinkParams::new(30.0, 1e9);
        let slowed =
            TrainingSim::new(slowed_topo, small_cfg()).aggregation_time(&prob, &churn, &[]).0;
        assert!(
            slowed > base + 10.0,
            "the slow reverse control link must gate CAN TAKE: {slowed} vs {base}"
        );
    }

    #[test]
    fn aggregation_floods_from_every_data_node() {
        // Regression: BEGIN AGGREGATION used to flood only from
        // data_nodes[0]; a second data node behind slow outbound links
        // must now gate the first control hop.
        let (topo, _, _) = setup();
        let graph = std::sync::Arc::new(StageGraph {
            stages: vec![vec![NodeId(2), NodeId(3)], vec![NodeId(4)]],
            data_nodes: vec![NodeId(0), NodeId(1)],
        });
        let prob = FlowProblem {
            graph,
            cap: vec![4, 4, 2, 2, 2],
            demand: vec![1, 1],
            cost: Box::new(|_i, _j| 1.0),
        };
        let churn = ChurnProcess::new(5, vec![], 0.0, 7);
        let base =
            TrainingSim::new(topo.clone(), small_cfg()).aggregation_time(&prob, &churn, &[]).0;
        let mut slowed_topo = topo;
        slowed_topo.links_mut()[1][2] = crate::cost::LinkParams::new(30.0, 1e9);
        slowed_topo.links_mut()[1][3] = crate::cost::LinkParams::new(30.0, 1e9);
        let slowed =
            TrainingSim::new(slowed_topo, small_cfg()).aggregation_time(&prob, &churn, &[]).0;
        assert!(
            slowed > base + 10.0,
            "data node 1's slow outbound links must gate the flood: {slowed} vs {base}"
        );
    }

    #[test]
    fn deny_exclusion_clears_when_peer_frees_memory() {
        // §V-D: a DENYing peer is excluded "until they free memory", not
        // forever.  mb1 is DENYed at node 1 (mb0 resident), reroutes to
        // node 2, and arrives there long after mb0's round trip has
        // cleared node 1 — but node 2 is full (mb2 parked on it while
        // node 4 grinds).  The second DENY must re-admit the freed node 1
        // rather than exhaust the candidate set and drop the microbatch.
        let (mut topo, _, _) = setup();
        // Slow 0 -> 2: the rerouted mb1 reaches node 2 only after mb0
        // has freed node 1 (~25 s round trip vs a 60 s control link).
        topo.links_mut()[0][2] = crate::cost::LinkParams::new(60.0, 1e9);
        // Node 4 is glacial, so mb2 stays resident at node 2 throughout.
        topo.set_profile(NodeId(4), NodeProfile::new(200.0, 2));
        let graph = std::sync::Arc::new(StageGraph {
            stages: vec![vec![NodeId(1), NodeId(2)], vec![NodeId(3), NodeId(4)]],
            data_nodes: vec![NodeId(0)],
        });
        let prob = FlowProblem {
            graph,
            cap: vec![8, 1, 1, 2, 2],
            demand: vec![3],
            cost: Box::new(|_i, _j| 1.0),
        };
        let paths = vec![
            FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3)] },
            FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3)] },
            FlowPath { source: NodeId(0), relays: vec![NodeId(2), NodeId(4)] },
        ];
        let cfg = TrainingSimConfig {
            payload_bytes: 1e6,
            stage_param_bytes: 1e6,
            timeout_s: 1.0,
            max_restarts: 3,
            initial_iter_estimate_s: 1000.0,
            bwd_factor: 2.0,
            deadline_factor: 4.0,
            staleness_bound: None,
        };
        let mut sim = TrainingSim::new(topo, cfg);
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let churn_state =
            ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        let mut rng = Rng::new(0);
        let m = sim.run_schedule(
            &prob,
            &mut router,
            &WorldSchedule::default(),
            &churn_state,
            0.0,
            paths,
            None,
            &mut rng,
        );
        assert_eq!(m.denies, 2, "mb1 must be DENYed at node 1 and again at node 2");
        assert_eq!(m.dropped, 0, "the freed node 1 must be re-admitted");
        assert_eq!(m.completed, 3);
    }

    #[test]
    fn mid_iteration_join_provides_recovery_candidate() {
        // Stage 1 = {3, 4}; node 4 starts dead, node 3 crashes at t=0.
        // Without the join the microbatches through stage 1 are stuck; a
        // mid-iteration join of node 4 (continuous-time only) lets the
        // forward recovery pick it up once it is born.
        let (topo, prob, paths) = setup();
        let mut sim = TrainingSim::new(topo, small_cfg());
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let mut churn_state =
            ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        churn_state.alive[4] = false;
        let mut rng = Rng::new(0);

        let stuck = WorldSchedule { crashes: vec![(NodeId(3), 0.0)], ..Default::default() };
        let m_stuck = sim.run_schedule(
            &prob, &mut router, &stuck, &churn_state, 0.0, paths.clone(), None, &mut rng,
        );
        assert_eq!(m_stuck.completed, 0, "no stage-1 node available");

        let rejoined = WorldSchedule {
            crashes: vec![(NodeId(3), 0.0)],
            joins: vec![(NodeId(4), 1.0)],
            ..Default::default()
        };
        let m_joined = sim.run_schedule(
            &prob, &mut router, &rejoined, &churn_state, 0.0, paths, None, &mut rng,
        );
        assert_eq!(m_joined.completed, 2, "joiner must absorb the rerouted flows");
        assert!(m_joined.fwd_recoveries >= 1);
    }

    /// Tentpole degenerate case: `staleness_bound = Some(0)` must walk the
    /// exact synchronous code path — every metric bit-identical to `None`,
    /// across consecutive iterations (evolving iter_estimate) and under
    /// churn.
    #[test]
    fn staleness_zero_and_none_are_bitwise_identical() {
        let run_pair = |staleness: Option<usize>| -> Vec<IterationMetrics> {
            let (topo, prob, paths) = setup();
            let cfg = TrainingSimConfig { staleness_bound: staleness, ..small_cfg() };
            let mut sim = TrainingSim::new(topo, cfg);
            let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
            let churn_state =
                ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
            let mut rng = Rng::new(0);
            let crashy = WorldSchedule { crashes: vec![(NodeId(3), 4.0)], ..Default::default() };
            vec![
                sim.run_schedule(
                    &prob, &mut router, &crashy, &churn_state, 0.0, paths.clone(), None, &mut rng,
                ),
                sim.run_schedule(
                    &prob,
                    &mut router,
                    &WorldSchedule::default(),
                    &churn_state,
                    0.0,
                    paths,
                    None,
                    &mut rng,
                ),
            ]
        };
        let none = run_pair(None);
        let zero = run_pair(Some(0));
        for (a, b) in none.iter().zip(&zero) {
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
            assert_eq!(a.agg_s.to_bits(), b.agg_s.to_bits());
            assert_eq!(a.wasted_gpu_s.to_bits(), b.wasted_gpu_s.to_bits());
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.events, b.events);
            assert_eq!(a.staleness_mean.to_bits(), b.staleness_mean.to_bits());
            assert_eq!(a.deferred, b.deferred);
        }
    }

    /// Tentpole: with `s >= 1` the iteration has no global barrier — each
    /// stage's weight exchange fires on the engine clock as its gradients
    /// land and overlaps the microbatch tail, so the fault-free makespan
    /// is strictly below the synchronous one (which appends the full
    /// BEGIN-AGGREGATION / exchange / CAN-TAKE barrier), while the same
    /// microbatches complete and nothing is deferred or stale.
    #[test]
    fn bounded_staleness_overlaps_rolling_aggregation() {
        let sync = run_schedule_once(&WorldSchedule::default());
        let (topo, prob, paths) = setup();
        let cfg = TrainingSimConfig { staleness_bound: Some(1), ..small_cfg() };
        let mut sim = TrainingSim::new(topo, cfg);
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let churn_state =
            ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        let mut rng = Rng::new(0);
        let m = sim.run_schedule(
            &prob,
            &mut router,
            &WorldSchedule::default(),
            &churn_state,
            0.0,
            paths,
            None,
            &mut rng,
        );
        assert_eq!(m.completed, 2);
        assert_eq!(m.deferred, 0);
        assert_eq!(m.staleness_mean, 0.0);
        assert!(m.agg_s > 0.0, "rolling exchanges must still be charged");
        assert!(
            m.makespan_s < sync.makespan_s,
            "rolling aggregation must beat the barrier: async {} vs sync {}",
            m.makespan_s,
            sync.makespan_s
        );
        // Both stages aggregated: weights advanced to generation 1.
        let v = sim.versioned.as_ref().unwrap();
        assert_eq!(v.iter_gen, 1);
        assert_eq!(v.gen, vec![1, 1]);
    }

    /// Tentpole admission rule: a stage that keeps missing aggregation
    /// (here: both its members are dead, so every microbatch drops) falls
    /// behind the generation stamp; once its lag exceeds `s`, admission is
    /// deferred behind the catch-up exchanges and the deferral shows up in
    /// the metrics.
    #[test]
    fn stalled_stage_defers_and_catches_up() {
        let (topo, prob, paths) = setup();
        let cfg = TrainingSimConfig { staleness_bound: Some(1), ..small_cfg() };
        let mut sim = TrainingSim::new(topo, cfg);
        let mut router = FixedRouter::new(paths.clone(), RecoveryPolicy::RepairPath);
        let mut churn_state =
            ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        // Stage 1 = {3, 4}: both dead for the whole run, so every
        // microbatch drops in the forward pass and no stage ever gets a
        // gradient home — weight generations freeze at 0 while the
        // iteration stamp advances.
        churn_state.alive[3] = false;
        churn_state.alive[4] = false;
        let mut rng = Rng::new(0);
        let mut run = |sim: &mut TrainingSim| {
            sim.run_schedule(
                &prob,
                &mut router,
                &WorldSchedule::default(),
                &churn_state,
                0.0,
                paths.clone(),
                None,
                &mut rng,
            )
        };
        let m1 = run(&mut sim); // g=0, lag 0: admitted immediately
        let m2 = run(&mut sim); // g=1, lag 1 = s: still admitted
        let m3 = run(&mut sim); // g=2, lag 2 > s: catch-up + deferral
        assert_eq!((m1.deferred, m2.deferred), (0, 0));
        assert_eq!(m1.staleness_mean, 0.0);
        assert_eq!(m2.staleness_mean, 1.0, "one generation behind, within the bound");
        assert_eq!(m3.deferred, 2, "every microbatch waits for the catch-up");
        assert_eq!(m3.staleness_mean, 1.0, "catch-up pulls lag back to exactly s");
        assert_eq!(m1.completed + m2.completed + m3.completed, 0);
        // Stage 0 (alive members) replayed one missed exchange; its
        // generation caught back up to g - s.
        let v = sim.versioned.as_ref().unwrap();
        assert_eq!(v.iter_gen, 3);
        assert_eq!(v.gen[0], 1);
    }
}
