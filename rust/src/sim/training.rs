//! Virtual-time simulation of pipelined decentralized training iterations.
//!
//! Reproduces the paper's measurement methodology (§VI): each iteration,
//! every data node pushes its microbatches along the routed flows; the
//! simulator executes forward hops, loss, backward hops and the
//! aggregation barrier with per-node concurrency slots (`cap_i`), link
//! delays from the topology, node crashes mid-iteration, and the recovery
//! protocols (GWTF path repair vs SWARM full-pipeline restart).
//!
//! Reported metrics match the paper's Table II/III rows:
//! - *time per microbatch* — iteration makespan (slowest data node) divided
//!   by microbatches processed,
//! - *throughput* — microbatches completing both passes in the iteration,
//! - *communication time* — total payload transfer seconds,
//! - *wasted GPU time* — compute spent on work excluded from aggregation
//!   (crashed mid-task, orphaned by a broken flow, or recomputed).

use crate::cost::NodeId;
use crate::flow::graph::{FlowPath, FlowProblem};
use crate::net::Topology;
use crate::util::Rng;

use super::churn::{ChurnEvents, ChurnProcess};
use super::events::{EventQueue, Slots, Time};

/// Backward-pass crash recovery policy (the paper's key GWTF/SWARM split).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// GWTF: repair the broken flow in place and resume from the stored
    /// gradient (§V-D "Crashes during the backward pass").
    RepairPath,
    /// SWARM: recompute the entire pipeline for the microbatch.
    RestartPipeline,
}

/// Routing policy plugged into the simulator (GWTF, SWARM, DT-FM, ...).
pub trait Router {
    fn name(&self) -> String;

    /// (Re)plan flows at iteration start. `alive[n]` is current liveness.
    /// Returns the routed paths and the planning wall-time to charge.
    fn plan(&mut self, alive: &[bool]) -> (Vec<FlowPath>, f64);

    /// Notify of a mid-iteration crash so internal state can adapt.
    fn on_crash(&mut self, node: NodeId);

    /// Choose a replacement relay at `stage` for a flow `prev -> X -> next`
    /// whose X crashed. `candidates` are alive nodes with a free slot.
    fn choose_replacement(
        &mut self,
        prev: NodeId,
        next: NodeId,
        stage: usize,
        sink: NodeId,
        candidates: &[NodeId],
    ) -> Option<NodeId>;

    fn recovery(&self) -> RecoveryPolicy;
}

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct TrainingSimConfig {
    /// Activation/gradient payload per hop, bytes (Eq. 1 `size`).
    pub payload_bytes: f64,
    /// Per-stage weight payload exchanged during aggregation, bytes.
    pub stage_param_bytes: f64,
    /// Crash-detection timeout (missing COMPLETE), seconds.
    pub timeout_s: f64,
    /// Maximum pipeline restarts per microbatch before it is dropped.
    pub max_restarts: usize,
    /// Reference iteration length used to place mid-iteration crash
    /// instants (updated online from the previous iteration's makespan).
    pub initial_iter_estimate_s: f64,
    /// Backward compute multiplier (bwd ~ 2x fwd for transformers).
    pub bwd_factor: f64,
    /// Aggregation cutoff: microbatches not home within
    /// `deadline_factor x` the running iteration estimate are "excluded
    /// from aggregation" (the paper's wasted-GPU definition) — data nodes
    /// do not stall the update phase for stragglers.
    pub deadline_factor: f64,
}

impl Default for TrainingSimConfig {
    fn default() -> Self {
        TrainingSimConfig {
            payload_bytes: 4.0 * 512.0 * 1024.0 * 4.0 * 32.0, // paper LLaMA inflated
            stage_param_bytes: 50e6 * 4.0,
            timeout_s: 5.0,
            max_restarts: 3,
            initial_iter_estimate_s: 240.0,
            bwd_factor: 2.0,
            deadline_factor: 2.0,
        }
    }
}

/// Per-iteration outcome (one row sample for Tables II/III).
#[derive(Debug, Clone, Copy, Default)]
pub struct IterationMetrics {
    pub makespan_s: f64,
    pub completed: usize,
    pub scheduled: usize,
    pub comm_s: f64,
    pub wasted_gpu_s: f64,
    pub agg_s: f64,
    pub planning_s: f64,
    pub fwd_recoveries: usize,
    pub bwd_recoveries: usize,
    pub restarts: usize,
    pub dropped: usize,
    /// Memory-overload DENYs (§V-D): a microbatch reached a node whose
    /// `cap_i` concurrent-residency budget was exhausted and was rerouted
    /// or deferred.  Capacity-oblivious routing (SWARM) pays these.
    pub denies: usize,
}

impl IterationMetrics {
    pub fn time_per_microbatch_s(&self) -> f64 {
        if self.completed == 0 {
            f64::INFINITY
        } else {
            self.makespan_s / self.completed as f64
        }
    }
}

/// Phase of a microbatch's journey.
#[derive(Debug, Clone, Copy)]
enum Phase {
    /// Payload left `prev`; arriving at relay index `hop` of its path.
    Fwd { hop: usize },
    /// Arrived back at the data node for loss + head backward.
    Loss,
    /// Gradient arriving at relay index `hop` (descending).
    Bwd { hop: usize },
    /// Gradient arrived back at the data node (embedding backward).
    Finish,
}

#[derive(Debug, Clone)]
struct MicrobatchState {
    path: FlowPath,
    restarts: usize,
    /// Compute seconds spent so far (wasted if the microbatch is dropped).
    compute_spent: f64,
    dropped: bool,
    done_at: Option<Time>,
    /// Relays currently holding this microbatch's forward activation
    /// (memory residency: acquired at forward compute, released when the
    /// backward pass clears the node — the paper's `cap_i` semantics).
    resident: Vec<NodeId>,
    /// Overload reroutes so far (bounded to keep DENY storms finite).
    overload_reroutes: usize,
    /// (stage, node) pairs that DENYed this microbatch — "excluded until
    /// they free memory" (§V-D).
    denied: Vec<(usize, NodeId)>,
}

impl MicrobatchState {
    /// Free every residency this microbatch holds (drop / restart).
    fn release_all(&mut self, inflight: &mut [usize]) {
        for r in self.resident.drain(..) {
            inflight[r.0] = inflight[r.0].saturating_sub(1);
        }
    }
}

/// The training simulator.
pub struct TrainingSim {
    pub topo: Topology,
    pub cfg: TrainingSimConfig,
    /// Virtual availability: node is usable while `alive`, dying at
    /// `death_at` during the current iteration (f64::INFINITY otherwise).
    death_at: Vec<Time>,
    alive: Vec<bool>,
    iter_estimate: f64,
}

impl TrainingSim {
    pub fn new(topo: Topology, cfg: TrainingSimConfig) -> Self {
        let n = topo.n();
        let iter_estimate = cfg.initial_iter_estimate_s;
        TrainingSim { topo, cfg, death_at: vec![f64::INFINITY; n], alive: vec![true; n], iter_estimate }
    }

    fn transfer_s(&self, from: NodeId, to: NodeId) -> f64 {
        self.topo.delay(from, to, self.cfg.payload_bytes)
    }

    fn fwd_compute_s(&self, n: NodeId) -> f64 {
        self.topo.profiles[n.0].compute_s
    }

    fn bwd_compute_s(&self, n: NodeId) -> f64 {
        self.topo.profiles[n.0].compute_s * self.cfg.bwd_factor
    }

    fn is_up(&self, n: NodeId, t: Time) -> bool {
        self.alive[n.0] && t < self.death_at[n.0]
    }

    /// Run one full training iteration.
    ///
    /// `paths`: routed flows (one per microbatch).  `churn`: this
    /// iteration's crash/rejoin schedule.  `prob` gives stage structure
    /// and capacities for recovery candidate search.
    pub fn run_iteration(
        &mut self,
        prob: &FlowProblem,
        router: &mut dyn Router,
        churn: &ChurnEvents,
        churn_state: &ChurnProcess,
        planning_s: f64,
        paths: Vec<FlowPath>,
        _rng: &mut Rng,
    ) -> IterationMetrics {
        let n = self.topo.n();
        // Liveness at iteration start (rejoins already applied by caller).
        for i in 0..n {
            self.alive[i] = churn_state.alive[i];
            self.death_at[i] = f64::INFINITY;
        }
        // Nodes crashing mid-iteration die at frac * current estimate.
        for &(node, frac) in &churn.crashes {
            self.alive[node.0] = true; // alive until its death instant
            self.death_at[node.0] = frac * self.iter_estimate;
        }

        let mut metrics = IterationMetrics { scheduled: paths.len(), planning_s, ..Default::default() };
        let mut slots: Vec<Slots> = (0..n).map(|i| Slots::new(prob.cap[i].max(1))).collect();
        // Memory residency per node (forward activations awaiting backward).
        let mut inflight: Vec<usize> = vec![0; n];
        let mut mbs: Vec<MicrobatchState> = paths
            .into_iter()
            .map(|p| MicrobatchState {
                path: p,
                restarts: 0,
                compute_spent: 0.0,
                dropped: false,
                done_at: None,
                resident: Vec::new(),
                overload_reroutes: 0,
                denied: Vec::new(),
            })
            .collect();

        let mut q: EventQueue<(usize, Phase)> = EventQueue::new();
        // Data nodes send out all their microbatches at t=0 (transfer to hop 0).
        for (mi, mb) in mbs.iter().enumerate() {
            let d = mb.path.source;
            let first = mb.path.relays[0];
            let dt = self.transfer_s(d, first);
            metrics.comm_s += dt;
            q.schedule(dt, (mi, Phase::Fwd { hop: 0 }));
        }

        // Stragglers past the aggregation cutoff are excluded (wasted).
        let deadline = self.cfg.deadline_factor * self.iter_estimate;
        while let Some((t, (mi, phase))) = q.pop() {
            if mbs[mi].dropped {
                continue;
            }
            if t > deadline && mbs[mi].done_at.is_none() {
                mbs[mi].release_all(&mut inflight);
                mbs[mi].dropped = true;
                continue;
            }
            match phase {
                Phase::Fwd { hop } => {
                    self.handle_relay_compute(
                        t, mi, hop, /*is_fwd=*/ true, prob, router, &mut slots, &mut inflight,
                        &mut mbs, &mut q, &mut metrics,
                    );
                }
                Phase::Loss => {
                    // Loss + head backward at the data node (always alive).
                    let d = mbs[mi].path.source;
                    let c = self.fwd_compute_s(d) + self.bwd_compute_s(d);
                    mbs[mi].compute_spent += c;
                    let last = mbs[mi].path.relays.len() - 1;
                    let nxt = mbs[mi].path.relays[last];
                    let dt = self.transfer_s(d, nxt);
                    metrics.comm_s += dt;
                    q.schedule(t + c + dt, (mi, Phase::Bwd { hop: last }));
                }
                Phase::Bwd { hop } => {
                    self.handle_relay_compute(
                        t, mi, hop, /*is_fwd=*/ false, prob, router, &mut slots, &mut inflight,
                        &mut mbs, &mut q, &mut metrics,
                    );
                }
                Phase::Finish => {
                    // Embedding backward at the data node.
                    let d = mbs[mi].path.source;
                    let c = self.bwd_compute_s(d);
                    mbs[mi].compute_spent += c;
                    mbs[mi].done_at = Some(t + c);
                }
            }
        }

        // Tally results.
        let mut makespan: f64 = 0.0;
        for mb in &mbs {
            match mb.done_at {
                Some(t) => {
                    metrics.completed += 1;
                    makespan = makespan.max(t);
                }
                None => {
                    metrics.dropped += 1;
                    metrics.wasted_gpu_s += mb.compute_spent;
                }
            }
        }

        // Aggregation barrier (§V-E): BEGIN AGGREGATION propagates forward,
        // stages exchange weights internally, CAN TAKE propagates back.
        let agg = self.aggregation_time(prob, churn_state);
        metrics.agg_s = agg;
        metrics.makespan_s = makespan + agg + planning_s;
        // EMA keeps the crash-instant / deadline reference stable.  Only
        // productive iterations update it: a zero-completion iteration has
        // a tiny makespan, and folding that in would shrink the next
        // deadline and wedge the system in a drop-everything spiral.
        if metrics.completed > 0 {
            self.iter_estimate = (0.5 * self.iter_estimate + 0.5 * metrics.makespan_s)
                .max(self.cfg.initial_iter_estimate_s * 0.1)
                .max(1e-6);
        }
        metrics
    }

    /// Relay-stage compute (fwd or bwd) with crash detection + recovery.
    #[allow(clippy::too_many_arguments)]
    fn handle_relay_compute(
        &mut self,
        t: Time,
        mi: usize,
        hop: usize,
        is_fwd: bool,
        prob: &FlowProblem,
        router: &mut dyn Router,
        slots: &mut [Slots],
        inflight: &mut [usize],
        mbs: &mut Vec<MicrobatchState>,
        q: &mut EventQueue<(usize, Phase)>,
        metrics: &mut IterationMetrics,
    ) {
        let path = mbs[mi].path.clone();
        let node = path.relays[hop];
        let sink = path.source;
        let n_stages = path.relays.len();
        let prev: NodeId = if is_fwd {
            if hop == 0 { sink } else { path.relays[hop - 1] }
        } else if hop + 1 < n_stages {
            path.relays[hop + 1]
        } else {
            sink
        };
        let next: NodeId = if is_fwd {
            if hop + 1 < n_stages { path.relays[hop + 1] } else { sink }
        } else if hop == 0 {
            sink
        } else {
            path.relays[hop - 1]
        };

        let compute = if is_fwd { self.fwd_compute_s(node) } else { self.bwd_compute_s(node) };

        // Memory overload (§V-D DENY): a forward arrival at a node whose
        // residency budget is exhausted cannot be accepted — the upstream
        // node reroutes to a peer with spare memory or defers the batch.
        // Capacity-aware planning (GWTF) never trips this; SWARM's
        // capacity-oblivious wiring does.
        if is_fwd && self.is_up(node, t) && inflight[node.0] >= prob.cap[node.0] {
            metrics.denies += 1;
            mbs[mi].overload_reroutes += 1;
            mbs[mi].denied.push((hop, node));
            if mbs[mi].overload_reroutes > 4 * n_stages {
                mbs[mi].release_all(inflight);
                mbs[mi].dropped = true;
                return;
            }
            // The upstream node only learns a peer is full when that peer
            // DENYs; it retries the next-best peer it knows, which may be
            // full too ("this process can continue recursively", SV-D).
            // It has NO global memory view, so candidates are filtered only
            // by received DENYs, not by actual residency.
            let denied = &mbs[mi].denied;
            let candidates: Vec<NodeId> = prob.graph.stages[hop]
                .iter()
                .filter(|&&m| {
                    m != node && self.is_up(m, t) && !denied.contains(&(hop, m))
                })
                .copied()
                .collect();
            match router.choose_replacement(prev, next, hop, sink, &candidates) {
                Some(m) => {
                    let dt = self.transfer_s(prev, m);
                    metrics.comm_s += dt;
                    let mut newpath = path.clone();
                    newpath.relays[hop] = m;
                    mbs[mi].path = newpath;
                    q.schedule(t + dt, (mi, Phase::Fwd { hop }));
                }
                None => {
                    // DENY propagates to the source; deferred to next iter.
                    mbs[mi].release_all(inflight);
                    mbs[mi].dropped = true;
                }
            }
            return;
        }

        if self.is_up(node, t) {
            let start = slots[node.0].earliest_start(t);
            let end = start + compute;
            let death = self.death_at[node.0];
            if start < death && end <= death {
                // Success: book the slot, forward the payload.
                slots[node.0].book(start, end);
                mbs[mi].compute_spent += compute;
                if is_fwd {
                    // activation stays resident until the backward clears
                    inflight[node.0] += 1;
                    mbs[mi].resident.push(node);
                } else if let Some(pos) = mbs[mi].resident.iter().position(|&r| r == node) {
                    mbs[mi].resident.remove(pos);
                    inflight[node.0] = inflight[node.0].saturating_sub(1);
                }
                let dt = self.transfer_s(node, next);
                metrics.comm_s += dt;
                let arrive = end + dt;
                let next_phase = if is_fwd {
                    if hop + 1 < n_stages { Phase::Fwd { hop: hop + 1 } } else { Phase::Loss }
                } else if hop == 0 {
                    Phase::Finish
                } else {
                    Phase::Bwd { hop: hop - 1 }
                };
                // If the receiver is a relay that might be dead on arrival,
                // the crash branch below (on its own event) handles it.
                q.schedule(arrive, (mi, next_phase));
                return;
            }
            // Node dies mid-task: partial work is wasted, crash detected
            // after the COMPLETE timeout.
            if start < death {
                metrics.wasted_gpu_s += death - start;
            }
        }

        // --- crash handling ---
        let death = self.death_at[node.0].min(t);
        let detect = death.max(t) + self.cfg.timeout_s;
        router.on_crash(node);

        let stage = hop;
        if is_fwd {
            metrics.fwd_recoveries += 1;
            // Reroute to an alive same-stage replacement with a free slot.
            let with_memory: Vec<NodeId> = prob.graph.stages[stage]
                .iter()
                .filter(|&&m| {
                    m != node
                        && self.is_up(m, detect)
                        && slots[m.0].in_use_at(detect) < slots[m.0].cap
                        && inflight[m.0] < prob.cap[m.0]
                })
                .copied()
                .collect();
            // If every alive peer is memory-full right now, wait one
            // timeout for residencies to clear (flows keep draining) and
            // retry the best alive peer; the Fwd-arrival overload branch
            // DENY-reroutes again if it is still full.
            let (candidates, wait) = if with_memory.is_empty() {
                let alive_only: Vec<NodeId> = prob.graph.stages[stage]
                    .iter()
                    .filter(|&&m| m != node && self.is_up(m, detect))
                    .copied()
                    .collect();
                (alive_only, self.cfg.timeout_s)
            } else {
                (with_memory, 0.0)
            };
            match router.choose_replacement(prev, next, stage, sink, &candidates) {
                Some(m) => {
                    // prev resends its stored activation to m.
                    let dt = self.transfer_s(prev, m);
                    metrics.comm_s += dt;
                    let mut newpath = path.clone();
                    newpath.relays[hop] = m;
                    mbs[mi].path = newpath;
                    q.schedule(detect + wait + dt, (mi, Phase::Fwd { hop }));
                }
                None => {
                    // DENY up to the source; batch deferred to next iteration.
                    mbs[mi].release_all(inflight);
                    mbs[mi].dropped = true;
                }
            }
        } else {
            metrics.bwd_recoveries += 1;
            match router.recovery() {
                RecoveryPolicy::RepairPath => {
                    // §V-D: replacement recomputes this stage's forward from
                    // the stored upstream activation, then the backward pass
                    // resumes from the stored gradient.
                    let with_memory: Vec<NodeId> = prob.graph.stages[stage]
                        .iter()
                        .filter(|&&m| {
                            m != node
                                && self.is_up(m, detect)
                                && slots[m.0].in_use_at(detect) < slots[m.0].cap
                                && inflight[m.0] < prob.cap[m.0]
                        })
                        .copied()
                        .collect();
                    // memory-full everywhere: wait one timeout for a
                    // residency to clear rather than dropping the batch
                    let (candidates, wait) = if with_memory.is_empty() {
                        let alive_only: Vec<NodeId> = prob.graph.stages[stage]
                            .iter()
                            .filter(|&&m| m != node && self.is_up(m, detect))
                            .copied()
                            .collect();
                        (alive_only, self.cfg.timeout_s)
                    } else {
                        (with_memory, 0.0)
                    };
                    match router.choose_replacement(prev, next, stage, sink, &candidates) {
                        Some(m) => {
                            // fetch activation from the fwd-side neighbour +
                            // recompute fwd at m, then continue bwd at m.
                            let dt_act = self.transfer_s(prev, m);
                            let refwd = self.fwd_compute_s(m);
                            mbs[mi].compute_spent += refwd;
                            metrics.comm_s += dt_act;
                            // residency moves from the dead node to m
                            if let Some(pos) = mbs[mi].resident.iter().position(|&r| r == node) {
                                mbs[mi].resident.remove(pos);
                                inflight[node.0] = inflight[node.0].saturating_sub(1);
                            }
                            inflight[m.0] += 1;
                            mbs[mi].resident.push(m);
                            let mut newpath = path.clone();
                            newpath.relays[hop] = m;
                            mbs[mi].path = newpath;
                            q.schedule(detect + wait + dt_act + refwd, (mi, Phase::Bwd { hop }));
                        }
                        None => {
                            mbs[mi].release_all(inflight);
                            mbs[mi].dropped = true;
                        }
                    }
                }
                RecoveryPolicy::RestartPipeline => {
                    // SWARM: all work on this microbatch is discarded and the
                    // whole pipeline re-executes from the data node.
                    metrics.restarts += 1;
                    metrics.wasted_gpu_s += mbs[mi].compute_spent;
                    mbs[mi].compute_spent = 0.0;
                    mbs[mi].release_all(inflight);
                    if mbs[mi].restarts + 1 > self.cfg.max_restarts {
                        mbs[mi].dropped = true;
                        return;
                    }
                    mbs[mi].restarts += 1;
                    // Re-wire dead relays before restarting.
                    let mut newpath = mbs[mi].path.clone();
                    for (s, r) in newpath.relays.clone().into_iter().enumerate() {
                        if !self.is_up(r, detect) {
                            let candidates: Vec<NodeId> = prob.graph.stages[s]
                                .iter()
                                .filter(|&&m| m != r && self.is_up(m, detect))
                                .copied()
                                .collect();
                            match router.choose_replacement(
                                if s == 0 { sink } else { newpath.relays[s - 1] },
                                if s + 1 < n_stages { newpath.relays[s + 1] } else { sink },
                                s,
                                sink,
                                &candidates,
                            ) {
                                Some(m) => newpath.relays[s] = m,
                                None => {
                                    mbs[mi].release_all(inflight);
                                    mbs[mi].dropped = true;
                                    return;
                                }
                            }
                        }
                    }
                    mbs[mi].path = newpath;
                    let d = mbs[mi].path.source;
                    let first = mbs[mi].path.relays[0];
                    let dt = self.transfer_s(d, first);
                    metrics.comm_s += dt;
                    q.schedule(detect + dt, (mi, Phase::Fwd { hop: 0 }));
                }
            }
        }
    }

    /// §V-E training/aggregation synchronization barrier duration.
    fn aggregation_time(&self, prob: &FlowProblem, churn: &ChurnProcess) -> f64 {
        const CTRL_BYTES: f64 = 1024.0;
        let mut fwd_ctrl: f64 = 0.0;
        let mut back_ctrl: f64 = 0.0;
        let mut exchange: f64 = 0.0;
        let data = prob.graph.data_nodes[0];
        let mut prev_stage: Vec<NodeId> = vec![data];
        for s in 0..prob.graph.n_stages() {
            let members: Vec<NodeId> = prob.graph.stages[s]
                .iter()
                .filter(|&&m| churn.is_alive(m))
                .copied()
                .collect();
            if members.is_empty() {
                continue;
            }
            // BEGIN AGGREGATION flood: worst link from any previous-stage node.
            let hop = prev_stage
                .iter()
                .flat_map(|&p| members.iter().map(move |&m| self.topo.delay(p, m, CTRL_BYTES)))
                .fold(0.0f64, f64::max);
            fwd_ctrl += hop;
            back_ctrl += hop; // CAN TAKE travels the same boundary backwards
            // Intra-stage weight broadcast (pairs exchange in parallel).
            let mut worst: f64 = 0.0;
            for &a in &members {
                for &b in &members {
                    if a != b {
                        worst = worst.max(self.topo.delay(a, b, self.cfg.stage_param_bytes));
                    }
                }
            }
            exchange = exchange.max(worst);
            prev_stage = members;
        }
        fwd_ctrl + exchange + back_ctrl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::NodeProfile;
    use crate::flow::graph::StageGraph;
    use crate::net::TopologyConfig;

    /// Trivial fixed router for tests: static paths, first-candidate reroute.
    struct FixedRouter {
        paths: Vec<FlowPath>,
        policy: RecoveryPolicy,
    }

    impl Router for FixedRouter {
        fn name(&self) -> String {
            "fixed".into()
        }
        fn plan(&mut self, _alive: &[bool]) -> (Vec<FlowPath>, f64) {
            (self.paths.clone(), 0.0)
        }
        fn on_crash(&mut self, _node: NodeId) {}
        fn choose_replacement(
            &mut self,
            _prev: NodeId,
            _next: NodeId,
            _stage: usize,
            _sink: NodeId,
            candidates: &[NodeId],
        ) -> Option<NodeId> {
            candidates.first().copied()
        }
        fn recovery(&self) -> RecoveryPolicy {
            self.policy
        }
    }

    fn setup() -> (Topology, FlowProblem, Vec<FlowPath>) {
        // data node 0; stage0 {1,2}; stage1 {3,4}; 2 microbatches
        let mut rng = Rng::new(42);
        let mut topo = Topology::generate(
            &TopologyConfig { n_nodes: 5, ..Default::default() },
            &mut rng,
        );
        for i in 0..5 {
            topo.set_profile(NodeId(i), NodeProfile::new(2.0, 2));
        }
        let graph = StageGraph {
            stages: vec![vec![NodeId(1), NodeId(2)], vec![NodeId(3), NodeId(4)]],
            data_nodes: vec![NodeId(0)],
        };
        let prob = FlowProblem {
            graph,
            cap: vec![4, 2, 2, 2, 2],
            demand: vec![2],
            cost: Box::new(|_i, _j| 1.0),
        };
        let paths = vec![
            FlowPath { source: NodeId(0), relays: vec![NodeId(1), NodeId(3)] },
            FlowPath { source: NodeId(0), relays: vec![NodeId(2), NodeId(4)] },
        ];
        (topo, prob, paths)
    }

    fn small_cfg() -> TrainingSimConfig {
        TrainingSimConfig {
            payload_bytes: 1e6,
            stage_param_bytes: 1e6,
            timeout_s: 1.0,
            max_restarts: 3,
            initial_iter_estimate_s: 30.0,
            bwd_factor: 2.0,
            deadline_factor: 4.0,
        }
    }

    fn run_once(policy: RecoveryPolicy, crashes: Vec<(NodeId, f64)>) -> IterationMetrics {
        let (topo, prob, paths) = setup();
        let mut sim = TrainingSim::new(topo, small_cfg());
        let mut router = FixedRouter { paths: paths.clone(), policy };
        let churn_state = ChurnProcess::new(5, vec![NodeId(1), NodeId(2), NodeId(3), NodeId(4)], 0.0, 7);
        let churn = ChurnEvents { crashes, rejoins: vec![] };
        let mut rng = Rng::new(0);
        sim.run_iteration(&prob, &mut router, &churn, &churn_state, 0.0, paths, &mut rng)
    }

    #[test]
    fn fault_free_completes_everything() {
        let m = run_once(RecoveryPolicy::RepairPath, vec![]);
        assert_eq!(m.completed, 2);
        assert_eq!(m.dropped, 0);
        assert_eq!(m.wasted_gpu_s, 0.0);
        assert!(m.makespan_s > 0.0);
        assert!(m.comm_s > 0.0);
        assert!(m.agg_s > 0.0);
        assert!(m.time_per_microbatch_s().is_finite());
    }

    #[test]
    fn fwd_crash_recovers_via_reroute() {
        // Node 1 dies immediately: microbatch 0 must reroute to node 2.
        let m = run_once(RecoveryPolicy::RepairPath, vec![(NodeId(1), 0.0)]);
        assert_eq!(m.completed, 2);
        assert!(m.fwd_recoveries >= 1);
    }

    #[test]
    fn bwd_crash_repair_cheaper_than_restart() {
        // Node dies late (during backward pass window).
        let frac = 0.4;
        let repair = run_once(RecoveryPolicy::RepairPath, vec![(NodeId(3), frac)]);
        let restart = run_once(RecoveryPolicy::RestartPipeline, vec![(NodeId(3), frac)]);
        assert_eq!(repair.completed, 2);
        assert_eq!(restart.completed, 2);
        assert!(
            repair.makespan_s <= restart.makespan_s + 1e-9,
            "repair {} vs restart {}",
            repair.makespan_s,
            restart.makespan_s
        );
        assert!(repair.wasted_gpu_s <= restart.wasted_gpu_s + 1e-9);
    }

    #[test]
    fn whole_stage_dead_drops_microbatch() {
        let m = run_once(
            RecoveryPolicy::RepairPath,
            vec![(NodeId(1), 0.0), (NodeId(2), 0.0)],
        );
        assert_eq!(m.completed, 0);
        assert_eq!(m.dropped, 2);
    }

    #[test]
    fn restart_counts_wasted_gpu() {
        let m = run_once(RecoveryPolicy::RestartPipeline, vec![(NodeId(3), 0.4)]);
        assert!(m.restarts >= 1);
        assert!(m.wasted_gpu_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_once(RecoveryPolicy::RepairPath, vec![(NodeId(1), 0.3)]);
        let b = run_once(RecoveryPolicy::RepairPath, vec![(NodeId(1), 0.3)]);
        assert_eq!(a.completed, b.completed);
        assert!((a.makespan_s - b.makespan_s).abs() < 1e-12);
    }

    #[test]
    fn makespan_includes_aggregation_and_planning() {
        let (topo, prob, paths) = setup();
        let mut sim = TrainingSim::new(topo, small_cfg());
        let mut router = FixedRouter { paths: paths.clone(), policy: RecoveryPolicy::RepairPath };
        let churn_state = ChurnProcess::new(5, vec![], 0.0, 7);
        let churn = ChurnEvents::default();
        let mut rng = Rng::new(0);
        let m = sim.run_iteration(&prob, &mut router, &churn, &churn_state, 3.0, paths, &mut rng);
        assert!(m.makespan_s >= m.agg_s + 3.0);
        assert_eq!(m.planning_s, 3.0);
    }
}
