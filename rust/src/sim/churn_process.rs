//! Continuous-clock Poisson churn sampler (paper §VI "Node Crashes",
//! continuous-time refinement).
//!
//! The legacy model in [`super::churn`] flips a Bernoulli coin per relay
//! per iteration — every liveness change is synchronized to an iteration
//! boundary.  This module samples each relay's crash/rejoin *transitions*
//! from a memoryless hazard instead: inter-arrival times are exponential
//! with a constant rate, so arrivals land at arbitrary instants of the
//! continuous virtual clock and residual waiting times carry across
//! iteration boundaries.  That is the arrival structure robustness
//! studies of decentralized training assume (see PAPERS.md: Lu et al.,
//! FusionLLM), and it is what PR 1's engine was built to dispatch.
//!
//! # Rate-equivalence mapping
//!
//! A legacy join-leave chance `p` flips each relay with probability `p`
//! per iteration regardless of its current state, i.e. an expected `p`
//! transitions per relay-iteration.  An always-on hazard `rate` produces
//! exactly `rate` expected transitions per relay-iteration (the
//! transition stream of one relay is a Poisson process: the hazard does
//! not depend on whether the relay is currently alive or dead).  So the
//! paper's 0%/10%/20% configs map to `rate = p` per iteration
//! ([`PoissonChurn::rate_for_chance`]); the models agree on expected
//! churn per iteration.  The Poisson model then sees at least one
//! transition in an iteration with probability `1 - exp(-p)` and a *net*
//! state flip (odd transition count) with probability
//! `(1 - exp(-2p)) / 2` — both slightly below `p`, because multiple
//! transitions per iteration are possible and an even count cancels out.
//!
//! The raw transition stream ([`PoissonChurn::advance_iteration`]) is
//! exact — `rust/tests/churn_stats.rs` validates it with seeded KS and
//! chi-square checks against the configured exponential law.  The
//! engine-facing collapse to one liveness window per iteration lives in
//! [`super::churn::ChurnProcess`].

use crate::cost::NodeId;
use crate::util::Rng;

/// One crash/rejoin transition of the continuous-clock process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transition {
    pub node: NodeId,
    /// Instant inside the iteration, as a fraction in `[0, 1)`.
    pub at: f64,
    /// `true` = alive → dead (crash); `false` = dead → alive (rejoin).
    pub crash: bool,
}

/// Per-relay exponential crash/rejoin clocks, advanced one iteration at a
/// time.  Deterministic from its seed; clock residuals carry across
/// iteration boundaries so the process is genuinely continuous.
#[derive(Debug, Clone)]
pub struct PoissonChurn {
    /// Transition hazard per relay, in expected events per iteration.
    pub rate: f64,
    relays: Vec<NodeId>,
    /// True process liveness per relay (indexed like `relays`).
    alive: Vec<bool>,
    /// Residual time to each relay's next transition, iteration units.
    next_in: Vec<f64>,
    rng: Rng,
}

/// Draw an exponential inter-arrival time (iteration units).  Floored at
/// a subnormal-safe epsilon so per-relay arrival times are strictly
/// increasing even on the astronomically unlikely zero draw.
fn sample_exp(rng: &mut Rng, rate: f64) -> f64 {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    // f64() is in [0, 1): 1 - u is in (0, 1], so ln is finite.
    (-(1.0 - rng.f64()).ln() / rate).max(1e-12)
}

impl PoissonChurn {
    /// Hazard equivalent to a legacy per-iteration join-leave chance `p`
    /// (expected-transitions-per-iteration equivalence; module docs).
    pub fn rate_for_chance(p: f64) -> f64 {
        p
    }

    pub fn new(relays: Vec<NodeId>, rate: f64, seed: u64) -> Self {
        assert!(rate >= 0.0 && rate.is_finite(), "churn rate must be finite and >= 0, got {rate}");
        let mut rng = Rng::new(seed);
        let n = relays.len();
        let next_in = (0..n).map(|_| sample_exp(&mut rng, rate)).collect();
        PoissonChurn { rate, relays, alive: vec![true; n], next_in, rng }
    }

    /// True process liveness of relay index `i` (for invariant tests; the
    /// engine's liveness authority is [`super::churn::ChurnProcess`]).
    pub fn relay_alive(&self, i: usize) -> bool {
        self.alive[i]
    }

    pub fn relays(&self) -> &[NodeId] {
        &self.relays
    }

    /// Reconcile the process's internal liveness with the authority's
    /// `alive` view (indexed by node id) before sampling an iteration.
    ///
    /// Other event sources may kill or revive relays behind the model's
    /// back (the engine applies their crashes/joins to the authority
    /// after each iteration); the exponential clocks are memoryless, so
    /// adopting the externally-imposed state and keeping each residual
    /// unchanged is exactly the conditional law of the process — the next
    /// transition of an externally-killed relay simply becomes a rejoin.
    pub fn sync_liveness(&mut self, alive: &[bool]) {
        for (i, &node) in self.relays.iter().enumerate() {
            if let Some(&up) = alive.get(node.0) {
                self.alive[i] = up;
            }
        }
    }

    /// Advance every relay's clock by one iteration and return the
    /// transitions that fired, with `at` fractions in `[0, 1)`.  Relays
    /// are visited in order and each relay's transitions are emitted in
    /// time order, so the stream is deterministic for a fixed seed.
    pub fn advance_iteration(&mut self) -> Vec<Transition> {
        let mut out = Vec::new();
        for i in 0..self.relays.len() {
            let node = self.relays[i];
            let mut elapsed = 0.0;
            // Fire every transition that lands inside this iteration.
            while elapsed + self.next_in[i] < 1.0 {
                elapsed += self.next_in[i];
                self.alive[i] = !self.alive[i];
                out.push(Transition { node, at: elapsed, crash: !self.alive[i] });
                self.next_in[i] = sample_exp(&mut self.rng, self.rate);
            }
            // Carry the residual across the boundary (INFINITY for rate 0
            // stays INFINITY).  The loop exits on fl(elapsed + next_in)
            // >= 1.0, which in floating point does not quite imply
            // next_in >= 1.0 - elapsed; floor the carried residual like
            // the zero-draw case so `at` can never go negative.
            self.next_in[i] = (self.next_in[i] - (1.0 - elapsed)).max(1e-12);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn relays(n: usize) -> Vec<NodeId> {
        (0..n).map(NodeId).collect()
    }

    #[test]
    fn zero_rate_never_fires() {
        let mut pc = PoissonChurn::new(relays(8), 0.0, 1);
        for _ in 0..50 {
            assert!(pc.advance_iteration().is_empty());
        }
        assert!((0..8).all(|i| pc.relay_alive(i)));
    }

    #[test]
    fn transitions_alternate_starting_with_a_crash() {
        let mut pc = PoissonChurn::new(relays(4), 2.0, 7);
        let mut expect_crash = vec![true; 4];
        let mut fired = 0;
        for _ in 0..30 {
            for tr in pc.advance_iteration() {
                assert_eq!(tr.crash, expect_crash[tr.node.0], "{tr:?}");
                expect_crash[tr.node.0] = !expect_crash[tr.node.0];
                fired += 1;
            }
        }
        assert!(fired > 100, "rate 2.0 over 4x30 node-iterations fired only {fired}");
    }

    #[test]
    fn fractions_in_unit_interval_and_increasing_per_relay() {
        let mut pc = PoissonChurn::new(relays(6), 1.5, 11);
        let mut last = vec![-1.0f64; 6];
        for iter in 0..40 {
            for tr in pc.advance_iteration() {
                assert!((0.0..1.0).contains(&tr.at), "{}", tr.at);
                let t = iter as f64 + tr.at;
                assert!(t > last[tr.node.0], "arrivals must strictly increase");
                last[tr.node.0] = t;
            }
        }
    }

    #[test]
    fn residuals_carry_across_iterations() {
        // The first arrival's absolute time must equal the first
        // exponential draw exactly (up to boundary-subtraction rounding),
        // however many iteration boundaries it crosses — the clock
        // carries its residual, it does not reset each iteration.
        let rate = 0.05;
        let mut want_rng = Rng::new(3);
        let want = -(1.0 - want_rng.f64()).ln() / rate;
        let mut pc = PoissonChurn::new(relays(1), rate, 3);
        let mut first = None;
        for iter in 0..2000 {
            if let Some(tr) = pc.advance_iteration().first() {
                first = Some(iter as f64 + tr.at);
                break;
            }
        }
        let got = first.expect("rate 0.05 over 2000 iterations must fire");
        assert!(
            (got - want).abs() < 1e-6 * want.max(1.0),
            "first arrival {got} vs single draw {want}"
        );
    }

    #[test]
    fn deterministic_stream_for_fixed_seed() {
        let mut a = PoissonChurn::new(relays(5), 0.7, 99);
        let mut b = PoissonChurn::new(relays(5), 0.7, 99);
        for _ in 0..50 {
            let (ea, eb) = (a.advance_iteration(), b.advance_iteration());
            assert_eq!(ea.len(), eb.len());
            for (x, y) in ea.iter().zip(&eb) {
                assert_eq!(x.node, y.node);
                assert_eq!(x.crash, y.crash);
                assert_eq!(x.at.to_bits(), y.at.to_bits());
            }
        }
    }
}
