//! Adversarial relay behavior models (ISSUE 9 tentpole, part 1).
//!
//! A scenario may attach an [`AdversaryRoster`] assigning a [`Behavior`]
//! to a subset of relays.  Four behaviors model the misbehavior taxonomy
//! from the decentralized-training robustness literature (PAPERS.md):
//!
//! - **Free-riders** advertise phantom capacity: the planner sees an
//!   inflated microbatch capacity (`cap * phantom_cap_factor`) and
//!   over-subscribes the relay, but at runtime the relay only honors its
//!   *true* capacity, so the surplus admissions bounce as DENYs.
//! - **DENY-storm** relays accept microbatches at planning time and then
//!   refuse every arrival (§V-D DENY) regardless of actual occupancy,
//!   forcing the router's replacement machinery on every hop.
//! - **Deliberate stragglers** inflate their service time by a constant
//!   factor, emitted as a persistent [`Slowdown`] through the normal
//!   [`EventSource`] channel so the engine's compute-factor scan picks
//!   it up without any hot-path branching.
//! - **Eclipse attackers** lie during gossip shuffles: after every
//!   shuffle round they overwrite one active-view slot of each adjacent
//!   victim with themselves (see `Overlay::apply_eclipse_lies`),
//!   monopolizing the victim's planning view.
//!
//! The roster is *assignment-deterministic*: given the same stage layout
//! and config it always picks the same relays (round-robin across
//! stages, from the back of each stage's member list) and cycles the
//! four behaviors in a fixed order.  No RNG is consumed, so attaching a
//! roster never perturbs the churn/jitter draws of the legacy engine.
//!
//! **Zero-overhead guarantee**: when no roster is configured the
//! `TrainingSim` fields stay `None`, the handler sites reduce to the
//! legacy predicates, and the engine's source list is unchanged — the
//! parity tests in `rust/tests/adversary_guard.rs` pin this bit for
//! bit.  The defense side lives in [`crate::net::reputation`].

use std::sync::Arc;

use super::engine::{EventSource, Slowdown, WorldSchedule};
use super::events::Time;
use super::sources::SPAN_FACTOR;
use crate::cost::NodeId;
use crate::trace::{self, TraceKind, TraceRecord};

/// Per-relay misbehavior model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Behavior {
    /// Advertises `advertised_cap` microbatch slots to the planner while
    /// only honoring the true capacity at runtime.
    FreeRider {
        /// Capacity the planner is shown (strictly above the true cap).
        advertised_cap: usize,
    },
    /// Accepts microbatches at planning time, refuses every arrival.
    DenyStorm,
    /// Inflates compute service time by `factor` (> 1).
    Straggler {
        /// Multiplier applied to the relay's compute time.
        factor: f64,
    },
    /// Lies in gossip shuffles to monopolize neighbors' views.
    Eclipse,
}

/// Knobs for building an [`AdversaryRoster`]; attach via
/// `ScenarioConfig::adversaries`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdversaryConfig {
    /// Fraction of relays that misbehave (rounded to a global count).
    pub fraction: f64,
    /// Service-time multiplier for `Straggler` relays.
    pub straggler_factor: f64,
    /// Capacity multiplier advertised by `FreeRider` relays.
    pub phantom_cap_factor: usize,
}

impl AdversaryConfig {
    /// Default behavior mix at malicious fraction `fraction`.
    pub fn with_fraction(fraction: f64) -> Self {
        AdversaryConfig { fraction, straggler_factor: 2.5, phantom_cap_factor: 3 }
    }
}

/// Immutable per-relay behavior table, shared by the sim handlers, the
/// engine's event sources, and the overlay's eclipse hook.
#[derive(Clone, Debug)]
pub struct AdversaryRoster {
    /// `behavior[n.0]` for every node (None = honest).
    behavior: Vec<Option<Behavior>>,
    /// True (pre-inflation) capacities, indexed by node id.
    true_cap: Vec<usize>,
}

/// Fixed behavior cycle used by [`AdversaryRoster::assign`].
const BEHAVIOR_CYCLE: usize = 4;

impl AdversaryRoster {
    /// Deterministically assign behaviors to `round(fraction * n_relays)`
    /// relays, distributed round-robin across `stages` (taking members
    /// from the back of each stage list so stage heads — often the ones
    /// exercised hardest by ring relinking — stay honest).  `cap` is the
    /// honest capacity vector *before* phantom inflation; the roster
    /// records it so the runtime can enforce true capacities.
    pub fn assign(
        n: usize,
        stages: &[Vec<NodeId>],
        cap: &[usize],
        cfg: &AdversaryConfig,
    ) -> AdversaryRoster {
        let n_relays: usize = stages.iter().map(|s| s.len()).sum();
        let total = ((cfg.fraction * n_relays as f64).round() as usize).min(n_relays);
        let mut behavior = vec![None; n];
        let mut taken = vec![0usize; stages.len()];
        let mut assigned = 0usize;
        let mut stage_idx = 0usize;
        while assigned < total {
            let s = stage_idx % stages.len();
            stage_idx += 1;
            let members = &stages[s];
            if taken[s] >= members.len() {
                continue;
            }
            let r = members[members.len() - 1 - taken[s]];
            taken[s] += 1;
            let b = match assigned % BEHAVIOR_CYCLE {
                0 => Behavior::DenyStorm,
                1 => Behavior::Straggler { factor: cfg.straggler_factor },
                2 => Behavior::FreeRider {
                    advertised_cap: (cap[r.0] * cfg.phantom_cap_factor).max(cap[r.0] + 1),
                },
                _ => Behavior::Eclipse,
            };
            behavior[r.0] = Some(b);
            assigned += 1;
        }
        AdversaryRoster { behavior, true_cap: cap.to_vec() }
    }

    /// Behavior of node `n`, if any.
    pub fn behavior(&self, n: NodeId) -> Option<Behavior> {
        self.behavior.get(n.0).copied().flatten()
    }

    /// True when `n` refuses every microbatch arrival.
    pub fn is_deny_storm(&self, n: NodeId) -> bool {
        matches!(self.behavior(n), Some(Behavior::DenyStorm))
    }

    /// Runtime admission capacity for node `n`: free-riders honor their
    /// *true* capacity regardless of what `planned` (the possibly
    /// phantom-inflated planner cap) says; everyone else honors the
    /// planner's view.
    pub fn runtime_cap(&self, n: NodeId, planned: usize) -> usize {
        match self.behavior(n) {
            Some(Behavior::FreeRider { .. }) => self.true_cap[n.0],
            _ => planned,
        }
    }

    /// Capacity node `n` advertises to the planner, when it lies.
    pub fn advertised_cap(&self, n: NodeId) -> Option<usize> {
        match self.behavior(n) {
            Some(Behavior::FreeRider { advertised_cap }) => Some(advertised_cap),
            _ => None,
        }
    }

    /// All free-rider nodes (phantom-capacity advertisers).
    pub fn free_riders(&self) -> Vec<NodeId> {
        self.collect(|b| matches!(b, Behavior::FreeRider { .. }))
    }

    /// All deliberate stragglers with their service-time factors.
    pub fn stragglers(&self) -> Vec<(NodeId, f64)> {
        self.behavior
            .iter()
            .enumerate()
            .filter_map(|(i, b)| match b {
                Some(Behavior::Straggler { factor }) => Some((NodeId(i), *factor)),
                _ => None,
            })
            .collect()
    }

    /// All eclipse attackers (gossip-shuffle liars).
    pub fn eclipse_nodes(&self) -> Vec<NodeId> {
        self.collect(|b| matches!(b, Behavior::Eclipse))
    }

    /// True when no relay misbehaves (fraction rounded to zero).
    pub fn is_empty(&self) -> bool {
        self.behavior.iter().all(|b| b.is_none())
    }

    fn collect(&self, pred: impl Fn(&Behavior) -> bool) -> Vec<NodeId> {
        self.behavior
            .iter()
            .enumerate()
            .filter_map(|(i, b)| match b {
                Some(b) if pred(b) => Some(NodeId(i)),
                _ => None,
            })
            .collect()
    }
}

/// [`EventSource`] that injects the roster's *schedulable* misbehavior
/// each iteration: persistent slowdowns for deliberate stragglers and
/// phantom-capacity advert trace instants for free-riders.  DENY storms
/// and runtime capacity enforcement are handler-side policies (consulted
/// in `handle_relay_compute`), and eclipse lies live in the overlay —
/// neither needs scheduling here.  The source is RNG-free and emits the
/// same schedule every iteration, so it composes with churn/jitter
/// sources without perturbing their draws.
pub struct AdversarySource {
    roster: Arc<AdversaryRoster>,
}

impl AdversarySource {
    /// Wrap a shared roster as an engine event source.
    pub fn new(roster: Arc<AdversaryRoster>) -> Self {
        AdversarySource { roster }
    }
}

impl EventSource for AdversarySource {
    fn name(&self) -> &str {
        "adversaries"
    }

    fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
        let mut ws = WorldSchedule::default();
        if trace::enabled() {
            for r in self.roster.free_riders() {
                let adv = self.roster.advertised_cap(r).unwrap_or(0);
                trace::emit(|| {
                    TraceRecord::instant(
                        0.0,
                        Some(r),
                        None,
                        TraceKind::PhantomAdvert { advertised: adv },
                    )
                });
            }
        }
        for (node, factor) in self.roster.stragglers() {
            ws.slowdowns.push(Slowdown {
                node,
                from: 0.0,
                until: horizon * SPAN_FACTOR,
                factor,
            });
        }
        ws
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stages4x6() -> Vec<Vec<NodeId>> {
        // 6 stages x 4 relays, node ids 2..26 (0/1 reserved for data).
        (0..6).map(|s| (0..4).map(|k| NodeId(2 + s * 4 + k)).collect()).collect()
    }

    #[test]
    fn fraction_zero_assigns_nobody() {
        let stages = stages4x6();
        let cap = vec![4usize; 26];
        let roster =
            AdversaryRoster::assign(26, &stages, &cap, &AdversaryConfig::with_fraction(0.0));
        assert!(roster.is_empty());
        assert!(roster.free_riders().is_empty());
        assert!(roster.stragglers().is_empty());
        assert!(roster.eclipse_nodes().is_empty());
    }

    #[test]
    fn assignment_is_deterministic_and_counts_match_fraction() {
        let stages = stages4x6();
        let cap = vec![4usize; 26];
        let cfg = AdversaryConfig::with_fraction(0.25);
        let a = AdversaryRoster::assign(26, &stages, &cap, &cfg);
        let b = AdversaryRoster::assign(26, &stages, &cap, &cfg);
        let count =
            |r: &AdversaryRoster| (0..26).filter(|&i| r.behavior(NodeId(i)).is_some()).count();
        // 25% of 24 relays = 6 adversaries; byte-identical across builds.
        assert_eq!(count(&a), 6);
        for i in 0..26 {
            assert_eq!(a.behavior(NodeId(i)), b.behavior(NodeId(i)));
        }
        // The fixed cycle covers all four behaviors at this count.
        assert!(!a.free_riders().is_empty());
        assert!(!a.stragglers().is_empty());
        assert!(!a.eclipse_nodes().is_empty());
        assert!((0..26).any(|i| a.is_deny_storm(NodeId(i))));
        // Round-robin: no stage hosts more than its share (6 over 6
        // stages = exactly one each).
        for members in &stages {
            let hit = members.iter().filter(|r| a.behavior(**r).is_some()).count();
            assert_eq!(hit, 1);
        }
    }

    #[test]
    fn free_riders_honor_true_cap_at_runtime() {
        let stages = stages4x6();
        let cap = vec![4usize; 26];
        let cfg = AdversaryConfig::with_fraction(0.25);
        let roster = AdversaryRoster::assign(26, &stages, &cap, &cfg);
        for r in roster.free_riders() {
            let adv = roster.advertised_cap(r).unwrap();
            assert_eq!(adv, 12, "cap 4 x phantom factor 3");
            // Planner sees 12, runtime honors the true 4.
            assert_eq!(roster.runtime_cap(r, adv), 4);
        }
        // Honest relays honor the planner's number verbatim.
        let honest = (2..26).map(NodeId).find(|&n| roster.behavior(n).is_none()).unwrap();
        assert_eq!(roster.runtime_cap(honest, 7), 7);
    }

    #[test]
    fn source_emits_identical_slowdowns_every_iteration() {
        let stages = stages4x6();
        let cap = vec![4usize; 26];
        let roster = Arc::new(AdversaryRoster::assign(
            26,
            &stages,
            &cap,
            &AdversaryConfig::with_fraction(0.25),
        ));
        let mut src = AdversarySource::new(roster.clone());
        let a = src.sample(0, 100.0);
        let b = src.sample(5, 100.0);
        assert_eq!(a.slowdowns.len(), roster.stragglers().len());
        assert_eq!(a.slowdowns.len(), b.slowdowns.len());
        for (x, y) in a.slowdowns.iter().zip(&b.slowdowns) {
            assert_eq!(x.node, y.node);
            assert_eq!(x.factor.to_bits(), y.factor.to_bits());
            assert_eq!(x.until.to_bits(), y.until.to_bits());
            assert!(x.factor > 1.0);
        }
        assert!(a.crashes.is_empty() && a.rejoins.is_empty() && a.joins.is_empty());
    }
}
