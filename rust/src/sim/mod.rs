//! Discrete-event simulation substrate.
//!
//! The paper's testbed runs logical nodes on 5 throttled GPUs; we replace
//! the wall clock with a deterministic continuous-time event simulation of
//! the same system (DESIGN.md §Substitutions): pipelined microbatch
//! execution with per-node concurrency slots, link delays from the
//! topology, world events at arbitrary virtual timestamps (crashes,
//! joins, link jitter, stragglers — see [`engine`]), the recovery
//! protocols, and the training/aggregation synchronization barrier.
//!
//! Layering:
//! - [`events`]   — the deterministic virtual-time queue, the compute
//!   slot model, and the shared-capacity NIC substrate
//!   ([`events::NicQueues`]: per-node uplink/downlink transmission
//!   queues; unlimited concurrency = the legacy contention-free model).
//! - [`engine`]   — the continuous-time kernel (dispatch loop + the
//!   [`engine::EventSource`] plugin contract) and the multi-iteration
//!   [`engine::Engine`] driver with cold-plan / warm-replan dispatch.
//! - [`handlers`] — per-event microbatch handlers (§V-D recovery logic).
//! - [`sources`]  — built-in event sources (jitter, stragglers,
//!   mid-aggregation crashes, delayed joins).
//! - [`churn`]    — the churn models (per-iteration Bernoulli and
//!   continuous-clock Poisson) and the liveness authority; churn feeds
//!   the engine through the same [`engine::EventSource`] contract as
//!   every other source.
//! - [`churn_process`] — the exact exponential inter-arrival sampler
//!   behind [`churn::ChurnModel::Poisson`].
//! - [`training`] — the [`training::RoutingPolicy`] plan-lifecycle
//!   contract (request -> rounds on the clock -> commit at convergence),
//!   configuration, metrics, the physical model, and the
//!   [`training::VersionedWeights`] store behind bounded-staleness
//!   asynchronous aggregation.
//! - [`adversary`] — misbehaving-relay models (free-riders, DENY
//!   storms, deliberate stragglers, eclipse attackers) attached per
//!   scenario; zero-overhead and bit-for-bit inert when unconfigured.
//! - [`scenario`] — builders for the paper's experiment setups.
//!
//! Every layer also emits [`crate::trace`] records (spans for compute /
//! transmission / waits, instants for churn and plan transitions) through
//! the ambient sink — strictly observational: with no sink armed the
//! emission closures are never evaluated and the simulation is
//! bit-for-bit identical to a build without tracing.

pub mod adversary;
pub mod churn;
pub mod churn_process;
pub mod engine;
pub mod events;
pub mod handlers;
pub mod scenario;
pub mod sources;
pub mod training;

pub use adversary::{AdversaryConfig, AdversaryRoster, AdversarySource, Behavior};
pub use churn::{ChurnModel, ChurnProcess};
pub use churn_process::PoissonChurn;
pub use engine::{
    Engine, EventSource, JitterWindow, PlanLifecycle, PlanSession, Slowdown, WorldSchedule,
};
pub use events::{EventQueue, NicQueues};
pub use training::{
    BlockingPlanAdapter, BlockingPlanner, CritPath, IterationMetrics, PlanOutcome, PlanRequest,
    PlanTicket, RecoveryPolicy, RoutingPolicy, TrainingSim, TrainingSimConfig, VersionedWeights,
};
