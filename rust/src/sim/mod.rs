//! Discrete-event simulation substrate.
//!
//! The paper's testbed runs logical nodes on 5 throttled GPUs; we replace
//! the wall clock with a deterministic virtual-time event simulation of
//! the same system (DESIGN.md §Substitutions): pipelined microbatch
//! execution with per-node concurrency slots, link delays from the
//! topology, node churn mid-iteration, the recovery protocols, and the
//! training/aggregation synchronization barrier.

pub mod churn;
pub mod events;
pub mod scenario;
pub mod training;

pub use churn::ChurnProcess;
pub use events::EventQueue;
pub use training::{IterationMetrics, RecoveryPolicy, Router, TrainingSim, TrainingSimConfig};
