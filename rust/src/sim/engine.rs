//! Continuous-time simulation engine.
//!
//! The kernel owns the deterministic [`EventQueue`](super::events::EventQueue)
//! and dispatches *world events* — churn crashes/rejoins, link-latency
//! jitter windows, straggler slowdowns, mid-iteration node joins, and
//! mid-aggregation crashes — at **arbitrary virtual timestamps**, not just
//! iteration boundaries.  This is the asynchronous-gossip view of §V-A/§V-D:
//! the system reacts to a crash *when it happens*, while the older
//! iteration-synchronous simulator could only sample churn once per
//! iteration.
//!
//! # Event-source plugin contract
//!
//! An [`EventSource`] contributes one [`WorldSchedule`] per iteration:
//!
//! - `sample(iter, horizon)` is called once at the start of iteration
//!   `iter`; `horizon` is the engine's current iteration-length estimate
//!   (the same reference the deadline and churn instants use).  Sources
//!   place events at any absolute virtual time `>= 0`; times past the
//!   iteration's actual end are simply never reached.
//! - Sources must be **deterministic** functions of their seed and
//!   `iter` — the whole simulator is replayable from seeds, and the
//!   proptests assert byte-identical metrics across runs.
//! - Sources are independent: the engine merges all schedules
//!   ([`WorldSchedule::merge`]) and interleaves the events with the
//!   microbatch events on one timeline (ties broken by insertion order,
//!   world events first).
//! - Churn itself is an event source: [`ChurnProcess`] implements
//!   [`EventSource`] (Bernoulli or continuous-clock Poisson, see
//!   [`super::churn`]) and its crashes/rejoins/joins flow through the
//!   same [`WorldSchedule`] merge and event queue as everything else.
//!   It is sampled *before* planning — it is the liveness authority, so
//!   its planner-visible membership (Bernoulli rejoins) must land first —
//!   while extra sources are sampled after planning and can never be
//!   planner-visible in their own iteration.
//! - Liveness authority stays with the [`ChurnProcess`]: the engine
//!   applies source-scheduled crashes/joins to it *after* the iteration,
//!   so planners only ever see start-of-iteration membership (no
//!   clairvoyance), exactly like paper churn.
//!
//! # Scenario mapping to paper §VI
//!
//! | schedule ingredient | paper experiment |
//! |---|---|
//! | `crashes` / `rejoins` | §VI "Node Crashes" (Tables II/III churn) |
//! | `agg_crashes` | §V-E barrier under churn — the mid-aggregation-crash scenario (`experiments::scenarios::run_mid_agg_crash`) |
//! | `jitter` | geo-link variability beyond the static 50–500 Mb/s envelope (`experiments::scenarios::run_link_jitter`) |
//! | `slowdowns` | the heterogeneous-device rows, made time-varying (stragglers) |
//! | `joins` | §V-B joining nodes, visible to recovery mid-iteration |

use crate::cost::NodeId;
use crate::flow::graph::{FlowPath, FlowProblem};
use crate::util::Rng;

use super::churn::ChurnProcess;
use super::events::{EventQueue, Slots, Time};
use super::handlers::{MicrobatchState, Phase};
use super::scenario::Scenario;
use super::training::{IterationMetrics, Router, TrainingSim};

/// Piecewise-constant link-delay multiplier window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JitterWindow {
    pub from: Time,
    pub until: Time,
    /// Multiplier applied to every payload-transfer delay started inside
    /// the window (1.0 = nominal).
    pub factor: f64,
}

/// A straggler window: `node` computes `factor`x slower in `[from, until)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slowdown {
    pub node: NodeId,
    pub from: Time,
    pub until: Time,
    pub factor: f64,
}

/// One iteration's world events, on the absolute virtual timeline.
#[derive(Debug, Clone, Default)]
pub struct WorldSchedule {
    /// `(node, t)`: node dies at virtual time `t`.  Crash targets must be
    /// alive at iteration start — [`Engine::step`] drops source crashes
    /// aimed at already-dead nodes (membership additions go through
    /// `joins`/`rejoins` instead).
    pub crashes: Vec<(NodeId, Time)>,
    /// Nodes returning to the membership.  Churn-process rejoins are
    /// already alive before planning; rejoins emitted by an
    /// [`EventSource`] take effect for the *next* iteration (sources are
    /// sampled after planning, so same-iteration planner visibility is
    /// impossible by construction — use `joins` for mid-iteration
    /// recovery availability).
    pub rejoins: Vec<NodeId>,
    /// `(node, t)`: node becomes available at virtual time `t` — invisible
    /// to the planner, but recovery can route onto it from `t` on.
    pub joins: Vec<(NodeId, Time)>,
    /// Link-latency jitter windows (global multiplier).
    pub jitter: Vec<JitterWindow>,
    /// Straggler compute-slowdown windows.
    pub slowdowns: Vec<Slowdown>,
    /// `(node, frac)`: node dies after `frac` of the §V-E aggregation
    /// barrier has elapsed; its stage redoes that fraction of its weight
    /// exchange among the survivors.
    pub agg_crashes: Vec<(NodeId, f64)>,
    /// Virtual instants at which the gossip overlay runs one protocol
    /// round (probe / suspicion / shuffle), delivered to the router via
    /// [`crate::sim::training::Router::on_gossip`] so failure detection
    /// interleaves with churn and jitter on the same timeline.
    pub gossip_ticks: Vec<Time>,
}

impl WorldSchedule {
    /// Fold another source's schedule into this one.
    pub fn merge(&mut self, other: WorldSchedule) {
        self.crashes.extend(other.crashes);
        self.rejoins.extend(other.rejoins);
        self.joins.extend(other.joins);
        self.jitter.extend(other.jitter);
        self.slowdowns.extend(other.slowdowns);
        self.agg_crashes.extend(other.agg_crashes);
        self.gossip_ticks.extend(other.gossip_ticks);
    }

    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.rejoins.is_empty()
            && self.joins.is_empty()
            && self.jitter.is_empty()
            && self.slowdowns.is_empty()
            && self.agg_crashes.is_empty()
            && self.gossip_ticks.is_empty()
    }
}

/// A pluggable generator of world events (see the module docs for the
/// contract).  Implementations live in [`super::sources`].
pub trait EventSource {
    fn name(&self) -> &str;

    /// Events for iteration `iter`; `horizon` is the engine's current
    /// iteration-length estimate in virtual seconds.
    fn sample(&mut self, iter: usize, horizon: Time) -> WorldSchedule;
}

/// World events delivered on the engine timeline.
#[derive(Debug, Clone, Copy)]
pub(crate) enum WorldEvent {
    Crash(NodeId),
    Join(NodeId),
    /// One gossip-overlay protocol round (Router::on_gossip).
    Gossip,
}

/// Everything the engine dispatches: microbatch progress or world events.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Ev {
    Micro(usize, Phase),
    World(WorldEvent),
}

/// Multi-iteration driver: owns the simulator, the churn process (the
/// liveness authority) and any extra event sources, and handles the
/// cold-plan / warm-replan dispatch to the [`Router`].
pub struct Engine {
    pub sim: TrainingSim,
    pub churn: ChurnProcess,
    pub sources: Vec<Box<dyn EventSource>>,
    /// When true, iterations after the first call [`Router::replan`] with
    /// the diff of consecutive liveness views (GWTF warm-starts from its
    /// surviving chains; baselines fall back to a cold plan).  Off by
    /// default — the paper harness (Tables II/III/VI) cold-plans every
    /// iteration.
    pub warm_replan: bool,
    prev_alive: Option<Vec<bool>>,
    iter: usize,
    rng: Rng,
}

impl Engine {
    pub fn new(sim: TrainingSim, churn: ChurnProcess, seed: u64) -> Engine {
        Engine {
            sim,
            churn,
            sources: Vec::new(),
            warm_replan: false,
            prev_alive: None,
            iter: 0,
            rng: Rng::new(seed),
        }
    }

    /// Build from a scenario (clones its topology, config and churn).
    /// Overlay scenarios (`ScenarioConfig::overlay_fanout`) get the
    /// gossip cadence source so failure detection runs on the same
    /// continuous clock as churn and jitter.
    pub fn from_scenario(sc: &Scenario, seed: u64) -> Engine {
        let mut engine = Engine::new(
            TrainingSim::new(sc.topo.clone(), sc.sim_cfg.clone()),
            sc.churn.clone(),
            seed,
        );
        if sc.cfg.overlay_fanout.is_some() {
            engine.add_source(Box::new(super::sources::GossipCadenceSource::new(
                super::scenario::GOSSIP_PERIOD_S,
            )));
        }
        engine
    }

    pub fn add_source(&mut self, source: Box<dyn EventSource>) {
        self.sources.push(source);
    }

    /// Iterations run so far.
    pub fn iterations(&self) -> usize {
        self.iter
    }

    /// Run one training iteration: sample churn + sources, plan (or warm
    /// re-plan) routes, execute the continuous-time schedule.
    pub fn step(&mut self, prob: &FlowProblem, router: &mut dyn Router) -> IterationMetrics {
        let horizon = self.sim.current_iter_estimate();
        let iter = self.iter;
        // The churn model speaks the same EventSource contract as every
        // other world-event generator; it is sampled first and held in a
        // dedicated slot because it is the liveness *authority*: its
        // planner-visible membership changes (Bernoulli rejoins) must
        // land before routes are planned, and its crashes leave the
        // aggregation barrier's membership for this iteration.
        let mut sched = self.churn.sample(iter, horizon);
        // Planner view: mid-iteration crashes are in the future.
        let alive = self.churn.planning_view_for(&sched);
        let (paths, planning_s) = match &self.prev_alive {
            Some(prev) if self.warm_replan => {
                let dirty: Vec<NodeId> = (0..alive.len())
                    .filter(|&i| prev.get(i).copied().unwrap_or(true) && !alive[i])
                    .map(NodeId)
                    .collect();
                router.replan(&alive, &dirty)
            }
            _ => router.plan(&alive),
        };
        let plan_rounds = router.last_plan_rounds();

        for s in &mut self.sources {
            let mut extra = s.sample(iter, horizon);
            // A source may not crash a node that is already dead at
            // iteration start: that would resurrect it for [0, t).
            // Membership additions go through joins/rejoins.
            extra.crashes.retain(|&(n, _)| alive.get(n.0).copied().unwrap_or(false));
            extra.agg_crashes.retain(|&(n, _)| alive.get(n.0).copied().unwrap_or(false));
            sched.merge(extra);
        }
        self.prev_alive = Some(alive);
        self.iter += 1;

        let mut metrics = self.sim.run_schedule(
            prob,
            router,
            &sched,
            &self.churn,
            planning_s,
            paths,
            &mut self.rng,
        );
        metrics.replan_rounds = plan_rounds;

        // Source-scheduled crashes/joins/rejoins update the liveness
        // authority *after* the iteration: the next plan sees them, this
        // one didn't.  (Churn-process entries are already applied; these
        // writes are idempotent for them.)
        for &(node, _) in &sched.crashes {
            self.churn.alive[node.0] = false;
        }
        for &(node, _) in &sched.agg_crashes {
            self.churn.alive[node.0] = false;
        }
        for &(node, _) in &sched.joins {
            self.churn.alive[node.0] = true;
        }
        for &node in &sched.rejoins {
            self.churn.alive[node.0] = true;
        }
        metrics
    }
}

impl TrainingSim {
    /// Execute one iteration's [`WorldSchedule`]: the continuous-time
    /// dispatch loop over the event queue.
    ///
    /// `churn_state` supplies start-of-iteration liveness (aggregation
    /// membership and availability windows); `paths` are the routed flows
    /// (one per microbatch).  With a churn-only schedule this reproduces
    /// the pre-engine simulator byte for byte.
    #[allow(clippy::too_many_arguments)]
    pub fn run_schedule(
        &mut self,
        prob: &FlowProblem,
        router: &mut dyn Router,
        sched: &WorldSchedule,
        churn_state: &ChurnProcess,
        planning_s: f64,
        paths: Vec<FlowPath>,
        _rng: &mut Rng,
    ) -> IterationMetrics {
        let n = self.topo.n();
        // Availability windows at iteration start (rejoins already applied
        // by the caller via the churn process).
        for i in 0..n {
            self.birth_at[i] = if churn_state.alive[i] { 0.0 } else { f64::INFINITY };
            self.death_at[i] = f64::INFINITY;
        }
        for &(node, t) in &sched.crashes {
            self.birth_at[node.0] = 0.0; // alive until its death instant
            self.death_at[node.0] = t;
        }
        for &(node, t) in &sched.joins {
            if self.birth_at[node.0].is_infinite() {
                self.birth_at[node.0] = t;
            }
        }
        self.jitter = sched.jitter.clone();
        // Sorted by start so the per-transfer factor lookup can binary
        // search (merged sources may interleave windows).
        self.jitter.sort_by(|a, b| a.from.total_cmp(&b.from));
        self.slowdowns = sched.slowdowns.clone();

        let mut metrics =
            IterationMetrics { scheduled: paths.len(), planning_s, ..Default::default() };
        let mut slots: Vec<Slots> = (0..n).map(|i| Slots::new(prob.cap[i].max(1))).collect();
        // Memory residency per node (forward activations awaiting backward).
        let mut inflight: Vec<usize> = vec![0; n];
        let mut mbs: Vec<MicrobatchState> = paths.into_iter().map(MicrobatchState::new).collect();

        let mut q: EventQueue<Ev> = EventQueue::new();
        // World events enter the timeline first: a crash at time t is
        // delivered to the router at t (the asynchronous-gossip view),
        // not at first detection.
        for &(node, t) in &sched.crashes {
            q.schedule(t.max(0.0), Ev::World(WorldEvent::Crash(node)));
        }
        for &(node, t) in &sched.joins {
            q.schedule(t.max(0.0), Ev::World(WorldEvent::Join(node)));
        }
        for &t in &sched.gossip_ticks {
            q.schedule(t.max(0.0), Ev::World(WorldEvent::Gossip));
        }
        // Data nodes send out all their microbatches at t=0 (transfer to hop 0).
        for (mi, mb) in mbs.iter().enumerate() {
            let d = mb.path.source;
            let first = mb.path.relays[0];
            let dt = self.transfer_s(d, first, 0.0);
            metrics.comm_s += dt;
            q.schedule(dt, Ev::Micro(mi, Phase::Fwd { hop: 0 }));
        }

        // Stragglers past the aggregation cutoff are excluded (wasted).
        let deadline = self.cfg.deadline_factor * self.iter_estimate;
        while let Some((t, ev)) = q.pop() {
            let (mi, phase) = match ev {
                Ev::World(WorldEvent::Crash(node)) => {
                    router.on_crash(node);
                    continue;
                }
                Ev::World(WorldEvent::Join(_)) => continue,
                Ev::World(WorldEvent::Gossip) => {
                    router.on_gossip(t);
                    continue;
                }
                Ev::Micro(mi, phase) => (mi, phase),
            };
            if mbs[mi].dropped {
                continue;
            }
            if t > deadline && mbs[mi].done_at.is_none() {
                mbs[mi].release_all(&mut inflight);
                mbs[mi].dropped = true;
                continue;
            }
            match phase {
                Phase::Fwd { hop } => {
                    self.handle_relay_compute(
                        t, mi, hop, /*is_fwd=*/ true, prob, router, &mut slots, &mut inflight,
                        &mut mbs, &mut q, &mut metrics,
                    );
                }
                Phase::Loss => {
                    // Loss + head backward at the data node (always alive).
                    let d = mbs[mi].path.source;
                    let c = self.fwd_compute_s(d, t) + self.bwd_compute_s(d, t);
                    mbs[mi].compute_spent += c;
                    let last = mbs[mi].path.relays.len() - 1;
                    let nxt = mbs[mi].path.relays[last];
                    let dt = self.transfer_s(d, nxt, t + c);
                    metrics.comm_s += dt;
                    q.schedule(t + c + dt, Ev::Micro(mi, Phase::Bwd { hop: last }));
                }
                Phase::Bwd { hop } => {
                    self.handle_relay_compute(
                        t, mi, hop, /*is_fwd=*/ false, prob, router, &mut slots, &mut inflight,
                        &mut mbs, &mut q, &mut metrics,
                    );
                }
                Phase::Finish => {
                    // Embedding backward at the data node.
                    let d = mbs[mi].path.source;
                    let c = self.bwd_compute_s(d, t);
                    mbs[mi].compute_spent += c;
                    mbs[mi].done_at = Some(t + c);
                }
            }
        }

        // Tally results.
        let mut makespan: f64 = 0.0;
        for mb in &mbs {
            match mb.done_at {
                Some(t) => {
                    metrics.completed += 1;
                    makespan = makespan.max(t);
                }
                None => {
                    metrics.dropped += 1;
                    metrics.wasted_gpu_s += mb.compute_spent;
                }
            }
        }

        // Aggregation barrier (§V-E), with mid-aggregation crash recovery.
        let (agg, agg_recoveries) =
            self.aggregation_time(prob, churn_state, &sched.agg_crashes);
        metrics.agg_s = agg;
        metrics.agg_recoveries = agg_recoveries;
        metrics.makespan_s = makespan + agg + planning_s;
        // EMA keeps the crash-instant / deadline reference stable.  Only
        // productive iterations update it: a zero-completion iteration has
        // a tiny makespan, and folding that in would shrink the next
        // deadline and wedge the system in a drop-everything spiral.
        if metrics.completed > 0 {
            self.iter_estimate = (0.5 * self.iter_estimate + 0.5 * metrics.makespan_s)
                .max(self.cfg.initial_iter_estimate_s * 0.1)
                .max(1e-6);
        }
        metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GwtfRouter;
    use crate::flow::FlowParams;
    use crate::sim::scenario::{build, ScenarioConfig};

    #[test]
    fn schedule_merge_concatenates_everything() {
        let mut a = WorldSchedule {
            crashes: vec![(NodeId(1), 2.0)],
            ..Default::default()
        };
        a.merge(WorldSchedule {
            crashes: vec![(NodeId(2), 3.0)],
            rejoins: vec![NodeId(4)],
            joins: vec![(NodeId(5), 1.0)],
            jitter: vec![JitterWindow { from: 0.0, until: 1.0, factor: 1.5 }],
            slowdowns: vec![Slowdown { node: NodeId(3), from: 0.0, until: 9.0, factor: 2.0 }],
            agg_crashes: vec![(NodeId(6), 0.2)],
            gossip_ticks: vec![4.5, 9.0],
        });
        assert_eq!(a.crashes.len(), 2);
        assert_eq!(a.rejoins, vec![NodeId(4)]);
        assert_eq!(a.joins.len(), 1);
        assert_eq!(a.jitter.len(), 1);
        assert_eq!(a.slowdowns.len(), 1);
        assert_eq!(a.agg_crashes.len(), 1);
        assert_eq!(a.gossip_ticks, vec![4.5, 9.0]);
        assert!(!a.is_empty());
        assert!(WorldSchedule::default().is_empty());
    }

    #[test]
    fn engine_step_matches_manual_loop_zero_churn() {
        // The engine refactor must not move a single number for the
        // legacy (churn-only, cold-plan) path: same seed => same metrics.
        let sc = build(&ScenarioConfig::table2(true, 0.0, 3));
        let mut manual_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 3);
        let mut manual_sim = TrainingSim::new(sc.topo.clone(), sc.sim_cfg.clone());
        let mut manual_churn = sc.churn.clone();
        let mut manual_rng = Rng::new(9);
        let mut engine_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 3);
        let mut engine = Engine::from_scenario(&sc, 9);
        for _ in 0..3 {
            let ev = manual_churn.sample_iteration();
            let alive = manual_churn.planning_view(&ev);
            let (paths, planning) = manual_router.plan(&alive);
            let a = manual_sim.run_iteration(
                &sc.prob, &mut manual_router, &ev, &manual_churn, planning, paths, &mut manual_rng,
            );
            let b = engine.step(&sc.prob, &mut engine_router);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
            assert_eq!(a.agg_s.to_bits(), b.agg_s.to_bits());
        }
    }

    #[test]
    fn engine_step_matches_manual_loop_under_bernoulli_churn() {
        // ChurnModel::Bernoulli parity (ISSUE 2 acceptance): with churn as
        // an EventSource, the engine must reproduce the legacy
        // sample_iteration + run_iteration loop bit for bit — crashes,
        // rejoins and all — at the paper's 20% join-leave chance.
        let sc = build(&ScenarioConfig::table2(false, 0.2, 41));
        let mut manual_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 41);
        let mut manual_sim = TrainingSim::new(sc.topo.clone(), sc.sim_cfg.clone());
        let mut manual_churn = sc.churn.clone();
        let mut manual_rng = Rng::new(13);
        let mut engine_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 41);
        let mut engine = Engine::from_scenario(&sc, 13);
        for _ in 0..5 {
            let ev = manual_churn.sample_iteration();
            let alive = manual_churn.planning_view(&ev);
            let (paths, planning) = manual_router.plan(&alive);
            let a = manual_sim.run_iteration(
                &sc.prob, &mut manual_router, &ev, &manual_churn, planning, paths, &mut manual_rng,
            );
            let b = engine.step(&sc.prob, &mut engine_router);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.fwd_recoveries, b.fwd_recoveries);
            assert_eq!(a.bwd_recoveries, b.bwd_recoveries);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
            assert_eq!(a.wasted_gpu_s.to_bits(), b.wasted_gpu_s.to_bits());
            assert_eq!(a.agg_s.to_bits(), b.agg_s.to_bits());
            assert_eq!(manual_churn.alive, engine.churn.alive, "liveness authorities agree");
        }
    }

    #[test]
    fn engine_applies_source_crashes_to_liveness_after_iteration() {
        struct OneShotCrash {
            victim: NodeId,
            fired: bool,
        }
        impl EventSource for OneShotCrash {
            fn name(&self) -> &str {
                "one-shot-crash"
            }
            fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
                if self.fired {
                    return WorldSchedule::default();
                }
                self.fired = true;
                WorldSchedule {
                    crashes: vec![(self.victim, horizon * 0.1)],
                    ..Default::default()
                }
            }
        }
        let sc = build(&ScenarioConfig::table2(true, 0.0, 5));
        let victim = sc.relays[0];
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 5);
        let mut engine = Engine::from_scenario(&sc, 5);
        engine.add_source(Box::new(OneShotCrash { victim, fired: false }));
        assert!(engine.churn.is_alive(victim));
        let m = engine.step(&sc.prob, &mut router);
        assert!(m.completed > 0);
        assert!(!engine.churn.is_alive(victim), "source crash must persist");
        assert_eq!(engine.iterations(), 1);
    }
}
