//! Continuous-time simulation engine.
//!
//! The kernel owns the deterministic [`EventQueue`](super::events::EventQueue)
//! and dispatches *world events* — churn crashes/rejoins, link-latency
//! jitter windows, straggler slowdowns, mid-iteration node joins, and
//! mid-aggregation crashes — at **arbitrary virtual timestamps**, not just
//! iteration boundaries.  This is the asynchronous-gossip view of §V-A/§V-D:
//! the system reacts to a crash *when it happens*, while the older
//! iteration-synchronous simulator could only sample churn once per
//! iteration.
//!
//! # Event-source plugin contract
//!
//! An [`EventSource`] contributes one [`WorldSchedule`] per iteration:
//!
//! - `sample(iter, horizon)` is called once at the start of iteration
//!   `iter`; `horizon` is the engine's current iteration-length estimate
//!   (the same reference the deadline and churn instants use).  Sources
//!   place events at any absolute virtual time `>= 0`; times past the
//!   iteration's actual end are simply never reached.
//! - Sources must be **deterministic** functions of their seed and
//!   `iter` — the whole simulator is replayable from seeds, and the
//!   proptests assert byte-identical metrics across runs.
//! - Sources are independent: the engine merges all schedules
//!   ([`WorldSchedule::merge`]) and interleaves the events with the
//!   microbatch events on one timeline (ties broken by insertion order,
//!   world events first).
//! - Churn itself is an event source: [`ChurnProcess`] implements
//!   [`EventSource`] (Bernoulli or continuous-clock Poisson, see
//!   [`super::churn`]) and its crashes/rejoins/joins flow through the
//!   same [`WorldSchedule`] merge and event queue as everything else.
//!   It is sampled *before* planning — it is the liveness authority, so
//!   its planner-visible membership (Bernoulli rejoins) must land first —
//!   while extra sources are sampled after planning and can never be
//!   planner-visible in their own iteration.
//! - Liveness authority stays with the [`ChurnProcess`]: the engine
//!   applies source-scheduled crashes/joins to it *after* the iteration,
//!   so planners only ever see start-of-iteration membership (no
//!   clairvoyance), exactly like paper churn.
//!
//! # Scenario mapping to paper §VI
//!
//! | schedule ingredient | paper experiment |
//! |---|---|
//! | `crashes` / `rejoins` | §VI "Node Crashes" (Tables II/III churn) |
//! | `agg_crashes` | §V-E barrier under churn — the mid-aggregation-crash scenario (`experiments::scenarios::run_mid_agg_crash`) |
//! | `jitter` | geo-link variability beyond the static 50–500 Mb/s envelope (`experiments::scenarios::run_link_jitter`) |
//! | `slowdowns` | the heterogeneous-device rows, made time-varying (stragglers) |
//! | `joins` | §V-B joining nodes, visible to recovery mid-iteration |
//! | `gossip_ticks` | the overlay failure detector's probe rounds |
//! | `plan_rounds` | §V-C flow-protocol rounds: the plan lifecycle's convergence clock (`gwtf bench planlag`) |

use crate::cost::NodeId;
use crate::flow::graph::{FlowPath, FlowProblem};
use crate::trace::{self, TraceKind, TraceRecord};
use crate::util::Rng;

use super::churn::ChurnProcess;
use super::events::{EventQueue, NicQueues, Slots, Time};
use super::handlers::{MicrobatchState, Phase};
use super::scenario::Scenario;
use super::training::{
    IterationMetrics, PlanOutcome, PlanRequest, PlanTicket, RoutingPolicy, StageAggTracker,
    TrainingSim, VersionedWeights,
};

/// Piecewise-constant link-delay multiplier window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JitterWindow {
    pub from: Time,
    pub until: Time,
    /// Multiplier applied to every payload-transfer delay started inside
    /// the window (1.0 = nominal).
    pub factor: f64,
}

/// A straggler window: `node` computes `factor`x slower in `[from, until)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slowdown {
    pub node: NodeId,
    pub from: Time,
    pub until: Time,
    pub factor: f64,
}

/// One iteration's world events, on the absolute virtual timeline.
#[derive(Debug, Clone, Default)]
pub struct WorldSchedule {
    /// `(node, t)`: node dies at virtual time `t`.  Crash targets must be
    /// alive at iteration start — [`Engine::step`] drops source crashes
    /// aimed at already-dead nodes (membership additions go through
    /// `joins`/`rejoins` instead).
    pub crashes: Vec<(NodeId, Time)>,
    /// Nodes returning to the membership.  Churn-process rejoins are
    /// already alive before planning; rejoins emitted by an
    /// [`EventSource`] take effect for the *next* iteration (sources are
    /// sampled after planning, so same-iteration planner visibility is
    /// impossible by construction — use `joins` for mid-iteration
    /// recovery availability).
    pub rejoins: Vec<NodeId>,
    /// `(node, t)`: node becomes available at virtual time `t` — invisible
    /// to the planner, but recovery can route onto it from `t` on.
    pub joins: Vec<(NodeId, Time)>,
    /// Link-latency jitter windows (global multiplier).
    pub jitter: Vec<JitterWindow>,
    /// Straggler compute-slowdown windows.
    pub slowdowns: Vec<Slowdown>,
    /// `(node, frac)`: node dies after `frac` of the §V-E aggregation
    /// barrier has elapsed; its stage redoes that fraction of its weight
    /// exchange among the survivors.
    pub agg_crashes: Vec<(NodeId, f64)>,
    /// Virtual instants at which the gossip overlay runs one protocol
    /// round (probe / suspicion / shuffle), delivered to the router via
    /// [`crate::sim::training::RoutingPolicy::on_gossip`] so failure
    /// detection interleaves with churn and jitter on the same timeline.
    pub gossip_ticks: Vec<Time>,
    /// Virtual instants at which the flow protocol completes one planning
    /// round (emitted by [`crate::sim::sources::PlanningSource`]).  The
    /// engine's in-flight [`PlanSession`] advances one round per tick and
    /// commits at the tick where its rounds converge, so plan convergence
    /// interleaves with churn, jitter and gossip on one timeline.
    pub plan_rounds: Vec<Time>,
}

impl WorldSchedule {
    /// Fold another source's schedule into this one.
    pub fn merge(&mut self, other: WorldSchedule) {
        self.crashes.extend(other.crashes);
        self.rejoins.extend(other.rejoins);
        self.joins.extend(other.joins);
        self.jitter.extend(other.jitter);
        self.slowdowns.extend(other.slowdowns);
        self.agg_crashes.extend(other.agg_crashes);
        self.gossip_ticks.extend(other.gossip_ticks);
        self.plan_rounds.extend(other.plan_rounds);
    }

    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.rejoins.is_empty()
            && self.joins.is_empty()
            && self.jitter.is_empty()
            && self.slowdowns.is_empty()
            && self.agg_crashes.is_empty()
            && self.gossip_ticks.is_empty()
            && self.plan_rounds.is_empty()
    }
}

/// A pluggable generator of world events (see the module docs for the
/// contract).  Implementations live in [`super::sources`].
pub trait EventSource {
    fn name(&self) -> &str;

    /// Events for iteration `iter`; `horizon` is the engine's current
    /// iteration-length estimate in virtual seconds.
    fn sample(&mut self, iter: usize, horizon: Time) -> WorldSchedule;
}

/// World events delivered on the engine timeline.
#[derive(Debug, Clone, Copy)]
pub(crate) enum WorldEvent {
    Crash(NodeId),
    Join(NodeId),
    /// One gossip-overlay protocol round (RoutingPolicy::on_gossip).
    Gossip,
    /// One flow-planning protocol round completes: the in-flight
    /// [`PlanSession`] (if any) advances and commits when converged.
    PlanRound,
    /// Bounded-staleness mode: stage `st`'s rolling §V-E weight exchange
    /// completes — its weights advance to the iteration's generation + 1.
    /// Scheduled by the backward handler the moment the stage's last
    /// expected gradient lands; never emitted on the synchronous path.
    StageAgg(usize),
}

/// Everything the engine dispatches: microbatch progress or world events.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Ev {
    Micro(usize, Phase),
    World(WorldEvent),
}

/// When a requested plan becomes usable — the knob behind the
/// plan-lifecycle redesign.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlanLifecycle {
    /// Degenerate lifecycle (the default): every request commits at the
    /// request instant and the iteration blocks for the ticket's
    /// `ready_after_s` charge.  Reproduces the pre-lifecycle simulator
    /// bit for bit (the router/engine/golden-trace parity tests pin
    /// this).
    CommitAtRequest,
    /// Planning rounds ride the engine clock: a
    /// [`crate::sim::sources::PlanningSource`] emits one
    /// `WorldSchedule::plan_rounds` tick every `rtt_s` virtual seconds,
    /// the in-flight [`PlanSession`] advances per tick, and the plan
    /// commits at the tick its rounds converge.  Iterations run on the
    /// *previous* committed plan while the next converges; if a session
    /// outlasts its iteration, the uncovered tail is charged to the next
    /// iteration as a planning stall.
    RoundLatency {
        /// Virtual seconds per flow-protocol round (one control-message
        /// exchange across the slowest participating link).
        rtt_s: f64,
    },
}

/// Engine-side state of one in-flight planning session: the ticket, the
/// rounds delivered so far, crashes that landed while the plan was
/// converging (the ticket's runtime invalidation set), and the outcome
/// once committed.
pub struct PlanSession {
    ticket: PlanTicket,
    rtt_s: f64,
    rounds_done: usize,
    last_round_at: Time,
    invalidated: Vec<NodeId>,
    outcome: Option<PlanOutcome>,
}

impl PlanSession {
    pub fn new(ticket: PlanTicket, rtt_s: f64) -> PlanSession {
        PlanSession {
            ticket,
            rtt_s,
            rounds_done: 0,
            last_round_at: 0.0,
            invalidated: Vec::new(),
            outcome: None,
        }
    }

    /// A crash at `node` while the session is in flight invalidates the
    /// ticket; the policy repairs around it at commit (§V-D).
    pub(crate) fn note_crash(&mut self, node: NodeId) {
        if self.outcome.is_none() {
            self.invalidated.push(node);
        }
    }

    /// Ticks this session can still consume (it commits on the
    /// `ticket.rounds`-th): the dispatcher schedules no more than this, so
    /// a fine-grained cadence never floods the queue with dead events.
    pub(crate) fn rounds_needed(&self) -> usize {
        self.ticket.rounds
    }

    /// One planning round completes at virtual time `t`; commit once the
    /// ticket's rounds have converged.  Repair rounds a stale commit adds
    /// push the availability instant past the tick.
    pub(crate) fn on_round(&mut self, t: Time, router: &mut dyn RoutingPolicy) {
        if self.outcome.is_some() {
            return;
        }
        self.rounds_done += 1;
        self.last_round_at = t;
        if self.rounds_done >= self.ticket.rounds {
            self.commit(t, router);
        }
    }

    fn commit(&mut self, now: Time, router: &mut dyn RoutingPolicy) {
        let mut out = router.commit_plan(&self.ticket, &self.invalidated);
        let extra = out.rounds.saturating_sub(self.rounds_done.max(self.ticket.rounds));
        out.committed_at = now + extra as f64 * self.rtt_s;
        trace::emit(|| {
            TraceRecord::instant(
                out.committed_at,
                None,
                None,
                TraceKind::PlanCommit { rounds: out.rounds, stale: out.stale },
            )
        });
        self.outcome = Some(out);
    }

    /// Close the session.  If the iteration's event queue drained before
    /// the rounds converged, the remaining rounds complete off-timeline
    /// at the session cadence (the commit instant still lands at
    /// `rounds * rtt_s` after the request).
    pub(crate) fn finalize(mut self, router: &mut dyn RoutingPolicy) -> PlanOutcome {
        if self.outcome.is_none() {
            let pending = self.ticket.rounds.saturating_sub(self.rounds_done);
            let at = self.last_round_at + pending as f64 * self.rtt_s;
            self.commit(at, router);
        }
        self.outcome.expect("finalized session has an outcome")
    }
}

/// Multi-iteration driver: owns the simulator, the churn process (the
/// liveness authority) and any extra event sources, and drives the
/// [`RoutingPolicy`] plan lifecycle (request at iteration start, rounds
/// on the engine clock, commit at convergence).
pub struct Engine {
    pub sim: TrainingSim,
    pub churn: ChurnProcess,
    pub sources: Vec<Box<dyn EventSource>>,
    /// When true, iterations after the first request warm re-plans
    /// (`PlanRequest::warm`) carrying the diff of consecutive liveness
    /// views as the invalidation set (GWTF warm-starts from its surviving
    /// chains; single-shot planners ignore the hint and cold-plan).  Off
    /// by default — the paper harness (Tables II/III/VI) cold-plans every
    /// iteration.
    pub warm_replan: bool,
    /// When a requested plan becomes usable (see [`PlanLifecycle`]).
    pub plan_lifecycle: PlanLifecycle,
    /// The last committed plan ([`PlanLifecycle::RoundLatency`] only):
    /// what an iteration runs on while its own request converges.
    committed: Option<Vec<FlowPath>>,
    /// Planning stall carried into the next iteration: the part of the
    /// previous session's convergence window that its iteration did not
    /// cover.
    pending_stall: f64,
    prev_alive: Option<Vec<bool>>,
    iter: usize,
    rng: Rng,
}

impl Engine {
    pub fn new(sim: TrainingSim, churn: ChurnProcess, seed: u64) -> Engine {
        Engine {
            sim,
            churn,
            sources: Vec::new(),
            warm_replan: false,
            plan_lifecycle: PlanLifecycle::CommitAtRequest,
            committed: None,
            pending_stall: 0.0,
            prev_alive: None,
            iter: 0,
            rng: Rng::new(seed),
        }
    }

    /// Build from a scenario (shares its topology behind the `Arc`,
    /// copies the simulator config, clones the churn process).
    /// Overlay scenarios (`ScenarioConfig::overlay_fanout`) get the
    /// gossip cadence source; scenarios with
    /// `ScenarioConfig::plan_round_rtt_s` set get the round-latency plan
    /// lifecycle and its [`crate::sim::sources::PlanningSource`], so both
    /// failure detection and plan convergence run on the same continuous
    /// clock as churn and jitter.  Congestion-aware scenarios also share
    /// the planner's [`crate::net::CongestionCache`], so NIC bookings
    /// that queue invalidate the planner's memoized edge costs.
    pub fn from_scenario(sc: &Scenario, seed: u64) -> Engine {
        let mut sim = TrainingSim::new(sc.topo.clone(), sc.sim_cfg);
        sim.set_cost_cache(sc.cost_cache.clone());
        sim.set_adversary(sc.adversary.clone());
        sim.set_reputation(sc.reputation.clone());
        let mut engine = Engine::new(sim, sc.churn.clone(), seed);
        if sc.cfg.overlay_fanout.is_some() {
            engine.add_source(Box::new(super::sources::GossipCadenceSource::new(
                super::scenario::GOSSIP_PERIOD_S,
            )));
        }
        if let Some(roster) = &sc.adversary {
            // Schedulable misbehavior (straggler slowdowns, phantom
            // advert traces); roster-free scenarios grow no source, so
            // the legacy bit-for-bit guarantees hold.
            engine.add_source(Box::new(super::adversary::AdversarySource::new(roster.clone())));
        }
        if let Some(rtt_s) = sc.cfg.plan_round_rtt_s {
            engine.set_plan_round_rtt(rtt_s);
        }
        engine
    }

    pub fn add_source(&mut self, source: Box<dyn EventSource>) {
        self.sources.push(source);
    }

    /// Switch to the [`PlanLifecycle::RoundLatency`] lifecycle at `rtt_s`
    /// seconds per planning round, attaching the matching
    /// [`crate::sim::sources::PlanningSource`].  Idempotent in the source
    /// list: any previously attached planning source is replaced, so
    /// re-tuning the RTT (or calling this on a scenario that already set
    /// `plan_round_rtt_s`) never leaves two tick cadences driving one
    /// session.
    pub fn set_plan_round_rtt(&mut self, rtt_s: f64) {
        self.plan_lifecycle = PlanLifecycle::RoundLatency { rtt_s };
        self.sources.retain(|s| s.name() != super::sources::PLANNING_SOURCE_NAME);
        self.add_source(Box::new(super::sources::PlanningSource::new(rtt_s)));
    }

    /// Iterations run so far.
    pub fn iterations(&self) -> usize {
        self.iter
    }

    /// Run one training iteration: sample churn + sources, request a plan
    /// (cold or warm) through the lifecycle, execute the continuous-time
    /// schedule, and commit the plan at the virtual time its rounds
    /// converge.
    pub fn step(&mut self, prob: &FlowProblem, router: &mut dyn RoutingPolicy) -> IterationMetrics {
        let horizon = self.sim.current_iter_estimate();
        let iter = self.iter;
        // Stamp every record this iteration emits (no-op when no sink is
        // armed; never read by the simulation itself).
        trace::set_iter(iter);
        // The churn model speaks the same EventSource contract as every
        // other world-event generator; it is sampled first and held in a
        // dedicated slot because it is the liveness *authority*: its
        // planner-visible membership changes (Bernoulli rejoins) must
        // land before routes are planned, and its crashes leave the
        // aggregation barrier's membership for this iteration.
        let mut sched = self.churn.sample(iter, horizon);
        // Planner view: mid-iteration crashes are in the future.
        let alive = self.churn.planning_view_for(&sched);
        // Invalidation set of the previous plan: nodes dead since it was
        // requested.  Seeds the new ticket (PlanRequest::dirty).
        let dirty: Vec<NodeId> = match &self.prev_alive {
            Some(prev) => (0..alive.len())
                .filter(|&i| prev.get(i).copied().unwrap_or(true) && !alive[i])
                .map(NodeId)
                .collect(),
            None => Vec::new(),
        };
        let warm = self.warm_replan && self.prev_alive.is_some();
        let req = PlanRequest { alive: &alive, dirty: &dirty, warm, requested_at: 0.0, iter };

        let mut session: Option<PlanSession> = None;
        let (paths, planning_s, blocking_rounds) = match self.plan_lifecycle {
            PlanLifecycle::CommitAtRequest => {
                // Degenerate lifecycle: commit at the request instant,
                // block for the ticket's charge (bit-for-bit the
                // pre-lifecycle behavior).
                let ticket = router.request_plan(&req);
                trace::emit(|| {
                    TraceRecord::instant(
                        0.0,
                        None,
                        None,
                        TraceKind::PlanRequest { rounds: ticket.rounds },
                    )
                });
                let charge = ticket.ready_after_s;
                let out = router.commit_plan(&ticket, &[]);
                trace::emit(|| {
                    TraceRecord::instant(
                        0.0,
                        None,
                        None,
                        TraceKind::PlanCommit { rounds: out.rounds, stale: out.stale },
                    )
                });
                (out.paths, charge, out.rounds)
            }
            PlanLifecycle::RoundLatency { rtt_s } => {
                let ticket = router.request_plan(&req);
                trace::emit(|| {
                    TraceRecord::instant(
                        0.0,
                        None,
                        None,
                        TraceKind::PlanRequest { rounds: ticket.rounds },
                    )
                });
                if self.committed.is_none() || ticket.rounds == 0 {
                    // Cold start (no plan to run on: the iteration blocks
                    // until the commit, charging the convergence window)
                    // or a single-shot planner (no round protocol: the
                    // plan is ready at the request for its blocking
                    // charge, one commit per request).
                    let charge = if ticket.rounds == 0 {
                        ticket.ready_after_s
                    } else {
                        ticket.rounds as f64 * rtt_s
                    };
                    let out = router.commit_plan(&ticket, &[]);
                    trace::emit(|| {
                        TraceRecord::instant(
                            0.0,
                            None,
                            None,
                            TraceKind::PlanCommit { rounds: out.rounds, stale: out.stale },
                        )
                    });
                    self.committed = Some(out.paths.clone());
                    (out.paths, charge, out.rounds)
                } else {
                    // Steady state: run on the previous committed plan
                    // while this request converges on the engine clock;
                    // charge any stall the previous session left behind.
                    let prev_paths =
                        self.committed.clone().expect("checked committed above");
                    session = Some(PlanSession::new(ticket, rtt_s));
                    let stall = std::mem::take(&mut self.pending_stall);
                    (prev_paths, stall, 0)
                }
            }
        };

        for s in &mut self.sources {
            let mut extra = s.sample(iter, horizon);
            // A source may not crash a node that is already dead at
            // iteration start: that would resurrect it for [0, t).
            // Membership additions go through joins/rejoins.
            extra.crashes.retain(|&(n, _)| alive.get(n.0).copied().unwrap_or(false));
            extra.agg_crashes.retain(|&(n, _)| alive.get(n.0).copied().unwrap_or(false));
            sched.merge(extra);
        }
        self.prev_alive = Some(alive);
        self.iter += 1;

        let mut metrics = self.sim.run_schedule(
            prob,
            router,
            &sched,
            &self.churn,
            planning_s,
            paths,
            session.as_mut(),
            &mut self.rng,
        );
        match session {
            Some(s) => {
                // Commit (off-timeline if the queue drained first); the
                // outcome serves the next iteration, any convergence tail
                // past this iteration's end is charged to it as a stall.
                let out = s.finalize(router);
                metrics.replan_rounds = out.rounds;
                metrics.plan_overlap_s = out.committed_at.min(metrics.makespan_s).max(0.0);
                metrics.stale_replans = out.stale as usize;
                self.pending_stall = (out.committed_at - metrics.makespan_s).max(0.0);
                self.committed = Some(out.paths);
            }
            None => metrics.replan_rounds = blocking_rounds,
        }

        // Source-scheduled crashes/joins/rejoins update the liveness
        // authority *after* the iteration: the next plan sees them, this
        // one didn't.  (Churn-process entries are already applied; these
        // writes are idempotent for them.)  Membership writes land in
        // *timestamp order* — a node that joins at t=1 and crashes at t=9
        // must end the iteration dead, and one that crashes at t=1 and
        // joins at t=9 alive; at equal instants the join wins, mirroring
        // the queue's delivery order (crashes enter the timeline first,
        // so the join is dispatched after).  Rejoins carry no timestamp
        // (they are iteration-start membership) and agg crashes happen
        // inside the aggregation barrier, after every timestamped event.
        for &node in &sched.rejoins {
            self.churn.alive[node.0] = true;
        }
        let mut writes: Vec<(Time, bool, NodeId)> = sched
            .crashes
            .iter()
            .map(|&(n, t)| (t, false, n))
            .chain(sched.joins.iter().map(|&(n, t)| (t, true, n)))
            .collect();
        writes.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for &(_, up, node) in &writes {
            self.churn.alive[node.0] = up;
        }
        for &(node, _) in &sched.agg_crashes {
            self.churn.alive[node.0] = false;
        }
        metrics
    }
}

impl TrainingSim {
    /// Execute one iteration's [`WorldSchedule`]: the continuous-time
    /// dispatch loop over the event queue.
    ///
    /// `churn_state` supplies start-of-iteration liveness (aggregation
    /// membership and availability windows); `paths` are the routed flows
    /// (one per microbatch); `session`, when present, is the in-flight
    /// plan session the schedule's `plan_rounds` ticks advance (crashes
    /// landing before it converges invalidate its ticket).  With a
    /// churn-only schedule this reproduces the pre-engine simulator byte
    /// for byte.
    #[allow(clippy::too_many_arguments)]
    pub fn run_schedule(
        &mut self,
        prob: &FlowProblem,
        router: &mut dyn RoutingPolicy,
        sched: &WorldSchedule,
        churn_state: &ChurnProcess,
        planning_s: f64,
        paths: Vec<FlowPath>,
        mut session: Option<&mut PlanSession>,
        _rng: &mut Rng,
    ) -> IterationMetrics {
        let n = self.topo.n();
        // Availability windows at iteration start (rejoins already applied
        // by the caller via the churn process).
        for i in 0..n {
            self.birth_at[i] = if churn_state.alive[i] { 0.0 } else { f64::INFINITY };
            self.death_at[i] = f64::INFINITY;
        }
        for &(node, t) in &sched.crashes {
            self.birth_at[node.0] = 0.0; // alive until its death instant
            self.death_at[node.0] = t;
        }
        for &(node, t) in &sched.joins {
            if self.birth_at[node.0].is_infinite() {
                self.birth_at[node.0] = t;
            }
        }
        // Reuse the retained window buffers across iterations: clearing a
        // Vec keeps its allocation, so steady-state runs stop paying a
        // pair of heap round-trips per schedule.
        self.jitter.clear();
        self.jitter.extend_from_slice(&sched.jitter);
        // Sorted by start so the per-transfer factor lookup can binary
        // search (merged sources may interleave windows).
        self.jitter.sort_by(|a, b| a.from.total_cmp(&b.from));
        self.slowdowns.clear();
        self.slowdowns.extend_from_slice(&sched.slowdowns);

        let mut metrics =
            IterationMetrics { scheduled: paths.len(), planning_s, ..Default::default() };
        // Planning charge (blocking convergence window or carried stall):
        // rendered at the virtual origin — it blocks the iteration start.
        if planning_s > 0.0 {
            trace::emit(|| TraceRecord::span(0.0, planning_s, None, None, TraceKind::PlanStall));
        }
        let mut slots: Vec<Slots> = (0..n).map(|i| Slots::new(prob.cap[i].max(1))).collect();
        // Shared-capacity NIC substrate: every payload transfer books its
        // transmission through the sender's uplink and the receiver's
        // downlink (unlimited caps = the legacy contention-free model,
        // bit for bit).
        let mut net = NicQueues::new(self.topo.nic, self.topo.region.clone());
        // Memory residency per node (forward activations awaiting backward).
        let mut inflight: Vec<usize> = vec![0; n];
        let mut mbs: Vec<MicrobatchState> = paths.into_iter().map(MicrobatchState::new).collect();

        let mut q: EventQueue<Ev> = EventQueue::new();
        // World events enter the timeline first: a crash at time t is
        // delivered to the router at t (the asynchronous-gossip view),
        // not at first detection.
        for &(node, t) in &sched.crashes {
            q.schedule(t.max(0.0), Ev::World(WorldEvent::Crash(node)));
        }
        for &(node, t) in &sched.joins {
            q.schedule(t.max(0.0), Ev::World(WorldEvent::Join(node)));
        }
        for &t in &sched.gossip_ticks {
            q.schedule(t.max(0.0), Ev::World(WorldEvent::Gossip));
        }
        // Only the ticks the in-flight session can consume enter the
        // queue: the session commits on its ticket's round count (repair
        // rounds extend the commit instant arithmetically, not via
        // ticks), and without a session every tick would be a dead event.
        let plan_ticks = session.as_deref().map_or(0, PlanSession::rounds_needed);
        for &t in sched.plan_rounds.iter().take(plan_ticks) {
            q.schedule(t.max(0.0), Ev::World(WorldEvent::PlanRound));
        }
        // Bounded-staleness asynchronous mode (staleness_bound >= 1):
        // per-stage versioned weights and rolling per-stage aggregation on
        // this queue.  `None`/`Some(0)` leave `agg_tracker` unset and every
        // branch below degenerates to the synchronous simulator bit for
        // bit (admit_at stays 0.0, no StageAgg events, the §V-E barrier
        // runs after the drain).
        let n_stages = prob.graph.n_stages();
        let mut admit_at: Time = 0.0;
        let mut agg_tracker: Option<StageAggTracker> = match self.cfg.staleness_bound {
            Some(s) if s >= 1 => {
                let v = self.versioned.get_or_insert_with(|| VersionedWeights {
                    gen: vec![0; n_stages],
                    iter_gen: 0,
                });
                if v.gen.len() != n_stages {
                    v.gen.resize(n_stages, 0); // problem shape changed
                }
                let g = v.iter_gen;
                // Per-stage §V-E exchange durations among the members
                // alive at iteration start (the same NIC law the
                // synchronous barrier charges).
                let exchange: Vec<f64> = (0..n_stages)
                    .map(|st| {
                        let members: Vec<NodeId> = prob.graph.stages[st]
                            .iter()
                            .filter(|&&m| churn_state.is_alive(m))
                            .copied()
                            .collect();
                        self.stage_exchange_s(&members)
                    })
                    .collect();
                // Admission rule: a stage whose weights lag more than `s`
                // generations behind this iteration's stamp must replay
                // its missed exchanges (catch-up) before new microbatches
                // may start; every microbatch's admission is deferred to
                // the slowest catch-up.
                let mut staleness_max: u64 = 0;
                for st in 0..n_stages {
                    let lag = g.saturating_sub(v.gen[st]);
                    if lag > s as u64 {
                        let catch_up = (lag - s as u64) as f64 * exchange[st];
                        admit_at = admit_at.max(catch_up);
                        metrics.agg_s += catch_up;
                        v.gen[st] = g - s as u64;
                    }
                    staleness_max = staleness_max.max(g.saturating_sub(v.gen[st]));
                }
                if !mbs.is_empty() {
                    metrics.staleness_mean = staleness_max as f64;
                    if admit_at > 0.0 {
                        metrics.deferred = mbs.len();
                    }
                }
                Some(StageAggTracker::new(n_stages, mbs.len(), exchange))
            }
            _ => None,
        };
        if admit_at > 0.0 {
            trace::emit(|| {
                TraceRecord::span(0.0, admit_at, None, None, TraceKind::StalenessCatchUp)
            });
        }
        // Data nodes send out all their microbatches at t=0 (transfer to
        // hop 0) — or at the staleness catch-up instant in async mode.
        for (mi, mb) in mbs.iter_mut().enumerate() {
            let d = mb.path.source;
            let first = mb.path.relays[0];
            // The catch-up window is dead time on every microbatch's
            // timeline: charge it so the critical path stays contiguous.
            mb.crit.stale_s += admit_at;
            let arrive = self.send(&mut net, d, first, admit_at, mi, &mut metrics, &mut mb.crit);
            q.schedule(arrive, Ev::Micro(mi, Phase::Fwd { hop: 0 }));
        }

        // Stragglers past the aggregation cutoff are excluded (wasted).
        let deadline = self.cfg.deadline_factor * self.iter_estimate;
        while let Some((t, ev)) = q.pop() {
            metrics.events += 1;
            let (mi, phase) = match ev {
                Ev::World(WorldEvent::Crash(node)) => {
                    trace::emit(|| TraceRecord::instant(t, Some(node), None, TraceKind::Crash));
                    router.on_crash(node);
                    // A crash while a plan is converging invalidates the
                    // in-flight ticket (§V-D repair at commit).
                    if let Some(s) = session.as_deref_mut() {
                        s.note_crash(node);
                    }
                    continue;
                }
                Ev::World(WorldEvent::Join(node)) => {
                    trace::emit(|| TraceRecord::instant(t, Some(node), None, TraceKind::Join));
                    continue;
                }
                Ev::World(WorldEvent::Gossip) => {
                    trace::emit(|| TraceRecord::instant(t, None, None, TraceKind::GossipTick));
                    router.on_gossip(t);
                    continue;
                }
                Ev::World(WorldEvent::PlanRound) => {
                    trace::emit(|| TraceRecord::instant(t, None, None, TraceKind::PlanRound));
                    if let Some(s) = session.as_deref_mut() {
                        s.on_round(t, router);
                    }
                    continue;
                }
                Ev::World(WorldEvent::StageAgg(st)) => {
                    // One stage's rolling weight exchange completes: its
                    // weights advance past the iteration's generation.  No
                    // other stage (and no in-flight microbatch) waited.
                    if let Some(tr) = agg_tracker.as_mut() {
                        tr.fired[st] = true;
                        tr.done_at[st] = t;
                        metrics.agg_s += tr.exchange[st];
                        // The exchange ran over [t - exchange, t] (it was
                        // scheduled at last-gradient-home + exchange).
                        trace::emit(|| {
                            TraceRecord::span(
                                t - tr.exchange[st],
                                tr.exchange[st],
                                None,
                                None,
                                TraceKind::StageAgg { stage: st },
                            )
                        });
                        if let Some(v) = self.versioned.as_mut() {
                            v.gen[st] = v.iter_gen + 1;
                        }
                    }
                    continue;
                }
                Ev::Micro(mi, phase) => (mi, phase),
            };
            if mbs[mi].dropped {
                continue;
            }
            if t > deadline && mbs[mi].done_at.is_none() {
                mbs[mi].release_all(&mut inflight);
                mbs[mi].dropped = true;
                trace::emit(|| TraceRecord::instant(t, None, Some(mi), TraceKind::Drop));
                continue;
            }
            match phase {
                Phase::Fwd { hop } => {
                    self.handle_relay_compute(
                        t, mi, hop, /*is_fwd=*/ true, prob, router, &mut slots, &mut net,
                        &mut inflight, &mut mbs, &mut q, &mut agg_tracker, &mut metrics,
                    );
                }
                Phase::Loss => {
                    // Loss + head backward at the data node (always alive).
                    let d = mbs[mi].path.source;
                    let c = self.fwd_compute_s(d, t) + self.bwd_compute_s(d, t);
                    mbs[mi].compute_spent += c;
                    mbs[mi].crit.compute_s += c;
                    trace::emit(|| {
                        TraceRecord::span(t, c, Some(d), Some(mi), TraceKind::LossCompute)
                    });
                    let last = mbs[mi].path.relays.len() - 1;
                    let nxt = mbs[mi].path.relays[last];
                    let arrive =
                        self.send(&mut net, d, nxt, t + c, mi, &mut metrics, &mut mbs[mi].crit);
                    q.schedule(arrive, Ev::Micro(mi, Phase::Bwd { hop: last }));
                }
                Phase::Bwd { hop } => {
                    self.handle_relay_compute(
                        t, mi, hop, /*is_fwd=*/ false, prob, router, &mut slots, &mut net,
                        &mut inflight, &mut mbs, &mut q, &mut agg_tracker, &mut metrics,
                    );
                }
                Phase::Finish => {
                    // Embedding backward at the data node.
                    let d = mbs[mi].path.source;
                    let c = self.bwd_compute_s(d, t);
                    mbs[mi].compute_spent += c;
                    mbs[mi].crit.compute_s += c;
                    trace::emit(|| {
                        TraceRecord::span(t, c, Some(d), Some(mi), TraceKind::FinishCompute)
                    });
                    mbs[mi].done_at = Some(t + c);
                }
            }
        }

        // Tally results.  `ender` is the microbatch whose completion set
        // the makespan: its per-bucket timeline *is* the critical path of
        // the microbatch phase (see [`super::training::CritPath`]).
        let mut makespan: f64 = 0.0;
        let mut ender: Option<usize> = None;
        for (mi, mb) in mbs.iter().enumerate() {
            match mb.done_at {
                Some(t) => {
                    metrics.completed += 1;
                    if ender.is_none() || t > makespan {
                        makespan = t;
                        ender = Some(mi);
                    }
                }
                None => {
                    metrics.dropped += 1;
                    metrics.wasted_gpu_s += mb.compute_spent;
                }
            }
        }

        match agg_tracker {
            None => {
                // Aggregation barrier (§V-E), with mid-aggregation crash
                // recovery — the synchronous path, bit for bit.
                let (agg, agg_recoveries) =
                    self.aggregation_time(prob, churn_state, &sched.agg_crashes);
                metrics.agg_s = agg;
                metrics.agg_recoveries = agg_recoveries;
                if agg > 0.0 {
                    trace::emit(|| {
                        TraceRecord::span(makespan, agg, None, None, TraceKind::AggBarrier)
                    });
                }
                metrics.makespan_s = makespan + agg + planning_s;
            }
            Some(mut tr) => {
                // Rolling-aggregation residue: a stage whose expectation
                // never filled (drops, deadline exclusions) aggregates the
                // gradients it does hold once the microbatch phase ends —
                // §V-E's deadline semantics already excluded the
                // stragglers.  A stage with nothing home keeps its old
                // weights and falls behind; that lag is exactly what the
                // admission rule bounds next iteration.
                let g = self.versioned.as_ref().map_or(0, |v| v.iter_gen);
                let mut agg_end: f64 = 0.0;
                for st in 0..n_stages {
                    if !tr.fired[st] && tr.home[st] > 0 {
                        tr.fired[st] = true;
                        tr.done_at[st] = tr.last_home[st] + tr.exchange[st];
                        metrics.agg_s += tr.exchange[st];
                        trace::emit(|| {
                            TraceRecord::span(
                                tr.last_home[st],
                                tr.exchange[st],
                                None,
                                None,
                                TraceKind::StageAgg { stage: st },
                            )
                        });
                        if let Some(v) = self.versioned.as_mut() {
                            v.gen[st] = g + 1;
                        }
                    }
                    if tr.fired[st] {
                        agg_end = agg_end.max(tr.done_at[st]);
                    }
                }
                // Crashes landing inside a rolling exchange force the same
                // §V-E redo among the survivors as inside the barrier.
                let (extra, agg_recoveries) =
                    self.agg_crash_extra(prob, churn_state, &sched.agg_crashes);
                metrics.agg_s += extra;
                metrics.agg_recoveries = agg_recoveries;
                // No barrier: the iteration ends when the last microbatch
                // *or* the last rolling exchange finishes, whichever is
                // later — exchanges overlap the microbatch tail instead of
                // extending it.
                metrics.makespan_s = makespan.max(agg_end) + extra + planning_s;
                if let Some(v) = self.versioned.as_mut() {
                    v.iter_gen += 1;
                }
            }
        }
        // Critical-path attribution: promote the ending microbatch's
        // bucket tiling of [0, done_at], charge the planning window, and
        // book everything past the microbatch phase (barrier, rolling
        // tail, crash redo) as aggregation by residual — so the seven
        // buckets sum to the makespan by construction.
        if let Some(mi) = ender {
            metrics.crit_path = mbs[mi].crit;
        }
        metrics.crit_path.plan_s = metrics.planning_s;
        metrics.crit_path.agg_s = metrics.makespan_s - metrics.planning_s - makespan;
        // Per-node link load: each node's busier NIC direction's
        // microbatch-phase transmission seconds over the full iteration
        // makespan.  Demanded work, not wall-clock occupancy — under
        // unlimited concurrency a hot NIC can exceed 1 (oversubscribed).
        if metrics.makespan_s > 0.0 && n > 0 {
            let loads = (0..n).map(|i| net.node_load_s(i));
            metrics.nic_util_max = loads.clone().fold(0.0f64, f64::max) / metrics.makespan_s;
            metrics.nic_util_mean = loads.sum::<f64>() / n as f64 / metrics.makespan_s;
        }
        // EMA keeps the crash-instant / deadline reference stable.  Only
        // productive iterations update it: a zero-completion iteration has
        // a tiny makespan, and folding that in would shrink the next
        // deadline and wedge the system in a drop-everything spiral.
        if metrics.completed > 0 {
            self.iter_estimate = (0.5 * self.iter_estimate + 0.5 * metrics.makespan_s)
                .max(self.cfg.initial_iter_estimate_s * 0.1)
                .max(1e-6);
        }
        metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GwtfRouter;
    use crate::flow::FlowParams;
    use crate::sim::scenario::{build, ScenarioConfig};

    #[test]
    fn schedule_merge_concatenates_everything() {
        let mut a = WorldSchedule {
            crashes: vec![(NodeId(1), 2.0)],
            ..Default::default()
        };
        a.merge(WorldSchedule {
            crashes: vec![(NodeId(2), 3.0)],
            rejoins: vec![NodeId(4)],
            joins: vec![(NodeId(5), 1.0)],
            jitter: vec![JitterWindow { from: 0.0, until: 1.0, factor: 1.5 }],
            slowdowns: vec![Slowdown { node: NodeId(3), from: 0.0, until: 9.0, factor: 2.0 }],
            agg_crashes: vec![(NodeId(6), 0.2)],
            gossip_ticks: vec![4.5, 9.0],
            plan_rounds: vec![1.5, 3.0],
        });
        assert_eq!(a.crashes.len(), 2);
        assert_eq!(a.rejoins, vec![NodeId(4)]);
        assert_eq!(a.joins.len(), 1);
        assert_eq!(a.jitter.len(), 1);
        assert_eq!(a.slowdowns.len(), 1);
        assert_eq!(a.agg_crashes.len(), 1);
        assert_eq!(a.gossip_ticks, vec![4.5, 9.0]);
        assert_eq!(a.plan_rounds, vec![1.5, 3.0]);
        assert!(!a.is_empty());
        assert!(WorldSchedule::default().is_empty());
    }

    #[test]
    fn engine_step_matches_manual_loop_zero_churn() {
        // The engine refactor must not move a single number for the
        // legacy (churn-only, cold-plan) path: same seed => same metrics.
        let sc = build(&ScenarioConfig::table2(true, 0.0, 3));
        let mut manual_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 3);
        let mut manual_sim = TrainingSim::new(sc.topo.clone(), sc.sim_cfg);
        let mut manual_churn = sc.churn.clone();
        let mut manual_rng = Rng::new(9);
        let mut engine_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 3);
        let mut engine = Engine::from_scenario(&sc, 9);
        for _ in 0..3 {
            let ev = manual_churn.sample_iteration();
            let alive = manual_churn.planning_view(&ev);
            let (paths, planning) = manual_router.plan(&alive);
            let a = manual_sim.run_iteration(
                &sc.prob, &mut manual_router, &ev, &manual_churn, planning, paths, &mut manual_rng,
            );
            let b = engine.step(&sc.prob, &mut engine_router);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
            assert_eq!(a.agg_s.to_bits(), b.agg_s.to_bits());
        }
    }

    #[test]
    fn engine_step_matches_manual_loop_under_bernoulli_churn() {
        // ChurnModel::Bernoulli parity (ISSUE 2 acceptance): with churn as
        // an EventSource, the engine must reproduce the legacy
        // sample_iteration + run_iteration loop bit for bit — crashes,
        // rejoins and all — at the paper's 20% join-leave chance.
        let sc = build(&ScenarioConfig::table2(false, 0.2, 41));
        let mut manual_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 41);
        let mut manual_sim = TrainingSim::new(sc.topo.clone(), sc.sim_cfg);
        let mut manual_churn = sc.churn.clone();
        let mut manual_rng = Rng::new(13);
        let mut engine_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 41);
        let mut engine = Engine::from_scenario(&sc, 13);
        for _ in 0..5 {
            let ev = manual_churn.sample_iteration();
            let alive = manual_churn.planning_view(&ev);
            let (paths, planning) = manual_router.plan(&alive);
            let a = manual_sim.run_iteration(
                &sc.prob, &mut manual_router, &ev, &manual_churn, planning, paths, &mut manual_rng,
            );
            let b = engine.step(&sc.prob, &mut engine_router);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.fwd_recoveries, b.fwd_recoveries);
            assert_eq!(a.bwd_recoveries, b.bwd_recoveries);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
            assert_eq!(a.wasted_gpu_s.to_bits(), b.wasted_gpu_s.to_bits());
            assert_eq!(a.agg_s.to_bits(), b.agg_s.to_bits());
            assert_eq!(manual_churn.alive, engine.churn.alive, "liveness authorities agree");
        }
    }

    #[test]
    fn engine_nic_substrate_without_contention_matches_legacy_bit_for_bit() {
        // ISSUE 5 acceptance: unlimited-NIC-concurrency mode must
        // reproduce the legacy contention-free model bit for bit.  The
        // strong version: even with the substrate *enabled* (finite but
        // ample caps so no transmission ever queues), every metric bit
        // matches a default-config engine across churny iterations —
        // booked transfers use the exact legacy arithmetic, queueing is
        // the only new effect and it never triggers.
        let sc = build(&ScenarioConfig::table2(false, 0.2, 23));
        let mut legacy_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 23);
        let mut legacy = Engine::from_scenario(&sc, 17);

        let mut nic_sc = build(&ScenarioConfig::table2(false, 0.2, 23));
        std::sync::Arc::make_mut(&mut nic_sc.topo).nic = crate::cost::NicConfig::uniform(512);
        let mut nic_router = GwtfRouter::from_scenario(&nic_sc, FlowParams::default(), 23);
        let mut nic_engine = Engine::from_scenario(&nic_sc, 17);

        for _ in 0..4 {
            let a = legacy.step(&sc.prob, &mut legacy_router);
            let b = nic_engine.step(&nic_sc.prob, &mut nic_router);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
            assert_eq!(a.agg_s.to_bits(), b.agg_s.to_bits());
            assert_eq!(a.wasted_gpu_s.to_bits(), b.wasted_gpu_s.to_bits());
            assert_eq!(b.queue_s, 0.0, "ample NICs must never queue");
        }
    }

    #[test]
    fn staleness_zero_reproduces_synchronous_engine_bit_for_bit() {
        // Tentpole degenerate case at the engine level: a `Some(0)` bound
        // must leave every metric bit-identical to the synchronous
        // scenario across churny engine steps (evolving iter_estimate,
        // Bernoulli churn, warm replans untouched).
        let sc = build(&ScenarioConfig::table2(false, 0.2, 31));
        let mut sync_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 31);
        let mut sync_engine = Engine::from_scenario(&sc, 13);

        let mut zero_cfg = ScenarioConfig::table2(false, 0.2, 31);
        zero_cfg.staleness_bound = Some(0);
        let zc = build(&zero_cfg);
        let mut zero_router = GwtfRouter::from_scenario(&zc, FlowParams::default(), 31);
        let mut zero_engine = Engine::from_scenario(&zc, 13);

        for _ in 0..4 {
            let a = sync_engine.step(&sc.prob, &mut sync_router);
            let b = zero_engine.step(&zc.prob, &mut zero_router);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.events, b.events);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
            assert_eq!(a.agg_s.to_bits(), b.agg_s.to_bits());
            assert_eq!(a.wasted_gpu_s.to_bits(), b.wasted_gpu_s.to_bits());
            assert_eq!(b.deferred, 0);
            assert_eq!(b.staleness_mean, 0.0);
        }
    }

    #[test]
    fn bounded_staleness_engine_beats_barrier_fault_free() {
        // Fault-free async vs sync on the same scenario shape: rolling
        // exchanges overlap the microbatch tail, the barrier does not.
        let sc = build(&ScenarioConfig::table2(false, 0.0, 41));
        let mut sync_router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 41);
        let mut sync_engine = Engine::from_scenario(&sc, 19);
        let a = sync_engine.step(&sc.prob, &mut sync_router);

        let ac = build(&ScenarioConfig {
            staleness_bound: Some(2),
            ..ScenarioConfig::table2(false, 0.0, 41)
        });
        let mut async_router = GwtfRouter::from_scenario(&ac, FlowParams::default(), 41);
        let mut async_engine = Engine::from_scenario(&ac, 19);
        let b = async_engine.step(&ac.prob, &mut async_router);

        assert_eq!(a.completed, b.completed, "fault-free: same microbatches complete");
        assert!(b.agg_s > 0.0);
        assert_eq!(b.deferred, 0);
        assert!(
            b.makespan_s < a.makespan_s,
            "rolling aggregation must beat the barrier: async {} vs sync {}",
            b.makespan_s,
            a.makespan_s
        );
    }

    #[test]
    fn engine_applies_source_crashes_to_liveness_after_iteration() {
        struct OneShotCrash {
            victim: NodeId,
            fired: bool,
        }
        impl EventSource for OneShotCrash {
            fn name(&self) -> &str {
                "one-shot-crash"
            }
            fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
                if self.fired {
                    return WorldSchedule::default();
                }
                self.fired = true;
                WorldSchedule {
                    crashes: vec![(self.victim, horizon * 0.1)],
                    ..Default::default()
                }
            }
        }
        let sc = build(&ScenarioConfig::table2(true, 0.0, 5));
        let victim = sc.relays[0];
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 5);
        let mut engine = Engine::from_scenario(&sc, 5);
        engine.add_source(Box::new(OneShotCrash { victim, fired: false }));
        assert!(engine.churn.is_alive(victim));
        let m = engine.step(&sc.prob, &mut router);
        assert!(m.completed > 0);
        assert!(!engine.churn.is_alive(victim), "source crash must persist");
        assert_eq!(engine.iterations(), 1);
    }

    #[test]
    fn source_membership_writes_apply_in_timestamp_order() {
        // Regression: the post-iteration write-back used to apply all
        // crashes before all joins regardless of virtual time, so a node
        // that joined at t=0.1h and crashed at t=0.9h ended the
        // iteration alive.
        struct JoinAndCrash {
            victim: NodeId,
            join_frac: f64,
            crash_frac: f64,
            fired: bool,
        }
        impl EventSource for JoinAndCrash {
            fn name(&self) -> &str {
                "join-and-crash"
            }
            fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
                if self.fired {
                    return WorldSchedule::default();
                }
                self.fired = true;
                WorldSchedule {
                    joins: vec![(self.victim, self.join_frac * horizon)],
                    crashes: vec![(self.victim, self.crash_frac * horizon)],
                    ..Default::default()
                }
            }
        }
        let run = |join_frac: f64, crash_frac: f64| -> bool {
            let sc = build(&ScenarioConfig::table2(true, 0.0, 5));
            let victim = sc.relays[0];
            let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 5);
            let mut engine = Engine::from_scenario(&sc, 5);
            engine.add_source(Box::new(JoinAndCrash {
                victim,
                join_frac,
                crash_frac,
                fired: false,
            }));
            assert!(engine.churn.is_alive(victim));
            let m = engine.step(&sc.prob, &mut router);
            assert!(m.completed > 0);
            engine.churn.is_alive(victim)
        };
        assert!(
            !run(0.1, 0.9),
            "crash at 0.9h postdates the join at 0.1h: the node must end dead"
        );
        assert!(
            run(0.9, 0.1),
            "join at 0.9h postdates the crash at 0.1h: the node must end alive"
        );
    }

    /// Drive `iters` iterations of a fresh table2 scenario under the
    /// round-latency lifecycle at `rtt_s` seconds per planning round.
    fn round_latency_run(rtt_s: f64, churn: f64, iters: usize) -> Vec<IterationMetrics> {
        let sc = build(&ScenarioConfig::table2(true, churn, 11));
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 11);
        let mut engine = Engine::from_scenario(&sc, 3);
        engine.warm_replan = true;
        engine.set_plan_round_rtt(rtt_s);
        (0..iters).map(|_| engine.step(&sc.prob, &mut router)).collect()
    }

    #[test]
    fn round_latency_cold_start_charges_then_overlap_hides_planning() {
        let ms = round_latency_run(0.5, 0.0, 4);
        // Iteration 0 blocks on the cold plan: charge = rounds * rtt.
        assert!(ms[0].replan_rounds > 0);
        assert!(
            (ms[0].planning_s - ms[0].replan_rounds as f64 * 0.5).abs() < 1e-9,
            "cold-start charge must be the convergence window: {} vs {} rounds",
            ms[0].planning_s,
            ms[0].replan_rounds
        );
        // Steady state at a small RTT: the warm session converges well
        // inside the iteration — overlap hides it all, no stall.
        for m in &ms[1..] {
            assert!(m.plan_overlap_s > 0.0, "session must overlap training");
            assert!(m.replan_rounds > 0, "session rounds recorded");
            assert_eq!(m.stale_replans, 0, "no churn, no stale tickets");
        }
        for m in &ms[2..] {
            assert_eq!(m.planning_s, 0.0, "fully-overlapped plans cost nothing");
        }
    }

    #[test]
    fn round_latency_stall_grows_once_rtt_stops_hiding() {
        // 600s per round: even a handful of warm rounds outlasts any
        // iteration (the 2x-estimate deadline bounds the microbatch
        // phase), so the convergence tail must surface as a stall.
        let fast: f64 = round_latency_run(0.5, 0.0, 5).iter().map(|m| m.makespan_s).sum();
        let slow_ms = round_latency_run(600.0, 0.0, 5);
        let slow: f64 = slow_ms.iter().map(|m| m.makespan_s).sum();
        assert!(
            slow > fast,
            "rounds at 600s RTT must stop hiding behind the iteration: {slow} vs {fast}"
        );
        assert!(
            slow_ms[2..].iter().any(|m| m.planning_s > 0.0),
            "some steady-state iteration must pay a planning stall"
        );
    }

    #[test]
    fn mid_planning_crash_marks_ticket_stale_and_repairs() {
        struct CrashAt {
            at_iter: usize,
            victim: NodeId,
            frac: f64,
        }
        impl EventSource for CrashAt {
            fn name(&self) -> &str {
                "crash-at"
            }
            fn sample(&mut self, iter: usize, horizon: Time) -> WorldSchedule {
                if iter != self.at_iter {
                    return WorldSchedule::default();
                }
                WorldSchedule {
                    crashes: vec![(self.victim, self.frac * horizon)],
                    ..Default::default()
                }
            }
        }
        let sc = build(&ScenarioConfig::table2(true, 0.0, 21));
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 21);
        let mut engine = Engine::from_scenario(&sc, 9);
        engine.warm_replan = true;
        // 30s per round: the warm session is still converging when the
        // crash lands at 5% of the horizon (well before the session's
        // earliest possible convergence tick).
        engine.set_plan_round_rtt(30.0);
        let m0 = engine.step(&sc.prob, &mut router);
        assert_eq!(m0.stale_replans, 0, "cold start commits before any crash");
        let victim = sc.prob.graph.stages[1][0];
        engine.add_source(Box::new(CrashAt { at_iter: 1, victim, frac: 0.05 }));
        let m1 = engine.step(&sc.prob, &mut router);
        assert_eq!(
            m1.stale_replans, 1,
            "a crash during plan convergence must mark the ticket stale"
        );
        // The stale commit's §V-D repair keeps the next iteration off the
        // dead relay without a restart: the run keeps completing work.
        let m2 = engine.step(&sc.prob, &mut router);
        assert!(m2.completed > 0, "repaired plan must keep routing work");
    }
}
