//! Deterministic virtual-time event queue.
//!
//! Ties are broken by insertion sequence so simulation runs are exactly
//! reproducible regardless of float equality quirks.  Timestamps must be
//! finite: a NaN key would silently collapse the heap ordering (every
//! comparison against NaN is "equal"), so [`EventQueue::schedule`]
//! rejects non-finite times outright and the key comparator uses IEEE
//! `total_cmp`, which cannot lie even if a NaN slipped through.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual timestamp in seconds.
pub type Time = f64;

#[derive(Debug, Clone, Copy, PartialEq)]
struct Key {
    t: Time,
    seq: u64,
}

impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // `total_cmp` is a total order over all f64 values (unlike
        // `partial_cmp`, whose NaN case previously collapsed to Equal and
        // silently broke heap ordering).
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap event queue over an arbitrary payload type.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(KeyWrap, u64)>>,
    items: std::collections::HashMap<u64, (Time, E)>,
    seq: u64,
    pub now: Time,
}

// BinaryHeap needs Ord; wrap Key.
#[derive(Debug, Clone, Copy, PartialEq)]
struct KeyWrap(Key);
impl Eq for KeyWrap {}
impl PartialOrd for KeyWrap {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.0.cmp(&other.0))
    }
}
impl Ord for KeyWrap {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.cmp(&other.0)
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), items: Default::default(), seq: 0, now: 0.0 }
    }

    /// Schedule `ev` at absolute time `t` (must be finite and >= now).
    ///
    /// Panics on NaN/infinite `t`: a non-finite key is always a caller
    /// bug (a division by zero bandwidth, an uninitialized estimate) and
    /// silently mis-ordering the simulation would corrupt every metric
    /// downstream.
    pub fn schedule(&mut self, t: Time, ev: E) {
        assert!(t.is_finite(), "EventQueue::schedule: non-finite event time {t}");
        debug_assert!(t >= self.now - 1e-9, "schedule into the past: {t} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.items.insert(seq, (t, ev));
        self.heap.push(Reverse((KeyWrap(Key { t, seq }), seq)));
    }

    /// Schedule after a delay.
    pub fn after(&mut self, dt: Time, ev: E) {
        self.schedule(self.now + dt, ev);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let Reverse((_, seq)) = self.heap.pop()?;
        let (t, ev) = self.items.remove(&seq).expect("event body");
        self.now = t;
        Some((t, ev))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Per-node concurrency slots: a node can hold `cap` microbatches at once.
/// `acquire(t)` returns the earliest time >= t a slot frees up, and books it
/// until the caller `release`s by pushing the finish time.
#[derive(Debug, Clone)]
pub struct Slots {
    /// Finish times of currently-booked slots (len <= cap).
    busy_until: Vec<Time>,
    pub cap: usize,
}

impl Slots {
    pub fn new(cap: usize) -> Self {
        Slots { busy_until: Vec::new(), cap }
    }

    /// Earliest start time >= `ready` given concurrency cap: the moment the
    /// number of still-active bookings drops below `cap`.
    pub fn earliest_start(&self, ready: Time) -> Time {
        let mut active: Vec<Time> =
            self.busy_until.iter().copied().filter(|&b| b > ready + 1e-9).collect();
        if active.len() < self.cap {
            return ready;
        }
        active.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // need (active.len() - cap + 1) slots to free up
        active[active.len() - self.cap]
    }

    /// Book a slot for [start, end). Caller must use start >= earliest_start.
    pub fn book(&mut self, start: Time, end: Time) {
        self.busy_until.retain(|&b| b > start + 1e-9); // drop finished bookings
        debug_assert!(
            self.busy_until.len() < self.cap,
            "booking beyond capacity: {} active, cap {}",
            self.busy_until.len(),
            self.cap
        );
        self.busy_until.push(end.max(start));
    }

    pub fn in_use_at(&self, t: Time) -> usize {
        self.busy_until.iter().filter(|&&b| b > t + 1e-9).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_rejected() {
        let mut q = EventQueue::new();
        q.schedule(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_rejected() {
        let mut q = EventQueue::new();
        q.schedule(f64::INFINITY, ());
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.pop();
        assert_eq!(q.now, 5.0);
        q.after(1.5, ());
        let (t, _) = q.pop().unwrap();
        assert!((t - 6.5).abs() < 1e-12);
    }

    #[test]
    fn slots_respect_capacity() {
        let mut s = Slots::new(2);
        assert_eq!(s.earliest_start(0.0), 0.0);
        s.book(0.0, 10.0);
        s.book(0.0, 20.0);
        // both slots busy until 10
        assert_eq!(s.earliest_start(0.0), 10.0);
        s.book(10.0, 15.0);
        assert_eq!(s.in_use_at(12.0), 2);
        assert_eq!(s.earliest_start(12.0), 15.0);
    }

    #[test]
    fn slots_free_after_finish() {
        let mut s = Slots::new(1);
        s.book(0.0, 5.0);
        assert_eq!(s.earliest_start(6.0), 6.0);
        s.book(6.0, 7.0);
        assert_eq!(s.in_use_at(6.5), 1);
    }
}
