//! Deterministic virtual-time event queue, compute slots and the
//! shared-capacity NIC substrate ([`NicQueues`]).
//!
//! Ties are broken by insertion sequence so simulation runs are exactly
//! reproducible regardless of float equality quirks.  Timestamps must be
//! finite: a NaN key would silently collapse the heap ordering (every
//! comparison against NaN is "equal"), so [`EventQueue::schedule`]
//! rejects non-finite times outright and the key comparator uses IEEE
//! `total_cmp`, which cannot lie even if a NaN slipped through.
//!
//! Event bodies live in a free-list slab indexed by the heap key's slot
//! (not a side map): `pop` is a heap pop plus one slab index, with no
//! per-event hash or tree removal.  The slab never shrinks during a run;
//! its high-water mark is the maximum number of in-flight events, so
//! the queue's resident memory tracks concurrency, not event count.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cost::{NicConfig, NodeId};

/// Virtual timestamp in seconds.
pub type Time = f64;

/// Heap key: time-then-sequence ordering plus the slab slot holding the
/// event body.  `seq` is unique per scheduled event, so the ordering is
/// fully decided before `slot` is ever compared — the slot rides along
/// only to make `pop` an O(1) slab index.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Key {
    t: Time,
    seq: u64,
    slot: u32,
}

impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // `total_cmp` is a total order over all f64 values (unlike
        // `partial_cmp`, whose NaN case previously collapsed to Equal and
        // silently broke heap ordering).
        self.t
            .total_cmp(&other.t)
            .then(self.seq.cmp(&other.seq))
            .then(self.slot.cmp(&other.slot))
    }
}

/// Min-heap event queue over an arbitrary payload type.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Key>>,
    /// Event bodies, indexed by `Key::slot`; `None` = free.
    slab: Vec<Option<E>>,
    /// Indices of free slab entries, reused LIFO.
    free: Vec<u32>,
    seq: u64,
    pub now: Time,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), slab: Vec::new(), free: Vec::new(), seq: 0, now: 0.0 }
    }

    /// Schedule `ev` at absolute time `t` (must be finite and >= now).
    ///
    /// Panics on NaN/infinite `t`: a non-finite key is always a caller
    /// bug (a division by zero bandwidth, an uninitialized estimate) and
    /// silently mis-ordering the simulation would corrupt every metric
    /// downstream.
    pub fn schedule(&mut self, t: Time, ev: E) {
        assert!(t.is_finite(), "EventQueue::schedule: non-finite event time {t}");
        debug_assert!(t >= self.now - 1e-9, "schedule into the past: {t} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = Some(ev);
                s
            }
            None => {
                assert!(self.slab.len() < u32::MAX as usize, "event slab exhausted");
                self.slab.push(Some(ev));
                (self.slab.len() - 1) as u32
            }
        };
        self.heap.push(Reverse(Key { t, seq, slot }));
    }

    /// Schedule after a delay.
    pub fn after(&mut self, dt: Time, ev: E) {
        self.schedule(self.now + dt, ev);
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let Reverse(Key { t, slot, .. }) = self.heap.pop()?;
        let ev = self.slab[slot as usize].take().expect("event body");
        self.free.push(slot);
        self.now = t;
        Some((t, ev))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Slab high-water mark: the maximum number of events that were ever
    /// simultaneously in flight (telemetry for the scale guard).
    pub fn slab_capacity(&self) -> usize {
        self.slab.len()
    }
}

/// Per-node concurrency slots: a node can hold `cap` microbatches at once.
/// `acquire(t)` returns the earliest time >= t a slot frees up, and books it
/// until the caller `release`s by pushing the finish time.
#[derive(Debug, Clone)]
pub struct Slots {
    /// Finish times of currently-booked slots (len <= cap).
    busy_until: Vec<Time>,
    pub cap: usize,
}

impl Slots {
    pub fn new(cap: usize) -> Self {
        Slots { busy_until: Vec::new(), cap }
    }

    /// Earliest start time >= `ready` given concurrency cap: the moment the
    /// number of still-active bookings drops below `cap`.
    ///
    /// Allocation-free: `book` keeps `busy_until.len() <= cap` (finished
    /// bookings are dropped there), so when every slot is active the
    /// answer is simply the earliest active finish — one cap-sized scan,
    /// no scratch `Vec`, no sort.  This sits on the relay-compute hot
    /// path (every Fwd/Bwd event books a slot).
    pub fn earliest_start(&self, ready: Time) -> Time {
        // Count still-active bookings and track the k-th finish we would
        // need: with `active < cap` a slot is free at `ready`; otherwise
        // `active == cap` (the book-time invariant caps the length) and
        // the first slot frees at the minimum active finish.  `total_cmp`
        // keeps the selection NaN-safe, consistent with the queue's key
        // comparator.
        let mut active = 0usize;
        let mut kth = f64::INFINITY;
        for &b in &self.busy_until {
            if b > ready + 1e-9 {
                active += 1;
                if b.total_cmp(&kth) == std::cmp::Ordering::Less {
                    kth = b;
                }
            }
        }
        if active < self.cap {
            ready
        } else {
            kth
        }
    }

    /// Book a slot for [start, end). Caller must use start >= earliest_start.
    pub fn book(&mut self, start: Time, end: Time) {
        self.busy_until.retain(|&b| b > start + 1e-9); // drop finished bookings
        debug_assert!(
            self.busy_until.len() < self.cap,
            "booking beyond capacity: {} active, cap {}",
            self.busy_until.len(),
            self.cap
        );
        self.busy_until.push(end.max(start));
    }

    pub fn in_use_at(&self, t: Time) -> usize {
        self.busy_until.iter().filter(|&&b| b > t + 1e-9).count()
    }
}

/// One NIC direction's transmission bookings: `[start, end)` intervals
/// plus the class concurrency cap.
///
/// Unlike the compute [`Slots`] (which tracks only finish times — fine
/// there, because compute is always acquired at the current event
/// instant), a NIC booking can start in the *future*: the remote end may
/// clear later than the local one.  Idle gaps before such a booking must
/// stay usable — overlap is therefore counted against the actual
/// intervals, never from booking time.
#[derive(Debug, Clone, Default)]
struct NicSlots {
    bookings: Vec<(Time, Time)>,
    cap: usize,
}

impl NicSlots {
    fn new(cap: usize) -> NicSlots {
        NicSlots { bookings: Vec::new(), cap }
    }

    /// Concurrent transmissions at instant `t` (half-open `[start, end)`
    /// with the same 1e-9 guard as [`Slots`]).
    fn overlap_at(&self, t: Time) -> usize {
        self.bookings.iter().filter(|&&(s, e)| s <= t + 1e-9 && e > t + 1e-9).count()
    }

    /// True iff one more transmission can hold a slot for the whole
    /// window `[t, t + tx_s)`.  Overlap is piecewise-constant and only
    /// rises at booking starts, so checking `t` plus every start inside
    /// the window is exact.
    fn window_fits(&self, t: Time, tx_s: f64) -> bool {
        if self.overlap_at(t) >= self.cap {
            return false;
        }
        self.bookings
            .iter()
            .filter(|&&(s, _)| s > t + 1e-9 && s < t + tx_s - 1e-9)
            .all(|&(s, _)| self.overlap_at(s) < self.cap)
    }

    fn book(&mut self, start: Time, end: Time) {
        self.bookings.push((start, end.max(start)));
    }
}

/// Shared-capacity network substrate: per-node uplink/downlink
/// transmission queues — the bandwidth analog of [`Slots`].
///
/// A payload transfer `i -> j` occupies `i`'s uplink NIC and `j`'s
/// downlink NIC for its *transmission* time (`size/β`, jitter applied);
/// propagation latency pipelines and occupies nothing.  Each NIC
/// direction sustains at most `cap` concurrent transmissions for its
/// link class ([`NicConfig`]: intra-region LAN vs inter-region WAN);
/// excess transfers queue until a slot frees.  An unlimited class is the
/// degenerate legacy model: [`NicQueues::acquire`] returns the ready
/// instant untouched, so every existing trace reproduces bit for bit.
#[derive(Debug)]
pub struct NicQueues {
    cfg: NicConfig,
    region: Vec<usize>,
    up_wan: Vec<NicSlots>,
    down_wan: Vec<NicSlots>,
    up_lan: Vec<NicSlots>,
    down_lan: Vec<NicSlots>,
    /// Per-node uplink transmission-busy seconds, kept even in unlimited
    /// mode so link-load metrics always populate.  This is demanded
    /// transmission work, not wall-clock occupancy: under unlimited
    /// concurrency a node's busy seconds can exceed the makespan
    /// (oversubscription).
    pub busy_up_s: Vec<f64>,
    /// Per-node downlink transmission-busy seconds (see `busy_up_s`).
    pub busy_down_s: Vec<f64>,
    /// Retained candidate-start scratch for [`NicQueues::acquire`] —
    /// reused across calls so the booking hot path allocates nothing
    /// (mirrors the allocation-free [`Slots::earliest_start`] fix).
    scratch: Vec<Time>,
}

impl NicQueues {
    pub fn new(cfg: NicConfig, region: Vec<usize>) -> Self {
        let n = region.len();
        let slots = |cap: Option<usize>| -> Vec<NicSlots> {
            let cap = cap.unwrap_or(usize::MAX);
            assert!(cap >= 1, "NIC concurrency must be >= 1");
            (0..n).map(|_| NicSlots::new(cap)).collect()
        };
        NicQueues {
            up_wan: slots(cfg.wan_concurrency),
            down_wan: slots(cfg.wan_concurrency),
            up_lan: slots(cfg.lan_concurrency),
            down_lan: slots(cfg.lan_concurrency),
            cfg,
            region,
            busy_up_s: vec![0.0; n],
            busy_down_s: vec![0.0; n],
            scratch: Vec::new(),
        }
    }

    /// True iff some link class has a finite concurrency cap (the
    /// substrate actually books transmissions).
    pub fn enabled(&self) -> bool {
        !self.cfg.is_unlimited()
    }

    /// A node's busier interface direction, transmission-seconds (the
    /// per-node link-load metric).
    pub fn node_load_s(&self, node: usize) -> f64 {
        self.busy_up_s[node].max(self.busy_down_s[node])
    }

    /// Book a transmission of `tx_s` seconds on `from`'s uplink and
    /// `to`'s downlink, earliest-start >= `ready`.  Returns the start
    /// instant (`== ready` when both NICs can hold the whole window —
    /// and always, in unlimited mode).  The caller's transfer then
    /// arrives at `start + tx_s + propagation`.
    pub fn acquire(&mut self, from: NodeId, to: NodeId, ready: Time, tx_s: f64) -> Time {
        self.busy_up_s[from.0] += tx_s;
        self.busy_down_s[to.0] += tx_s;
        let same_region = self.region[from.0] == self.region[to.0];
        if self.cfg.cap(same_region).is_none() {
            return ready;
        }
        // Take the retained scratch out first: `up`/`down` below borrow
        // other fields of `self` mutably.
        let mut scratch = std::mem::take(&mut self.scratch);
        let (up, down) = if same_region {
            (&mut self.up_lan, &mut self.down_lan)
        } else {
            (&mut self.up_wan, &mut self.down_wan)
        };
        // Both end NICs must hold a slot for the whole `[t, t + tx)`
        // window.  Candidate starts: the ready instant and every booked
        // end after it on either interface — overlap only ever falls at
        // ends, and past the last end everything is free, so the scan
        // always terminates with a fit.  Candidates are tried in
        // ascending order by successive-minimum selection over the
        // unsorted scratch (find the smallest end strictly above the
        // last attempt) rather than a full sort: the fit almost always
        // lands within the first few candidates, and revisiting a
        // duplicate end would only re-test an identical fit, so the
        // chosen start is bit-identical to the sorted scan's.
        let start = {
            let (u, d) = (&up[from.0], &down[to.0]);
            scratch.clear();
            scratch.extend(
                u.bookings
                    .iter()
                    .chain(d.bookings.iter())
                    .map(|&(_, e)| e)
                    .filter(|&e| e > ready),
            );
            let mut cur = ready;
            loop {
                if u.window_fits(cur, tx_s) && d.window_fits(cur, tx_s) {
                    break cur;
                }
                let mut next = f64::INFINITY;
                for &e in &scratch {
                    if e.total_cmp(&cur) == std::cmp::Ordering::Greater
                        && e.total_cmp(&next) == std::cmp::Ordering::Less
                    {
                        next = e;
                    }
                }
                assert!(
                    next.is_finite(),
                    "a start past the last booked end always fits"
                );
                cur = next;
            }
        };
        up[from.0].book(start, start + tx_s);
        down[to.0].book(start, start + tx_s);
        self.scratch = scratch;
        start
    }

    /// Concurrent transmissions on `node`'s NIC at `t` for a direction
    /// and link class (`up`, `same_region`) — test/diagnostic hook for
    /// the cap invariant.
    pub fn in_use_at(&self, node: NodeId, up: bool, same_region: bool, t: Time) -> usize {
        let q = match (up, same_region) {
            (true, true) => &self.up_lan,
            (true, false) => &self.up_wan,
            (false, true) => &self.down_lan,
            (false, false) => &self.down_wan,
        };
        q[node.0].overlap_at(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_rejected() {
        let mut q = EventQueue::new();
        q.schedule(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_rejected() {
        let mut q = EventQueue::new();
        q.schedule(f64::INFINITY, ());
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.pop();
        assert_eq!(q.now, 5.0);
        q.after(1.5, ());
        let (t, _) = q.pop().unwrap();
        assert!((t - 6.5).abs() < 1e-12);
    }

    #[test]
    fn slots_respect_capacity() {
        let mut s = Slots::new(2);
        assert_eq!(s.earliest_start(0.0), 0.0);
        s.book(0.0, 10.0);
        s.book(0.0, 20.0);
        // both slots busy until 10
        assert_eq!(s.earliest_start(0.0), 10.0);
        s.book(10.0, 15.0);
        assert_eq!(s.in_use_at(12.0), 2);
        assert_eq!(s.earliest_start(12.0), 15.0);
    }

    #[test]
    fn slots_earliest_start_scans_unsorted_bookings() {
        // The allocation-free scan must find the *minimum* active finish
        // regardless of booking order (the old implementation sorted a
        // scratch Vec; the scan has no order to lean on).
        let mut s = Slots::new(3);
        s.book(0.0, 30.0);
        s.book(0.0, 10.0);
        s.book(0.0, 20.0);
        assert_eq!(s.earliest_start(0.0), 10.0);
        // A booking finishing exactly at `ready` (within the 1e-9 guard)
        // no longer counts as active: a slot is free immediately.
        assert_eq!(s.earliest_start(10.0), 10.0);
        assert_eq!(s.in_use_at(10.0), 2);
    }

    #[test]
    fn slots_free_after_finish() {
        let mut s = Slots::new(1);
        s.book(0.0, 5.0);
        assert_eq!(s.earliest_start(6.0), 6.0);
        s.book(6.0, 7.0);
        assert_eq!(s.in_use_at(6.5), 1);
    }

    #[test]
    fn nic_unlimited_never_queues() {
        // 3 nodes, 2 regions; no caps: acquire is the identity on `ready`.
        let mut nq = NicQueues::new(NicConfig::UNLIMITED, vec![0, 0, 1]);
        assert!(!nq.enabled());
        for k in 0..8 {
            let t = nq.acquire(NodeId(0), NodeId(2), 1.0, 10.0);
            assert_eq!(t, 1.0, "transfer {k} queued in unlimited mode");
        }
        // busy accounting still runs (link-load metrics) — per direction.
        assert!((nq.busy_up_s[0] - 80.0).abs() < 1e-9);
        assert_eq!(nq.busy_down_s[0], 0.0);
        assert!((nq.busy_down_s[2] - 80.0).abs() < 1e-9);
        assert!((nq.node_load_s(0) - 80.0).abs() < 1e-9);
    }

    #[test]
    fn nic_serializes_uplink_fanout() {
        // node 0 (region 0) sends to 1 and 2 (region 1): WAN cap 1 means
        // the second transmission waits for the first to clear 0's uplink.
        let nic = NicConfig { wan_concurrency: Some(1), lan_concurrency: None };
        let mut nq = NicQueues::new(nic, vec![0, 1, 1]);
        assert!(nq.enabled());
        let a = nq.acquire(NodeId(0), NodeId(1), 0.0, 5.0);
        let b = nq.acquire(NodeId(0), NodeId(2), 0.0, 5.0);
        assert_eq!(a, 0.0);
        assert_eq!(b, 5.0, "uplink must serialize the fan-out");
        assert_eq!(nq.in_use_at(NodeId(0), true, false, 2.0), 1);
    }

    #[test]
    fn nic_serializes_downlink_fanin_and_pipelines_classes() {
        // nodes 1 and 2 both send into node 0's downlink (WAN cap 1), but
        // a LAN transfer rides its own interface untouched.
        let nic = NicConfig { wan_concurrency: Some(1), lan_concurrency: Some(4) };
        let mut nq = NicQueues::new(nic, vec![0, 1, 2, 0]);
        let a = nq.acquire(NodeId(1), NodeId(0), 0.0, 4.0);
        let b = nq.acquire(NodeId(2), NodeId(0), 1.0, 4.0);
        assert_eq!(a, 0.0);
        assert_eq!(b, 4.0, "downlink fan-in must queue behind the first arrival");
        // Intra-region 3 -> 0 uses the LAN class: no WAN contention.
        let c = nq.acquire(NodeId(3), NodeId(0), 1.0, 4.0);
        assert_eq!(c, 1.0, "LAN transfer must not queue behind WAN traffic");
    }

    #[test]
    fn nic_both_endpoints_must_be_free() {
        // 0 -> 1 busy until 6; a 2 -> 1 transfer at t=2 waits for 1's
        // downlink even though 2's uplink is idle.
        let nic = NicConfig { wan_concurrency: Some(1), lan_concurrency: None };
        let mut nq = NicQueues::new(nic, vec![0, 1, 2]);
        nq.acquire(NodeId(0), NodeId(1), 0.0, 6.0);
        let t = nq.acquire(NodeId(2), NodeId(1), 2.0, 3.0);
        assert_eq!(t, 6.0);
    }

    /// The pre-slab booking algorithm: collect every candidate start
    /// into a fresh `Vec`, full-sort, first fit.  Kept here as the
    /// reference the retained-scratch selection scan must match bit for
    /// bit.
    fn sorted_reference(u: &NicSlots, d: &NicSlots, ready: Time, tx_s: f64) -> Time {
        let mut candidates: Vec<Time> = vec![ready];
        candidates.extend(
            u.bookings
                .iter()
                .chain(d.bookings.iter())
                .map(|&(_, e)| e)
                .filter(|&e| e > ready),
        );
        candidates.sort_by(|a, b| a.total_cmp(b));
        candidates
            .into_iter()
            .find(|&t| u.window_fits(t, tx_s) && d.window_fits(t, tx_s))
            .expect("a start past the last booked end always fits")
    }

    #[test]
    fn nic_acquire_selection_scan_matches_sorted_reference_bits() {
        // Drive a contended mixed-class NIC substrate with a pseudo-random
        // transfer stream; before every booking, compute the start the
        // old sort-based algorithm would choose from the same state and
        // pin the selection scan to it bitwise.
        let region = vec![0usize, 0, 1, 1, 2, 2];
        let nic = NicConfig { wan_concurrency: Some(2), lan_concurrency: Some(1) };
        let mut nq = NicQueues::new(nic, region.clone());
        let mut rng = crate::util::Rng::new(0xB00C);
        let mut clock = 0.0;
        for step in 0..400 {
            let from = rng.index(region.len());
            let to = (from + 1 + rng.index(region.len() - 1)) % region.len();
            clock += rng.uniform(0.0, 0.4);
            let ready = clock;
            let tx = rng.uniform(0.05, 2.0);
            let same = region[from] == region[to];
            let want = {
                let (u, d) = if same {
                    (&nq.up_lan[from], &nq.down_lan[to])
                } else {
                    (&nq.up_wan[from], &nq.down_wan[to])
                };
                sorted_reference(u, d, ready, tx)
            };
            let got = nq.acquire(NodeId(from), NodeId(to), ready, tx);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "step {step}: scan chose {got}, sorted reference chose {want}"
            );
        }
    }

    #[test]
    fn event_slab_reuses_slots() {
        // Interleaved schedule/pop traffic must recycle slab slots: the
        // high-water mark tracks in-flight events, not total scheduled.
        let mut q = EventQueue::new();
        for round in 0..50 {
            q.schedule(round as f64, round);
            q.schedule(round as f64 + 0.5, round + 1000);
            let (_, a) = q.pop().unwrap();
            let (_, b) = q.pop().unwrap();
            assert_eq!((a, b), (round, round + 1000));
        }
        assert!(q.slab_capacity() <= 2, "slab grew past peak concurrency");
    }

    #[test]
    fn nic_backfills_idle_gap_before_future_booking() {
        // A transfer delayed by the *remote* end books its local uplink
        // in the future; the idle gap before that booking must stay
        // usable (regression: interval-aware overlap, not
        // blocks-from-booking-time).  Nodes A B C D in distinct regions,
        // WAN cap 1.
        let nic = NicConfig { wan_concurrency: Some(1), lan_concurrency: None };
        let mut nq = NicQueues::new(nic, vec![0, 1, 2, 3]);
        // A -> B occupies B's downlink [0, 5).
        assert_eq!(nq.acquire(NodeId(0), NodeId(1), 0.0, 5.0), 0.0);
        // C -> B waits for B's downlink: C's uplink booked [5, 10).
        assert_eq!(nq.acquire(NodeId(2), NodeId(1), 0.0, 5.0), 5.0);
        // C -> D (tx 1) fits C's idle uplink gap [0, 5) — no phantom wait.
        assert_eq!(nq.acquire(NodeId(2), NodeId(3), 0.0, 1.0), 0.0);
        // A tx that cannot finish inside the gap waits for the future
        // booking to clear instead (whole-window fit).
        assert_eq!(nq.acquire(NodeId(2), NodeId(3), 0.0, 30.0), 10.0);
    }
}
