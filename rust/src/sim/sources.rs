//! Built-in [`EventSource`](super::engine::EventSource) implementations.
//!
//! Each source is a deterministic function of `(seed, iteration)` and
//! contributes one [`WorldSchedule`] per iteration; the engine merges all
//! sources onto a single virtual timeline.  These cover the event kinds
//! the iteration-synchronous simulator could not express: link-latency
//! jitter, time-varying stragglers, crashes *inside* the aggregation
//! barrier, nodes joining mid-iteration, and the protocol cadences that
//! put gossip failure detection ([`GossipCadenceSource`]) and flow-plan
//! convergence ([`PlanningSource`]) on the engine clock.  Churn itself
//! goes through the same contract: [`crate::sim::ChurnProcess`]
//! implements [`EventSource`] (Bernoulli or continuous-clock Poisson) and
//! holds the engine's dedicated liveness-authority slot rather than
//! living in the extra-sources list.

use crate::cost::NodeId;
use crate::util::Rng;

use super::engine::{EventSource, JitterWindow, Slowdown, WorldSchedule};
use super::events::Time;

/// How far past the iteration estimate a source's windows must reach so
/// straggling microbatches (deadline factor <= 4x) stay covered.
/// `pub(crate)` so the adversary layer's persistent slowdowns cover the
/// same span as the built-in straggler source.
pub(crate) const SPAN_FACTOR: f64 = 4.0;

/// Piecewise-constant global link-latency jitter: every `window_s` of
/// virtual time a fresh delay multiplier is drawn from
/// `U(1 - amp, 1 + amp)` (floored at 0.1).
pub struct LinkJitterSource {
    pub amp: f64,
    pub window_s: f64,
    rng: Rng,
}

impl LinkJitterSource {
    pub fn new(amp: f64, window_s: f64, seed: u64) -> Self {
        assert!(amp >= 0.0, "jitter amplitude must be non-negative");
        assert!(window_s > 0.0, "jitter window must be positive");
        LinkJitterSource { amp, window_s, rng: Rng::new(seed) }
    }
}

impl EventSource for LinkJitterSource {
    fn name(&self) -> &str {
        "link-jitter"
    }

    fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
        if self.amp == 0.0 {
            return WorldSchedule::default();
        }
        let span = horizon * SPAN_FACTOR;
        let n_windows = ((span / self.window_s).ceil() as usize).clamp(1, 4096);
        let mut jitter = Vec::with_capacity(n_windows);
        for k in 0..n_windows {
            let from = k as f64 * self.window_s;
            jitter.push(JitterWindow {
                from,
                until: from + self.window_s,
                factor: self.rng.uniform((1.0 - self.amp).max(0.1), 1.0 + self.amp),
            });
        }
        WorldSchedule { jitter, ..Default::default() }
    }
}

/// Time-varying stragglers: each iteration every relay independently
/// becomes `U(lo, hi)`x slower for the whole iteration with probability
/// `p` (the heterogeneous-device rows of Tables II/III, made dynamic).
pub struct StragglerSource {
    pub p: f64,
    pub factor: (f64, f64),
    relays: Vec<NodeId>,
    rng: Rng,
}

impl StragglerSource {
    pub fn new(p: f64, factor: (f64, f64), relays: Vec<NodeId>, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        assert!(factor.0 >= 1.0 && factor.1 >= factor.0, "slowdown factors must be >= 1");
        StragglerSource { p, factor, relays, rng: Rng::new(seed) }
    }
}

impl EventSource for StragglerSource {
    fn name(&self) -> &str {
        "stragglers"
    }

    fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
        let mut slowdowns = Vec::new();
        for &r in &self.relays {
            if self.rng.chance(self.p) {
                slowdowns.push(Slowdown {
                    node: r,
                    from: 0.0,
                    until: horizon * SPAN_FACTOR,
                    factor: self.rng.uniform(self.factor.0, self.factor.1),
                });
            }
        }
        WorldSchedule { slowdowns, ..Default::default() }
    }
}

/// One crash *inside* the §V-E aggregation barrier: at iteration
/// `at_iter`, `victim` dies after `frac` of the barrier has elapsed.  The
/// old per-iteration churn model could only kill nodes during the
/// microbatch phase; this is the scenario behind
/// `experiments::scenarios::run_mid_agg_crash`.
pub struct MidAggCrashSource {
    pub at_iter: usize,
    pub victim: NodeId,
    pub frac: f64,
    fired: bool,
}

impl MidAggCrashSource {
    pub fn new(at_iter: usize, victim: NodeId, frac: f64) -> Self {
        assert!((0.0..=1.0).contains(&frac));
        MidAggCrashSource { at_iter, victim, frac, fired: false }
    }
}

impl EventSource for MidAggCrashSource {
    fn name(&self) -> &str {
        "mid-aggregation-crash"
    }

    fn sample(&mut self, iter: usize, _horizon: Time) -> WorldSchedule {
        if self.fired || iter != self.at_iter {
            return WorldSchedule::default();
        }
        self.fired = true;
        WorldSchedule { agg_crashes: vec![(self.victim, self.frac)], ..Default::default() }
    }
}

/// Periodic gossip-overlay protocol rounds on the continuous clock: one
/// `gossip_ticks` entry every `period_s` of virtual time, covering the
/// same 4x-horizon span as the other sources so failure detection keeps
/// running while straggling microbatches drain.  The engine delivers each
/// tick to the router (`Router::on_gossip`), where the overlay probes
/// peers, escalates suspicion and repairs views — interleaved with
/// churn crashes and jitter on one timeline.  Stateless and identical
/// every iteration, so it perturbs no RNG stream.
pub struct GossipCadenceSource {
    pub period_s: f64,
}

impl GossipCadenceSource {
    pub fn new(period_s: f64) -> Self {
        assert!(period_s > 0.0, "gossip period must be positive");
        GossipCadenceSource { period_s }
    }
}

impl EventSource for GossipCadenceSource {
    fn name(&self) -> &str {
        "gossip-cadence"
    }

    fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
        let span = horizon * SPAN_FACTOR;
        let n_ticks = ((span / self.period_s).ceil() as usize).clamp(1, 4096);
        let gossip_ticks: Vec<Time> =
            (1..=n_ticks).map(|k| k as f64 * self.period_s).collect();
        WorldSchedule { gossip_ticks, ..Default::default() }
    }
}

/// Flow-planning protocol rounds on the continuous clock: one
/// `plan_rounds` tick every `rtt_s` of virtual time (the §V-C
/// control-message round trip across the slowest participating link),
/// covering the same 4x-horizon span as the other sources so a slow plan
/// keeps converging while straggling microbatches drain.  The engine's
/// in-flight [`crate::sim::engine::PlanSession`] advances one protocol
/// round per tick and the plan commits at the tick its rounds converge —
/// this is the clock that decides where warm-replan overlap stops hiding
/// planning cost (`gwtf bench planlag`).  Stateless and identical every
/// iteration, so it perturbs no RNG stream.
pub struct PlanningSource {
    pub rtt_s: f64,
}

impl PlanningSource {
    pub fn new(rtt_s: f64) -> Self {
        assert!(rtt_s > 0.0, "plan-round RTT must be positive");
        PlanningSource { rtt_s }
    }
}

/// [`EventSource::name`] of the planning-round cadence, used by
/// [`crate::sim::engine::Engine::set_plan_round_rtt`] to replace a
/// previously attached instance instead of stacking cadences.
pub const PLANNING_SOURCE_NAME: &str = "plan-rounds";

impl EventSource for PlanningSource {
    fn name(&self) -> &str {
        PLANNING_SOURCE_NAME
    }

    fn sample(&mut self, _iter: usize, horizon: Time) -> WorldSchedule {
        let span = horizon * SPAN_FACTOR;
        let n_ticks = ((span / self.rtt_s).ceil() as usize).clamp(1, 4096);
        let plan_rounds: Vec<Time> = (1..=n_ticks).map(|k| k as f64 * self.rtt_s).collect();
        WorldSchedule { plan_rounds, ..Default::default() }
    }
}

/// A node joining mid-iteration (§V-B): invisible to the planner this
/// iteration, but crash recovery can route onto it from its join instant,
/// and it is full membership from the next iteration on.
pub struct DelayedJoinSource {
    pub at_iter: usize,
    pub node: NodeId,
    /// Join instant as a fraction of the iteration estimate.
    pub frac: f64,
    fired: bool,
}

impl DelayedJoinSource {
    pub fn new(at_iter: usize, node: NodeId, frac: f64) -> Self {
        assert!(frac >= 0.0);
        DelayedJoinSource { at_iter, node, frac, fired: false }
    }
}

impl EventSource for DelayedJoinSource {
    fn name(&self) -> &str {
        "delayed-join"
    }

    fn sample(&mut self, iter: usize, horizon: Time) -> WorldSchedule {
        if self.fired || iter != self.at_iter {
            return WorldSchedule::default();
        }
        self.fired = true;
        WorldSchedule { joins: vec![(self.node, self.frac * horizon)], ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_windows_tile_the_span() {
        let mut s = LinkJitterSource::new(0.5, 10.0, 1);
        let sched = s.sample(0, 100.0);
        assert_eq!(sched.jitter.len(), 40, "4x span / 10s windows");
        for (k, w) in sched.jitter.iter().enumerate() {
            assert!((w.from - k as f64 * 10.0).abs() < 1e-9);
            assert!((w.until - w.from - 10.0).abs() < 1e-9);
            assert!((0.5..=1.5).contains(&w.factor), "{}", w.factor);
        }
    }

    #[test]
    fn jitter_zero_amp_is_empty() {
        let mut s = LinkJitterSource::new(0.0, 10.0, 1);
        assert!(s.sample(0, 100.0).is_empty());
    }

    #[test]
    fn jitter_deterministic_per_seed() {
        let a = LinkJitterSource::new(0.3, 5.0, 9).sample(0, 50.0);
        let b = LinkJitterSource::new(0.3, 5.0, 9).sample(0, 50.0);
        assert_eq!(a.jitter, b.jitter);
    }

    #[test]
    fn stragglers_respect_probability_extremes() {
        let relays: Vec<NodeId> = (0..10).map(NodeId).collect();
        let mut never = StragglerSource::new(0.0, (2.0, 3.0), relays.clone(), 1);
        assert!(never.sample(0, 100.0).slowdowns.is_empty());
        let mut always = StragglerSource::new(1.0, (2.0, 3.0), relays, 1);
        let sched = always.sample(0, 100.0);
        assert_eq!(sched.slowdowns.len(), 10);
        for s in &sched.slowdowns {
            assert!((2.0..=3.0).contains(&s.factor));
        }
    }

    #[test]
    fn gossip_cadence_tiles_the_span_every_iteration() {
        let mut s = GossipCadenceSource::new(25.0);
        for iter in 0..3 {
            let sched = s.sample(iter, 100.0);
            assert_eq!(sched.gossip_ticks.len(), 16, "4x span / 25s period");
            for (k, &t) in sched.gossip_ticks.iter().enumerate() {
                assert!((t - (k + 1) as f64 * 25.0).abs() < 1e-9);
            }
            assert!(!sched.is_empty());
            assert!(sched.crashes.is_empty() && sched.joins.is_empty());
        }
    }

    #[test]
    fn plan_rounds_tile_the_span_every_iteration() {
        let mut s = PlanningSource::new(10.0);
        for iter in 0..3 {
            let sched = s.sample(iter, 100.0);
            assert_eq!(sched.plan_rounds.len(), 40, "4x span / 10s RTT");
            for (k, &t) in sched.plan_rounds.iter().enumerate() {
                assert!((t - (k + 1) as f64 * 10.0).abs() < 1e-9);
            }
            assert!(!sched.is_empty());
            assert!(sched.crashes.is_empty() && sched.gossip_ticks.is_empty());
        }
    }

    #[test]
    fn mid_agg_crash_fires_once_at_target_iteration() {
        let mut s = MidAggCrashSource::new(2, NodeId(7), 0.5);
        assert!(s.sample(0, 100.0).is_empty());
        assert!(s.sample(1, 100.0).is_empty());
        let fired = s.sample(2, 100.0);
        assert_eq!(fired.agg_crashes, vec![(NodeId(7), 0.5)]);
        assert!(s.sample(2, 100.0).is_empty(), "one-shot");
        assert!(s.sample(3, 100.0).is_empty());
    }

    #[test]
    fn delayed_join_places_instant_on_horizon() {
        let mut s = DelayedJoinSource::new(1, NodeId(4), 0.25);
        assert!(s.sample(0, 200.0).is_empty());
        let fired = s.sample(1, 200.0);
        assert_eq!(fired.joins, vec![(NodeId(4), 50.0)]);
        assert!(s.sample(1, 200.0).is_empty());
    }
}
