//! Scenario builders for the paper's experiments (§VI Setup).
//!
//! Node Crashes (Tables II & III): 18 nodes — 2 persistent data nodes and
//! 16 relays over 6 stages — each data node pushing 4 microbatches per
//! iteration, payloads inflated 32x (LLaMA-like) as in the paper,
//! homogeneous (cap 4) or heterogeneous (cap U(1,3)) relays, join-leave
//! probability 0/10/20%.

use std::sync::Arc;

use crate::cost::{ActivationProfile, LinkParams, NicConfig, NodeId, NodeProfile};
use crate::flow::graph::{FlowProblem, StageGraph};
use crate::net::{
    CongestionCache, LinkGen, ReputationBook, Topology, TopologyConfig, PROCEDURAL_MIN_NODES,
    REP_ALPHA, REP_PENALTY_WEIGHT,
};
use crate::util::Rng;

use super::adversary::{AdversaryConfig, AdversaryRoster};
use super::churn::{ChurnModel, ChurnProcess};
use super::engine::Engine;
use super::training::TrainingSimConfig;

/// Model family for payload/compute shaping (Tables II vs III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Llama,
    Gpt,
}

/// High-level experiment scenario description.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub family: Family,
    pub n_data: usize,
    pub n_relays: usize,
    pub n_stages: usize,
    pub microbatches_per_data: usize,
    /// true = all relays cap 4; false = caps U(1,3) + heterogeneous compute.
    pub homogeneous: bool,
    /// Join-leave probability per relay per iteration.
    pub churn_p: f64,
    /// Churn sampling model: per-iteration Bernoulli coin (the paper's
    /// literal setup, bit-for-bit stable) or the rate-equivalent
    /// continuous-clock Poisson process (see `sim::churn`).
    pub churn_model: ChurnModel,
    /// Base forward compute per microbatch at a relay stage, seconds.
    pub base_compute_s: f64,
    /// Gossip-overlay view size per adjacent stage (`k` in the planner's
    /// O(chains·k) bound).  `Some(k)` attaches a
    /// [`crate::net::Overlay`] to the GWTF router and a
    /// [`super::sources::GossipCadenceSource`] to the engine; `None`
    /// keeps the legacy global-visibility planner (the paper-table
    /// scenarios, bit-for-bit stable).
    pub overlay_fanout: Option<usize>,
    /// Virtual seconds per flow-planning protocol round.  `Some(rtt)`
    /// puts the plan lifecycle on the continuous clock
    /// ([`super::engine::PlanLifecycle::RoundLatency`] plus a
    /// [`super::sources::PlanningSource`]): iterations run on the
    /// previous committed plan while the next converges, and planning
    /// that outlasts an iteration stalls the next one.  `None` keeps the
    /// degenerate commit-at-request lifecycle (bit-for-bit stable).
    pub plan_round_rtt_s: Option<f64>,
    /// Per-node NIC transmission concurrency by link class (intra-region
    /// LAN vs inter-region WAN).  Unlimited (the default) is the legacy
    /// contention-free network — bit-for-bit stable; finite caps enable
    /// the shared-capacity substrate ([`crate::sim::events::NicQueues`]):
    /// transmissions serialize per NIC, propagation pipelines.
    pub nic: NicConfig,
    /// Route the planner's Eq. 1 cost closure through
    /// [`crate::net::Topology::congestion_cost`]: each edge additionally
    /// charges the expected NIC-queueing term derived from the *same*
    /// substrate parameters (`nic`) the simulator executes.  Off (the
    /// default, and a no-op under unlimited NICs) = the paper's
    /// contention-blind Eq. 1.
    pub congestion_aware_planning: bool,
    /// Override for the topology's inter-region bandwidth envelope, Mb/s
    /// (paper default 50–500).  The congestion scenario starves it.
    pub wan_bw_mbps: Option<(f64, f64)>,
    /// Shape a fan-in hotspot: stage `s`'s first relay becomes a "hub" —
    /// residency capacity for the full demand, fast compute, and links
    /// that look great *per transfer* — so capacity-oblivious wiring
    /// (SWARM's nearest-peer greedy) funnels every flow through one NIC.
    pub fanin_hub: bool,
    /// Override [`TrainingSimConfig::deadline_factor`] (congestion runs
    /// stretch iterations far past the contention-free estimate).
    pub deadline_factor: Option<f64>,
    /// Override [`TrainingSimConfig::initial_iter_estimate_s`].
    pub iter_estimate_s: Option<f64>,
    /// Bounded-staleness asynchronous training
    /// ([`TrainingSimConfig::staleness_bound`]): `Some(s >= 1)` replaces
    /// the global §V-E barrier with rolling per-stage aggregation events;
    /// `None`/`Some(0)` keep the synchronous simulator bit for bit.
    pub staleness_bound: Option<usize>,
    /// Misbehaving-relay models ([`crate::sim::adversary`]):
    /// `Some(cfg)` assigns the fixed behavior mix (DENY storm /
    /// straggler / free-rider / eclipse) to `round(fraction x
    /// n_relays)` relays at build time.  `None` (the default) keeps
    /// every relay honest — no roster, no extra engine source,
    /// bit-for-bit the legacy simulator.
    pub adversaries: Option<AdversaryConfig>,
    /// Reputation-aware routing ([`crate::net::reputation`]): service
    /// observations charged at the handler sites, scores published at
    /// the gossip cadence, and an Eq. 1 penalty folded into the
    /// planner's cost closure.  Off by default; on a clean fleet the
    /// all-honest prior keeps the closure bitwise-transparent.
    pub reputation: bool,
    /// Link generation/storage arm ([`LinkGen`]).  `Dense` (the
    /// default) is the legacy materialized matrix, bit for bit; `Auto`
    /// lets the scale scenario switch to the O(regions²) procedural
    /// substrate at [`PROCEDURAL_MIN_NODES`]+ nodes.
    pub link_gen: LinkGen,
    pub seed: u64,
}

impl ScenarioConfig {
    /// Table II setting (LLaMA-like).
    pub fn table2(homogeneous: bool, churn_p: f64, seed: u64) -> Self {
        ScenarioConfig {
            family: Family::Llama,
            n_data: 2,
            n_relays: 16,
            n_stages: 6,
            microbatches_per_data: 4,
            homogeneous,
            churn_p,
            churn_model: ChurnModel::Bernoulli,
            base_compute_s: 8.0,
            overlay_fanout: None,
            plan_round_rtt_s: None,
            nic: NicConfig::UNLIMITED,
            congestion_aware_planning: false,
            wan_bw_mbps: None,
            fanin_hub: false,
            deadline_factor: None,
            iter_estimate_s: None,
            staleness_bound: None,
            adversaries: None,
            reputation: false,
            link_gen: LinkGen::Dense,
            seed,
        }
    }

    /// Bounded-staleness setting (`gwtf bench async`): Table II's shape
    /// under heavy heterogeneity (per-node caps and compute spread) and
    /// continuous-clock Poisson churn, swept over the staleness bound.
    /// `None` is the synchronous-barrier reference arm.
    pub fn bounded_staleness(s: Option<usize>, churn_p: f64, seed: u64) -> Self {
        ScenarioConfig {
            churn_model: ChurnModel::Poisson,
            staleness_bound: s,
            ..Self::table2(false, churn_p, seed)
        }
    }

    /// Table III setting (GPT-like: heavier activation traffic).
    pub fn table3(homogeneous: bool, churn_p: f64, seed: u64) -> Self {
        ScenarioConfig { family: Family::Gpt, ..Self::table2(homogeneous, churn_p, seed) }
    }

    /// Table VI setting: 3 data nodes, relays over 6 stages, no churn,
    /// homogeneous (comparison against DT-FM's GPipe arrangement).
    ///
    /// The paper says "15 relay nodes distributed across 6 stages (3 nodes
    /// per stage)", which is internally inconsistent (3 x 6 = 18); three
    /// disjoint GPipe pipelines need 3 relays in *every* stage, so we use
    /// 18 (DESIGN.md SSubstitutions).
    pub fn table6(seed: u64) -> Self {
        ScenarioConfig {
            n_data: 3,
            n_relays: 18,
            churn_p: 0.0,
            ..Self::table2(true, 0.0, seed)
        }
    }

    /// Scale setting (`gwtf bench scale`): `n_relays` relays over 6
    /// stages in 10 regions, 2 persistent data nodes pushing 8
    /// microbatches each, homogeneous caps, continuous-clock Poisson
    /// churn, and the gossip overlay at the default fanout — Table II's
    /// shape pushed to the 100+ relay regime the overlay exists for.
    pub fn scale(n_relays: usize, churn_p: f64, seed: u64) -> Self {
        ScenarioConfig {
            n_relays,
            microbatches_per_data: 8,
            churn_model: ChurnModel::Poisson,
            overlay_fanout: Some(DEFAULT_OVERLAY_FANOUT),
            // At PROCEDURAL_MIN_NODES+ relays the sparse substrate takes
            // over: Auto selects the O(regions²) procedural link store,
            // and the planner closure goes through the (sharded, lazy)
            // congestion-cost memo.  Under the scale scenario's
            // unlimited NICs `congestion_cost` IS `cost` bit for bit, so
            // the knob exercises the sparse cache without moving a
            // single plan; below the threshold both knobs stay in their
            // legacy bit-stable positions.
            link_gen: LinkGen::Auto,
            congestion_aware_planning: n_relays >= PROCEDURAL_MIN_NODES,
            ..Self::table2(true, churn_p, seed)
        }
    }

    /// Congestion setting (`gwtf bench congestion`): Table II's shape
    /// over a bandwidth-starved WAN (20–60 Mb/s) with a fan-in hub in
    /// every stage, no churn.  `nic_wan = None` is the contention-free
    /// reference; `Some(c)` caps every node's WAN NIC at `c` concurrent
    /// transmissions (LAN gets 4x — local interfaces are fat).
    /// `congestion_aware` routes GWTF's Eq. 1 closure through the
    /// expected-queueing term so the planner prices the hub's NIC
    /// backlog instead of funnelling into it.
    pub fn congestion(nic_wan: Option<usize>, congestion_aware: bool, seed: u64) -> Self {
        ScenarioConfig {
            nic: NicConfig {
                wan_concurrency: nic_wan,
                lan_concurrency: nic_wan.map(|c| c * 4),
            },
            congestion_aware_planning: congestion_aware,
            wan_bw_mbps: Some((20.0, 60.0)),
            fanin_hub: true,
            // 16 relays over 4 stages: every stage keeps enough lean
            // peers (3 x cap 2) that a congestion-aware plan can push
            // most of the demand around its hub.
            n_stages: 4,
            // Queueing stretches iterations far past the contention-free
            // 240 s estimate: keep the aggregation-cutoff deadline out of
            // the way so contention delays work instead of dropping it.
            deadline_factor: Some(8.0),
            iter_estimate_s: Some(1500.0),
            ..Self::table2(true, 0.0, seed)
        }
    }

    /// Adversarial setting (`gwtf bench adversary`): Table II's
    /// homogeneous shape widened to 24 relays over 6 stages (4 per
    /// stage, cap 4 each — demand 8/stage leaves honest headroom even
    /// at f = 25%), the gossip overlay attached (eclipse lies need
    /// views to poison), no churn, and `fraction` of the relays
    /// running the fixed behavior mix.  `reputation` toggles the
    /// defense: oblivious GWTF replans into the same liars every
    /// iteration; the reputation-aware arm prices them out after the
    /// first gossip publish.
    pub fn adversary(fraction: f64, reputation: bool, seed: u64) -> Self {
        ScenarioConfig {
            n_relays: 24,
            overlay_fanout: Some(DEFAULT_OVERLAY_FANOUT),
            adversaries: Some(AdversaryConfig::with_fraction(fraction)),
            reputation,
            ..Self::table2(true, 0.0, seed)
        }
    }
}

/// Default gossip-overlay view size per adjacent stage (`k`).
pub const DEFAULT_OVERLAY_FANOUT: usize = 8;

/// Virtual seconds between gossip-overlay protocol rounds.
pub const GOSSIP_PERIOD_S: f64 = 30.0;

/// Fully-instantiated scenario.
pub struct Scenario {
    pub cfg: ScenarioConfig,
    /// One shared topology: the planner's cost closure, the simulator and
    /// every engine built from this scenario point at the *same*
    /// allocation (a full `links` matrix is O(n²) — at 1k nodes deep
    /// clones per run dominated setup time).
    pub topo: Arc<Topology>,
    pub prob: FlowProblem,
    pub churn: ChurnProcess,
    pub sim_cfg: TrainingSimConfig,
    /// Congestion-cost memo backing the planner closure when
    /// `congestion_aware_planning` is set (None otherwise); the engine
    /// hands it to the simulator so the booking path can invalidate.
    pub cost_cache: Option<Arc<CongestionCache>>,
    /// Misbehaving-relay roster shared by the simulator, the engine's
    /// adversary source and the overlay's eclipse hook (None = all
    /// honest — the legacy engine, bit for bit).
    pub adversary: Option<Arc<AdversaryRoster>>,
    /// Shared reputation book when reputation-aware routing is on
    /// (None = oblivious planning; no observation code runs).
    pub reputation: Option<Arc<ReputationBook>>,
    pub relays: Vec<NodeId>,
    pub data_nodes: Vec<NodeId>,
}

impl Scenario {
    /// A continuous-time engine over this scenario (shares the topology,
    /// copies the simulator config and clones the churn process; attach
    /// extra event sources via [`Engine::add_source`]).
    pub fn engine(&self, seed: u64) -> Engine {
        Engine::from_scenario(self, seed)
    }
}

/// Build the topology, stage assignment, capacities and churn process.
pub fn build(cfg: &ScenarioConfig) -> Scenario {
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.n_data + cfg.n_relays;
    let topo_defaults = TopologyConfig::default();
    let mut topo = Topology::generate(
        &TopologyConfig {
            n_nodes: n,
            n_regions: 10,
            inter_bw_mbps: cfg.wan_bw_mbps.unwrap_or(topo_defaults.inter_bw_mbps),
            nic: cfg.nic,
            link_gen: cfg.link_gen,
            ..topo_defaults
        },
        &mut rng,
    );

    let data_nodes: Vec<NodeId> = (0..cfg.n_data).map(NodeId).collect();
    let relays: Vec<NodeId> = (cfg.n_data..n).map(NodeId).collect();

    // Stage assignment: round-robin for even sizes.
    let mut stages: Vec<Vec<NodeId>> = vec![Vec::new(); cfg.n_stages];
    for (i, &r) in relays.iter().enumerate() {
        stages[i % cfg.n_stages].push(r);
    }

    // Capacities + compute profiles.
    let mut cap = vec![0usize; n];
    for &d in &data_nodes {
        cap[d.0] = cfg.microbatches_per_data * 2; // ample: data nodes are persistent
        topo.set_profile(d, NodeProfile::new(cfg.base_compute_s * 0.5, cap[d.0]));
    }
    for &r in &relays {
        let (c, compute) = if cfg.homogeneous {
            (4, cfg.base_compute_s)
        } else {
            (
                rng.int_range(1, 3) as usize,
                cfg.base_compute_s * rng.uniform(0.7, 2.2),
            )
        };
        cap[r.0] = c;
        topo.set_profile(r, NodeProfile::new(compute, c));
    }

    // Fan-in hotspot: one hub per stage with residency capacity for the
    // whole demand, fast compute, and links that beat the starved WAN
    // per transfer (80 Mb/s, low latency) — so capacity-oblivious
    // nearest-peer wiring funnels every flow through one NIC, and only a
    // congestion-aware planner prices the serialized backlog that
    // creates.  Link edits draw nothing from the RNG: the non-hub
    // topology stays identical across knob settings at a fixed seed.
    if cfg.fanin_hub {
        let total_demand = cfg.n_data * cfg.microbatches_per_data;
        let hub_link = LinkParams::new(0.005, 80.0 * 1e6 / 8.0);
        for stage in &stages {
            let hub = stage[0];
            cap[hub.0] = total_demand;
            topo.set_profile(hub, NodeProfile::new(cfg.base_compute_s * 0.5, total_demand));
            // Lean peers: only the hub can absorb the whole demand, so
            // capacity-oblivious wiring funnels into its NIC while the
            // peers' own interfaces stay nearly idle.
            for &r in &stage[1..] {
                cap[r.0] = 2;
                topo.set_profile(r, NodeProfile::new(cfg.base_compute_s, 2));
            }
            let links = topo.links_mut();
            for x in 0..n {
                if x != hub.0 {
                    links[x][hub.0] = hub_link;
                    links[hub.0][x] = hub_link;
                }
            }
        }
    }

    // Adversarial roster: deterministic assignment over the final stage
    // layout and honest capacities.  Free-riders advertise phantom
    // capacity, so the *planner's* cap vector is inflated here while
    // the roster keeps the true values for runtime enforcement in
    // `handle_relay_compute`.  A fraction that rounds to zero leaves
    // the scenario roster-free (the legacy engine, bit for bit).
    let adversary = match &cfg.adversaries {
        Some(acfg) if acfg.fraction > 0.0 => {
            let roster = AdversaryRoster::assign(n, &stages, &cap, acfg);
            if roster.is_empty() {
                None
            } else {
                for r in roster.free_riders() {
                    if let Some(adv) = roster.advertised_cap(r) {
                        cap[r.0] = adv;
                    }
                }
                Some(Arc::new(roster))
            }
        }
        _ => None,
    };
    let reputation = cfg
        .reputation
        .then(|| Arc::new(ReputationBook::new(n, REP_ALPHA, REP_PENALTY_WEIGHT)));

    // Activation payload (GPT ships more bytes — paper §VI).
    let act = match cfg.family {
        Family::Llama => ActivationProfile::paper_llama(),
        Family::Gpt => ActivationProfile::paper_gpt(),
    };
    let payload = act.bytes();

    let demand = vec![cfg.microbatches_per_data; cfg.n_data];
    let graph = Arc::new(StageGraph { stages, data_nodes: data_nodes.clone() });
    // Topology mutation is done: freeze it behind one Arc shared by the
    // planner closure, the scenario and every simulator built from it.
    let topo = Arc::new(topo);
    // The planner's Eq. 1 closure derives from the same substrate
    // parameters the simulator executes (the shared topology carries
    // `nic`): congestion-aware scenarios add the expected NIC-queueing
    // term per edge — served through the [`CongestionCache`] memo — and
    // everything else keeps the contention-blind paper cost (identical
    // closure under unlimited NICs either way, and the cache is
    // bit-transparent over `congestion_cost`).
    let mut cost_cache = None;
    let cost: Box<dyn Fn(NodeId, NodeId) -> f64 + Send + Sync> =
        if cfg.congestion_aware_planning {
            let cache = Arc::new(CongestionCache::new(topo.clone(), payload));
            cost_cache = Some(cache.clone());
            Box::new(move |i, j| cache.cost(i, j))
        } else {
            let topo = topo.clone();
            Box::new(move |i, j| topo.cost(i, j, payload))
        };
    let prob = FlowProblem { graph, cap: cap.clone(), demand, cost };

    let churn = ChurnProcess::with_model(
        cfg.churn_model,
        n,
        relays.clone(),
        cfg.churn_p,
        rng.fork(0xC0).next_u64(),
    );

    let sim_cfg = TrainingSimConfig {
        payload_bytes: payload,
        stage_param_bytes: 75e6 * 4.0 / cfg.n_stages as f64, // ~300M params split over stages
        timeout_s: 5.0,
        max_restarts: 3,
        initial_iter_estimate_s: cfg.iter_estimate_s.unwrap_or(240.0),
        bwd_factor: 2.0,
        deadline_factor: cfg.deadline_factor.unwrap_or(2.0),
        staleness_bound: cfg.staleness_bound,
    };

    Scenario {
        cfg: cfg.clone(),
        topo,
        prob,
        churn,
        sim_cfg,
        cost_cache,
        adversary,
        reputation,
        relays,
        data_nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape() {
        let s = build(&ScenarioConfig::table2(true, 0.1, 1));
        assert_eq!(s.data_nodes.len(), 2);
        assert_eq!(s.relays.len(), 16);
        assert_eq!(s.prob.graph.n_stages(), 6);
        let total: usize = s.prob.graph.stages.iter().map(|v| v.len()).sum();
        assert_eq!(total, 16);
        for &r in &s.relays {
            assert_eq!(s.prob.cap[r.0], 4);
        }
    }

    #[test]
    fn staleness_bound_knob_reaches_sim_config() {
        let sync = build(&ScenarioConfig::table2(false, 0.1, 3));
        assert_eq!(sync.sim_cfg.staleness_bound, None);
        let s = build(&ScenarioConfig::bounded_staleness(Some(2), 0.1, 3));
        assert_eq!(s.sim_cfg.staleness_bound, Some(2));
        assert!(matches!(s.cfg.churn_model, ChurnModel::Poisson));
    }

    #[test]
    fn heterogeneous_caps_in_range() {
        let s = build(&ScenarioConfig::table2(false, 0.0, 2));
        for &r in &s.relays {
            assert!((1..=3).contains(&s.prob.cap[r.0]), "{}", s.prob.cap[r.0]);
        }
        // compute heterogeneity present
        let speeds: Vec<f64> = s.relays.iter().map(|&r| s.topo.profiles[r.0].compute_s).collect();
        let min = speeds.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = speeds.iter().cloned().fold(0.0f64, f64::max);
        assert!(max > min * 1.2);
    }

    #[test]
    fn gpt_ships_more_bytes_than_llama() {
        let l = build(&ScenarioConfig::table2(true, 0.0, 3));
        let g = build(&ScenarioConfig::table3(true, 0.0, 3));
        assert!(g.sim_cfg.payload_bytes > l.sim_cfg.payload_bytes);
    }

    #[test]
    fn table6_shape() {
        let s = build(&ScenarioConfig::table6(4));
        assert_eq!(s.data_nodes.len(), 3);
        assert_eq!(s.relays.len(), 18);
        // 18 relays over 6 stages: 3 per stage (three disjoint pipelines)
        let sizes: Vec<usize> = s.prob.graph.stages.iter().map(|v| v.len()).collect();
        assert!(sizes.iter().all(|&n| n == 3));
    }

    #[test]
    fn churn_model_knob_reaches_the_process() {
        let bern = build(&ScenarioConfig::table2(true, 0.1, 6));
        assert_eq!(bern.churn.model, ChurnModel::Bernoulli);
        let mut cfg = ScenarioConfig::table2(true, 0.1, 6);
        cfg.churn_model = ChurnModel::Poisson;
        let pois = build(&cfg);
        assert_eq!(pois.churn.model, ChurnModel::Poisson);
        // Same seed, same topology/problem either way: the knob only
        // changes churn sampling.
        assert_eq!(bern.prob.cap, pois.prob.cap);
        assert_eq!(bern.topo.region, pois.topo.region);
    }

    #[test]
    fn scale_shape_overlay_knob_and_gossip_cadence() {
        let s = build(&ScenarioConfig::scale(100, 0.2, 8));
        assert_eq!(s.relays.len(), 100);
        assert_eq!(s.data_nodes.len(), 2);
        assert_eq!(s.cfg.overlay_fanout, Some(DEFAULT_OVERLAY_FANOUT));
        assert_eq!(s.cfg.churn_model, ChurnModel::Poisson);
        let sizes: Vec<usize> = s.prob.graph.stages.iter().map(|v| v.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 100);
        assert!(sizes.iter().all(|&n| n >= 16), "{sizes:?}");
        // overlay scenarios drive the failure detector from the engine
        // clock; legacy scenarios must not grow a source (bit-for-bit
        // guarantees depend on it)
        assert_eq!(s.engine(1).sources.len(), 1);
        let legacy = build(&ScenarioConfig::table2(true, 0.1, 8));
        assert!(legacy.engine(1).sources.is_empty());
    }

    #[test]
    fn scale_scenario_selects_sparse_substrate_at_1k() {
        // Below the threshold: legacy dense links, contention-blind
        // closure — the historical bit-stable configuration.
        let small = build(&ScenarioConfig::scale(100, 0.2, 8));
        assert!(!small.topo.is_procedural());
        assert!(!small.cfg.congestion_aware_planning);
        assert!(small.cost_cache.is_none());
        // At PROCEDURAL_MIN_NODES relays: O(regions²) procedural links
        // plus the lazily-populated congestion memo behind the closure.
        let big = build(&ScenarioConfig::scale(PROCEDURAL_MIN_NODES, 0.2, 8));
        assert!(big.topo.is_procedural());
        assert!(big.cfg.congestion_aware_planning);
        let cache = big.cost_cache.as_ref().expect("memo behind the closure");
        assert_eq!(
            big.topo.resident_link_entries(),
            100,
            "10 regions -> 100 resident range entries, not n²"
        );
        // Unlimited NICs: the memoized congestion closure is plain Eq. 1
        // bit for bit, and only touched edges become resident.
        let (d, r) = (big.data_nodes[0], big.relays[7]);
        assert_eq!(
            big.prob.cost(d, r).to_bits(),
            big.topo.cost(d, r, big.sim_cfg.payload_bytes).to_bits()
        );
        assert_eq!(cache.resident_entries(), 1, "exactly the touched edge resides");
    }

    #[test]
    fn plan_round_rtt_knob_wires_the_lifecycle() {
        use super::super::engine::PlanLifecycle;
        let mut cfg = ScenarioConfig::table2(true, 0.0, 11);
        cfg.plan_round_rtt_s = Some(2.5);
        let s = build(&cfg);
        let engine = s.engine(1);
        assert_eq!(engine.plan_lifecycle, PlanLifecycle::RoundLatency { rtt_s: 2.5 });
        assert_eq!(engine.sources.len(), 1, "planning cadence source attached");
        assert_eq!(engine.sources[0].name(), crate::sim::sources::PLANNING_SOURCE_NAME);
    }

    #[test]
    fn congestion_scenario_shapes_hub_nic_and_deadline() {
        let sc = build(&ScenarioConfig::congestion(Some(2), false, 9));
        assert_eq!(sc.cfg.nic.wan_concurrency, Some(2));
        assert_eq!(sc.cfg.nic.lan_concurrency, Some(8), "LAN gets 4x the WAN cap");
        assert_eq!(sc.topo.nic, sc.cfg.nic, "substrate params reach the topology");
        assert!((sc.sim_cfg.deadline_factor - 8.0).abs() < 1e-12);
        assert!((sc.sim_cfg.initial_iter_estimate_s - 1500.0).abs() < 1e-12);
        let total_demand = sc.cfg.n_data * sc.cfg.microbatches_per_data;
        assert_eq!(sc.prob.graph.n_stages(), 4, "16 relays over 4 fan-in stages");
        for stage in &sc.prob.graph.stages {
            let hub = stage[0];
            assert_eq!(sc.prob.cap[hub.0], total_demand, "hub holds the whole demand");
            for &r in &stage[1..] {
                assert_eq!(sc.prob.cap[r.0], 2, "non-hub peers are lean");
            }
            // The hub's links beat the starved 20-60 Mb/s WAN per transfer.
            let bw = sc.topo.link(0, hub.0).bandwidth_bps * 8.0 / 1e6;
            assert!((bw - 80.0).abs() < 1e-9, "{bw}");
        }
        // Starved WAN on non-hub inter-region links.
        let hubs: Vec<NodeId> = sc.prob.graph.stages.iter().map(|s| s[0]).collect();
        for i in 0..sc.topo.n() {
            for j in 0..sc.topo.n() {
                if i == j
                    || sc.topo.region[i] == sc.topo.region[j]
                    || hubs.contains(&NodeId(i))
                    || hubs.contains(&NodeId(j))
                {
                    continue;
                }
                let mbps = sc.topo.link(i, j).bandwidth_bps * 8.0 / 1e6;
                assert!((20.0..=60.0).contains(&mbps), "{mbps}");
            }
        }
    }

    #[test]
    fn congestion_aware_knob_prices_hub_edges_higher() {
        // Same seed: identical topology; only the planner closure moves.
        let blind = build(&ScenarioConfig::congestion(Some(1), false, 11));
        let aware = build(&ScenarioConfig::congestion(Some(1), true, 11));
        assert_eq!(blind.topo.region, aware.topo.region);
        let hub = blind.prob.graph.stages[0][0];
        let other = *blind.prob.graph.stages[0]
            .iter()
            .find(|&&m| m != hub)
            .expect("stage has a non-hub relay");
        let data = blind.data_nodes[0];
        assert_eq!(
            blind.prob.cost(data, other).to_bits(),
            blind.topo.cost(data, other, blind.sim_cfg.payload_bytes).to_bits(),
            "blind closure is plain Eq. 1"
        );
        assert!(
            aware.prob.cost(data, hub) > blind.prob.cost(data, hub),
            "aware closure must charge the hub's expected queueing"
        );
        // Unlimited NICs: the aware closure degenerates to plain Eq. 1.
        let unlimited = build(&ScenarioConfig::congestion(None, true, 11));
        assert_eq!(
            unlimited.prob.cost(data, hub).to_bits(),
            blind.prob.cost(data, hub).to_bits()
        );
    }

    #[test]
    fn adversary_scenario_assigns_roster_and_inflates_phantom_caps() {
        let sc = build(&ScenarioConfig::adversary(0.25, true, 13));
        assert_eq!(sc.relays.len(), 24);
        let roster = sc.adversary.as_ref().expect("roster attached at f=25%");
        let book = sc.reputation.as_ref().expect("reputation book on");
        assert_eq!(book.len(), sc.topo.n());
        let flagged =
            sc.relays.iter().filter(|&&r| roster.behavior(r).is_some()).count();
        assert_eq!(flagged, 6, "round(0.25 * 24)");
        // Planner sees the phantom caps; the roster keeps the truth.
        for r in roster.free_riders() {
            let adv = roster.advertised_cap(r).unwrap();
            assert_eq!(sc.prob.cap[r.0], adv);
            assert!(roster.runtime_cap(r, adv) < adv);
        }
        // Data nodes never misbehave.
        for &d in &sc.data_nodes {
            assert!(roster.behavior(d).is_none());
        }
    }

    #[test]
    fn adversary_fraction_zero_keeps_the_legacy_build() {
        let clean = build(&ScenarioConfig::adversary(0.0, false, 13));
        assert!(clean.adversary.is_none(), "fraction 0 rounds to no roster");
        assert!(clean.reputation.is_none());
        // Identical caps/topology to the same config without the knob.
        let mut cfg = ScenarioConfig::adversary(0.0, false, 13);
        cfg.adversaries = None;
        let plain = build(&cfg);
        assert_eq!(clean.prob.cap, plain.prob.cap);
        assert_eq!(clean.topo.region, plain.topo.region);
    }

    #[test]
    fn reputation_without_adversaries_is_allowed() {
        let mut cfg = ScenarioConfig::table2(true, 0.0, 5);
        cfg.reputation = true;
        let sc = build(&cfg);
        assert!(sc.adversary.is_none());
        assert!(sc.reputation.is_some());
    }

    #[test]
    fn deterministic_scenarios() {
        let a = build(&ScenarioConfig::table2(false, 0.1, 9));
        let b = build(&ScenarioConfig::table2(false, 0.1, 9));
        assert_eq!(a.prob.cap, b.prob.cap);
        assert_eq!(a.topo.region, b.topo.region);
    }
}
