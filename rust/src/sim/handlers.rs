//! Per-event microbatch handlers for the continuous-time engine.
//!
//! One microbatch is a little state machine walking its routed flow:
//! forward hops through the relay stages, loss + head backward at the
//! data node, backward hops in reverse, embedding backward.  Each arrival
//! is one engine event; this module holds the handler the engine
//! dispatches for relay-stage compute — including §V-D memory-overload
//! DENYs, forward-pass reroutes and the backward-pass repair/restart
//! split that separates GWTF from SWARM.

use crate::cost::NodeId;
use crate::flow::graph::{FlowPath, FlowProblem};
use crate::trace::{self, TraceKind, TraceRecord};

use super::engine::{Ev, WorldEvent};
use super::events::{EventQueue, NicQueues, Slots, Time};
use super::training::{
    CritPath, IterationMetrics, RecoveryPolicy, RoutingPolicy, StageAggTracker, TrainingSim,
};

/// Phase of a microbatch's journey.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Phase {
    /// Payload left `prev`; arriving at relay index `hop` of its path.
    Fwd { hop: usize },
    /// Arrived back at the data node for loss + head backward.
    Loss,
    /// Gradient arriving at relay index `hop` (descending).
    Bwd { hop: usize },
    /// Gradient arrived back at the data node (embedding backward).
    Finish,
}

#[derive(Debug, Clone)]
pub(crate) struct MicrobatchState {
    pub path: FlowPath,
    pub restarts: usize,
    /// Compute seconds spent so far (wasted if the microbatch is dropped).
    pub compute_spent: f64,
    pub dropped: bool,
    pub done_at: Option<Time>,
    /// Relays currently holding this microbatch's forward activation
    /// (memory residency: acquired at forward compute, released when the
    /// backward pass clears the node — the paper's `cap_i` semantics).
    pub resident: Vec<NodeId>,
    /// Overload reroutes so far (bounded to keep DENY storms finite).
    pub overload_reroutes: usize,
    /// (stage, node) pairs that DENYed this microbatch — "excluded until
    /// they free memory" (§V-D).
    pub denied: Vec<(usize, NodeId)>,
    /// Per-microbatch critical-path buckets: the handlers charge every
    /// segment of this microbatch's contiguous virtual timeline
    /// (admission → gradient home) to a bucket as they advance it.  The
    /// engine's tally promotes the makespan-ending microbatch's buckets
    /// to `IterationMetrics::crit_path`.
    pub crit: CritPath,
}

impl MicrobatchState {
    pub fn new(path: FlowPath) -> Self {
        MicrobatchState {
            path,
            restarts: 0,
            compute_spent: 0.0,
            dropped: false,
            done_at: None,
            resident: Vec::new(),
            overload_reroutes: 0,
            denied: Vec::new(),
            crit: CritPath::default(),
        }
    }

    /// Free every residency this microbatch holds (drop / restart).
    pub fn release_all(&mut self, inflight: &mut [usize]) {
        for r in self.resident.drain(..) {
            inflight[r.0] = inflight[r.0].saturating_sub(1);
        }
    }
}

impl TrainingSim {
    /// Relay-stage compute (fwd or bwd) with crash detection + recovery.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn handle_relay_compute(
        &mut self,
        t: Time,
        mi: usize,
        hop: usize,
        is_fwd: bool,
        prob: &FlowProblem,
        router: &mut dyn RoutingPolicy,
        slots: &mut [Slots],
        net: &mut NicQueues,
        inflight: &mut [usize],
        mbs: &mut Vec<MicrobatchState>,
        q: &mut EventQueue<Ev>,
        agg: &mut Option<StageAggTracker>,
        metrics: &mut IterationMetrics,
    ) {
        let path = mbs[mi].path.clone();
        let node = path.relays[hop];
        let sink = path.source;
        let n_stages = path.relays.len();
        let prev: NodeId = if is_fwd {
            if hop == 0 { sink } else { path.relays[hop - 1] }
        } else if hop + 1 < n_stages {
            path.relays[hop + 1]
        } else {
            sink
        };
        let next: NodeId = if is_fwd {
            if hop + 1 < n_stages { path.relays[hop + 1] } else { sink }
        } else if hop == 0 {
            sink
        } else {
            path.relays[hop - 1]
        };

        let compute =
            if is_fwd { self.fwd_compute_s(node, t) } else { self.bwd_compute_s(node, t) };

        // Adversary policies (None = every relay honest and both
        // lookups fold to the legacy constants below).
        let roster = self.adversary.as_deref();
        let storm = roster.map_or(false, |a| a.is_deny_storm(node));
        let node_cap = roster.map_or(prob.cap[node.0], |a| a.runtime_cap(node, prob.cap[node.0]));

        // Memory overload (§V-D DENY): a forward arrival at a node whose
        // residency budget is exhausted cannot be accepted — the upstream
        // node reroutes to a peer with spare memory or defers the batch.
        // Capacity-aware planning (GWTF) never trips this; SWARM's
        // capacity-oblivious wiring does.  A DENY-storm relay refuses
        // every forward arrival regardless of occupancy, and a
        // free-rider enforces its *true* capacity rather than the
        // phantom one the planner saw.
        if is_fwd && self.is_up(node, t) && (storm || inflight[node.0] >= node_cap) {
            metrics.denies += 1;
            if let Some(book) = &self.reputation {
                book.observe_deny(node);
            }
            let kind = if storm { TraceKind::DenyStorm } else { TraceKind::Deny };
            trace::emit(|| TraceRecord::instant(t, Some(node), Some(mi), kind));
            mbs[mi].overload_reroutes += 1;
            mbs[mi].denied.push((hop, node));
            if mbs[mi].overload_reroutes > 4 * n_stages {
                mbs[mi].release_all(inflight);
                mbs[mi].dropped = true;
                trace::emit(|| TraceRecord::instant(t, Some(node), Some(mi), TraceKind::Drop));
                return;
            }
            // The upstream node only learns a peer is full when that peer
            // DENYs; it retries the next-best peer it knows, which may be
            // full too ("this process can continue recursively", SV-D).
            // It has NO global memory view, so candidates are filtered only
            // by received DENYs, not by actual residency.  A DENY excludes
            // the peer only "until they free memory" (§V-D): entries for
            // this stage whose peer has observable residency headroom
            // again drop out of the exclusion set — re-probing a peer
            // that freed up would succeed, and one that refilled would
            // just DENY again and re-enter the set.  DENY-storm peers
            // never free up, and a free-rider's observable headroom is
            // against its true capacity — so adversarial exclusions
            // persist exactly as long as the misbehavior does.
            mbs[mi].denied.retain(|&(h, m)| {
                h != hop
                    || roster.map_or(false, |a| a.is_deny_storm(m))
                    || inflight[m.0] >= roster.map_or(prob.cap[m.0], |a| a.runtime_cap(m, prob.cap[m.0]))
            });
            let denied = &mbs[mi].denied;
            let candidates: Vec<NodeId> = prob.graph.stages[hop]
                .iter()
                .filter(|&&m| {
                    m != node && self.is_up(m, t) && !denied.contains(&(hop, m))
                })
                .copied()
                .collect();
            match router.choose_replacement(prev, next, &candidates) {
                Some(m) => {
                    let arrive = self.send(net, prev, m, t, mi, metrics, &mut mbs[mi].crit);
                    let mut newpath = path.clone();
                    newpath.relays[hop] = m;
                    mbs[mi].path = newpath;
                    q.schedule(arrive, Ev::Micro(mi, Phase::Fwd { hop }));
                }
                None => {
                    // DENY propagates to the source; deferred to next iter.
                    mbs[mi].release_all(inflight);
                    mbs[mi].dropped = true;
                    trace::emit(|| TraceRecord::instant(t, Some(node), Some(mi), TraceKind::Drop));
                }
            }
            return;
        }

        if self.is_up(node, t) {
            let start = slots[node.0].earliest_start(t);
            let end = start + compute;
            let death = self.death_at[node.0];
            if start < death && end <= death {
                // Success: book the slot, forward the payload.
                slots[node.0].book(start, end);
                if let Some(book) = &self.reputation {
                    // Charge the promised/observed compute-time ratio:
                    // the promise is the profile the relay advertised
                    // (un-slowed), the observation includes deliberate
                    // straggling, so liars score near 1/factor.
                    let promised = self.topo.profiles[node.0].compute_s
                        * if is_fwd { 1.0 } else { self.cfg.bwd_factor };
                    book.observe_service(node, promised, compute);
                }
                mbs[mi].compute_spent += compute;
                mbs[mi].crit.queue_s += start - t;
                mbs[mi].crit.compute_s += compute;
                if trace::enabled() {
                    if start > t {
                        trace::emit(|| {
                            TraceRecord::span(t, start - t, Some(node), Some(mi), TraceKind::SlotWait)
                        });
                    }
                    trace::emit(|| {
                        TraceRecord::span(
                            start,
                            compute,
                            Some(node),
                            Some(mi),
                            TraceKind::Compute { hop, fwd: is_fwd },
                        )
                    });
                }
                if is_fwd {
                    // activation stays resident until the backward clears
                    inflight[node.0] += 1;
                    mbs[mi].resident.push(node);
                } else if let Some(pos) = mbs[mi].resident.iter().position(|&r| r == node) {
                    mbs[mi].resident.remove(pos);
                    inflight[node.0] = inflight[node.0].saturating_sub(1);
                }
                // Bounded-staleness mode: a backward compute clearing this
                // stage is the stage's gradient contribution for the
                // microbatch — when the last expected one lands, the
                // stage's rolling weight exchange goes on the queue.
                if !is_fwd {
                    if let Some(tr) = agg.as_mut() {
                        if let Some(fire_at) = tr.grad_home(mi, hop, end) {
                            q.schedule(fire_at, Ev::World(WorldEvent::StageAgg(hop)));
                        }
                    }
                }
                let arrive = self.send(net, node, next, end, mi, metrics, &mut mbs[mi].crit);
                let next_phase = if is_fwd {
                    if hop + 1 < n_stages { Phase::Fwd { hop: hop + 1 } } else { Phase::Loss }
                } else if hop == 0 {
                    Phase::Finish
                } else {
                    Phase::Bwd { hop: hop - 1 }
                };
                // If the receiver is a relay that might be dead on arrival,
                // the crash branch below (on its own event) handles it.
                q.schedule(arrive, Ev::Micro(mi, next_phase));
                return;
            }
            // Node dies mid-task: partial work is wasted, crash detected
            // after the COMPLETE timeout.
            if start < death {
                metrics.wasted_gpu_s += death - start;
            }
        }

        // --- crash handling ---
        // Detection time is one COMPLETE timeout after the *event
        // instant*: the upstream peer only notices the crash when the
        // COMPLETE it expects fails to arrive, counted from when the work
        // was handed over — not from the (earlier) death instant.  The
        // old `death.min(t)`/`.max(t)` dance always collapsed to `t`.
        let detect = t + self.cfg.timeout_s;
        router.on_crash(node);

        let stage = hop;
        if is_fwd {
            metrics.fwd_recoveries += 1;
            // Reroute to an alive same-stage replacement with a free slot.
            let with_memory: Vec<NodeId> = prob.graph.stages[stage]
                .iter()
                .filter(|&&m| {
                    m != node
                        && self.is_up(m, detect)
                        && slots[m.0].in_use_at(detect) < slots[m.0].cap
                        && inflight[m.0] < prob.cap[m.0]
                })
                .copied()
                .collect();
            // If every alive peer is memory-full right now, wait one
            // timeout for residencies to clear (flows keep draining) and
            // retry the best alive peer; the Fwd-arrival overload branch
            // DENY-reroutes again if it is still full.
            let (candidates, wait) = if with_memory.is_empty() {
                let alive_only: Vec<NodeId> = prob.graph.stages[stage]
                    .iter()
                    .filter(|&&m| m != node && self.is_up(m, detect))
                    .copied()
                    .collect();
                (alive_only, self.cfg.timeout_s)
            } else {
                (with_memory, 0.0)
            };
            match router.choose_replacement(prev, next, &candidates) {
                Some(m) => {
                    // prev resends its stored activation to m.
                    mbs[mi].crit.queue_s += detect + wait - t;
                    if trace::enabled() {
                        trace::emit(|| {
                            TraceRecord::span(
                                t,
                                detect + wait - t,
                                Some(node),
                                Some(mi),
                                TraceKind::RecoveryWait,
                            )
                        });
                        trace::emit(|| {
                            TraceRecord::instant(detect, Some(m), Some(mi), TraceKind::FwdRecovery)
                        });
                    }
                    let arrive =
                        self.send(net, prev, m, detect + wait, mi, metrics, &mut mbs[mi].crit);
                    let mut newpath = path.clone();
                    newpath.relays[hop] = m;
                    mbs[mi].path = newpath;
                    q.schedule(arrive, Ev::Micro(mi, Phase::Fwd { hop }));
                }
                None => {
                    // DENY up to the source; batch deferred to next iteration.
                    mbs[mi].release_all(inflight);
                    mbs[mi].dropped = true;
                    trace::emit(|| {
                        TraceRecord::instant(detect, Some(node), Some(mi), TraceKind::Drop)
                    });
                }
            }
        } else {
            metrics.bwd_recoveries += 1;
            match router.recovery() {
                RecoveryPolicy::RepairPath => {
                    // §V-D: replacement recomputes this stage's forward from
                    // the stored upstream activation, then the backward pass
                    // resumes from the stored gradient.
                    let with_memory: Vec<NodeId> = prob.graph.stages[stage]
                        .iter()
                        .filter(|&&m| {
                            m != node
                                && self.is_up(m, detect)
                                && slots[m.0].in_use_at(detect) < slots[m.0].cap
                                && inflight[m.0] < prob.cap[m.0]
                        })
                        .copied()
                        .collect();
                    // memory-full everywhere: wait one timeout for a
                    // residency to clear rather than dropping the batch
                    let (candidates, wait) = if with_memory.is_empty() {
                        let alive_only: Vec<NodeId> = prob.graph.stages[stage]
                            .iter()
                            .filter(|&&m| m != node && self.is_up(m, detect))
                            .copied()
                            .collect();
                        (alive_only, self.cfg.timeout_s)
                    } else {
                        (with_memory, 0.0)
                    };
                    match router.choose_replacement(prev, next, &candidates) {
                        Some(m) => {
                            // fetch activation from the fwd-side neighbour +
                            // recompute fwd at m, then continue bwd at m.
                            // The recompute occupies one of m's compute
                            // slots like every other stage compute: a
                            // saturated replacement serializes repairs
                            // instead of absorbing unboundedly many
                            // concurrent recomputes for free.
                            mbs[mi].crit.queue_s += detect + wait - t;
                            if trace::enabled() {
                                trace::emit(|| {
                                    TraceRecord::span(
                                        t,
                                        detect + wait - t,
                                        Some(node),
                                        Some(mi),
                                        TraceKind::RecoveryWait,
                                    )
                                });
                                trace::emit(|| {
                                    TraceRecord::instant(
                                        detect,
                                        Some(m),
                                        Some(mi),
                                        TraceKind::BwdRecovery { restart: false },
                                    )
                                });
                            }
                            let act_arrive = self.send(
                                net,
                                prev,
                                m,
                                detect + wait,
                                mi,
                                metrics,
                                &mut mbs[mi].crit,
                            );
                            let refwd = self.fwd_compute_s(m, detect + wait);
                            let start = slots[m.0].earliest_start(act_arrive);
                            slots[m.0].book(start, start + refwd);
                            mbs[mi].compute_spent += refwd;
                            mbs[mi].crit.queue_s += start - act_arrive;
                            mbs[mi].crit.compute_s += refwd;
                            if trace::enabled() {
                                if start > act_arrive {
                                    trace::emit(|| {
                                        TraceRecord::span(
                                            act_arrive,
                                            start - act_arrive,
                                            Some(m),
                                            Some(mi),
                                            TraceKind::SlotWait,
                                        )
                                    });
                                }
                                trace::emit(|| {
                                    TraceRecord::span(
                                        start,
                                        refwd,
                                        Some(m),
                                        Some(mi),
                                        TraceKind::Compute { hop, fwd: true },
                                    )
                                });
                            }
                            // residency moves from the dead node to m
                            if let Some(pos) = mbs[mi].resident.iter().position(|&r| r == node) {
                                mbs[mi].resident.remove(pos);
                                inflight[node.0] = inflight[node.0].saturating_sub(1);
                            }
                            inflight[m.0] += 1;
                            mbs[mi].resident.push(m);
                            let mut newpath = path.clone();
                            newpath.relays[hop] = m;
                            mbs[mi].path = newpath;
                            q.schedule(start + refwd, Ev::Micro(mi, Phase::Bwd { hop }));
                        }
                        None => {
                            mbs[mi].release_all(inflight);
                            mbs[mi].dropped = true;
                            trace::emit(|| {
                                TraceRecord::instant(detect, Some(node), Some(mi), TraceKind::Drop)
                            });
                        }
                    }
                }
                RecoveryPolicy::RestartPipeline => {
                    // SWARM: all work on this microbatch is discarded and the
                    // whole pipeline re-executes from the data node.
                    metrics.restarts += 1;
                    metrics.wasted_gpu_s += mbs[mi].compute_spent;
                    mbs[mi].compute_spent = 0.0;
                    mbs[mi].release_all(inflight);
                    trace::emit(|| {
                        TraceRecord::instant(
                            detect,
                            Some(node),
                            Some(mi),
                            TraceKind::BwdRecovery { restart: true },
                        )
                    });
                    if mbs[mi].restarts + 1 > self.cfg.max_restarts {
                        mbs[mi].dropped = true;
                        trace::emit(|| {
                            TraceRecord::instant(detect, Some(node), Some(mi), TraceKind::Drop)
                        });
                        return;
                    }
                    mbs[mi].restarts += 1;
                    // Re-wire dead relays before restarting.
                    let mut newpath = mbs[mi].path.clone();
                    for (s, r) in newpath.relays.clone().into_iter().enumerate() {
                        if !self.is_up(r, detect) {
                            let candidates: Vec<NodeId> = prob.graph.stages[s]
                                .iter()
                                .filter(|&&m| m != r && self.is_up(m, detect))
                                .copied()
                                .collect();
                            match router.choose_replacement(
                                if s == 0 { sink } else { newpath.relays[s - 1] },
                                if s + 1 < n_stages { newpath.relays[s + 1] } else { sink },
                                &candidates,
                            ) {
                                Some(m) => newpath.relays[s] = m,
                                None => {
                                    mbs[mi].release_all(inflight);
                                    mbs[mi].dropped = true;
                                    trace::emit(|| {
                                        TraceRecord::instant(
                                            detect,
                                            Some(node),
                                            Some(mi),
                                            TraceKind::Drop,
                                        )
                                    });
                                    return;
                                }
                            }
                        }
                    }
                    mbs[mi].path = newpath;
                    let d = mbs[mi].path.source;
                    let first = mbs[mi].path.relays[0];
                    // The restart's wall segment [t, detect) is detection
                    // wait on the microbatch's timeline.
                    mbs[mi].crit.queue_s += detect - t;
                    trace::emit(|| {
                        TraceRecord::span(
                            t,
                            detect - t,
                            Some(node),
                            Some(mi),
                            TraceKind::RecoveryWait,
                        )
                    });
                    let arrive = self.send(net, d, first, detect, mi, metrics, &mut mbs[mi].crit);
                    q.schedule(arrive, Ev::Micro(mi, Phase::Fwd { hop: 0 }));
                }
            }
        }
    }
}
