//! GWTF — Go With The Flow: churn-tolerant decentralized training of LLMs.
//!
//! A reproduction of Blagoev et al., "Go With The Flow: Churn-Tolerant
//! Decentralized Training of Large Language Models" (2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - [`flow`], [`coordinator`], [`sim`], [`net`], [`cost`] — the paper's
//!   system contribution (decentralized min-cost flow routing, node join,
//!   crash recovery, aggregation sync) over a simulated geo-distributed
//!   volunteer network.
//! - [`baselines`] — SWARM, DT-FM (genetic comm-optimal arrangement), and
//!   the Fig. 5 join baselines the paper compares against.
//! - [`runtime`], [`trainer`], [`data`] — the real training path: PJRT
//!   executes the AOT-lowered JAX/Pallas stage computations from Rust.
//! - [`config`], [`metrics`], [`util`] — launcher/config system, metric
//!   reporters, and offline-build substitutes for rand/serde/criterion.
//! - [`trace`] — flight-recorder tracing of the continuous-time engine
//!   (ambient `TraceSink`, Chrome-trace export via `gwtf bench --trace`,
//!   CI flight-recorder dumps, critical-path attribution).
#![allow(clippy::needless_range_loop)]
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod experiments;
pub mod flow;
pub mod metrics;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod trainer;
pub mod util;
