//! `gwtf` — the launcher.
//!
//! Subcommands:
//!
//! ```text
//! gwtf doctor                         PJRT + artifact sanity check
//! gwtf sim    [--system gwtf|swarm] [--heterogeneous] [--churn P] [--iters N]
//! gwtf train  [--family llama|gpt] [--steps N] [--churn P] [--lr X]
//! gwtf bench  <TARGET>          (see BENCH_TARGETS: tables, figures, and the
//!             [--reps N] [--full]  continuous-time scenario sweeps)
//!             [--trace out.json]   (Chrome/Perfetto trace of every iteration)
//! gwtf join-demo                      Fig. 3 walkthrough
//! ```
//!
//! Every run is deterministic from `--seed`.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use gwtf::baselines::SwarmRouter;
use gwtf::config::Args;
use gwtf::coordinator::join::{utilization_query, JoinPolicy, Leader};
use gwtf::coordinator::GwtfRouter;
use gwtf::cost::NodeId;
use gwtf::experiments::{
    results_dir, run_adversary, run_async, run_congestion, run_fig5, run_fig6, run_fig7,
    run_link_jitter, run_mid_agg_crash, run_plan_lag, run_poisson_churn, run_scale, run_table2,
    run_table3, run_table6, update_adversary_json, update_async_json, update_congestion_json,
    update_plan_lag_json, update_scale_json, AdversaryOpts, AsyncOpts, CongestionOpts, Fig6Opts,
    PlanLagOpts, ScaleOpts, ScenarioOpts, TableOpts,
};
use gwtf::flow::mcmf::mcmf_min_cost;
use gwtf::flow::FlowParams;
use gwtf::metrics::MetricsTable;
use gwtf::runtime::Manifest;
use gwtf::sim::scenario::{build, Family, ScenarioConfig};
use gwtf::sim::training::{BlockingPlanAdapter, RoutingPolicy};
use gwtf::trainer::{ChurnTrainer, PipelineTrainer};
use gwtf::util::Rng;

/// The canonical bench-target list: the single source for the usage
/// text and the `gwtf bench` error message (they drifted apart once
/// already — new targets go here and nowhere else).
const BENCH_TARGETS: &str = "table2|table3|table6|fig5|fig6|fig7|midagg|jitter|poissonchurn|\
                             scale|planlag|congestion|async|adversary|all";

fn usage() -> String {
    format!(
        "usage: gwtf <doctor|sim|train|bench|join-demo> [options]
  doctor                         check PJRT + artifacts
  sim       --system gwtf|swarm  --heterogeneous --churn P --iters N --seed S
            --warm-replan        (GWTF warm-starts re-plans from surviving chains)
  train     --family llama|gpt   --steps N --churn P --lr X --microbatches M
  bench     {BENCH_TARGETS}
            --reps N --iters N --full --warm-replan
            --trace FILE         (record every simulated iteration and export
             a Chrome/Perfetto trace-event JSON: one track per node, spans
             per compute/transfer/wait, instants for churn + plan events;
             open in chrome://tracing or ui.perfetto.dev)
            (scale: --relays \"100,200\" --gwtf-relays \"1000,10000\" --churn P
             --threads T — overlay GWTF vs baselines (the --gwtf-relays
             sizes run GWTF only, T planner worker threads; sizes >= 1000
             take the procedural link store + sparse congestion cache, so
             10000 relays fits the same footprint), writes
             BENCH_scale.json at the repo root)
            (planlag: --rtts \"0,0.5,2,8,30,120\" --churn P — plan-lifecycle
             round-RTT sweep, writes BENCH_planlag.json at the repo root)
            (congestion: --nics \"0,8,4,2,1\" — shared-capacity NIC sweep
             over a fan-in hotspot, writes BENCH_congestion.json)
            (async: --staleness \"1,2,4\" --churn P — bounded-staleness
             sweep vs the synchronous barrier, writes BENCH_async.json)
            (adversary: --fractions \"0,0.1,0.25\" — Byzantine-relay sweep,
             oblivious vs reputation-aware GWTF vs SWARM, writes
             BENCH_adversary.json)
  join-demo                      Fig. 3 walkthrough"
    )
}

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("doctor") => doctor(args),
        Some("sim") => sim(args),
        Some("train") => train(args),
        Some("bench") => bench(args),
        Some("join-demo") => join_demo(args),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn doctor(_args: &Args) -> Result<()> {
    println!("PJRT platform: {}", gwtf::runtime::smoke()?);
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => {
            for (fam, f) in &m.families {
                println!(
                    "artifacts[{fam}]: {} fns, {} params, {} stages, d_model={}",
                    f.entries.len(),
                    f.config.param_count,
                    f.config.n_stages,
                    f.config.d_model
                );
            }
        }
        Err(e) => println!("artifacts: NOT READY ({e})"),
    }
    Ok(())
}

fn sim(args: &Args) -> Result<()> {
    let system = args.str_or("system", "gwtf");
    let homogeneous = !args.flag("heterogeneous");
    let churn = args.f64_or("churn", 0.1)?;
    let iters = args.usize_or("iters", 8)?;
    let seed = args.u64_or("seed", 1)?;
    let family =
        if args.str_or("family", "llama") == "gpt" { Family::Gpt } else { Family::Llama };

    let mut cfg = ScenarioConfig::table2(homogeneous, churn, seed);
    cfg.family = family;
    let sc = build(&cfg);
    let mut engine = sc.engine(seed ^ 0x51);
    engine.warm_replan = args.flag("warm-replan");

    let mut router: Box<dyn RoutingPolicy> = match system.as_str() {
        "gwtf" => Box::new(GwtfRouter::from_scenario(&sc, FlowParams::default(), seed)),
        "swarm" => {
            // comm-only cost: SWARM's greedy is blind to compute (SVI)
            let topo = sc.topo.clone();
            let payload = sc.sim_cfg.payload_bytes;
            Box::new(BlockingPlanAdapter::new(SwarmRouter::from_problem(
                &sc.prob,
                Arc::new(move |i, j| topo.comm(i, j, payload)),
                seed,
            )))
        }
        other => bail!("unknown --system {other} (gwtf|swarm)"),
    };

    println!(
        "# {} | {} | churn {:.0}% | {} iterations",
        router.name(),
        if homogeneous { "homogeneous" } else { "heterogeneous" },
        churn * 100.0,
        iters
    );
    println!(
        "{:>4} {:>12} {:>6} {:>10} {:>12} {:>8} {:>8}",
        "iter", "makespan_s", "done", "comm_s", "wasted_s", "fwd_rec", "bwd_rec"
    );
    for i in 0..iters {
        let m = engine.step(&sc.prob, router.as_mut());
        println!(
            "{:>4} {:>12.1} {:>6} {:>10.1} {:>12.1} {:>8} {:>8}",
            i, m.makespan_s, m.completed, m.comm_s, m.wasted_gpu_s, m.fwd_recoveries, m.bwd_recoveries
        );
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let family = args.str_or("family", "llama");
    let steps = args.usize_or("steps", 20)?;
    let churn = args.f64_or("churn", 0.0)?;
    let lr = args.f64_or("lr", 0.1)? as f32;
    let microbatches = args.usize_or("microbatches", 4)?;
    let seed = args.u64_or("seed", 42)?;
    let default_dir = Manifest::default_dir();
    let artifacts = args.str_or("artifacts", default_dir.to_str().unwrap());

    let trainer = PipelineTrainer::new(&artifacts, &family, seed, lr, microbatches)?;
    println!(
        "# training {family} ({} stages) for {steps} steps, churn {:.0}%",
        trainer.n_stages(),
        churn * 100.0
    );
    if churn > 0.0 {
        let cfg = ScenarioConfig::table2(false, churn, seed);
        let mut t = ChurnTrainer::new(trainer, &cfg);
        println!(
            "{:>5} {:>10} {:>14} {:>8} {:>8}",
            "step", "loss", "sim_makespan_s", "fwd_rec", "bwd_rec"
        );
        for _ in 0..steps {
            let m = t.step()?;
            println!(
                "{:>5} {:>10.4} {:>14.1} {:>8} {:>8}",
                m.step, m.loss, m.sim_makespan_s, m.fwd_recoveries, m.bwd_recoveries
            );
        }
    } else {
        let mut t = trainer;
        println!("{:>5} {:>10}", "step", "loss");
        for _ in 0..steps {
            let m = t.step()?;
            println!("{:>5} {:>10.4}", m.step, m.loss);
        }
    }
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    let target = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("bench needs a target: {BENCH_TARGETS}"))?
        .clone();
    let reps = args.usize_or("reps", 25)?;
    let iters = args.usize_or("iters", 4)?;
    let seed = args.u64_or("seed", 1)?;
    let opts = TableOpts {
        reps,
        iters_per_rep: iters,
        seed,
        gwtf_restart_recovery: args.flag("recovery-restart"),
        no_anneal: args.flag("no-anneal"),
        sum_objective: args.flag("sum-objective"),
        warm_replan: args.flag("warm-replan"),
    };
    let dir = results_dir();
    let mut ran = false;

    // --trace FILE arms the ambient collector around every sweep below
    // and exports the stream as Chrome trace-event JSON at the end.
    let trace_out = match args.get("trace") {
        None => None,
        Some("true") => bail!("--trace expects an output path (e.g. --trace trace.json)"),
        Some(p) => Some(std::path::PathBuf::from(p)),
    };
    let recording = trace_out.as_ref().map(|_| gwtf::trace::arm_collector());

    let emit = |t: &MetricsTable, name: &str| -> Result<()> {
        t.write(&dir, name)?;
        println!("{}", t.to_markdown());
        println!("-> {}/{}.md / .csv", dir.display(), name);
        Ok(())
    };

    if target == "table2" || target == "all" {
        emit(&run_table2(&opts)?, "table2")?;
        ran = true;
    }
    if target == "table3" || target == "all" {
        emit(&run_table3(&opts)?, "table3")?;
        ran = true;
    }
    if target == "table6" || target == "all" {
        emit(&run_table6(&opts)?, "table6")?;
        ran = true;
    }
    if target == "fig5" || target == "all" {
        let runs = args.usize_or("runs", 10)?;
        let r = run_fig5(runs, seed, args.flag("full"))?;
        r.write(&dir, "fig5")?;
        println!("# Fig. 5 — improvement per Table IV setting (higher = better)");
        println!("{}", gwtf::experiments::fig5_summary(&r));
        println!("-> {}/fig5.csv", dir.display());
        ran = true;
    }
    if target == "midagg" || target == "all" {
        let sopts = ScenarioOpts { reps: reps.min(10), iters_per_rep: iters, seed };
        emit(&run_mid_agg_crash(&sopts)?, "midagg")?;
        ran = true;
    }
    if target == "jitter" || target == "all" {
        let sopts = ScenarioOpts { reps: reps.min(10), iters_per_rep: iters, seed };
        emit(&run_link_jitter(&sopts)?, "jitter")?;
        ran = true;
    }
    if target == "poissonchurn" || target == "all" {
        let sopts = ScenarioOpts { reps: reps.min(10), iters_per_rep: iters, seed };
        emit(&run_poisson_churn(&sopts)?, "poissonchurn")?;
        ran = true;
    }
    if target == "scale" || target == "all" {
        let parse_sizes = |csv: String, flag: &str| -> Result<Vec<usize>> {
            csv.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().map_err(|_| anyhow!("{flag} expects integers")))
                .collect()
        };
        let sizes = parse_sizes(args.str_or("relays", "100,200"), "--relays")?;
        let gwtf_only_sizes =
            parse_sizes(args.str_or("gwtf-relays", "1000"), "--gwtf-relays")?;
        let sopts = ScaleOpts {
            sizes,
            gwtf_only_sizes,
            reps: reps.min(3),
            iters_per_rep: iters,
            seed,
            churn_p: args.f64_or("churn", 0.2)?,
            planner_threads: args.usize_or("threads", 1)?,
            ..Default::default()
        };
        let (t, report) = run_scale(&sopts)?;
        emit(&t, "scale")?;
        let json_path = gwtf::experiments::scale_json_path();
        update_scale_json(&json_path, "full", &report)?;
        println!("-> {}", json_path.display());
        ran = true;
    }
    if target == "planlag" || target == "all" {
        let rtts: Vec<f64> = args
            .str_or("rtts", "0,0.5,2,8,30,120")
            .split(',')
            .map(|s| s.trim().parse().map_err(|_| anyhow!("--rtts expects numbers (seconds)")))
            .collect::<Result<_>>()?;
        let lopts = PlanLagOpts {
            rtts_s: rtts,
            reps: reps.min(5),
            iters_per_rep: iters.max(6),
            seed,
            churn_p: args.f64_or("churn", 0.1)?,
        };
        let (t, report) = run_plan_lag(&lopts)?;
        emit(&t, "planlag")?;
        let json_path = gwtf::experiments::plan_lag_json_path();
        update_plan_lag_json(&json_path, "full", &report)?;
        println!("-> {}", json_path.display());
        ran = true;
    }
    if target == "congestion" || target == "all" {
        let nic_caps: Vec<usize> = args
            .str_or("nics", "0,8,4,2,1")
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow!("--nics expects integers (0 = unlimited)"))
            })
            .collect::<Result<_>>()?;
        let copts = CongestionOpts { nic_caps, reps: reps.min(5), iters_per_rep: iters, seed };
        let (t, report) = run_congestion(&copts)?;
        emit(&t, "congestion")?;
        let json_path = gwtf::experiments::congestion_json_path();
        update_congestion_json(&json_path, "full", &report)?;
        println!("-> {}", json_path.display());
        ran = true;
    }
    if target == "async" || target == "all" {
        let bounds: Vec<usize> = args
            .str_or("staleness", "1,2,4")
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow!("--staleness expects integers >= 1"))
            })
            .collect::<Result<_>>()?;
        let aopts = AsyncOpts {
            bounds,
            churn_p: args.f64_or("churn", 0.2)?,
            reps: reps.min(5),
            iters_per_rep: iters,
            seed,
        };
        let (t, report) = run_async(&aopts)?;
        emit(&t, "async")?;
        let json_path = gwtf::experiments::async_json_path();
        update_async_json(&json_path, "full", &report)?;
        println!("-> {}", json_path.display());
        ran = true;
    }
    if target == "adversary" || target == "all" {
        let fractions: Vec<f64> = args
            .str_or("fractions", "0,0.1,0.25")
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| anyhow!("--fractions expects numbers in [0, 1]"))
            })
            .collect::<Result<_>>()?;
        let aopts = AdversaryOpts { fractions, reps: reps.min(5), iters_per_rep: iters, seed };
        let (t, report) = run_adversary(&aopts)?;
        emit(&t, "adversary")?;
        let json_path = gwtf::experiments::adversary_json_path();
        update_adversary_json(&json_path, "full", &report)?;
        println!("-> {}", json_path.display());
        ran = true;
    }
    if target == "fig7" || target == "all" {
        let r = run_fig7(reps.min(10), seed)?;
        r.write(&dir, "fig7")?;
        println!("{}", r.to_text());
        println!("-> {}/fig7.csv", dir.display());
        ran = true;
    }
    if target == "fig6" {
        let opts6 = Fig6Opts {
            steps: args.usize_or("steps", 20)?,
            churn_p: args.f64_or("churn", 0.1)?,
            family: args.str_or("family", "llama"),
            seed,
            ..Default::default()
        };
        let (r, max_delta) = run_fig6(&opts6)?;
        r.write(&dir, "fig6")?;
        println!("{}", r.to_text());
        println!("max |loss(gwtf) - loss(centralized)| = {max_delta:.2e}");
        println!("-> {}/fig6.csv", dir.display());
        ran = true;
    }
    if !ran {
        bail!("unknown bench target {target:?}");
    }
    if let (Some(path), Some((guard, records))) = (trace_out, recording) {
        drop(guard); // disarm before touching the shared buffer
        let records = records.borrow();
        gwtf::trace::chrome::write_chrome_trace(&path, &records)?;
        println!("-> {} ({} trace events)", path.display(), records.len());
    }
    Ok(())
}

fn join_demo(args: &Args) -> Result<()> {
    // Fig. 3: a joining node of high capacity lands in the bottleneck
    // stage, moving the bottleneck to the next-tightest stage.
    let seed = args.u64_or("seed", 3)?;
    let mut rng = Rng::new(seed);
    let setting = gwtf::baselines::JoinSetting {
        name: "fig3-demo",
        stages: 3,
        n_relays: 9,
        n_candidates: 3,
        cap_range: (1.0, 4.0),
        inter_range: (1.0, 20.0),
        intra_extra: (50.0, 100.0),
        random_stage_sizes: false,
    };
    let exp = gwtf::baselines::JoinExperiment::generate(&setting, seed);
    let prob = exp.problem();
    println!("# Fig. 3 join walkthrough");
    for s in 0..prob.graph.n_stages() {
        println!("stage {s}: capacity {}", prob.stage_capacity(s));
    }
    let sol = mcmf_min_cost(&prob);
    println!("initial: {} flows at total cost {:.1}", sol.flow, sol.total_cost);
    let util = utilization_query(&prob, &vec![sol.flow; prob.graph.n_stages()]);
    let mut leader = Leader::new(NodeId(0), JoinPolicy::UtilizationRanked);
    for &(n, c) in &exp.pending {
        println!("candidate {n} announces capacity {c}");
        leader.on_join_request(n, c);
    }
    for (cand, stage) in leader.place(&util, &mut rng) {
        println!("leader assigns {cand} -> stage {stage}");
    }
    let out = exp.run(gwtf::baselines::JoinPolicyExt::Gwtf);
    println!(
        "after insertions: cost {:.1} -> {:.1} (improvement {:.1}%)",
        out.cost_before,
        out.cost_after,
        out.improvement() * 100.0
    );
    Ok(())
}
