//! SWARM baseline [Ryabinin et al., ICML 2023] as the paper models it.
//!
//! SWARM nodes route each microbatch independently through the stages with
//! a *greedy stochastic wiring* rule — "sending to the next stage closest
//! node" (paper §VI Ablation) — without accounting for node memory
//! constraints ("SWARM assumes that all nodes have the same amount of
//! memory", §I) and without any global cost objective.  Crash recovery:
//! forward-pass timeouts re-send to a different next-stage peer, but a
//! crash in the *backward* pass forces a complete pipeline recomputation
//! (§II, §III) — the paper's key inefficiency that GWTF's path repair
//! removes.

use std::sync::Arc;

use crate::cost::NodeId;
use crate::flow::graph::{FlowPath, FlowProblem, StageGraph};
use crate::sim::training::{BlockingPlanner, RecoveryPolicy};
use crate::util::Rng;

use super::CostFn;

/// Greedy-wiring SWARM router.  A single-shot planner
/// ([`BlockingPlanner`]): every plan is a fresh greedy rewire with no
/// session state — wrap in a
/// [`crate::sim::training::BlockingPlanAdapter`] to plug into the
/// engine's plan lifecycle (one commit per request).
pub struct SwarmRouter {
    pub graph: Arc<StageGraph>,
    pub cap: Vec<usize>,
    pub demand: Vec<usize>,
    pub cost: CostFn,
    /// If true (SWARM's actual behaviour) capacity limits are ignored
    /// during wiring; the simulator's per-node slots then serialize
    /// overloaded nodes.  If false, wiring respects capacity (ablation).
    pub ignore_capacity: bool,
    /// Stochastic wiring: with probability `epsilon` pick a random peer
    /// instead of the nearest (SWARM's exploration).
    pub epsilon: f64,
    rng: Rng,
}

impl SwarmRouter {
    pub fn new(
        graph: Arc<StageGraph>,
        cap: Vec<usize>,
        demand: Vec<usize>,
        cost: CostFn,
        seed: u64,
    ) -> Self {
        SwarmRouter { graph, cap, demand, cost, ignore_capacity: true, epsilon: 0.0, rng: Rng::new(seed) }
    }

    /// Build from a flow problem sharing its cost closure through `cost`.
    pub fn from_problem(prob: &FlowProblem, cost: CostFn, seed: u64) -> Self {
        SwarmRouter::new(prob.graph.clone(), prob.cap.clone(), prob.demand.clone(), cost, seed)
    }

    /// Wire one microbatch greedily from `source` through all stages.
    fn wire_one(&mut self, source: NodeId, alive: &[bool], load: &mut [usize]) -> Option<FlowPath> {
        let mut relays = Vec::with_capacity(self.graph.n_stages());
        let mut cur = source;
        for s in 0..self.graph.n_stages() {
            let members: Vec<NodeId> = self.graph.stages[s]
                .iter()
                .filter(|&&m| {
                    alive.get(m.0).copied().unwrap_or(true)
                        && (self.ignore_capacity || load[m.0] < self.cap[m.0])
                })
                .copied()
                .collect();
            if members.is_empty() {
                return None;
            }
            let pick = if self.epsilon > 0.0 && self.rng.chance(self.epsilon) {
                *self.rng.choose(&members).unwrap()
            } else {
                // greedy: nearest next-stage node from where we stand
                *members
                    .iter()
                    .min_by(|&&a, &&b| {
                        (self.cost)(cur, a).partial_cmp(&(self.cost)(cur, b)).unwrap()
                    })
                    .unwrap()
            };
            load[pick.0] += 1;
            relays.push(pick);
            cur = pick;
        }
        Some(FlowPath { source, relays })
    }

    /// Total Eq. 1 cost of a set of wired paths (Fig. 7 series).
    pub fn total_cost(&self, paths: &[FlowPath]) -> f64 {
        paths
            .iter()
            .map(|p| {
                let mut c = 0.0;
                let mut prev = p.source;
                for &r in &p.relays {
                    c += (self.cost)(prev, r);
                    prev = r;
                }
                c + (self.cost)(prev, p.source)
            })
            .sum()
    }
}

impl BlockingPlanner for SwarmRouter {
    fn name(&self) -> String {
        "swarm".into()
    }

    /// SWARM has no incremental mode: every plan is a cold greedy rewire
    /// from scratch (the baseline behavior the paper compares GWTF's
    /// warm-start chain repair against), wired on the fly — no separate
    /// planning phase is charged.
    fn plan_once(&mut self, alive: &[bool]) -> (Vec<FlowPath>, f64) {
        let n = self.cap.len();
        let mut load = vec![0usize; n];
        let mut paths = Vec::new();
        let data_nodes = self.graph.data_nodes.clone();
        let demand = self.demand.clone();
        for (di, d) in data_nodes.into_iter().enumerate() {
            for _ in 0..demand[di] {
                if let Some(p) = self.wire_one(d, alive, &mut load) {
                    paths.push(p);
                }
            }
        }
        (paths, 0.0)
    }

    fn on_crash(&mut self, _node: NodeId) {}

    fn choose_replacement(
        &mut self,
        prev: NodeId,
        _next: NodeId,
        candidates: &[NodeId],
    ) -> Option<NodeId> {
        // Greedy: nearest alternative from the upstream node only (SWARM
        // does not know the downstream cost).
        candidates
            .iter()
            .min_by(|&&a, &&b| (self.cost)(prev, a).partial_cmp(&(self.cost)(prev, b)).unwrap())
            .copied()
    }

    fn recovery(&self) -> RecoveryPolicy {
        RecoveryPolicy::RestartPipeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::graph::random_problem;

    fn setup(seed: u64) -> (FlowProblem, SwarmRouter) {
        let mut rng = Rng::new(seed);
        let prob = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng);
        // Rebuild the same deterministic cost closure for the router.
        let mut rng2 = Rng::new(seed);
        let prob2 = random_problem(1, 24, 4, (1.0, 3.0), (1.0, 20.0), &mut rng2);
        let cost: CostFn = Arc::new(move |i, j| prob2.cost(i, j));
        let router = SwarmRouter::from_problem(&prob, cost, seed);
        (prob, router)
    }

    #[test]
    fn wires_all_demand() {
        let (prob, mut r) = setup(1);
        let alive = vec![true; prob.cap.len()];
        let (paths, planning) = r.plan_once(&alive);
        assert_eq!(paths.len(), prob.demand[0]);
        assert_eq!(planning, 0.0);
        for p in &paths {
            assert_eq!(p.relays.len(), prob.graph.n_stages());
        }
    }

    #[test]
    fn greedy_picks_nearest_next_hop() {
        let (prob, mut r) = setup(2);
        let alive = vec![true; prob.cap.len()];
        let (paths, _) = r.plan_once(&alive);
        // first hop of the first path is the nearest stage-0 node to the source
        let p = &paths[0];
        let best = prob.graph.stages[0]
            .iter()
            .min_by(|&&a, &&b| {
                (r.cost)(p.source, a).partial_cmp(&(r.cost)(p.source, b)).unwrap()
            })
            .copied()
            .unwrap();
        assert_eq!(p.relays[0], best);
    }

    #[test]
    fn dead_nodes_avoided() {
        let (prob, mut r) = setup(3);
        let mut alive = vec![true; prob.cap.len()];
        let victim = prob.graph.stages[0][0];
        alive[victim.0] = false;
        let (paths, _) = r.plan_once(&alive);
        for p in &paths {
            assert!(!p.relays.contains(&victim));
        }
    }

    #[test]
    fn recovery_is_full_restart() {
        let (_, r) = setup(4);
        assert_eq!(r.recovery(), RecoveryPolicy::RestartPipeline);
    }

    #[test]
    fn ignores_capacity_by_default() {
        // All microbatches pile onto the nearest nodes even beyond cap.
        let (prob, mut r) = setup(5);
        assert!(r.ignore_capacity);
        let alive = vec![true; prob.cap.len()];
        let (paths, _) = r.plan_once(&alive);
        assert_eq!(paths.len(), prob.demand[0]);
    }

    #[test]
    fn capacity_aware_mode_respects_caps() {
        let (prob, mut r) = setup(6);
        r.ignore_capacity = false;
        let alive = vec![true; prob.cap.len()];
        let (paths, _) = r.plan_once(&alive);
        let mut usage = vec![0usize; prob.cap.len()];
        for p in &paths {
            for &n in &p.relays {
                usage[n.0] += 1;
            }
        }
        for (i, &u) in usage.iter().enumerate() {
            assert!(u <= prob.cap[i]);
        }
    }

    #[test]
    fn replacement_nearest_to_upstream() {
        let (prob, mut r) = setup(7);
        let prev = prob.graph.data_nodes[0];
        let cands = prob.graph.stages[0].clone();
        let pick = r.choose_replacement(prev, prob.graph.stages[1][0], &cands).unwrap();
        let best = cands
            .iter()
            .min_by(|&&a, &&b| (r.cost)(prev, a).partial_cmp(&(r.cost)(prev, b)).unwrap())
            .copied()
            .unwrap();
        assert_eq!(pick, best);
    }
}
