//! DT-FM baseline [Yuan et al., NeurIPS 2022]: communication-optimal
//! GPipe arrangement computed by a centralized genetic algorithm.
//!
//! DT-FM assigns nodes to pipeline positions so as to minimize the
//! *maximum* communication cost between subsequent nodes in a pipeline
//! (the min-max objective the paper's §V-A cites), then trains with fixed
//! GPipe pipelines — no churn handling, expensive to compute
//! ("scales exponentially with the number of nodes", §VI Optimality).
//!
//! Chromosome: a permutation of the relay nodes; position `k` of pipeline
//! `p` is gene `p * n_stages + k`.  With `P` pipelines over `S` stages the
//! permutation is cut into `P` contiguous pipelines.  Fitness = the
//! worst Eq. 1 edge cost across all pipelines (including the data-node
//! boundary hops), which the GA minimizes through tournament selection,
//! order crossover (OX1), and swap mutation.

use std::sync::Arc;

use crate::cost::NodeId;
use crate::flow::graph::{FlowPath, StageGraph};
use crate::sim::training::{BlockingPlanner, RecoveryPolicy};
use crate::util::Rng;

use super::CostFn;

/// GA tunables.
#[derive(Debug, Clone)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    pub tournament: usize,
    pub crossover_p: f64,
    pub mutation_p: f64,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams { population: 64, generations: 200, tournament: 4, crossover_p: 0.9, mutation_p: 0.2 }
    }
}

/// The computed arrangement: `pipelines[p]` lists one relay per stage.
#[derive(Debug, Clone)]
pub struct Arrangement {
    pub pipelines: Vec<Vec<NodeId>>,
    /// min-max objective value of the arrangement.
    pub max_edge_cost: f64,
    /// GA generations actually run (diagnostics).
    pub generations: usize,
    /// Wall-clock the GA took, seconds (the paper charges this cost).
    pub compute_s: f64,
}

/// GA-based arrangement optimizer + static GPipe router.  A single-shot
/// planner ([`BlockingPlanner`]): the GA has no incremental or
/// round-based mode — wrap in a
/// [`crate::sim::training::BlockingPlanAdapter`] to plug into the
/// engine's plan lifecycle (one commit per request, the paper's point
/// about the GA being expensive under churn).
pub struct DtfmRouter {
    pub graph: Arc<StageGraph>,
    pub demand: Vec<usize>,
    pub cost: CostFn,
    pub params: GaParams,
    /// data node feeding each pipeline (round-robin over data nodes).
    assignment: Option<Arrangement>,
    rng: Rng,
}

impl DtfmRouter {
    pub fn new(
        graph: Arc<StageGraph>,
        demand: Vec<usize>,
        cost: CostFn,
        params: GaParams,
        seed: u64,
    ) -> Self {
        DtfmRouter { graph, demand, cost, params, assignment: None, rng: Rng::new(seed) }
    }

    fn n_pipelines(&self) -> usize {
        // one GPipe pipeline per data node (paper Table VI: 3 dataholders,
        // 15 relays over 6 stages -> "several pipelines with 4 microbatches
        // per pipeline").
        self.graph.data_nodes.len()
    }

    /// Decode a permutation into pipelines (cut into contiguous chunks).
    fn decode(&self, perm: &[NodeId]) -> Vec<Vec<NodeId>> {
        let s = self.graph.n_stages();
        (0..self.n_pipelines()).map(|p| perm[p * s..(p + 1) * s].to_vec()).collect()
    }

    /// Min-max Eq. 1 edge cost over all pipelines for a permutation.
    fn fitness(&self, perm: &[NodeId]) -> f64 {
        let s = self.graph.n_stages();
        let mut worst: f64 = 0.0;
        for (p, d) in self.graph.data_nodes.iter().enumerate() {
            let pipe = &perm[p * s..(p + 1) * s];
            let mut prev = *d;
            for &r in pipe {
                worst = worst.max((self.cost)(prev, r));
                prev = r;
            }
            worst = worst.max((self.cost)(prev, *d));
        }
        worst
    }

    /// A permutation is *stage-valid* if gene `p*s + k` holds a stage-`k`
    /// node.  We encode directly per stage to keep all individuals valid:
    /// each stage's members are permuted independently and column `k` of
    /// every pipeline draws from stage `k`.
    fn random_individual(&mut self, alive: &[bool]) -> Option<Vec<NodeId>> {
        let s = self.graph.n_stages();
        let p = self.n_pipelines();
        let mut cols: Vec<Vec<NodeId>> = Vec::with_capacity(s);
        for k in 0..s {
            let mut members: Vec<NodeId> = self.graph.stages[k]
                .iter()
                .filter(|&&m| alive.get(m.0).copied().unwrap_or(true))
                .copied()
                .collect();
            if members.len() < p {
                return None; // not enough alive nodes for disjoint pipelines
            }
            self.rng.shuffle(&mut members);
            cols.push(members);
        }
        let mut perm = Vec::with_capacity(p * s);
        for pi in 0..p {
            for col in cols.iter().take(s) {
                perm.push(col[pi]);
            }
        }
        Some(perm)
    }

    /// Column-wise swap mutation: exchange the stage-`k` relay of two pipelines.
    fn mutate(&mut self, perm: &mut [NodeId]) {
        let s = self.graph.n_stages();
        let p = self.n_pipelines();
        if p < 2 {
            return;
        }
        let k = self.rng.index(s);
        let (a, b) = (self.rng.index(p), self.rng.index(p));
        perm.swap(a * s + k, b * s + k);
    }

    /// Column-wise crossover: child takes each stage column from one parent.
    fn crossover(&mut self, a: &[NodeId], b: &[NodeId]) -> Vec<NodeId> {
        let s = self.graph.n_stages();
        let p = self.n_pipelines();
        let mut child = a.to_vec();
        for k in 0..s {
            if self.rng.chance(0.5) {
                // copy column k from b (columns are disjoint sets per stage,
                // so this preserves validity)
                for pi in 0..p {
                    child[pi * s + k] = b[pi * s + k];
                }
            }
        }
        child
    }

    /// Run the GA; returns the best arrangement found.
    pub fn optimize(&mut self, alive: &[bool]) -> Option<Arrangement> {
        let t0 = std::time::Instant::now();
        let pop_size = self.params.population;
        let mut pop: Vec<Vec<NodeId>> = Vec::with_capacity(pop_size);
        for _ in 0..pop_size {
            pop.push(self.random_individual(alive)?);
        }
        let mut best = pop[0].clone();
        let mut best_fit = self.fitness(&best);
        let mut gens = 0;
        for _ in 0..self.params.generations {
            gens += 1;
            // fitness cache for this generation
            let fits: Vec<f64> = pop.iter().map(|p| self.fitness(p)).collect();
            for (ind, &f) in pop.iter().zip(&fits) {
                if f < best_fit {
                    best_fit = f;
                    best = ind.clone();
                }
            }
            let tournament = |rng_self: &mut Self, fits: &[f64]| -> usize {
                let mut bi = rng_self.rng.index(fits.len());
                for _ in 1..rng_self.params.tournament {
                    let c = rng_self.rng.index(fits.len());
                    if fits[c] < fits[bi] {
                        bi = c;
                    }
                }
                bi
            };
            let mut next = Vec::with_capacity(pop_size);
            // elitism: carry the champion
            next.push(best.clone());
            while next.len() < pop_size {
                let a = tournament(self, &fits);
                let b = tournament(self, &fits);
                let mut child = if self.rng.chance(self.params.crossover_p) {
                    let (pa, pb) = (pop[a].clone(), pop[b].clone());
                    self.crossover(&pa, &pb)
                } else {
                    pop[a].clone()
                };
                if self.rng.chance(self.params.mutation_p) {
                    self.mutate(&mut child);
                }
                next.push(child);
            }
            pop = next;
        }
        for ind in &pop {
            let f = self.fitness(ind);
            if f < best_fit {
                best_fit = f;
                best = ind.clone();
            }
        }
        Some(Arrangement {
            pipelines: self.decode(&best),
            max_edge_cost: best_fit,
            generations: gens,
            compute_s: t0.elapsed().as_secs_f64(),
        })
    }
}

impl BlockingPlanner for DtfmRouter {
    fn name(&self) -> String {
        "dtfm".into()
    }

    /// Arrangement computed once (DT-FM ignores churn); the GA re-runs
    /// from scratch only when the cached arrangement references a dead
    /// node — there is no incremental path.
    fn plan_once(&mut self, alive: &[bool]) -> (Vec<FlowPath>, f64) {
        let needs_replan = match &self.assignment {
            None => true,
            Some(a) => a
                .pipelines
                .iter()
                .flatten()
                .any(|&n| !alive.get(n.0).copied().unwrap_or(true)),
        };
        let mut planning_s = 0.0;
        if needs_replan {
            match self.optimize(alive) {
                Some(a) => {
                    planning_s = a.compute_s;
                    self.assignment = Some(a);
                }
                None => return (Vec::new(), 0.0),
            }
        }
        let arr = self.assignment.as_ref().unwrap();
        let mut paths = Vec::new();
        for (p, &d) in self.graph.data_nodes.iter().enumerate() {
            for _ in 0..self.demand[p] {
                paths.push(FlowPath { source: d, relays: arr.pipelines[p].clone() });
            }
        }
        (paths, planning_s)
    }

    fn on_crash(&mut self, _node: NodeId) {}

    fn choose_replacement(
        &mut self,
        prev: NodeId,
        next: NodeId,
        candidates: &[NodeId],
    ) -> Option<NodeId> {
        candidates
            .iter()
            .min_by(|&&a, &&b| {
                let ca = (self.cost)(prev, a).max((self.cost)(a, next));
                let cb = (self.cost)(prev, b).max((self.cost)(b, next));
                ca.partial_cmp(&cb).unwrap()
            })
            .copied()
    }

    fn recovery(&self) -> RecoveryPolicy {
        // GPipe-style: a failed pipeline must recompute.
        RecoveryPolicy::RestartPipeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::graph::random_problem;

    fn setup(seed: u64, sources: usize, relays: usize, stages: usize) -> DtfmRouter {
        let mut rng = Rng::new(seed);
        let prob = random_problem(sources, relays, stages, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut rng2 = Rng::new(seed);
        let prob2 = random_problem(sources, relays, stages, (1.0, 3.0), (1.0, 20.0), &mut rng2);
        let cost: CostFn = Arc::new(move |i, j| prob2.cost(i, j));
        DtfmRouter::new(prob.graph.clone(), prob.demand.clone(), cost, GaParams::default(), seed)
    }

    #[test]
    fn arrangement_is_stage_valid_and_disjoint() {
        let mut r = setup(1, 3, 18, 6);
        let alive = vec![true; 21];
        let arr = r.optimize(&alive).unwrap();
        assert_eq!(arr.pipelines.len(), 3);
        let mut seen = std::collections::HashSet::new();
        for pipe in &arr.pipelines {
            assert_eq!(pipe.len(), 6);
            for (s, &n) in pipe.iter().enumerate() {
                assert!(r.graph.stages[s].contains(&n), "node {n} not in stage {s}");
                assert!(seen.insert(n), "node {n} used twice");
            }
        }
    }

    #[test]
    fn ga_beats_random_individual() {
        let mut r = setup(2, 2, 16, 4);
        let alive = vec![true; 18];
        let random = r.random_individual(&alive).unwrap();
        let random_fit = r.fitness(&random);
        let arr = r.optimize(&alive).unwrap();
        assert!(
            arr.max_edge_cost <= random_fit + 1e-9,
            "GA {} vs random {}",
            arr.max_edge_cost,
            random_fit
        );
    }

    #[test]
    fn plan_charges_ga_time_once() {
        let mut r = setup(3, 2, 16, 4);
        let alive = vec![true; 18];
        let (paths, t1) = r.plan_once(&alive);
        assert_eq!(paths.len(), 8, "2 data nodes x 4 microbatches");
        assert!(t1 > 0.0);
        let (_, t2) = r.plan_once(&alive);
        assert_eq!(t2, 0.0, "cached arrangement re-used");
    }

    #[test]
    fn dead_node_triggers_replan() {
        let mut r = setup(4, 2, 16, 4);
        let mut alive = vec![true; 18];
        let (paths, _) = r.plan_once(&alive);
        let victim = paths[0].relays[0];
        alive[victim.0] = false;
        let (paths2, t2) = r.plan_once(&alive);
        assert!(t2 > 0.0, "replan charged");
        for p in &paths2 {
            assert!(!p.relays.contains(&victim));
        }
    }

    #[test]
    fn too_few_nodes_yields_empty_plan() {
        let mut r = setup(5, 3, 6, 6); // 1 node/stage but 3 pipelines needed
        let alive = vec![true; 9];
        let (paths, _) = r.plan_once(&alive);
        assert!(paths.is_empty());
    }

    #[test]
    fn restart_recovery_policy() {
        let r = setup(6, 1, 8, 4);
        assert_eq!(r.recovery(), RecoveryPolicy::RestartPipeline);
    }
}
