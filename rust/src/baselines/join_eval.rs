//! The Fig. 5 node-addition experiment (paper §VI "Handling Joining
//! Nodes", Table IV top).
//!
//! A system of 97 nodes (1 data holder + 96 relays over `S` stages) takes
//! 20 joining candidates, one at a time.  After every addition the
//! routing cost is re-evaluated with the exact min-cost flow solver
//! ([`crate::flow::mcmf`], the out-of-kilter optimum), and the experiment
//! reports the improvement `(cost_now - cost_after) / cost_now` of the
//! whole insertion sequence.  Four placement policies are compared:
//!
//! - **Gwtf** — the leader's utilization-ranked placement (§V-B),
//! - **CapacityFirst** — candidates in capacity order, stages
//!   round-robin (no utilization view — see coordinator::join),
//! - **Random** — uniform random stage,
//! - **Optimal** — exhaustive: try every (candidate, stage) pair, keep the
//!   one minimizing the resulting min-cost flow (the paper notes this
//!   "cannot be achieved in a decentralized setting").
//!
//! The flow demand is pinned to the *initial* bottleneck stage capacity so
//! the routed flow value stays constant across additions; the min-cost
//! objective is then monotonically non-increasing and improvements are
//! attributable to placement quality alone.

use std::collections::BTreeMap;

use crate::coordinator::join::{utilization_query, JoinPolicy, Leader};
use crate::cost::NodeId;
use crate::flow::graph::{FlowProblem, StageGraph};
use crate::flow::mcmf::mcmf_min_cost;
use crate::util::Rng;

/// Which placement rule drives the experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinPolicyExt {
    Gwtf,
    CapacityFirst,
    Random,
    Optimal,
}

impl JoinPolicyExt {
    pub fn name(&self) -> &'static str {
        match self {
            JoinPolicyExt::Gwtf => "gwtf",
            JoinPolicyExt::CapacityFirst => "capacity-first",
            JoinPolicyExt::Random => "random",
            JoinPolicyExt::Optimal => "optimal",
        }
    }
}

/// One Table IV (top) experiment setting.
#[derive(Debug, Clone)]
pub struct JoinSetting {
    pub name: &'static str,
    pub stages: usize,
    pub n_relays: usize,
    pub n_candidates: usize,
    /// Relay/candidate capacity range (floored uniform).
    pub cap_range: (f64, f64),
    /// Interlayer (adjacent-stage) cost range (floored uniform).
    pub inter_range: (f64, f64),
    /// Intralayer extra cost range added on top of the node's max
    /// interlayer cost φ (Table IV: φ + U(50, 100)).
    pub intra_extra: (f64, f64),
    /// Setting 5*: random (unequal) stage sizes.
    pub random_stage_sizes: bool,
}

impl JoinSetting {
    /// Table IV settings 1–5*.
    pub fn setting(i: usize) -> JoinSetting {
        match i {
            1 => JoinSetting {
                name: "1: 8 stages, cap U(1,20), inter U(1,100)",
                stages: 8,
                n_relays: 96,
                n_candidates: 20,
                cap_range: (1.0, 20.0),
                inter_range: (1.0, 100.0),
                intra_extra: (50.0, 100.0),
                random_stage_sizes: false,
            },
            2 => JoinSetting {
                name: "2: 8 stages, cap U(1,20), inter U(20,100)",
                inter_range: (20.0, 100.0),
                ..JoinSetting::setting(1)
            },
            3 => JoinSetting {
                name: "3: 8 stages, cap U(1,5), inter U(1,100)",
                cap_range: (1.0, 5.0),
                ..JoinSetting::setting(1)
            },
            4 => JoinSetting {
                name: "4: 12 stages, cap U(1,20), inter U(1,100)",
                stages: 12,
                ..JoinSetting::setting(1)
            },
            5 => JoinSetting {
                name: "5*: 8 stages, random stage sizes",
                random_stage_sizes: true,
                ..JoinSetting::setting(1)
            },
            other => panic!("unknown join setting {other}"),
        }
    }

    /// Reduced-size variant (4 relays/stage, 8 candidates): same structure,
    /// tractable for the exhaustive *optimal* baseline, which is
    /// O(candidates² · stages) min-cost-flow solves.  The full-size paper
    /// setting is available behind `gwtf bench fig5 --full`.
    pub fn reduced(mut self) -> JoinSetting {
        self.n_relays = self.stages * 4;
        self.n_candidates = 8;
        self
    }
}

/// Result of one run.
#[derive(Debug, Clone)]
pub struct JoinOutcome {
    pub policy: JoinPolicyExt,
    pub cost_before: f64,
    pub cost_after: f64,
    /// Per-addition cost trace (`n_candidates + 1` entries).
    pub trace: Vec<f64>,
}

impl JoinOutcome {
    /// The paper's Fig. 5 metric.
    pub fn improvement(&self) -> f64 {
        if self.cost_before == 0.0 {
            0.0
        } else {
            (self.cost_before - self.cost_after) / self.cost_before
        }
    }
}

/// A mutable instance of the experiment: growable staged graph + costs.
pub struct JoinExperiment {
    pub setting: JoinSetting,
    /// stage membership (relays only; node 0 is the data holder).
    pub stages: Vec<Vec<NodeId>>,
    pub cap: Vec<usize>,
    /// Dense pairwise interlayer cost matrix, grown as candidates join.
    pub costs: Vec<Vec<f64>>,
    /// Per-node intralayer cost (φ + U(50,100); used by same-stage moves).
    pub intra: Vec<f64>,
    /// Candidates not yet placed: (node, capacity).
    pub pending: Vec<(NodeId, usize)>,
    pub demand: usize,
    rng: Rng,
}

impl JoinExperiment {
    /// Generate the initial system + candidate pool for a setting.
    pub fn generate(setting: &JoinSetting, seed: u64) -> JoinExperiment {
        let mut rng = Rng::new(seed);
        let total = 1 + setting.n_relays + setting.n_candidates;
        // capacities
        let mut cap = vec![0usize; total];
        for c in cap.iter_mut().skip(1) {
            *c = rng.uniform(setting.cap_range.0, setting.cap_range.1).floor().max(1.0) as usize;
        }
        cap[0] = usize::MAX / 4; // data holder: ample
        // dense interlayer costs (floored uniform, per directed pair)
        let mut costs = vec![vec![0.0f64; total]; total];
        for i in 0..total {
            for j in 0..total {
                if i != j {
                    costs[i][j] =
                        rng.uniform(setting.inter_range.0, setting.inter_range.1).floor().max(1.0);
                }
            }
        }
        // intralayer: φ (the node's max interlayer cost) + U(50,100)
        let intra: Vec<f64> = (0..total)
            .map(|i| {
                let phi = costs[i].iter().cloned().fold(0.0f64, f64::max);
                phi + rng.uniform(setting.intra_extra.0, setting.intra_extra.1).floor()
            })
            .collect();
        // stage membership
        let mut stages: Vec<Vec<NodeId>> = vec![Vec::new(); setting.stages];
        if setting.random_stage_sizes {
            // random sizes, at least one per stage
            for s in 0..setting.stages {
                stages[s].push(NodeId(1 + s));
            }
            for r in setting.stages..setting.n_relays {
                let s = rng.index(setting.stages);
                stages[s].push(NodeId(1 + r));
            }
        } else {
            for r in 0..setting.n_relays {
                stages[r % setting.stages].push(NodeId(1 + r));
            }
        }
        let pending: Vec<(NodeId, usize)> = (0..setting.n_candidates)
            .map(|c| {
                let id = NodeId(1 + setting.n_relays + c);
                (id, cap[id.0])
            })
            .collect();
        // demand pinned to the initial bottleneck stage capacity
        let demand = stages
            .iter()
            .map(|s| s.iter().map(|n| cap[n.0]).sum::<usize>())
            .min()
            .unwrap_or(1)
            .max(1);
        JoinExperiment {
            setting: setting.clone(),
            stages,
            cap,
            costs,
            intra,
            pending,
            demand,
            rng: rng.fork(0x701),
        }
    }

    /// Snapshot the current system as a [`FlowProblem`] (placed nodes only).
    pub fn problem(&self) -> FlowProblem {
        let graph = std::sync::Arc::new(StageGraph {
            stages: self.stages.clone(),
            data_nodes: vec![NodeId(0)],
        });
        let costs = self.costs.clone();
        FlowProblem {
            graph,
            cap: self.cap.clone(),
            demand: vec![self.demand],
            cost: Box::new(move |i, j| costs[i.0][j.0]),
        }
    }

    /// Current optimal routing cost (the experiment's measuring stick).
    pub fn current_cost(&self) -> f64 {
        mcmf_min_cost(&self.problem()).total_cost
    }

    fn place(&mut self, node: NodeId, stage: usize) {
        self.stages[stage].push(node);
        self.pending.retain(|&(n, _)| n != node);
    }

    /// Run the full insertion sequence under `policy`.
    pub fn run(mut self, policy: JoinPolicyExt) -> JoinOutcome {
        let cost_before = self.current_cost();
        let mut trace = vec![cost_before];
        match policy {
            JoinPolicyExt::Gwtf => {
                // Nodes join *iteratively* (SVI: "Iteratively, 20 nodes are
                // added"): each leader round sees one arrival, ranks stages
                // by a fresh utilization snapshot (flooding query), and
                // places the candidate in the most-utilized stage — so
                // consecutive joins track the moving bottleneck (Fig. 3).
                while !self.pending.is_empty() {
                    let prob = self.problem();
                    let sol = mcmf_min_cost(&prob);
                    let flows = vec![sol.flow; self.setting.stages];
                    let util = utilization_query(&prob, &flows);
                    let mut leader = Leader::new(NodeId(0), JoinPolicy::UtilizationRanked);
                    let &(n, c) = self
                        .pending
                        .iter()
                        .max_by_key(|&&(_, c)| c)
                        .expect("pending nonempty");
                    leader.on_join_request(n, c);
                    for (node, stage) in leader.place(&util, &mut self.rng) {
                        self.place(node, stage);
                        trace.push(self.current_cost());
                    }
                }
            }
            JoinPolicyExt::CapacityFirst => {
                // "adding highest capacity first": candidates in capacity
                // order, stages round-robin (no utilization view)
                let mut i = 0;
                while !self.pending.is_empty() {
                    let &(node, _) = self
                        .pending
                        .iter()
                        .max_by_key(|&&(_, c)| c)
                        .expect("pending nonempty");
                    let stage = i % self.setting.stages;
                    i += 1;
                    self.place(node, stage);
                    trace.push(self.current_cost());
                }
            }
            JoinPolicyExt::Random => {
                while !self.pending.is_empty() {
                    let pick = self.rng.index(self.pending.len());
                    let (node, _) = self.pending[pick];
                    let stage = self.rng.index(self.setting.stages);
                    self.place(node, stage);
                    trace.push(self.current_cost());
                }
            }
            JoinPolicyExt::Optimal => {
                // exhaustive: each step tries every (candidate, stage) pair
                while !self.pending.is_empty() {
                    let mut best: Option<(NodeId, usize, f64)> = None;
                    let pending = self.pending.clone();
                    for &(node, _) in &pending {
                        for s in 0..self.setting.stages {
                            self.stages[s].push(node);
                            let c = self.current_cost();
                            self.stages[s].pop();
                            if best.map(|(_, _, bc)| c < bc).unwrap_or(true) {
                                best = Some((node, s, c));
                            }
                        }
                    }
                    let (node, stage, cost) = best.expect("candidates remain");
                    self.place(node, stage);
                    trace.push(cost);
                }
            }
        }
        let cost_after = *trace.last().unwrap();
        JoinOutcome { policy, cost_before, cost_after, trace }
    }
}

/// Run all four policies on the same generated instance.
pub fn compare_policies(setting: &JoinSetting, seed: u64) -> BTreeMap<&'static str, JoinOutcome> {
    let mut out = BTreeMap::new();
    for policy in [
        JoinPolicyExt::Gwtf,
        JoinPolicyExt::CapacityFirst,
        JoinPolicyExt::Random,
        JoinPolicyExt::Optimal,
    ] {
        let exp = JoinExperiment::generate(setting, seed);
        out.insert(policy.name(), exp.run(policy));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reduced-size setting so tests stay fast.
    fn small() -> JoinSetting {
        JoinSetting {
            name: "test",
            stages: 4,
            n_relays: 16,
            n_candidates: 6,
            cap_range: (1.0, 4.0),
            inter_range: (1.0, 100.0),
            intra_extra: (50.0, 100.0),
            random_stage_sizes: false,
        }
    }

    #[test]
    fn generation_shape() {
        let e = JoinExperiment::generate(&small(), 1);
        assert_eq!(e.stages.len(), 4);
        assert_eq!(e.stages.iter().map(Vec::len).sum::<usize>(), 16);
        assert_eq!(e.pending.len(), 6);
        assert!(e.demand >= 1);
        // intralayer cost exceeds the node's max interlayer cost
        for i in 1..e.costs.len() {
            let phi = e.costs[i].iter().cloned().fold(0.0f64, f64::max);
            assert!(e.intra[i] >= phi + 50.0 - 1e-9);
        }
    }

    #[test]
    fn additions_never_increase_cost() {
        for seed in [1, 2, 3] {
            let e = JoinExperiment::generate(&small(), seed);
            let out = e.run(JoinPolicyExt::Gwtf);
            for w in out.trace.windows(2) {
                assert!(w[1] <= w[0] + 1e-6, "cost increased: {} -> {}", w[0], w[1]);
            }
            assert!(out.improvement() >= -1e-12);
        }
    }

    #[test]
    fn optimal_beats_or_matches_all() {
        let outs = compare_policies(&small(), 7);
        let opt = outs["optimal"].improvement();
        for (name, o) in &outs {
            assert!(
                opt >= o.improvement() - 1e-9,
                "optimal {} < {} {}",
                opt,
                name,
                o.improvement()
            );
        }
    }

    #[test]
    fn all_candidates_placed() {
        for policy in [
            JoinPolicyExt::Gwtf,
            JoinPolicyExt::CapacityFirst,
            JoinPolicyExt::Random,
        ] {
            let e = JoinExperiment::generate(&small(), 11);
            let before: usize = e.stages.iter().map(Vec::len).sum();
            let n_cand = e.pending.len();
            let out = e.run(policy);
            assert_eq!(out.trace.len(), n_cand + 1, "{policy:?}");
            let _ = before;
        }
    }

    #[test]
    fn setting_constructors_match_table4() {
        let s1 = JoinSetting::setting(1);
        assert_eq!((s1.stages, s1.cap_range), (8, (1.0, 20.0)));
        let s3 = JoinSetting::setting(3);
        assert_eq!(s3.cap_range, (1.0, 5.0));
        let s4 = JoinSetting::setting(4);
        assert_eq!(s4.stages, 12);
        let s5 = JoinSetting::setting(5);
        assert!(s5.random_stage_sizes);
    }
}
