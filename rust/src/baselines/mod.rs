//! Every baseline the paper compares GWTF against.
//!
//! - [`swarm`] — SWARM [Ryabinin et al. 2023]: greedy stochastic wiring,
//!   capacity-oblivious, full pipeline recomputation on backward-pass
//!   crashes (Tables II/III, Fig. 7).
//! - [`dtfm`] — DT-FM [Yuan et al. 2022]: centralized genetic algorithm
//!   computing a communication-optimal GPipe arrangement (Table VI).
//! - [`join_eval`] — the Fig. 5 node-addition experiment: GWTF's
//!   utilization-ranked placement vs highest-capacity-first vs random vs
//!   the exhaustive optimal (out-of-kilter per candidate × stage).
//!
//! The exact min-cost max-flow optimum itself lives in
//! [`crate::flow::mcmf`] (it is shared by Fig. 5 and Fig. 7).

pub mod dtfm;
pub mod join_eval;
pub mod swarm;

pub use crate::coordinator::router::CostFn;
pub use dtfm::{Arrangement, DtfmRouter, GaParams};
pub use join_eval::{JoinExperiment, JoinOutcome, JoinPolicyExt, JoinSetting};
pub use swarm::SwarmRouter;
