//! Peak-memory telemetry: a tiny `/proc` RSS probe.
//!
//! The scale bench's acceptance claim is about *resident memory* — the
//! sparse substrate must keep the 10k-relay profile off the O(n²)
//! allocation cliff — so every `BENCH_*.json` profile records the
//! process's peak resident set alongside its timing figures.  Linux
//! exposes the high-water mark as `VmHWM` in `/proc/self/status`; on
//! other platforms (or sandboxes hiding `/proc`) the probe returns 0
//! and every consumer treats the figure as informational-only, never
//! gated.

/// Peak resident set size of this process in MiB, or 0.0 where the
/// probe has no `/proc` to read.
pub fn peak_rss_mib() -> f64 {
    peak_rss_kib().map_or(0.0, |kib| kib as f64 / 1024.0)
}

/// `VmHWM` from `/proc/self/status`, in KiB.
fn peak_rss_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_probe_is_sane() {
        let mib = peak_rss_mib();
        // Either the platform hides /proc (0.0) or the figure is a
        // plausible process footprint; a running test binary certainly
        // resides in more than 1 MiB when the probe works at all.
        assert!(mib == 0.0 || (1.0..1e6).contains(&mib), "{mib}");
    }

    #[test]
    fn peak_rss_is_monotone_nondecreasing() {
        let before = peak_rss_mib();
        // Touch a few MiB so the high-water mark cannot fall.
        let v: Vec<u64> = (0..(1 << 19)).collect();
        std::hint::black_box(&v);
        let after = peak_rss_mib();
        assert!(after >= before, "{after} < {before}");
    }
}
