//! Minimal JSON parser + writer (serde_json is unavailable offline).
//!
//! Used for `artifacts/manifest.json`, scenario config files and the
//! bench-result reports.  Supports the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP (not needed for our data).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["families", "llama", "n_stages"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"obj":{"k":true},"z":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn path_lookup() {
        let j = Json::parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(j.path(&["a", "b", "c"]).unwrap().as_usize(), Some(7));
        assert!(j.path(&["a", "x"]).is_none());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"fingerprint":"abc","families":{"llama":{"n_stages":2,
            "artifacts":{"stage_fwd":{"file":"llama_stage_fwd.hlo.txt",
            "inputs":[{"shape":[2,2],"dtype":"float32"}],"outputs":[]}}}}}"#;
        let j = Json::parse(src).unwrap();
        let inp = j
            .path(&["families", "llama", "artifacts", "stage_fwd", "inputs"])
            .unwrap();
        assert_eq!(inp.idx(0).unwrap().get("dtype").unwrap().as_str(), Some("float32"));
    }
}
