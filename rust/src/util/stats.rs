//! Summary statistics for experiment reporting (mean ± std, as the paper's
//! tables report over 25 repetitions).

/// Mean / std / min / max over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN, max: f64::NAN };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// "1.23 ± 0.45" with the given precision — the paper's table format.
    pub fn pm(&self, digits: usize) -> String {
        format!("{:.d$} ± {:.d$}", self.mean, self.std, d = digits)
    }
}

/// Percentile (nearest-rank) of a sample; used for latency reporting.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize - 1;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty() {
        assert!(Summary::of(&[]).mean.is_nan());
    }

    #[test]
    fn pm_format() {
        let s = Summary::of(&[1.0, 1.0]);
        assert_eq!(s.pm(2), "1.00 ± 0.00");
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }
}
