//! Summary statistics for experiment reporting (mean ± std, as the paper's
//! tables report over 25 repetitions).

/// Mean / std / min / max over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: f64::NAN, std: f64::NAN, min: f64::NAN, max: f64::NAN };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// "1.23 ± 0.45" with the given precision — the paper's table format.
    pub fn pm(&self, digits: usize) -> String {
        format!("{:.d$} ± {:.d$}", self.mean, self.std, d = digits)
    }
}

/// Percentile (nearest-rank) of a sample; used for latency reporting.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize - 1;
    v[rank.min(v.len() - 1)]
}

/// One-sample Kolmogorov–Smirnov statistic of `xs` against a continuous
/// CDF: `D = sup_x |F_empirical(x) - cdf(x)|`.  Used by the churn-process
/// statistical tests; compare against `c(alpha) / sqrt(n)` (e.g. 1.63 at
/// alpha = 0.01).
pub fn ks_statistic(xs: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len() as f64;
    let mut d: f64 = 0.0;
    for (i, &x) in v.iter().enumerate() {
        let f = cdf(x);
        // Sup over both sides of the empirical step at x.
        d = d.max((f - i as f64 / n).abs()).max(((i + 1) as f64 / n - f).abs());
    }
    d
}

/// Pearson chi-square statistic of `xs` over `k` equal-probability bins
/// of the hypothesized continuous `cdf` (degrees of freedom `k - 1`).
pub fn chi_square_edf(xs: &[f64], cdf: impl Fn(f64) -> f64, k: usize) -> f64 {
    assert!(k >= 2, "need at least two bins");
    assert!(!xs.is_empty());
    let mut counts = vec![0usize; k];
    for &x in xs {
        let u = cdf(x).clamp(0.0, 1.0 - 1e-12);
        counts[(u * k as f64) as usize] += 1;
    }
    let expected = xs.len() as f64 / k as f64;
    counts
        .iter()
        .map(|&o| {
            let d = o as f64 - expected;
            d * d / expected
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty() {
        assert!(Summary::of(&[]).mean.is_nan());
    }

    #[test]
    fn pm_format() {
        let s = Summary::of(&[1.0, 1.0]);
        assert_eq!(s.pm(2), "1.00 ± 0.00");
    }

    #[test]
    fn ks_accepts_true_distribution_and_rejects_wrong_one() {
        // 10k uniforms against the uniform CDF: D should sit near
        // 0.87/sqrt(n) ~ 0.009; against a clearly wrong CDF it explodes.
        let mut rng = crate::util::Rng::new(29);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.f64()).collect();
        let d_true = ks_statistic(&xs, |x| x.clamp(0.0, 1.0));
        assert!(d_true < 0.025, "{d_true}");
        let d_wrong = ks_statistic(&xs, |x| (x * x).clamp(0.0, 1.0));
        assert!(d_wrong > 0.1, "{d_wrong}");
    }

    #[test]
    fn chi_square_accepts_true_distribution_and_rejects_wrong_one() {
        let mut rng = crate::util::Rng::new(31);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.f64()).collect();
        // df = 19: mean 19, std ~6.2; 60 is a ~6.6 sigma acceptance bound.
        let chi_true = chi_square_edf(&xs, |x| x.clamp(0.0, 1.0), 20);
        assert!(chi_true < 60.0, "{chi_true}");
        let chi_wrong = chi_square_edf(&xs, |x| (x * x).clamp(0.0, 1.0), 20);
        assert!(chi_wrong > 500.0, "{chi_wrong}");
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }
}
