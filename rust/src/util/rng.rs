//! Deterministic pseudo-random number generator.
//!
//! All simulator randomness (topologies, churn, annealing acceptance) flows
//! through this RNG so every experiment in EXPERIMENTS.md is exactly
//! reproducible from its seed.  Implementation: xoshiro256++ seeded via
//! SplitMix64 — the standard, well-tested construction.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-node / per-repetition RNGs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform float in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in [lo, hi) — the paper's U(x, y).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.index(xs.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform(3.0, 9.0);
            assert!((3.0..9.0).contains(&x));
        }
    }

    #[test]
    fn int_range_inclusive_bounds_hit() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let v = r.int_range(1, 3);
            assert!((1..=3).contains(&v));
            lo_seen |= v == 1;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(17);
        assert!(!(0..1000).any(|_| r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
