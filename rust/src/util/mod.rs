//! Small self-contained utilities replacing crates that are unavailable in
//! this offline build (see Cargo.toml note): a deterministic RNG (`rand`),
//! a JSON parser (`serde_json`), summary statistics, a micro bench harness
//! (`criterion`) and a property-testing helper (`proptest`).

pub mod bench;
pub mod bitset;
pub mod json;
pub mod mem;
pub mod prop;
pub mod rng;
pub mod stats;

pub use bitset::{BitMatrix, BitSet};
pub use rng::Rng;
pub use stats::Summary;
