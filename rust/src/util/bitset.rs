//! Fixed-width bit containers for the planner's dense hot-path state.
//!
//! `NodeId(pub usize)` is already a dense index, so per-node predicates
//! (liveness) and per-pair predicates (overlay visibility) pack into u64
//! words: one cache line covers 512 nodes, and a visibility test is one
//! shift + mask instead of a `BTreeMap` walk plus a binary search.  The
//! word width is `u64` — the widest integer with single-instruction
//! test/set on every target we build for; wider SIMD words would need
//! per-arch code for no measurable win at n in the 1e3..1e4 range (the
//! row fits in L1 either way).

/// A fixed-capacity set over `0..len` backed by u64 words.
#[derive(Debug, Clone, Default)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// Empty set over the universe `0..len`.
    pub fn new(len: usize) -> BitSet {
        BitSet { words: vec![0; len.div_ceil(64)], len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    pub fn clear(&mut self) {
        self.words.fill(0);
    }
}

/// A dense boolean matrix (`rows == cols == n`) backed by u64 words —
/// the planner's visibility relation (`viewer sees peer`).
#[derive(Debug, Clone, Default)]
pub struct BitMatrix {
    words_per_row: usize,
    words: Vec<u64>,
    n: usize,
}

impl BitMatrix {
    /// All-false n x n matrix.
    pub fn new(n: usize) -> BitMatrix {
        let words_per_row = n.div_ceil(64);
        BitMatrix { words_per_row, words: vec![0; words_per_row * n], n }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.n && c < self.n);
        self.words[r * self.words_per_row + c / 64] & (1u64 << (c % 64)) != 0
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize) {
        debug_assert!(r < self.n && c < self.n);
        self.words[r * self.words_per_row + c / 64] |= 1u64 << (c % 64);
    }

    pub fn clear(&mut self) {
        self.words.fill(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitset_roundtrip_across_word_boundaries() {
        let mut s = BitSet::new(130);
        assert!(s.is_empty());
        for i in [0, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!s.contains(i));
            s.insert(i);
            assert!(s.contains(i));
        }
        assert!(!s.is_empty());
        s.remove(64);
        assert!(!s.contains(64) && s.contains(63) && s.contains(65));
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.len(), 130);
    }

    #[test]
    fn bitmatrix_rows_are_independent() {
        let mut m = BitMatrix::new(70);
        m.set(0, 69);
        m.set(69, 0);
        m.set(33, 33);
        assert!(m.get(0, 69) && m.get(69, 0) && m.get(33, 33));
        assert!(!m.get(0, 0) && !m.get(69, 69) && !m.get(1, 69));
        m.clear();
        assert!(!m.get(0, 69));
        assert_eq!(m.n(), 70);
    }
}
