//! Micro benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, calibrated iteration counts, and mean/p50/p99 reporting.
//! Used by the `rust/benches/*` targets (built with `harness = false`),
//! plus the shared `BENCH_*.json` profile writer every regression-gated
//! sweep funnels through ([`update_profile_json`]).

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::json::Json;
use super::stats::{percentile, Summary};

/// Merge one profile payload into the `BENCH_<bench>.json` document at
/// `path`, preserving every other key (notably the *other* profile:
/// `test_sized` captures must not clobber a committed `full` baseline
/// and vice versa).  Shared by all four gated sweeps (`scale`,
/// `planlag`, `congestion`, `async`).
///
/// Semantics the gates rely on:
/// - A missing file is a fresh capture.
/// - A present-but-corrupt file is an **error**, not a reset — a silent
///   rewrite would null the committed baseline and disarm the CI
///   regression gate without anyone noticing.
/// - Legacy documents parse leniently: unknown keys are preserved, the
///   `test_sized`/`full` slots are created as `null` when absent.
pub fn update_profile_json(
    path: &Path,
    bench: &str,
    source: &str,
    profile: &str,
    payload: Json,
) -> Result<()> {
    let mut root = match std::fs::read_to_string(path) {
        Err(_) => BTreeMap::new(), // no file yet: fresh capture
        Ok(text) => match Json::parse(text.trim()) {
            Ok(Json::Obj(o)) => o,
            _ => bail!(
                "{} exists but is not a JSON object; refusing to overwrite \
                 (fix or delete it to re-capture)",
                path.display()
            ),
        },
    };
    root.insert("bench".into(), Json::Str(bench.into()));
    root.insert("source".into(), Json::Str(source.into()));
    root.entry("test_sized".to_string()).or_insert(Json::Null);
    root.entry("full".to_string()).or_insert(Json::Null);
    root.insert(profile.to_string(), payload);
    std::fs::write(path, format!("{}\n", Json::Obj(root)))
        .with_context(|| format!("writing {path:?}"))?;
    Ok(())
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Run `f` repeatedly for ~`budget` after warmup; report timing stats.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: find an iteration count that takes >= ~1ms.
    let mut batch = 1usize;
    loop {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let el = t.elapsed();
        if el >= Duration::from_millis(1) || batch >= 1 << 20 {
            break;
        }
        batch *= 4;
    }

    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    let mut total_iters = 0usize;
    while start.elapsed() < budget || samples_ns.len() < 5 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let el = t.elapsed().as_nanos() as f64 / batch as f64;
        samples_ns.push(el);
        total_iters += batch;
        if samples_ns.len() >= 10_000 {
            break;
        }
    }

    let s = Summary::of(&samples_ns);
    BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: s.mean,
        p50_ns: percentile(&samples_ns, 50.0),
        p99_ns: percentile(&samples_ns, 99.0),
        std_ns: s.std,
    }
}

/// Black-box to defeat the optimizer (std::hint::black_box re-export).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_numbers() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn profile_json_merges_preserves_and_refuses_corruption() {
        let dir = std::env::temp_dir().join("gwtf_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_unit.json");
        let _ = std::fs::remove_file(&path);

        let payload = |v: f64| {
            let mut o = BTreeMap::new();
            o.insert("x".to_string(), Json::Num(v));
            Json::Obj(o)
        };
        // Fresh capture: both profile slots exist, ours filled.
        update_profile_json(&path, "unit", "tests::here", "test_sized", payload(1.0)).unwrap();
        let doc = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("unit"));
        assert_eq!(doc.get("full"), Some(&Json::Null));
        assert_eq!(doc.get("test_sized").unwrap().get("x").unwrap().as_f64(), Some(1.0));

        // Updating the other profile preserves the first.
        update_profile_json(&path, "unit", "tests::here", "full", payload(2.0)).unwrap();
        let doc = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        assert_eq!(doc.get("test_sized").unwrap().get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("full").unwrap().get("x").unwrap().as_f64(), Some(2.0));

        // A corrupt file refuses the update instead of resetting it.
        std::fs::write(&path, "not json at all").unwrap();
        let err = update_profile_json(&path, "unit", "tests::here", "full", payload(3.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("refusing to overwrite"), "{err}");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "not json at all");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with(" s"));
    }
}
