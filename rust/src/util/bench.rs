//! Micro benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, calibrated iteration counts, and mean/p50/p99 reporting.
//! Used by the `rust/benches/*` targets (built with `harness = false`).

use std::time::{Duration, Instant};

use super::stats::{percentile, Summary};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Run `f` repeatedly for ~`budget` after warmup; report timing stats.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: find an iteration count that takes >= ~1ms.
    let mut batch = 1usize;
    loop {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let el = t.elapsed();
        if el >= Duration::from_millis(1) || batch >= 1 << 20 {
            break;
        }
        batch *= 4;
    }

    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    let mut total_iters = 0usize;
    while start.elapsed() < budget || samples_ns.len() < 5 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let el = t.elapsed().as_nanos() as f64 / batch as f64;
        samples_ns.push(el);
        total_iters += batch;
        if samples_ns.len() >= 10_000 {
            break;
        }
    }

    let s = Summary::of(&samples_ns);
    BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: s.mean,
        p50_ns: percentile(&samples_ns, 50.0),
        p99_ns: percentile(&samples_ns, 99.0),
        std_ns: s.std,
    }
}

/// Black-box to defeat the optimizer (std::hint::black_box re-export).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_numbers() {
        let r = bench("noop-ish", Duration::from_millis(20), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with(" s"));
    }
}
