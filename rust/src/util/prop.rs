//! Property-testing helper (proptest is unavailable offline).
//!
//! `forall` runs a property over `cases` random inputs drawn via a
//! generator closure; on failure it retries with simpler inputs from the
//! same seed neighbourhood (a light-weight stand-in for shrinking) and
//! reports the failing seed so the case can be replayed deterministically.

use super::rng::Rng;

/// Run `prop` on `cases` inputs produced by `gen`.  Panics with the failing
/// seed on the first violated property.
pub fn forall<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n{input:#?}"
            );
        }
    }
}

/// Like `forall` but the property returns `Result` so failures can carry a
/// message.
pub fn forall_res<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE00u64 + case as u64;
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n{input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("sum-commutes", 50, |r| (r.int_range(0, 100), r.int_range(0, 100)), |&(a, b)| {
            count += 1;
            a + b == b + a
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        forall("always-false", 10, |r| r.int_range(0, 10), |_| false);
    }

    #[test]
    fn res_variant_reports_message() {
        forall_res("ok", 5, |r| r.f64(), |_| Ok(()));
    }
}
