//! Test-sized adversarial-relay sweep + acceptance gate (ISSUE 9).
//!
//! Runs the adversary sweep (Table II shape, deterministic Byzantine
//! roster: free-riders, DENY storms, deliberate stragglers, eclipse
//! liars) with tiny rep/iteration counts, asserts the tentpole's
//! acceptance properties —
//!
//! - **transparency at f = 0**: with no adversaries the reputation book
//!   never leaves its all-honest prior and the Eq. 1 penalty is exactly
//!   1.0, so the oblivious and reputation-aware arms measure bit for
//!   bit the same numbers,
//! - **retention under attack**: at f = 25% the reputation-aware arm
//!   keeps at least 70% of its clean-fleet goodput (re-plans price
//!   liars out of the chains), while the oblivious arm — which keeps
//!   planning into phantom capacity and straggler compute — retains
//!   strictly less, and
//! - **monotone damage**: goodput is non-increasing in the adversarial
//!   fraction for both GWTF arms (adversaries only ever remove service)
//!
//! — and maintains the `test_sized` profile of `BENCH_adversary.json`
//! at the repo root (capture on first run / `GWTF_UPDATE_ADVERSARY=1`,
//! then a 2x regression gate on the oblivious clean-fleet makespan).
//! The full-size sweep is `gwtf bench adversary`, which fills the
//! `full` profile of the same file.  CI runs this test in the guard
//! step and the `arm-baselines` job commits the captured profile on
//! `main`.

use gwtf::coordinator::GwtfRouter;
use gwtf::experiments::{
    adversary_json_path, read_adversary_profile, run_adversary, update_adversary_json,
    AdversaryOpts,
};
use gwtf::flow::FlowParams;
use gwtf::sim::scenario::{build, ScenarioConfig};
use gwtf::sim::AdversaryConfig;

fn opts() -> AdversaryOpts {
    AdversaryOpts { fractions: vec![0.0, 0.10, 0.25], reps: 2, iters_per_rep: 4, seed: 7 }
}

/// The transparency pin the whole subsystem hangs off: switching the
/// knobs on with nothing to observe (`fraction: 0.0` assigns nobody,
/// the reputation book never leaves its all-honest prior) must
/// reproduce the legacy engine bit for bit — same event order, same
/// float ops, same metrics words.
#[test]
fn no_adversaries_plus_reputation_knob_is_bit_for_bit_legacy() {
    let seed = 11;
    let legacy = build(&ScenarioConfig::table2(true, 0.2, seed));
    let mut knobbed_cfg = ScenarioConfig::table2(true, 0.2, seed);
    knobbed_cfg.adversaries = Some(AdversaryConfig::with_fraction(0.0));
    knobbed_cfg.reputation = true;
    let knobbed = build(&knobbed_cfg);
    assert!(knobbed.adversary.is_none(), "fraction 0.0 must assign nobody");
    assert!(knobbed.reputation.is_some(), "the book exists, at its prior");

    let mut legacy_router = GwtfRouter::from_scenario(&legacy, FlowParams::default(), seed ^ 0xA);
    let mut knobbed_router =
        GwtfRouter::from_scenario(&knobbed, FlowParams::default(), seed ^ 0xA);
    let mut legacy_engine = legacy.engine(seed ^ 0x1);
    let mut knobbed_engine = knobbed.engine(seed ^ 0x1);
    for i in 0..3 {
        let a = legacy_engine.step(&legacy.prob, &mut legacy_router);
        let b = knobbed_engine.step(&knobbed.prob, &mut knobbed_router);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "iter {i}: makespan");
        assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits(), "iter {i}: comm");
        assert_eq!(a.completed, b.completed, "iter {i}: completed");
        assert_eq!(a.denies, b.denies, "iter {i}: denies");
        assert_eq!(a.events, b.events, "iter {i}: kernel events");
    }
}

#[test]
fn reputation_routing_survives_adversaries_where_oblivious_bleeds() {
    // Keep a bounded event ring armed: if any gate below fails, the tail
    // of the simulated timeline lands on stderr + bench_results/.
    let _flight = gwtf::trace::flight::arm_flight_recorder("adversary_guard", 4096);
    let (table, report) = run_adversary(&opts()).unwrap();

    // Every (fraction, system) cell produced samples and completed work.
    assert_eq!(table.cells.len(), 3 * 3, "3 fractions x 3 systems");
    for ((row, col), acc) in &table.cells {
        assert_eq!(acc.throughput.len(), 2 * 4, "{row}/{col}: 2 reps x 4 iterations");
        assert!(acc.throughput.iter().sum::<f64>() > 0.0, "{row}/{col} completed nothing");
    }

    // Acceptance 0 (transparency): with no adversaries the reputation
    // book stays at its all-honest prior, its publish is a fixed-point
    // skip and the penalty multiplies every edge by exactly 1.0 — the
    // two GWTF arms must agree bit for bit, not just approximately.
    let obl_clean = report.case(0, "gwtf").expect("oblivious clean-fleet case");
    let rep_clean = report.case(0, "gwtf-rep").expect("reputation clean-fleet case");
    assert_eq!(
        obl_clean.makespan_total_s.to_bits(),
        rep_clean.makespan_total_s.to_bits(),
        "reputation must be bitwise-transparent on a clean fleet"
    );
    assert_eq!(obl_clean.throughput_total, rep_clean.throughput_total);
    assert_eq!(obl_clean.denies_total, rep_clean.denies_total);

    // Acceptance 1 (retention): at f = 25% the reputation-aware arm
    // keeps >= 70% of its clean-fleet goodput, and the oblivious arm
    // retains strictly less — the whole point of charging observed
    // service into the Eq. 1 penalty.
    let rep_attacked = report.case(25, "gwtf-rep").expect("reputation f=25% case");
    let obl_attacked = report.case(25, "gwtf").expect("oblivious f=25% case");
    let rep_retention = rep_attacked.goodput() / rep_clean.goodput();
    let obl_retention = obl_attacked.goodput() / obl_clean.goodput();
    assert!(
        rep_retention >= 0.70,
        "reputation-aware GWTF must retain >= 70% of clean goodput at f=25%: \
         retained {:.1}% ({} vs {})",
        rep_retention * 100.0,
        rep_attacked.goodput(),
        rep_clean.goodput()
    );
    assert!(
        obl_retention < rep_retention,
        "oblivious GWTF must bleed strictly more goodput than the reputation-aware \
         arm at f=25%: oblivious retained {:.1}%, reputation {:.1}%",
        obl_retention * 100.0,
        rep_retention * 100.0
    );

    // The attack is visible in the DENY column: storm relays refuse
    // unconditionally and phantom capacity bounces admissions.
    assert!(obl_attacked.denies_total > 0.0, "f=25% must show DENY traffic");

    // Acceptance 2 (monotone damage): adversaries only ever remove
    // service, so goodput must not rise with f for either GWTF arm.
    // The 2% slack covers scheduling anomalies when re-routes shift
    // event order between fractions.
    for sys in ["gwtf", "gwtf-rep"] {
        let arms: Vec<_> =
            [0, 10, 25].iter().map(|&p| report.case(p, sys).expect("arm")).collect();
        for w in arms.windows(2) {
            assert!(
                w[1].goodput() <= w[0].goodput() / 0.98,
                "{sys}: goodput rose with the adversarial fraction: {} @ {}% vs {} @ {}%",
                w[0].goodput(),
                w[0].fraction_pct,
                w[1].goodput(),
                w[1].fraction_pct
            );
        }
    }

    // Baseline: capture when null/missing (or on explicit request),
    // otherwise gate the oblivious clean-fleet total makespan at 2x
    // (deterministic per seed; the headroom covers libm-level drift
    // across machines).
    let path = adversary_json_path();
    let update = std::env::var("GWTF_UPDATE_ADVERSARY").is_ok();
    match (update, read_adversary_profile(&path, "test_sized")) {
        (false, Some(baseline)) => {
            let base = baseline.case(0, "gwtf").expect("baseline clean-fleet arm");
            assert!(
                obl_clean.makespan_total_s <= 2.0 * base.makespan_total_s,
                "clean-fleet makespan regressed >2x: {} vs baseline {} \
                 (GWTF_UPDATE_ADVERSARY=1 to re-baseline intentionally)",
                obl_clean.makespan_total_s,
                base.makespan_total_s
            );
        }
        (update, _) => {
            update_adversary_json(&path, "test_sized", &report).unwrap();
            eprintln!(
                "adversary test_sized profile {} at {} — commit BENCH_adversary.json to \
                 arm the regression gate",
                if update {
                    "re-captured (GWTF_UPDATE_ADVERSARY)"
                } else {
                    "was null/missing; captured"
                },
                path.display()
            );
        }
    }
}
