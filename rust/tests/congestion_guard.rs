//! Test-sized congestion sweep + acceptance gate (ISSUE 5).
//!
//! Runs the shared-capacity NIC sweep over the fan-in-hub scenario with
//! tiny rep/iteration counts, asserts the tentpole's acceptance
//! properties —
//!
//! - **monotone makespan growth as the NIC concurrency shrinks** for
//!   capacity-oblivious GWTF (its planner ignores the cap, so its paths
//!   are identical across the sweep and queueing is the only moving
//!   part), and
//! - **congestion-aware GWTF beating capacity-oblivious SWARM** under
//!   the fan-in hotspot at the tightest cap (the expected-queueing term
//!   prices the hub's serialized backlog; SWARM's nearest-peer greedy
//!   funnels everything through it) —
//!
//! and maintains the `test_sized` profile of `BENCH_congestion.json` at
//! the repo root (capture on first run / `GWTF_UPDATE_CONGESTION=1`,
//! then a 2x regression gate on the tight-cap makespan).  The full-size
//! sweep is `gwtf bench congestion`, which fills the `full` profile of
//! the same file.  The CI scale-guard step runs this test alongside
//! `scale_guard` and `plan_lag`, and the `arm-baselines` job commits the
//! captured profile on `main`.

use gwtf::experiments::{
    congestion_json_path, read_congestion_profile, run_congestion, update_congestion_json,
    CongestionCase, CongestionOpts,
};

fn opts() -> CongestionOpts {
    CongestionOpts { nic_caps: vec![0, 4, 2, 1], reps: 2, iters_per_rep: 2, seed: 7 }
}

#[test]
fn congestion_makespan_monotone_and_aware_beats_swarm() {
    // Keep a bounded event ring armed: if any gate below fails, the tail
    // of the simulated timeline lands on stderr + bench_results/.
    let _flight = gwtf::trace::flight::arm_flight_recorder("congestion_guard", 4096);
    let (table, report) = run_congestion(&opts()).unwrap();

    // Every (cap, system) cell produced samples and routed work.
    assert_eq!(table.cells.len(), 4 * 4, "4 caps x 4 systems");
    for ((row, col), acc) in &table.cells {
        assert_eq!(acc.throughput.len(), 2 * 2, "{row}/{col}: 2 reps x 2 iterations");
        assert!(acc.throughput.iter().sum::<f64>() > 0.0, "{row}/{col} routed nothing");
    }

    // Acceptance 1: capacity-oblivious GWTF's makespan grows
    // monotonically as the NIC concurrency shrinks (unlimited -> 1).
    // Same plans at every cap, so queueing is the only delta; greedy
    // slot assignment under event reordering can produce classic
    // small scheduling anomalies, hence the 2% slack — the cap-1 vs
    // unlimited growth assert below is the real teeth.
    let oblivious: Vec<&CongestionCase> = opts()
        .nic_caps
        .iter()
        .map(|&cap| report.case(cap, "gwtf").expect("gwtf case"))
        .collect();
    assert_eq!(oblivious[0].nic, 0);
    assert_eq!(oblivious[0].queue_mean_s, 0.0, "unlimited NICs never queue");
    for w in oblivious.windows(2) {
        assert!(
            w[1].makespan_mean_s >= 0.98 * w[0].makespan_mean_s,
            "makespan shrank as the NIC cap tightened: {} @ nic {} vs {} @ nic {}",
            w[0].makespan_mean_s,
            w[0].nic,
            w[1].makespan_mean_s,
            w[1].nic
        );
    }
    let free = oblivious[0];
    let tight = *oblivious.last().unwrap();
    assert!(
        tight.makespan_mean_s > 1.1 * free.makespan_mean_s,
        "a concurrency-1 NIC must visibly stretch the fan-in makespan: {} vs {}",
        tight.makespan_mean_s,
        free.makespan_mean_s
    );
    assert!(tight.queue_mean_s > 0.0, "tight NICs must record queueing");
    assert!(tight.nic_util_max_mean > 0.0, "utilization column populated");

    // Acceptance 2: at the tightest cap, congestion-aware GWTF (Eq. 1 +
    // expected NIC queueing from the same substrate parameters) beats
    // SWARM's capacity-oblivious nearest-peer funnel.
    let aware = report.case(1, "gwtf-aware").expect("gwtf-aware case");
    let swarm = report.case(1, "swarm").expect("swarm case");
    assert!(
        aware.makespan_mean_s < swarm.makespan_mean_s,
        "congestion-aware routing must beat the SWARM funnel at nic 1: {} vs {}",
        aware.makespan_mean_s,
        swarm.makespan_mean_s
    );
    assert!(
        aware.queue_mean_s < swarm.queue_mean_s,
        "spreading must cut the queueing SWARM pays: {} vs {}",
        aware.queue_mean_s,
        swarm.queue_mean_s
    );
    // The aware planner must not buy that with dropped work.
    assert!(aware.throughput_total >= swarm.throughput_total);

    // Baseline: capture when null/missing (or on explicit request),
    // otherwise gate the tight-cap makespan at 2x (deterministic per
    // seed; the headroom covers libm-level annealer drift across
    // machines).
    let path = congestion_json_path();
    let update = std::env::var("GWTF_UPDATE_CONGESTION").is_ok();
    match (update, read_congestion_profile(&path, "test_sized")) {
        (false, Some(baseline)) => {
            let base = baseline.case(1, "gwtf-aware").expect("baseline gwtf-aware case");
            let fresh = report.case(1, "gwtf-aware").unwrap();
            assert!(
                fresh.makespan_mean_s <= 2.0 * base.makespan_mean_s,
                "tight-cap congestion-aware makespan regressed >2x: {} vs baseline {} \
                 (GWTF_UPDATE_CONGESTION=1 to re-baseline intentionally)",
                fresh.makespan_mean_s,
                base.makespan_mean_s
            );
        }
        (update, _) => {
            update_congestion_json(&path, "test_sized", &report).unwrap();
            eprintln!(
                "congestion test_sized profile {} at {} — commit BENCH_congestion.json \
                 to arm the regression gate",
                if update {
                    "re-captured (GWTF_UPDATE_CONGESTION)"
                } else {
                    "was null/missing; captured"
                },
                path.display()
            );
        }
    }
}
