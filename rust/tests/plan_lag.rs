//! Test-sized plan-lag sweep + acceptance gate (ISSUE 4).
//!
//! Runs the plan-lifecycle round-RTT sweep with tiny rep/iteration
//! counts, asserts the tentpole's acceptance property — **monotone
//! makespan growth as the round-RTT approaches the iteration length**
//! (overlap hides planning until `rounds x RTT` stops fitting inside an
//! iteration, then every iteration pays a growing stall) — and maintains
//! the `test_sized` profile of `BENCH_planlag.json` at the repo root.
//! The full-size sweep is `gwtf bench planlag`, which fills the `full`
//! profile of the same file.
//!
//! The CI scale-guard step runs this test alongside `scale_guard` so the
//! plan-lifecycle property is gated on every push, and the
//! `arm-baselines` job commits the captured profile on `main`.

use gwtf::experiments::{
    plan_lag_json_path, read_plan_lag_profile, run_plan_lag, update_plan_lag_json, PlanLagCase,
    PlanLagOpts,
};

fn opts() -> PlanLagOpts {
    PlanLagOpts {
        rtts_s: vec![0.0, 0.5, 8.0, 30.0, 120.0],
        reps: 1,
        iters_per_rep: 5,
        seed: 7,
        churn_p: 0.2,
    }
}

#[test]
fn planlag_makespan_grows_monotonically_with_round_rtt() {
    // Keep a bounded event ring armed: if any gate below fails, the tail
    // of the simulated timeline lands on stderr + bench_results/.
    let _flight = gwtf::trace::flight::arm_flight_recorder("plan_lag", 4096);
    let (table, report) = run_plan_lag(&opts()).unwrap();

    // Every (churn, rtt) cell produced samples.
    assert_eq!(table.cells.len(), 2 * 5, "2 churn rows x 5 RTTs");
    for acc in table.cells.values() {
        assert_eq!(acc.throughput.len(), 5, "1 rep x 5 iterations");
    }

    // Acceptance: at 0% churn, makespan is monotone non-decreasing along
    // the on-the-clock RTTs, and the slowest RTT visibly beats the
    // blocking (rtt = 0) reference — the point where overlap stops
    // hiding planning cost.
    let clocked: Vec<&PlanLagCase> =
        report.cases.iter().filter(|c| c.churn_p == 0.0 && c.rtt_s > 0.0).collect();
    assert!(clocked.len() >= 3);
    for w in clocked.windows(2) {
        assert!(
            w[1].makespan_mean_s >= w[0].makespan_mean_s - 1e-6,
            "makespan regressed as RTT grew: {} @ {}s vs {} @ {}s",
            w[0].makespan_mean_s,
            w[0].rtt_s,
            w[1].makespan_mean_s,
            w[1].rtt_s
        );
    }
    let blocking = report.case(0.0, 0.0).expect("blocking reference case");
    let slowest = clocked.last().unwrap();
    assert!(
        slowest.makespan_mean_s > blocking.makespan_mean_s,
        "{}s round-RTT must stop hiding behind the iteration ({} vs {})",
        slowest.rtt_s,
        slowest.makespan_mean_s,
        blocking.makespan_mean_s
    );
    // A small RTT is fully hidden: overlap recorded, no steady-state
    // stall (the only planning charge is iteration 0's cold start).
    let fast = report.case(0.0, 0.5).unwrap();
    assert!(fast.overlap_mean_s > 0.0, "warm sessions must overlap training");
    assert!(
        fast.stall_mean_s <= blocking.makespan_mean_s,
        "a hidden plan must not stall more than an iteration"
    );

    // Capture the test_sized profile when it is still null/missing (or
    // on explicit request); an armed profile is left untouched so plain
    // `cargo test` runs never dirty the committed file.
    let path = plan_lag_json_path();
    let update = std::env::var("GWTF_UPDATE_PLANLAG").is_ok();
    if update || read_plan_lag_profile(&path, "test_sized").is_none() {
        update_plan_lag_json(&path, "test_sized", &report).unwrap();
        eprintln!(
            "planlag test_sized profile {} at {} — commit BENCH_planlag.json to record it",
            if update { "re-captured (GWTF_UPDATE_PLANLAG)" } else { "was null/missing; captured" },
            path.display()
        );
    }
}
